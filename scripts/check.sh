#!/usr/bin/env bash
# Tier-1 verify in one command (see ROADMAP.md): release build, tests,
# lints, and formatting. Run from anywhere; operates on the rust/ crate.
#
#   scripts/check.sh                           # build + test + clippy + fmt --check
#   SKIP_FMT=1 scripts/check.sh                # without the formatting gate
#   SKIP_CLIPPY=1 scripts/check.sh             # without the lint gate
#   CARGO_FLAGS=--no-default-features scripts/check.sh   # sim stack only (CI)
set -euo pipefail
cd "$(dirname "$0")/../rust"

# Poison-tolerance lint: production code takes mutexes via
# util::sync::lock (recover the data, don't cascade the panic). The
# helper's own file is the single allowed mention of the raw idiom.
if grep -rn --include='*.rs' -F 'lock().unwrap()' src | grep -v '^src/util/sync.rs:'; then
    echo "check.sh: raw lock().unwrap() found; use util::sync::lock" >&2
    exit 1
fi

cargo build --release ${CARGO_FLAGS:-}
# Runs every registered suite, including the fleet-layer tests
# (tests/fleet.rs) and the trace arrival-process property tests.
cargo test -q ${CARGO_FLAGS:-}
# `econoserve sweep` smoke: the parallel experiment engine end-to-end
# (grid spec in -> one JSON row per cell out) at an explicit thread
# count. The binary builds with or without the pjrt feature, so this
# runs in the CI --no-default-features flavor too.
cargo run --release ${CARGO_FLAGS:-} --bin econoserve -- sweep \
    --systems orca --model opt-13b --trace alpaca --rates 2 --seeds 7 \
    --duration 3 --max-time 60 --oracle --threads 2 \
    --out "${TMPDIR:-/tmp}/econoserve_sweep_smoke.json"
# `econoserve fleet --chaos` smoke: deterministic fault injection
# end-to-end (every router's goodput/SSR retention vs its fault-free
# baseline under replica crashes, plus the health-blind reference).
cargo run --release ${CARGO_FLAGS:-} --bin econoserve -- fleet \
    --chaos crashes --trace alpaca --workload poisson --rate 3 \
    --duration 120 --replicas 2 --min 2 --max 3 --oracle
# Guardrails smoke: retry + hedge under crashes end-to-end, and the
# merged snapshot (retries/hedges/aborts/brownout families included)
# must survive the strict promlint round-trip.
GUARD_OUT="${TMPDIR:-/tmp}/econoserve_guardrails_smoke.prom"
cargo run --release ${CARGO_FLAGS:-} --bin econoserve -- fleet \
    --chaos crashes --guardrails retry+hedge --trace alpaca \
    --workload poisson --rate 3 --duration 120 --replicas 2 --min 2 \
    --max 3 --oracle --metrics-out "$GUARD_OUT"
cargo run --release ${CARGO_FLAGS:-} --bin econoserve -- promlint "$GUARD_OUT"
# Trace smoke: a chaos + guardrails fleet run with span tracing on must
# produce a Chrome-format trace that lints clean (exact per-request
# lifetime partition, unique terminals) AND reconciles with the same
# run's requests_total{outcome} counters, and the attribution report
# must render. (tracelint parses the Chrome form, not .jsonl.)
TRACE_OUT="${TMPDIR:-/tmp}/econoserve_trace_smoke.json"
TRACE_METRICS="${TMPDIR:-/tmp}/econoserve_trace_smoke.prom"
cargo run --release ${CARGO_FLAGS:-} --bin econoserve -- fleet \
    --chaos crashes --guardrails retry+hedge --trace alpaca \
    --workload poisson --rate 3 --duration 120 --replicas 2 --min 2 \
    --max 3 --oracle --trace-out "$TRACE_OUT" --metrics-out "$TRACE_METRICS"
cargo run --release ${CARGO_FLAGS:-} --bin econoserve -- tracelint \
    --file "$TRACE_OUT" --metrics "$TRACE_METRICS"
cargo run --release ${CARGO_FLAGS:-} --bin econoserve -- trace-report \
    --file "$TRACE_OUT"
# Prediction-fault resilience smoke: a fleet under regime-shift
# predictor chaos with the adaptive headroom controller live must
# produce a metrics snapshot (prediction verdict/provision families,
# padding gauge, eviction-storm counter included) that survives strict
# promlint AND a span trace that lints clean against the same run's
# counters — the --predictor-faults / --headroom axes end-to-end.
HEADROOM_OUT="${TMPDIR:-/tmp}/econoserve_headroom_smoke.prom"
HEADROOM_TRACE="${TMPDIR:-/tmp}/econoserve_headroom_smoke.json"
cargo run --release ${CARGO_FLAGS:-} --bin econoserve -- fleet \
    --predictor-faults regime-shift --headroom adaptive --trace sharegpt \
    --workload poisson --rate 3 --duration 120 --replicas 2 --min 2 \
    --max 3 --oracle --metrics-out "$HEADROOM_OUT" --trace-out "$HEADROOM_TRACE"
cargo run --release ${CARGO_FLAGS:-} --bin econoserve -- promlint "$HEADROOM_OUT"
cargo run --release ${CARGO_FLAGS:-} --bin econoserve -- tracelint \
    --file "$HEADROOM_TRACE" --metrics "$HEADROOM_OUT"
# Telemetry smoke: a fleet run's merged registry snapshot must be
# canonical Prometheus exposition text (promlint = strict re-parse +
# byte-identical re-render).
METRICS_OUT="${TMPDIR:-/tmp}/econoserve_fleet_smoke.prom"
cargo run --release ${CARGO_FLAGS:-} --bin econoserve -- fleet \
    --trace alpaca --workload poisson --rate 3 --duration 60 \
    --replicas 2 --min 2 --max 2 --oracle --metrics-out "$METRICS_OUT"
cargo run --release ${CARGO_FLAGS:-} --bin econoserve -- promlint "$METRICS_OUT"
# The sim stack (telemetry included) must stay std-only: a pjrt-free
# build is a standing gate, not just a CI flavor.
cargo build --release --no-default-features
if [ -z "${SKIP_CLIPPY:-}" ]; then
    if cargo clippy --version >/dev/null 2>&1; then
        cargo clippy --all-targets ${CARGO_FLAGS:-} -- -D warnings
    else
        echo "check.sh: cargo-clippy not installed; skipping lint gate" >&2
    fi
fi
if [ -z "${SKIP_FMT:-}" ]; then
    cargo fmt --check
fi
echo "tier-1 check: OK"
