#!/usr/bin/env bash
# Tier-1 verify in one command (see ROADMAP.md): release build, tests,
# and formatting. Run from anywhere; operates on the rust/ crate.
#
#   scripts/check.sh                           # build + test + fmt --check
#   SKIP_FMT=1 scripts/check.sh                # without the formatting gate
#   CARGO_FLAGS=--no-default-features scripts/check.sh   # sim stack only (CI)
set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release ${CARGO_FLAGS:-}
cargo test -q ${CARGO_FLAGS:-}
if [ -z "${SKIP_FMT:-}" ]; then
    cargo fmt --check
fi
echo "tier-1 check: OK"
