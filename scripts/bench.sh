#!/usr/bin/env bash
# Scheduler hot-path micro-bench across the sched × alloc grid.
#
# Runs `benches/sched_hotpath.rs` (plan-formation latency at a ~1k-deep
# queue for every supported scheduler × allocator combination) and writes
# a single machine-readable artifact with p50/p95 per combination, so the
# perf trajectory is tracked across PRs:
#
#   scripts/bench.sh                  # writes BENCH_sched.json at repo root
#   scripts/bench.sh out/bench.json   # custom output path
#   FAST=1 scripts/bench.sh           # default pairings only
#   BENCH_THREADS=0 scripts/bench.sh  # fan the combo grid over all cores
#                                     # (wall-clock mode: per-sample p50s
#                                     # are contention-noisy; keep gate
#                                     # baselines at the default 1)
#
# The artifact records sweep_threads/sweep_wall_s, so a BENCH_THREADS=1
# vs BENCH_THREADS=0 pair gives the single- vs multi-thread sweep
# wall-clock comparison for docs/API.md.
set -euo pipefail
cd "$(dirname "$0")/.."
OUT="${1:-$PWD/BENCH_sched.json}"
case "$OUT" in
    /*) ;;
    *) OUT="$PWD/$OUT" ;;
esac
cd rust
# Machine label recorded in the artifact; the regression gate only FAILS
# when baseline and fresh run carry the same label (cross-machine
# comparisons are informational). CI pins this to its runner flavor.
export BENCH_HOST="${BENCH_HOST:-$(uname -sm | tr ' ' '-')}"
cargo bench --no-default-features --bench sched_hotpath -- --json "$OUT" \
    --threads "${BENCH_THREADS:-1}"
echo "bench artifact: $OUT (host: $BENCH_HOST)"
