#!/usr/bin/env python3
"""Perf-regression gate for BENCH_sched.json.

Usage: scripts/bench_gate.py <fresh.json> [baseline.json] [--update]

Compares a freshly measured sched_hotpath artifact against the
checked-in baseline (default: BENCH_sched.json at the repo root) and
fails (exit 1) if any (system, depth) combo's p50 plan latency regressed
more than the threshold (default 25%, override with BENCH_GATE_PCT).

Rules:
  * combos present only in one file are reported but do not fail the
    gate (the grid may legitimately grow/shrink with the code);
  * a baseline entry with null p50 (the schema artifact before the
    first measured run) is skipped — the gate only bites once the
    baseline is populated;
  * microsecond p50s are only comparable on like hardware: when the two
    artifacts carry different "host" labels (set via BENCH_HOST, pinned
    by CI to its runner flavor), regressions are reported but the gate
    exits 0 — only same-host regressions fail the job;
  * with --update, the fresh artifact is copied over the baseline after
    the gate passes, so the checked-in numbers track the current code.
"""

import json
import os
import shutil
import sys


def load(path):
    with open(path) as f:
        doc = json.load(f)
    rows = {}
    for c in doc.get("combos", []):
        rows[(c["system"], c.get("depth", 0))] = c
    return rows, doc.get("host", "unknown")


def main(argv):
    args = [a for a in argv[1:] if a != "--update"]
    update = "--update" in argv[1:]
    if not args:
        print(__doc__)
        return 2
    fresh_path = args[0]
    base_path = args[1] if len(args) > 1 else os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "BENCH_sched.json"
    )
    threshold = float(os.environ.get("BENCH_GATE_PCT", "25")) / 100.0

    fresh, fresh_host = load(fresh_path)
    if not os.path.exists(base_path):
        print(f"bench_gate: no baseline at {base_path}; accepting fresh run")
        if update:
            shutil.copyfile(fresh_path, base_path)
        return 0
    base, base_host = load(base_path)
    same_host = fresh_host == base_host
    if not same_host:
        print(
            f"bench_gate: host mismatch (baseline '{base_host}' vs fresh "
            f"'{fresh_host}'): comparison is informational only"
        )

    failures = []
    compared = 0
    for key, b in sorted(base.items()):
        if b.get("p50") is None:
            continue  # unpopulated schema artifact: gate not armed yet
        f = fresh.get(key)
        if f is None or f.get("p50") is None:
            print(f"bench_gate: note: {key} in baseline but not in fresh run")
            continue
        compared += 1
        if f["p50"] > b["p50"] * (1.0 + threshold):
            failures.append(
                f"{key[0]} @depth {key[1]}: p50 {b['p50']*1e6:.1f}us -> "
                f"{f['p50']*1e6:.1f}us (+{(f['p50']/b['p50']-1)*100:.0f}% > {threshold*100:.0f}%)"
            )
    for key in sorted(set(fresh) - set(base)):
        print(f"bench_gate: note: new combo {key} (no baseline)")

    if failures:
        verdict = "FAIL" if same_host else "note (different host, not failing)"
        print(f"bench_gate: {verdict} — {len(failures)} combo(s) regressed:")
        for line in failures:
            print(f"  {line}")
        if same_host:
            return 1
        return 0

    print(f"bench_gate: OK ({compared} combos within {threshold*100:.0f}% of baseline)")
    if update:
        shutil.copyfile(fresh_path, base_path)
        print(f"bench_gate: baseline refreshed at {base_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
