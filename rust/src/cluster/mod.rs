//! Multi-GPU serving: the DistServe [24] disaggregated baseline.
//!
//! The legacy replicated-EconoServe capacity model that used to live
//! here (`cluster::replicas`, index-pre-sharded traces) is gone; use
//! [`crate::fleet::replicated_run`] /
//! [`crate::fleet::min_replicas_for_goodput`] — online routing at
//! arrival time, GPU-hour accounting, and parallel candidate search.

pub mod distserve;

pub use distserve::{DistServeConfig, DistServeSim};
