//! Multi-GPU serving: the DistServe [24] disaggregated baseline and the
//! legacy replicated-EconoServe capacity model used for Fig 12 — now a
//! compat shim over the [`crate::fleet`] layer (online routing,
//! autoscaling, GPU-hour accounting).

pub mod distserve;
pub mod replicas;

pub use distserve::{DistServeConfig, DistServeSim};
#[allow(deprecated)]
pub use replicas::{min_replicas_for_goodput, replicated_run};
