//! Multi-GPU serving: the DistServe [24] disaggregated baseline and the
//! replicated-EconoServe capacity model used for Fig 12.

pub mod distserve;
pub mod replicas;

pub use distserve::{DistServeConfig, DistServeSim};
pub use replicas::{min_replicas_for_goodput, replicated_run};
