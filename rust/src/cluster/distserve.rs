//! DistServe [24]: prefill/decode disaggregation across two GPU instances
//! with KV-cache transfer between them.
//!
//! The prefill instance batches whole prompts FCFS up to its TFS; finished
//! prompts stream their KV cache to the decode instance over the
//! interconnect (Ethernet in the paper's §2/§4 setting — the transfer
//! takes `kv_bytes(prompt) / bandwidth + latency` and is OVERLAPPED with
//! other work, but delays the request's decode start; Observation 6).
//! The decode instance runs vLLM-style continuous batching with
//! block-allocation.
//!
//! Each instance has its own KVC pool and its own clock; the simulation
//! advances whichever instance is earliest (two-server discrete-event).

use std::collections::VecDeque;

use crate::config::{ModelProfile, SystemConfig};
use crate::core::{ReqId, Time};
use crate::kvc::{Allocator, BlockAlloc, ReserveClass};
use crate::metrics::{Collector, Summary};
use crate::trace::TraceItem;
use crate::util::stats::Samples;

#[derive(Debug, Clone)]
pub struct DistServeConfig {
    /// Profile of the prefill instance (H100 in the heterogeneous setting).
    pub prefill: ModelProfile,
    /// Profile of the decode instance.
    pub decode: ModelProfile,
    /// Interconnect bandwidth (bytes/s). Paper §2: 100 Gb/s Ethernet.
    pub net_bw: f64,
    /// Per-transfer fixed latency (s).
    pub net_lat: f64,
    pub slo_scale: f64,
    /// Mean service constants for the SLO formula (match the single-GPU
    /// calibration so SLOs are comparable across systems).
    pub t_p: Time,
    pub t_g: Time,
}

impl DistServeConfig {
    pub fn homogeneous(profile: ModelProfile, base: &SystemConfig) -> Self {
        DistServeConfig {
            prefill: profile.clone(),
            decode: profile,
            net_bw: 100e9 / 8.0, // 100 Gb/s
            net_lat: 0.5e-3,
            slo_scale: base.slo_scale,
            t_p: base.t_p,
            t_g: base.t_g,
        }
    }

    pub fn heterogeneous(a100: ModelProfile, base: &SystemConfig) -> Self {
        let mut c = Self::homogeneous(a100.clone(), base);
        c.prefill = a100.h100_scaled();
        c
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum St {
    WaitPrefill,
    Prefilling,
    Transferring { ready_at: Time },
    WaitDecode,
    Decoding,
    Done { at: Time },
}

struct Rec {
    it: TraceItem,
    deadline: Time,
    st: St,
    generated: u32,
    first_emit: Option<Time>,
    last_emit: Option<Time>,
    tbt: (f64, u32),
    exec_start: Option<Time>,
}

/// Simulation result for one DistServe pair.
pub struct DistResult {
    pub summary: Summary,
    /// Mean transfer time share of JCT (Observation 6: ~7%).
    pub transfer_share: f64,
    /// Per-instance utilizations.
    pub prefill_gpu_util: f64,
    pub prefill_kvc_util: f64,
    pub decode_gpu_util: f64,
    pub decode_kvc_util: f64,
    pub prefill_fwd: f64,
    pub decode_fwd: f64,
    /// Goodput: SLO-satisfying completions per second.
    pub goodput: f64,
}

pub struct DistServeSim {
    pub cfg: DistServeConfig,
}

impl DistServeSim {
    pub fn new(cfg: DistServeConfig) -> Self {
        DistServeSim { cfg }
    }

    /// Analytic iteration cost on `profile` (same roofline as engine::sim).
    fn iter_cost(profile: &ModelProfile, fwd: u32, context: f64) -> (f64, f64) {
        let attn = 4.0 * profile.hidden as f64 * context * profile.n_layers as f64;
        let compute = (profile.flops_per_token() * fwd as f64 + attn) / profile.peak_flops;
        let kv = profile.kv_bytes_per_token() as f64 * context;
        let memory = (profile.weight_bytes + kv) / profile.mem_bw;
        let dur = profile.iter_overhead + compute.max(memory);
        (dur, (compute / dur).clamp(0.0, 1.0))
    }

    pub fn run(&self, items: &[TraceItem], max_sim_time: f64) -> DistResult {
        let cfg = &self.cfg;
        let mut recs: Vec<Rec> = items
            .iter()
            .map(|it| Rec {
                it: *it,
                deadline: it.arrival
                    + cfg.slo_scale * (cfg.t_p + cfg.t_g * it.true_rl as f64),
                st: St::WaitPrefill,
                generated: 0,
                first_emit: None,
                last_emit: None,
                tbt: (0.0, 0),
                exec_start: None,
            })
            .collect();

        // Both instances speak the first-class allocator API; DistServe's
        // decode side is vLLM-style, so block-allocation on both.
        let mut p_pool = BlockAlloc::new(cfg.prefill.kvc_tokens(), 32, 0);
        let mut d_pool = BlockAlloc::new(cfg.decode.kvc_tokens(), 32, 0);
        let mut p_clock = 0.0f64;
        let mut d_clock = 0.0f64;
        let mut p_queue: VecDeque<ReqId> = VecDeque::new();
        let mut d_queue: VecDeque<ReqId> = VecDeque::new();
        let mut d_running: Vec<ReqId> = Vec::new();
        let mut arrivals: VecDeque<ReqId> = (0..recs.len()).collect();
        // In-flight KV transfers, in transfer-start order: the event-loop
        // promotion and idle fast-forward below consult only this list
        // instead of sweeping every record per loop turn.
        let mut transferring: Vec<ReqId> = Vec::new();
        let mut n_done_total = 0usize;

        let mut col_p = Collector::new();
        let mut col_d = Collector::new();
        let mut transfer_time_total = 0.0;
        let end_of_arrivals = items.last().map(|i| i.arrival).unwrap_or(0.0);

        let mut guard = 0u64;
        while n_done_total < recs.len() && guard < 60_000_000 {
            guard += 1;
            let now = p_clock.min(d_clock);
            if now > max_sim_time {
                break;
            }
            // Feed arrivals visible at `now`.
            while let Some(&id) = arrivals.front() {
                if recs[id].it.arrival <= now {
                    arrivals.pop_front();
                    p_queue.push_back(id);
                } else {
                    break;
                }
            }
            // Promote finished transfers whose ready time has passed
            // (order-preserving retain over the in-flight list).
            {
                let recs_ref = &mut recs;
                let d_queue_ref = &mut d_queue;
                transferring.retain(|&id| {
                    if let St::Transferring { ready_at } = recs_ref[id].st {
                        if ready_at <= d_clock {
                            recs_ref[id].st = St::WaitDecode;
                            d_queue_ref.push_back(id);
                            false
                        } else {
                            true
                        }
                    } else {
                        false
                    }
                });
            }

            if p_clock <= d_clock {
                // --- Prefill instance iteration ---
                // Admit FCFS prompts up to TFS.
                let mut batch: Vec<ReqId> = Vec::new();
                let mut fwd = 0u32;
                while let Some(&id) = p_queue.front() {
                    let plen = recs[id].it.prompt_len;
                    if fwd + plen > cfg.prefill.tfs && fwd > 0 {
                        break;
                    }
                    if !p_pool.extend(id, plen, ReserveClass::Reserved).ok() {
                        break;
                    }
                    p_queue.pop_front();
                    recs[id].exec_start.get_or_insert(p_clock);
                    recs[id].st = St::Prefilling;
                    batch.push(id);
                    fwd += plen;
                    if fwd >= cfg.prefill.tfs {
                        break;
                    }
                }
                if batch.is_empty() {
                    // Idle: advance to next input for this instance.
                    let next_arrival = arrivals
                        .front()
                        .map(|&id| recs[id].it.arrival)
                        .unwrap_or(f64::INFINITY);
                    let target = next_arrival.max(p_clock + 1e-4);
                    if target.is_infinite() {
                        p_clock = f64::INFINITY.min(max_sim_time + 1.0);
                    } else {
                        p_clock = target;
                    }
                    continue;
                }
                let context: f64 = batch.iter().map(|&id| recs[id].it.prompt_len as f64 * 0.5).sum();
                let (dur, util) = Self::iter_cost(&cfg.prefill, fwd, context);
                for &id in &batch {
                    p_pool.record_write(id, recs[id].it.prompt_len);
                }
                p_clock += dur;
                col_p.record_iteration(
                    p_clock,
                    dur,
                    fwd,
                    util,
                    p_pool.utilization(),
                    p_pool.allocation_ratio(),
                    0,
                );
                // Each finished prompt emits its first token here, then
                // streams KV to the decode instance.
                for &id in &batch {
                    recs[id].generated = 1;
                    recs[id].first_emit = Some(p_clock);
                    recs[id].last_emit = Some(p_clock);
                    let bytes = recs[id].it.prompt_len as f64
                        * cfg.decode.kv_bytes_per_token() as f64;
                    let t_x = bytes / cfg.net_bw + cfg.net_lat;
                    transfer_time_total += t_x;
                    if recs[id].it.true_rl <= 1 {
                        recs[id].st = St::Done { at: p_clock };
                        n_done_total += 1;
                    } else {
                        recs[id].st = St::Transferring { ready_at: p_clock + t_x };
                        transferring.push(id);
                    }
                    p_pool.release(id);
                }
            } else {
                // --- Decode instance iteration ---
                d_running.retain(|&id| !matches!(recs[id].st, St::Done { .. }));
                // Admit transferred requests (block-alloc for their context).
                while let Some(&id) = d_queue.front() {
                    let need = recs[id].it.prompt_len + 2;
                    if !d_pool.extend(id, need, ReserveClass::Reserved).ok() {
                        break;
                    }
                    d_pool.record_write(id, recs[id].it.prompt_len);
                    d_queue.pop_front();
                    recs[id].st = St::Decoding;
                    d_running.push(id);
                }
                if d_running.is_empty() {
                    let next_ready = transferring
                        .iter()
                        .filter_map(|&id| match recs[id].st {
                            St::Transferring { ready_at } => Some(ready_at),
                            _ => None,
                        })
                        .fold(f64::INFINITY, f64::min);
                    if next_ready.is_finite() {
                        d_clock = next_ready.max(d_clock + 1e-4);
                    } else if p_clock.is_finite() && n_done_total < recs.len() {
                        d_clock = (p_clock + 1e-4).max(d_clock + 1e-4);
                    } else {
                        d_clock = max_sim_time + 1.0;
                    }
                    continue;
                }
                // Grow each sequence by one token (swapless: preempt-free
                // decode pool sized by admission gate above; on growth
                // failure the latest request is bounced back to the queue).
                let mut i = 0;
                while i < d_running.len() {
                    let id = d_running[i];
                    let ctx = recs[id].it.prompt_len + recs[id].generated;
                    if d_pool.grow_to(id, ctx + 1, ReserveClass::Reserved).ok() {
                        i += 1;
                    } else {
                        let victim = *d_running.last().unwrap();
                        d_running.pop();
                        d_pool.release(victim);
                        recs[victim].st = St::WaitDecode;
                        d_queue.push_front(victim);
                        col_d.preemptions += 1;
                        if victim == id {
                            break;
                        }
                    }
                }
                let fwd = d_running.len() as u32;
                let context: f64 = d_running
                    .iter()
                    .map(|&id| (recs[id].it.prompt_len + recs[id].generated) as f64)
                    .sum();
                let (dur, util) = Self::iter_cost(&cfg.decode, fwd, context);
                d_clock += dur;
                let mut completed = 0;
                for &id in &d_running {
                    d_pool.record_write(id, 1);
                    let r = &mut recs[id];
                    r.generated += 1;
                    if let Some(last) = r.last_emit {
                        r.tbt.0 += d_clock - last;
                        r.tbt.1 += 1;
                    }
                    r.last_emit = Some(d_clock);
                    if r.generated >= r.it.true_rl {
                        r.st = St::Done { at: d_clock };
                        n_done_total += 1;
                        d_pool.release(id);
                        completed += 1;
                    }
                }
                col_d.record_iteration(
                    d_clock,
                    dur,
                    fwd,
                    util,
                    d_pool.utilization(),
                    d_pool.allocation_ratio(),
                    completed,
                );
            }
        }

        // Summarize.
        let end = p_clock.min(d_clock).max(end_of_arrivals).min(max_sim_time);
        let mut jct = Samples::new();
        let mut tbt = Samples::new();
        let mut norm = Samples::new();
        let mut n_done = 0usize;
        let mut slo_ok = 0usize;
        let mut tokens = 0u64;
        for r in &recs {
            if let St::Done { at } = r.st {
                n_done += 1;
                let j = at - r.it.arrival;
                jct.push(j);
                norm.push(j / r.it.true_rl.max(1) as f64);
                if at <= r.deadline {
                    slo_ok += 1;
                }
                tokens += r.generated as u64;
                if r.tbt.1 > 0 {
                    tbt.push(r.tbt.0 / r.tbt.1 as f64);
                }
            }
        }
        let span = end.max(1e-9);
        let summary = Summary {
            n_total: recs.len(),
            n_done,
            throughput_rps: n_done as f64 / span,
            throughput_tps: tokens as f64 / span,
            mean_jct: jct.mean(),
            p5_jct: jct.p5(),
            p95_jct: jct.p95(),
            norm_latency: norm.mean(),
            ssr: slo_ok as f64 / recs.len().max(1) as f64,
            mean_tbt: tbt.mean(),
            p5_tbt: tbt.p5(),
            p95_tbt: tbt.p95(),
            kvc_util: (col_p.kvc_util.mean() + col_d.kvc_util.mean()) / 2.0,
            kvc_alloc: (col_p.kvc_alloc.mean() + col_d.kvc_alloc.mean()) / 2.0,
            gpu_util: (col_p.gpu_util.mean() + col_d.gpu_util.mean()) / 2.0,
            avg_forward_size: (col_p.forward_size.mean() + col_d.forward_size.mean()) / 2.0,
            preemptions: col_d.preemptions,
            iterations: col_p.iterations + col_d.iterations,
            ..Default::default()
        };
        DistResult {
            transfer_share: if n_done > 0 {
                (transfer_time_total / n_done as f64) / summary.mean_jct.max(1e-9)
            } else {
                0.0
            },
            prefill_gpu_util: col_p.gpu_util.mean(),
            prefill_kvc_util: col_p.kvc_util.mean(),
            decode_gpu_util: col_d.gpu_util.mean(),
            decode_kvc_util: col_d.kvc_util.mean(),
            prefill_fwd: col_p.forward_size.mean(),
            decode_fwd: col_d.forward_size.mean(),
            goodput: slo_ok as f64 / span,
            summary,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::trace::{TraceGen, TraceSpec};

    fn base() -> SystemConfig {
        let mut c = SystemConfig::new(ModelProfile::opt_13b());
        c.t_p = 0.1;
        c.t_g = 0.025;
        c
    }

    #[test]
    fn completes_and_reports() {
        let base = base();
        let cfg = DistServeConfig::homogeneous(ModelProfile::opt_13b(), &base);
        let gen = TraceGen::new(TraceSpec::sharegpt());
        let items = gen.generate(60, 2.0, 4096, 3);
        let res = DistServeSim::new(cfg).run(&items, 1e6);
        assert_eq!(res.summary.n_done, 60);
        assert!(res.summary.mean_jct > 0.0);
        assert!(res.transfer_share > 0.0 && res.transfer_share < 0.5, "{}", res.transfer_share);
    }

    #[test]
    fn decode_instance_underutilizes_gpu() {
        // Observation 6: decode machine has low GPU utilization.
        let base = base();
        let cfg = DistServeConfig::homogeneous(ModelProfile::opt_13b(), &base);
        let gen = TraceGen::new(TraceSpec::sharegpt());
        let items = gen.generate(80, 4.0, 4096, 5);
        let res = DistServeSim::new(cfg).run(&items, 1e6);
        assert!(
            res.decode_gpu_util < res.prefill_gpu_util,
            "decode {} vs prefill {}",
            res.decode_gpu_util,
            res.prefill_gpu_util
        );
        assert!(res.prefill_fwd > res.decode_fwd);
    }

    #[test]
    fn heterogeneous_prefill_is_faster() {
        let base = base();
        let homo = DistServeConfig::homogeneous(ModelProfile::opt_13b(), &base);
        let het = DistServeConfig::heterogeneous(ModelProfile::opt_13b(), &base);
        let gen = TraceGen::new(TraceSpec::sharegpt());
        let items = gen.generate(50, 3.0, 4096, 7);
        let r1 = DistServeSim::new(homo).run(&items, 1e6);
        let r2 = DistServeSim::new(het).run(&items, 1e6);
        assert!(r2.summary.mean_jct <= r1.summary.mean_jct * 1.05);
    }
}
