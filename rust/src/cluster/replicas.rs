//! Replicated single-GPU serving and the Fig 12 min-GPU search —
//! **legacy compat wrappers** over the fleet layer.
//!
//! The original implementation pre-sharded the trace round-robin *by
//! index* and simulated each shard independently. That had two
//! artifacts the fleet layer fixes:
//!
//!  * index sharding silently reorders load versus any online balancer
//!    (a replica could sit idle while another queued, with no way to
//!    express a different router), and
//!  * empty shards were dropped from the per-replica summary list, so
//!    the "mean of summaries" was taken over a varying denominator.
//!
//! Both entry points are now thin wrappers over
//! [`crate::fleet::replicated_run`] — a static fleet with
//! `router=round-robin, autoscaler=static-k`, where each arrival is
//! routed at its arrival time. Goodput keeps the same currency
//! (SLO-satisfying completions per second) on the fleet-wide span. New
//! code should call the [`crate::fleet`] API directly, which also
//! exposes GPU-hours and the autoscaling axes.

use crate::config::SystemConfig;
use crate::metrics::Summary;
use crate::trace::TraceItem;

/// Run `system` on `k` round-robin replicas. Returns (aggregate goodput
/// req/s, per-replica summaries — always `k` of them).
#[deprecated(
    note = "use fleet::replicated_run (online routing, GPU-hour accounting); \
            this wrapper keeps the old (goodput, summaries) shape"
)]
pub fn replicated_run(
    cfg: &SystemConfig,
    system: &str,
    trace: &str,
    items: &[TraceItem],
    oracle: bool,
    k: usize,
    max_sim_time: f64,
) -> (f64, Vec<Summary>) {
    let res = crate::fleet::replicated_run(cfg, system, trace, items, oracle, k, max_sim_time);
    (res.summary.goodput_rps, res.per_replica)
}

/// Minimum number of GPUs `system` needs to reach `target_goodput`.
#[deprecated(note = "use fleet::min_replicas_for_goodput")]
#[allow(clippy::too_many_arguments)]
pub fn min_replicas_for_goodput(
    cfg: &SystemConfig,
    system: &str,
    trace: &str,
    items: &[TraceItem],
    oracle: bool,
    target_goodput: f64,
    max_replicas: usize,
    max_sim_time: f64,
) -> Option<usize> {
    crate::fleet::min_replicas_for_goodput(
        cfg,
        system,
        trace,
        items,
        oracle,
        target_goodput,
        max_replicas,
        max_sim_time,
    )
}

#[cfg(test)]
mod tests {
    use crate::config::{ModelProfile, SystemConfig};
    use crate::fleet;
    use crate::trace::{TraceGen, TraceSpec};

    #[test]
    fn more_replicas_more_goodput_under_load() {
        let mut cfg = SystemConfig::new(ModelProfile::opt_13b());
        cfg.t_p = 0.1;
        cfg.t_g = 0.025;
        let gen = TraceGen::new(TraceSpec::sharegpt());
        // Overload one replica.
        let items = gen.generate(300, 12.0, 4096, 11);
        let g1 = fleet::replicated_run(&cfg, "econoserve", "sharegpt", &items, true, 1, 300.0)
            .summary
            .goodput_rps;
        let g3 = fleet::replicated_run(&cfg, "econoserve", "sharegpt", &items, true, 3, 300.0)
            .summary
            .goodput_rps;
        assert!(g3 > g1, "g1={g1} g3={g3}");
    }

    #[test]
    fn search_finds_minimum() {
        let mut cfg = SystemConfig::new(ModelProfile::opt_13b());
        cfg.t_p = 0.1;
        cfg.t_g = 0.025;
        let gen = TraceGen::new(TraceSpec::sharegpt());
        let items = gen.generate(200, 8.0, 4096, 13);
        let g2 = fleet::replicated_run(&cfg, "econoserve", "sharegpt", &items, true, 2, 300.0)
            .summary
            .goodput_rps;
        let k = fleet::min_replicas_for_goodput(
            &cfg,
            "econoserve",
            "sharegpt",
            &items,
            true,
            g2 * 0.9,
            4,
            300.0,
        )
        .expect("target must be feasible with 4 replicas");
        assert!(k <= 2, "k={k}");
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_wrapper_matches_fleet() {
        let mut cfg = SystemConfig::new(ModelProfile::opt_13b());
        cfg.t_p = 0.1;
        cfg.t_g = 0.025;
        // Bit-deterministic runs: don't charge measured scheduler
        // wall-clock into the simulated clock.
        cfg.sched_time_scale = 0.0;
        let gen = TraceGen::new(TraceSpec::sharegpt());
        let items = gen.generate(120, 6.0, 4096, 17);
        let (g, summaries) =
            super::replicated_run(&cfg, "econoserve", "sharegpt", &items, true, 2, 300.0);
        let res = fleet::replicated_run(&cfg, "econoserve", "sharegpt", &items, true, 2, 300.0);
        assert_eq!(summaries.len(), 2, "one summary per replica, empty or not");
        assert!((g - res.summary.goodput_rps).abs() < 1e-9);
        assert_eq!(
            summaries.iter().map(|s| s.n_done).sum::<usize>(),
            res.summary.n_done
        );
    }
}
