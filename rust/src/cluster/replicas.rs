//! Replicated single-GPU serving and the Fig 12 min-GPU search.
//!
//! EconoServe (and the other single-engine schedulers) scale out by
//! running one replica per `gpus_per_replica` GPUs and load-balancing
//! requests across replicas (shortest-queue in the paper's homogeneous
//! setup; round-robin here — equivalent for Poisson arrivals).

use crate::config::SystemConfig;
use crate::coordinator::{harness, RunLimits};
use crate::metrics::Summary;
use crate::trace::TraceItem;

/// Run `system` on `k` replicas, splitting `items` round-robin. Returns
/// (aggregate goodput req/s, mean of per-replica summaries).
pub fn replicated_run(
    cfg: &SystemConfig,
    system: &str,
    trace: &str,
    items: &[TraceItem],
    oracle: bool,
    k: usize,
    max_sim_time: f64,
) -> (f64, Vec<Summary>) {
    assert!(k >= 1);
    let mut shards: Vec<Vec<TraceItem>> = vec![Vec::new(); k];
    for (i, it) in items.iter().enumerate() {
        shards[i % k].push(*it);
    }
    let mut goodput = 0.0;
    let mut summaries = Vec::with_capacity(k);
    for shard in shards {
        if shard.is_empty() {
            continue;
        }
        let res = harness::simulate(
            cfg,
            system,
            trace,
            &shard,
            oracle,
            RunLimits::for_time(max_sim_time),
        );
        let span = res.end_time.max(1e-9);
        // Goodput = SLO-satisfying completions per second.
        goodput += res.summary.ssr * shard.len() as f64 / span;
        summaries.push(res.summary);
    }
    (goodput, summaries)
}

/// Minimum number of GPUs `system` needs to reach `target_goodput`
/// (binary search over replica count; each replica occupies
/// `cfg.profile.gpus_per_replica` GPUs).
pub fn min_replicas_for_goodput(
    cfg: &SystemConfig,
    system: &str,
    trace: &str,
    items: &[TraceItem],
    oracle: bool,
    target_goodput: f64,
    max_replicas: usize,
    max_sim_time: f64,
) -> Option<usize> {
    let feasible = |k: usize| -> bool {
        let (g, _) = replicated_run(cfg, system, trace, items, oracle, k, max_sim_time);
        g >= target_goodput
    };
    if !feasible(max_replicas) {
        return None;
    }
    let (mut lo, mut hi) = (1usize, max_replicas);
    while lo < hi {
        let mid = (lo + hi) / 2;
        if feasible(mid) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    Some(lo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelProfile;
    use crate::trace::{TraceGen, TraceSpec};

    #[test]
    fn more_replicas_more_goodput_under_load() {
        let mut cfg = SystemConfig::new(ModelProfile::opt_13b());
        cfg.t_p = 0.1;
        cfg.t_g = 0.025;
        let gen = TraceGen::new(TraceSpec::sharegpt());
        // Overload one replica.
        let items = gen.generate(300, 12.0, 4096, 11);
        let (g1, _) = replicated_run(&cfg, "econoserve", "sharegpt", &items, true, 1, 300.0);
        let (g3, _) = replicated_run(&cfg, "econoserve", "sharegpt", &items, true, 3, 300.0);
        assert!(g3 > g1, "g1={g1} g3={g3}");
    }

    #[test]
    fn search_finds_minimum() {
        let mut cfg = SystemConfig::new(ModelProfile::opt_13b());
        cfg.t_p = 0.1;
        cfg.t_g = 0.025;
        let gen = TraceGen::new(TraceSpec::sharegpt());
        let items = gen.generate(200, 8.0, 4096, 13);
        let (g2, _) = replicated_run(&cfg, "econoserve", "sharegpt", &items, true, 2, 300.0);
        let k = min_replicas_for_goodput(
            &cfg,
            "econoserve",
            "sharegpt",
            &items,
            true,
            g2 * 0.9,
            4,
            300.0,
        )
        .expect("target must be feasible with 4 replicas");
        assert!(k <= 2, "k={k}");
    }
}
