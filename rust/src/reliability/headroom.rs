//! Adaptive KVC headroom: an online misprediction tracker driving the
//! padding ratio toward a target under-provision rate.
//!
//! The paper picks `padding_ratio` per trace offline (sweet spots §2.3,
//! Fig 15a) and holds it constant. That is the right call when the
//! predictor's error process is stationary and calibrated — and exactly
//! wrong when it drifts, grows tails, or goes stale
//! (`predictor::faults`): a static pad then either under-provisions
//! (reached-prediction storms, guest evictions, requeue livelock) or
//! wastes KVC. This module closes the loop:
//!
//!  * [`Headroom`] keeps a bounded ring of **signed log prediction
//!    errors** `ln(true_rl / raw_prediction)` — positive means the
//!    predictor under-shot — fed at request completion and at
//!    overrun-eviction time.
//!  * Every [`HeadroomConfig::window`] observations the controller sets
//!    the pad from the ring's `(1 - target_under)` quantile: the padding
//!    that would have left exactly the target fraction of requests
//!    under-provisioned. A deadband (hysteresis) suppresses twitchy
//!    updates; clamps bound the steered ratio.
//!  * A tiered fallback (mirroring [`super::Brownout`]'s
//!    escalate-fast / clear-slow shape) reacts to *sustained*
//!    misprediction faster than the quantile can: tier 1 over-pads, tier
//!    2 pads to the clamp and halves the per-iteration eviction budget —
//!    the request's reserved span becomes the conservative class before
//!    evictions cascade.
//!
//! Pure arithmetic over simulated quantities — no RNG, no wall clock —
//! so adaptive decisions are bit-identical at any thread count (pinned
//! in tests/equivalence.rs).

/// Knobs for the adaptive headroom controller. Parse a mode string with
/// [`HeadroomConfig::parse`]; `off()` keeps the static sweet-spot
/// constant and leaves runs bit-identical to pre-headroom builds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HeadroomConfig {
    /// Master switch: steer `padding_ratio` online.
    pub adaptive: bool,
    /// Under-provision rate the controller steers toward (the paper's
    /// sweet spots sit near ~10% under, Fig 5a).
    pub target_under: f64,
    /// Clamp bounds on the steered padding ratio.
    pub min_pad: f64,
    pub max_pad: f64,
    /// The pad only moves when the desired value differs by more than
    /// this (absolute padding units) — hysteresis against twitching.
    pub deadband: f64,
    /// Observations per controller step.
    pub window: u32,
    /// Overrun guest evictions allowed per iteration (tier 2 halves it).
    pub evict_budget: u32,
    /// Windowed under-rate at/above which the fallback escalates a tier.
    pub escalate_under: f64,
    /// Windowed under-rate at/below which it steps back down. The gap to
    /// `escalate_under` is the no-flap band.
    pub clear_under: f64,
}

/// Ring capacity for the streaming quantile (bounded memory; must be
/// >= any config's `window` so a full window is always in the ring).
const RING: usize = 256;

/// Highest fallback tier.
const MAX_LEVEL: u8 = 2;

/// Tier-1 over-padding multiplier.
const TIER1_PAD_BOOST: f64 = 1.5;

impl HeadroomConfig {
    /// Adaptive steering off: the static `padding_ratio` stands and the
    /// eviction budget is unlimited.
    pub fn off() -> Self {
        HeadroomConfig { adaptive: false, ..Self::adaptive() }
    }

    /// The `"adaptive"` mode defaults.
    pub fn adaptive() -> Self {
        HeadroomConfig {
            adaptive: true,
            target_under: 0.10,
            min_pad: 0.02,
            max_pad: 1.0,
            deadband: 0.02,
            window: 64,
            evict_budget: 4,
            escalate_under: 0.30,
            clear_under: 0.15,
        }
    }

    /// Parse a headroom mode (`SystemConfig::headroom` / `--headroom`):
    /// `""`, `"off"` and `"static"` keep the sweet-spot constant,
    /// `"adaptive"` enables the controller. `None` on unknown names.
    pub fn parse(mode: &str) -> Option<Self> {
        match mode {
            "" | "off" | "static" => Some(Self::off()),
            "adaptive" => Some(Self::adaptive()),
            _ => None,
        }
    }

    /// Registry names for CLI help and grid validation.
    pub fn all_modes() -> [&'static str; 2] {
        ["static", "adaptive"]
    }

    pub fn is_active(&self) -> bool {
        self.adaptive
    }
}

/// The online misprediction tracker + adaptive padding controller.
#[derive(Debug, Clone)]
pub struct Headroom {
    cfg: HeadroomConfig,
    /// Bounded ring of signed log errors (streaming quantile source).
    ring: Vec<f64>,
    pos: usize,
    /// Observations and under-provision marks in the current window.
    window_n: u32,
    window_under: u32,
    /// Steered base padding ratio (before the tier bump).
    pad: f64,
    level: u8,
    peak: u8,
    /// Lifetime counters for telemetry reconciliation.
    pub under_events: u64,
    pub over_events: u64,
    pub adjustments: u64,
}

impl Headroom {
    /// Start at the configured static sweet spot; the first full window
    /// takes over from there.
    pub fn new(cfg: HeadroomConfig, initial_pad: f64) -> Self {
        debug_assert!(cfg.window as usize <= RING, "window larger than the quantile ring");
        Headroom {
            cfg,
            ring: Vec::with_capacity(RING),
            pos: 0,
            window_n: 0,
            window_under: 0,
            pad: initial_pad.clamp(cfg.min_pad, cfg.max_pad),
            level: 0,
            peak: 0,
            under_events: 0,
            over_events: 0,
            adjustments: 0,
        }
    }

    /// The effective padding ratio for the next prediction, tier bump
    /// applied: tier 1 over-pads, tier 2 sits at the clamp.
    pub fn pad(&self) -> f64 {
        let p = match self.level {
            0 => self.pad,
            1 => self.pad * TIER1_PAD_BOOST,
            _ => self.cfg.max_pad,
        };
        p.clamp(self.cfg.min_pad, self.cfg.max_pad)
    }

    /// Overrun guest evictions allowed in one iteration.
    pub fn eviction_budget(&self) -> u32 {
        if self.level >= MAX_LEVEL {
            (self.cfg.evict_budget / 2).max(1)
        } else {
            self.cfg.evict_budget.max(1)
        }
    }

    pub fn level(&self) -> u8 {
        self.level
    }

    /// Highest tier reached over the run.
    pub fn peak_level(&self) -> u8 {
        self.peak
    }

    /// Feed one observation: the signed log error of a raw prediction
    /// (`ln(true / raw)`, positive = under-shot) and whether the padded
    /// reservation actually under-provisioned. Called at completion for
    /// every request, and again at overrun-eviction time — the double
    /// weight on storms is deliberate (sustained misprediction should
    /// escalate faster than its completion rate alone).
    pub fn observe(&mut self, signed_log_err: f64, under: bool) {
        if self.ring.len() < RING {
            self.ring.push(signed_log_err);
        } else {
            self.ring[self.pos] = signed_log_err;
        }
        self.pos = (self.pos + 1) % RING;
        self.window_n += 1;
        if under {
            self.window_under += 1;
            self.under_events += 1;
        } else {
            self.over_events += 1;
        }
        if self.window_n >= self.cfg.window {
            self.step();
        }
    }

    /// One controller step at the window boundary.
    fn step(&mut self) {
        let under_rate = self.window_under as f64 / self.window_n.max(1) as f64;
        self.window_n = 0;
        self.window_under = 0;

        // Quantile target: the pad that would have left `target_under`
        // of the ring's errors above it.
        let mut sorted = self.ring.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((1.0 - self.cfg.target_under) * (sorted.len() - 1) as f64).round() as usize;
        let desired = (sorted[idx].exp() - 1.0).clamp(self.cfg.min_pad, self.cfg.max_pad);
        if (desired - self.pad).abs() > self.cfg.deadband {
            self.pad = desired;
            self.adjustments += 1;
        }

        // Tiered fallback: escalate on a bad window immediately, clear
        // only once the windowed rate falls through the no-flap band.
        if under_rate >= self.cfg.escalate_under {
            self.level = (self.level + 1).min(MAX_LEVEL);
            self.peak = self.peak.max(self.level);
        } else if under_rate <= self.cfg.clear_under && self.level > 0 {
            self.level -= 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn parse_grammar_and_registry() {
        assert!(!HeadroomConfig::parse("").unwrap().adaptive);
        assert!(!HeadroomConfig::parse("off").unwrap().adaptive);
        assert!(!HeadroomConfig::parse("static").unwrap().adaptive);
        assert!(HeadroomConfig::parse("adaptive").unwrap().adaptive);
        assert!(HeadroomConfig::parse("galactic").is_none());
        for m in HeadroomConfig::all_modes() {
            assert!(HeadroomConfig::parse(m).is_some(), "{m}");
        }
    }

    #[test]
    fn pad_stays_inside_clamps_and_deadband_suppresses_noise() {
        let cfg = HeadroomConfig::adaptive();
        let mut h = Headroom::new(cfg, 0.15);
        // Tiny stationary errors: the desired pad (~0) clamps to min_pad.
        for _ in 0..(cfg.window * 4) {
            h.observe(0.001, false);
        }
        assert!((h.pad() - cfg.min_pad).abs() < 1e-12, "pad {} != min", h.pad());
        let adj = h.adjustments;
        // Errors matching the current pad exactly: inside the deadband,
        // no further adjustment.
        let q = (1.0 + h.pad()).ln();
        for _ in 0..(cfg.window * 4) {
            h.observe(q, false);
        }
        assert_eq!(h.adjustments, adj, "deadband must suppress no-op steps");
        // Huge errors: clamp at max_pad, never beyond.
        for _ in 0..(cfg.window * 4) {
            h.observe(3.0, true);
        }
        assert!(h.pad() <= cfg.max_pad + 1e-12);
    }

    #[test]
    fn sustained_under_escalates_and_recovery_clears_with_hysteresis() {
        let cfg = HeadroomConfig::adaptive();
        let mut h = Headroom::new(cfg, 0.15);
        // Every observation under-shoots: two bad windows reach tier 2.
        for _ in 0..(cfg.window * 2) {
            h.observe(0.8, true);
        }
        assert_eq!(h.level(), 2);
        assert_eq!(h.peak_level(), 2);
        assert_eq!(h.eviction_budget(), (cfg.evict_budget / 2).max(1));
        // A clean window steps down one tier at a time, not to zero.
        for _ in 0..cfg.window {
            h.observe(0.0, false);
        }
        assert_eq!(h.level(), 1);
        assert_eq!(h.eviction_budget(), cfg.evict_budget);
        for _ in 0..cfg.window {
            h.observe(0.0, false);
        }
        assert_eq!(h.level(), 0);
        assert_eq!(h.peak_level(), 2, "peak is sticky");
    }

    #[test]
    fn controller_converges_to_target_under_rate_on_stationary_errors() {
        // Property: on a stationary log-normal error process (the
        // SimPredictor's own model, sigma = sharegpt), the realized
        // under-provision rate converges to target_under. The fixed
        // point is pad* = exp(q_{1-target}(err)) - 1: by construction
        // P(err > ln(1 + pad*)) = target.
        let cfg = HeadroomConfig::adaptive();
        let sigma = 0.127;
        let mut rng = Rng::new(901);
        let mut h = Headroom::new(cfg, 0.15);
        // Burn-in: let the ring fill and the pad settle.
        for _ in 0..(RING * 4) {
            let err = -(rng.normal() * sigma);
            h.observe(err, err > (1.0 + h.pad()).ln());
        }
        let mut n = 0u32;
        let mut under = 0u32;
        for _ in 0..20_000 {
            let err = -(rng.normal() * sigma);
            let is_under = err > (1.0 + h.pad()).ln();
            h.observe(err, is_under);
            n += 1;
            if is_under {
                under += 1;
            }
        }
        let rate = under as f64 / n as f64;
        assert!(
            (rate - cfg.target_under).abs() < 0.05,
            "realized under rate {rate} vs target {}",
            cfg.target_under
        );
        // And the settled pad matches the analytic fixed point
        // exp(z_{0.9} * sigma) - 1 ~ 0.177 for sigma 0.127.
        let analytic = (1.2816 * sigma).exp() - 1.0;
        assert!(
            (h.pad() - analytic).abs() < 0.08,
            "settled pad {} vs analytic {analytic}",
            h.pad()
        );
        assert_eq!(h.level(), 0, "a calibrated process must not trip the fallback");
        assert_eq!(h.under_events + h.over_events, (RING * 4) as u64 + 20_000);
    }
}
