//! Reliability guardrails: deadline-aware aborts, budgeted retries with
//! backoff, request hedging, and brownout overload control.
//!
//! EconoServe's core mechanism is *timely KVC release* — §3.2's insight
//! that the KVC a finished request holds is worth more to the queue than
//! to the finisher. This module applies the same economics to requests
//! that are not going to finish *in time*:
//!
//!  * **Deadline-aware abort** — a request whose minimum remaining
//!    decode time provably exceeds its remaining SLO slack is hopeless:
//!    every further iteration it runs converts KVC into an SLO miss.
//!    [`crate::core::world::World::abort_hopeless`] cancels such
//!    requests between iterations and releases their KVC to queued work.
//!  * **Retry budgets** — crash-displaced and aborted requests get up to
//!    [`GuardrailConfig::max_retries`] re-routes, spaced by exponential
//!    backoff with seeded deterministic jitter
//!    (`util::rng::stream::GUARDRAILS`), re-injected via
//!    `World::push_item` with their ORIGINAL arrival so the SLO deadline
//!    never moves (the same idempotence contract as chaos re-routes).
//!  * **Hedging** — the front door dispatches a second copy of a
//!    still-unfinished request after [`GuardrailConfig::hedge_delay`]
//!    seconds; the first completion wins and the loser is cancelled,
//!    freeing its KVC. Tail insurance against stragglers.
//!  * **Brownout** — a tiered admission controller
//!    (normal → shed-batch-class → reject) driven by fleet queue/KVC
//!    pressure. In the sim it gates arrivals; on the HTTP server it
//!    surfaces as `503` + `Retry-After` (`api::ServeError::Brownout`).
//!
//! ## Determinism contract
//!
//! Every guardrail decision is a pure function of (config, seed):
//! aborts and brownout levels read simulated state that is
//! thread-invariant, hedge fire times are arithmetic on routing times,
//! and retry jitter draws come from the dedicated `GUARDRAILS` RNG
//! stream consumed only at single-threaded event-loop points. The
//! equivalence suite pins fleet summaries and merged telemetry
//! bit-identical at any thread count with all guardrails enabled.

pub mod headroom;

use crate::trace::TraceItem;

/// Tunable guardrail switches + knobs. Parse a mode string with
/// [`GuardrailConfig::parse`]; `off()` (all gates closed) leaves a fleet
/// run bit-identical to a build without guardrails.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GuardrailConfig {
    /// Cancel provably-hopeless decodes and release their KVC.
    pub abort: bool,
    /// Re-route crash-displaced / aborted requests with backoff.
    pub retry: bool,
    /// Dispatch a second copy of slow requests; first completion wins.
    pub hedge: bool,
    /// Tiered overload shedding at the admission front door.
    pub brownout: bool,
    /// Re-route attempts per request after its first placement.
    pub max_retries: u32,
    /// Backoff before retry k is `min(cap, base·2^k)·(1 + jitter·u)`,
    /// u ~ U[0,1) from the `GUARDRAILS` stream.
    pub retry_backoff_base: f64,
    pub retry_backoff_cap: f64,
    pub retry_jitter: f64,
    /// Seconds after first placement before a hedge copy is dispatched.
    pub hedge_delay: f64,
    /// Extra slack (seconds) a request must provably overshoot before it
    /// is aborted — guards against borderline kills.
    pub abort_slack: f64,
    /// Fleet pressure at which brownout starts shedding the batch class.
    pub shed_pressure: f64,
    /// Fleet pressure at which brownout rejects everything.
    pub reject_pressure: f64,
    /// A level steps back down only once pressure falls below
    /// `threshold - hysteresis` (no flapping at the boundary).
    pub hysteresis: f64,
    /// Requests with `prompt_len >= batch_prompt_len` are the
    /// "batch class": shed first under brownout (SageServe's slow lane).
    pub batch_prompt_len: u32,
}

impl GuardrailConfig {
    /// All guardrails disabled; the fleet loop takes every gated branch
    /// out, like the `"none"` fault profile.
    pub fn off() -> Self {
        GuardrailConfig {
            abort: false,
            retry: false,
            hedge: false,
            brownout: false,
            max_retries: 2,
            retry_backoff_base: 0.5,
            retry_backoff_cap: 8.0,
            retry_jitter: 0.5,
            hedge_delay: 10.0,
            abort_slack: 0.25,
            shed_pressure: 0.85,
            reject_pressure: 1.15,
            hysteresis: 0.15,
            batch_prompt_len: 512,
        }
    }

    /// Parse a mode string: `"off"`, `"full"` (everything), or `+`-joined
    /// components from {`retry`, `hedge`, `abort`, `brownout`} — e.g.
    /// `"retry+hedge"`. Returns `None` on an unknown component.
    pub fn parse(mode: &str) -> Option<Self> {
        let mut g = Self::off();
        match mode {
            "" | "off" => return Some(g),
            "full" => {
                g.abort = true;
                g.retry = true;
                g.hedge = true;
                g.brownout = true;
                return Some(g);
            }
            _ => {}
        }
        for part in mode.split('+') {
            match part {
                "abort" => g.abort = true,
                "retry" => g.retry = true,
                "hedge" => g.hedge = true,
                "brownout" => g.brownout = true,
                _ => return None,
            }
        }
        Some(g)
    }

    /// Whether any guardrail is enabled (gates the fleet loop branches).
    pub fn is_active(&self) -> bool {
        self.abort || self.retry || self.hedge || self.brownout
    }

    /// Seconds to wait before retry attempt `attempt` (0-based), given a
    /// uniform jitter draw `u` in [0, 1).
    pub fn backoff(&self, attempt: u32, u: f64) -> f64 {
        let exp = self.retry_backoff_base * 2f64.powi(attempt.min(20) as i32);
        exp.min(self.retry_backoff_cap) * (1.0 + self.retry_jitter * u)
    }

    /// Whether a fresh attempt started now could still meet the deadline
    /// (optimistic lower bound: full prefill + decode at calibrated
    /// speed). Retrying past this point can only burn KVC on a certain
    /// SLO miss, so abort-displaced requests are retried only while it
    /// holds; crash-displaced requests always get their budget (matching
    /// the chaos layer's unconditional re-route).
    pub fn retry_feasible(&self, now: f64, it: &TraceItem, t_p: f64, t_g: f64, deadline: f64) -> bool {
        now + t_p + t_g * it.true_rl as f64 <= deadline
    }
}

/// Mode strings accepted by the CLI / sweep `guardrails` axis.
pub fn all_modes() -> [&'static str; 5] {
    ["off", "retry", "retry+hedge", "retry+hedge+abort", "full"]
}

/// Stable identity of a request across replicas and re-injections.
/// `World::crash_all` and the abort sweep return bare `TraceItem`s, so
/// lineage (retry counts, hedge pairs) is keyed by the item's immutable
/// coordinates — exact on the arrival bit pattern.
pub fn lineage_key(it: &TraceItem) -> (u64, u32, u32) {
    (it.arrival.to_bits(), it.prompt_len, it.true_rl)
}

/// Why a displaced request is back at the front door.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DisplaceOrigin {
    /// In-flight on a replica that crashed.
    Crash,
    /// Cancelled by the deadline-aware abort sweep.
    Abort,
}

/// The tiered brownout controller. Levels: 0 = normal, 1 = shed the
/// batch class, 2 = reject everything. Driven by [`fleet_pressure`] at
/// control ticks; hysteresis keeps it from flapping at a threshold.
#[derive(Debug, Clone, Copy)]
pub struct Brownout {
    shed: f64,
    reject: f64,
    hysteresis: f64,
    batch_prompt_len: u32,
    level: u8,
    peak: u8,
}

impl Brownout {
    pub fn new(g: &GuardrailConfig) -> Self {
        Brownout {
            shed: g.shed_pressure,
            reject: g.reject_pressure,
            hysteresis: g.hysteresis,
            batch_prompt_len: g.batch_prompt_len,
            level: 0,
            peak: 0,
        }
    }

    /// Re-evaluate the tier against current pressure. Escalation is
    /// immediate; de-escalation requires pressure below
    /// `threshold - hysteresis`. Returns the new level.
    pub fn update(&mut self, pressure: f64) -> u8 {
        self.level = match self.level {
            0 => {
                if pressure >= self.reject {
                    2
                } else if pressure >= self.shed {
                    1
                } else {
                    0
                }
            }
            1 => {
                if pressure >= self.reject {
                    2
                } else if pressure < self.shed - self.hysteresis {
                    0
                } else {
                    1
                }
            }
            _ => {
                if pressure < self.shed - self.hysteresis {
                    0
                } else if pressure < self.reject - self.hysteresis {
                    1
                } else {
                    2
                }
            }
        };
        self.peak = self.peak.max(self.level);
        self.level
    }

    pub fn level(&self) -> u8 {
        self.level
    }

    /// Highest tier reached over the run (exported as the
    /// `econoserve_brownout_level` gauge of a sim snapshot).
    pub fn peak(&self) -> u8 {
        self.peak
    }

    /// Admission verdict for an arrival at the current tier.
    pub fn admits(&self, prompt_len: u32) -> bool {
        match self.level {
            0 => true,
            1 => prompt_len < self.batch_prompt_len,
            _ => false,
        }
    }
}

/// Fleet-wide overload pressure over the Active replica set: the max of
/// the in-flight ratio (total in-flight vs. what the fleet can
/// comfortably hold resident — the reactive autoscaler's ceiling) and
/// the mean written-KVC fraction. Reads the same thread-invariant
/// snapshots the router uses, so it is bit-identical at any thread
/// count. Empty set ⇒ infinite pressure (nothing can be admitted).
pub fn fleet_pressure(snaps: &[crate::fleet::ReplicaSnapshot], resident_ceiling: f64) -> f64 {
    if snaps.is_empty() {
        return f64::INFINITY;
    }
    let inflight: usize = snaps.iter().map(|s| s.in_flight).sum();
    let queue = inflight as f64 / (snaps.len() as f64 * resident_ceiling.max(1.0));
    let kvc = snaps
        .iter()
        .map(|s| 1.0 - s.free_kvc as f64 / s.kvc_capacity.max(1) as f64)
        .sum::<f64>()
        / snaps.len() as f64;
    queue.max(kvc)
}

/// Brownout thresholds for the HTTP front door. The serving path has no
/// replica snapshots, so pressure is proxied by the in-flight request
/// count (from [`crate::api::DrainGate::active`]) and the batch class by
/// request-body size — prompt length is unknown before the body is
/// parsed, and shedding must happen *before* parse work is spent.
///
/// Tier semantics mirror the fleet [`Brownout`]: at `shed_inflight`
/// concurrent requests, batch-class bodies (`>= batch_bytes`) are
/// refused; at `reject_inflight`, every generation request is refused.
/// Refusals surface as HTTP 503 with a `Retry-After: ceil(retry_after_s)`
/// header. `shed_inflight == 0` disables the controller entirely.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HttpBrownout {
    /// In-flight count at which batch-class requests are shed (tier 1).
    /// 0 disables brownout.
    pub shed_inflight: usize,
    /// In-flight count at which all generation requests are refused
    /// (tier 2). 0 means tier 2 never engages.
    pub reject_inflight: usize,
    /// Request-body size (bytes) at or above which a request counts as
    /// batch-class for tier-1 shedding.
    pub batch_bytes: usize,
    /// Retry-After hint sent with every brownout refusal, in seconds.
    pub retry_after_s: f64,
}

impl Default for HttpBrownout {
    fn default() -> Self {
        HttpBrownout {
            shed_inflight: 0,
            reject_inflight: 0,
            batch_bytes: 4096,
            retry_after_s: 1.0,
        }
    }
}

impl HttpBrownout {
    pub fn enabled(&self) -> bool {
        self.shed_inflight > 0
    }

    /// Whether a generation request with a `body_bytes`-byte body must
    /// be refused when `inflight` requests are already being served.
    pub fn refuses(&self, inflight: usize, body_bytes: usize) -> bool {
        if !self.enabled() {
            return false;
        }
        if self.reject_inflight > 0 && inflight >= self.reject_inflight {
            return true;
        }
        inflight >= self.shed_inflight && body_bytes >= self.batch_bytes
    }
}

/// Guardrail event counts that are *not* part of the request
/// conservation identity (those live in `fleet::FaultTally`): hedge
/// outcomes by label and the abort split by reason, plus the brownout
/// peak — exactly what the fleet metric overlay needs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct GuardrailStats {
    /// Hedge copies dispatched.
    pub hedges_launched: usize,
    /// Hedge copies cancelled because the primary finished first.
    pub hedges_lost: usize,
    /// Hedge races where both copies finished in one advance window; the
    /// loser's completion is voided in the summary but its counter
    /// increments are monotonic history (see `World::void_completion`).
    pub hedges_dup: usize,
    /// Terminal aborts by reason (their sum is `FaultTally::aborted`).
    pub aborted_deadline: usize,
    pub aborted_brownout: usize,
    /// Highest brownout tier reached.
    pub brownout_peak: u8,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_modes() {
        assert!(!GuardrailConfig::parse("off").unwrap().is_active());
        assert!(!GuardrailConfig::parse("").unwrap().is_active());
        let g = GuardrailConfig::parse("retry+hedge").unwrap();
        assert!(g.retry && g.hedge && !g.abort && !g.brownout);
        let g = GuardrailConfig::parse("retry+hedge+abort").unwrap();
        assert!(g.retry && g.hedge && g.abort && !g.brownout);
        let full = GuardrailConfig::parse("full").unwrap();
        assert!(full.retry && full.hedge && full.abort && full.brownout);
        assert!(GuardrailConfig::parse("retry+teleport").is_none());
        assert!(GuardrailConfig::parse("bogus").is_none());
        for m in all_modes() {
            assert!(GuardrailConfig::parse(m).is_some(), "mode {m} must parse");
        }
    }

    #[test]
    fn backoff_grows_and_caps() {
        let g = GuardrailConfig::off();
        let b0 = g.backoff(0, 0.0);
        let b1 = g.backoff(1, 0.0);
        let b9 = g.backoff(9, 0.0);
        assert!((b0 - 0.5).abs() < 1e-12);
        assert!((b1 - 1.0).abs() < 1e-12);
        assert!((b9 - g.retry_backoff_cap).abs() < 1e-12, "b9={b9}");
        // Jitter widens by at most the configured fraction.
        let hi = g.backoff(0, 0.999_999);
        assert!(hi > b0 && hi <= b0 * (1.0 + g.retry_jitter));
        // Huge attempt counts must not overflow the exponent.
        assert!(g.backoff(1000, 0.5).is_finite());
    }

    #[test]
    fn brownout_tiers_and_hysteresis() {
        let g = GuardrailConfig::off();
        let mut b = Brownout::new(&g);
        assert_eq!(b.update(0.2), 0);
        assert!(b.admits(10_000));
        // Escalate to shed: batch class refused, short prompts pass.
        assert_eq!(b.update(0.9), 1);
        assert!(b.admits(10));
        assert!(!b.admits(g.batch_prompt_len));
        // Pressure at the boundary minus a hair: hysteresis holds tier 1.
        assert_eq!(b.update(g.shed_pressure - 0.01), 1);
        // Full reject.
        assert_eq!(b.update(1.5), 2);
        assert!(!b.admits(1));
        // Recovery steps down through the hysteresis bands.
        assert_eq!(b.update(g.shed_pressure + 0.05), 1);
        assert_eq!(b.update(g.shed_pressure - g.hysteresis - 0.01), 0);
        assert_eq!(b.peak(), 2);
    }

    #[test]
    fn http_brownout_tiers() {
        let off = HttpBrownout::default();
        assert!(!off.enabled());
        assert!(!off.refuses(1_000_000, 1_000_000));
        let b = HttpBrownout {
            shed_inflight: 8,
            reject_inflight: 16,
            batch_bytes: 1024,
            retry_after_s: 2.0,
        };
        assert!(b.enabled());
        // Below shed: everything passes.
        assert!(!b.refuses(7, 10_000));
        // Tier 1: batch-class refused, small bodies pass.
        assert!(b.refuses(8, 1024));
        assert!(!b.refuses(8, 1023));
        // Tier 2: everything refused.
        assert!(b.refuses(16, 1));
        // reject_inflight == 0 leaves tier 2 disengaged.
        let shed_only = HttpBrownout { reject_inflight: 0, ..b };
        assert!(!shed_only.refuses(1_000_000, 1));
        assert!(shed_only.refuses(9, 4096));
    }

    #[test]
    fn lineage_keys_distinguish_items() {
        let a = TraceItem { arrival: 1.25, prompt_len: 100, true_rl: 40 };
        let b = TraceItem { arrival: 1.25, prompt_len: 100, true_rl: 41 };
        let c = TraceItem { arrival: 1.250000001, prompt_len: 100, true_rl: 40 };
        assert_eq!(lineage_key(&a), lineage_key(&a.clone()));
        assert_ne!(lineage_key(&a), lineage_key(&b));
        assert_ne!(lineage_key(&a), lineage_key(&c));
    }

    #[test]
    fn retry_feasibility_is_the_optimistic_bound() {
        let g = GuardrailConfig::off();
        let it = TraceItem { arrival: 0.0, prompt_len: 64, true_rl: 100 };
        // deadline 10s, t_p 0.1, t_g 0.02 -> needs 2.1s.
        assert!(g.retry_feasible(5.0, &it, 0.1, 0.02, 10.0));
        assert!(!g.retry_feasible(9.0, &it, 0.1, 0.02, 10.0));
    }

    #[test]
    fn pressure_reads_snapshots() {
        use crate::fleet::ReplicaSnapshot;
        let snaps = [
            ReplicaSnapshot { id: 0, in_flight: 8, free_kvc: 500, kvc_capacity: 1000, healthy: true },
            ReplicaSnapshot { id: 1, in_flight: 2, free_kvc: 900, kvc_capacity: 1000, healthy: true },
        ];
        // queue: 10 / (2 * 10) = 0.5; kvc: mean(0.5, 0.1) = 0.3.
        let p = fleet_pressure(&snaps, 10.0);
        assert!((p - 0.5).abs() < 1e-12, "p={p}");
        assert!(fleet_pressure(&[], 10.0).is_infinite());
    }
}
