//! Deterministic fault injection for the fleet layer.
//!
//! A [`FaultProfile`] (registry via [`by_name`], mirroring
//! `sched::by_name` / `router::by_name` / `autoscale::by_name`) compiles
//! into a seeded timeline of [`FaultEvent`]s that the fleet loop
//! (`fleet::sim::run`) consumes at their timestamps alongside arrivals,
//! boot completions, and control ticks:
//!
//!  * **Crash** — one live replica dies instantly. Its in-flight
//!    requests are either re-routed idempotently (health-aware fleets;
//!    the original arrival time is preserved so the SLO deadline does
//!    not move) or counted as lost (health-blind fleets, or profiles
//!    with `reroute = false`).
//!  * **ZoneOutage** — replicas carry an implicit zone tag
//!    (`id % profile.zones`); a whole zone crashes at once, booting
//!    replicas included. Models correlated failure domains.
//!  * **Straggler** — one live replica runs `straggle_factor`× slower
//!    for `straggle_len` seconds (its simulated step durations are
//!    dilated), then recovers.
//!  * **Boot failures** — each scale-up attempt fails with probability
//!    `boot_fail_prob`: it burns the full boot latency, then lands as
//!    Crashed instead of Active, forcing the autoscaler to retry.
//!
//! Event *times* and *picks* are drawn up front from per-process RNG
//! streams (crash / outage / straggler / boot, all derived from the
//! fleet seed via `derive_seed(seed, stream::FAULTS)`), never from
//! simulation state — so the timeline is a pure function of (profile,
//! seed) and bit-identical at any thread count. The `pick` is resolved
//! against the candidate set at application time (`pick % candidates`),
//! which is itself thread-invariant. Each event kind fires on a
//! jittered-periodic schedule — occurrence `k` lands uniformly in
//! `[(k + 0.25)·every, (k + 0.75)·every]` — so every profile is
//! guaranteed to fire within a known window (a Poisson schedule could
//! leave a short test run fault-free).
//!
//! Accounting flows back through [`FaultTally`], embedded in
//! `FleetSummary::faults`; `fleet::chaos_run` pairs a chaos run with its
//! fault-free twin to report goodput/SSR *retention*.

use crate::util::rng::{derive_seed, Rng};

/// A named fault-injection profile. `every = 0.0` disables that event
/// kind; `boot_fail_prob = 0.0` makes boots reliable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultProfile {
    pub name: &'static str,
    /// Mean seconds between single-replica crashes (0 = never).
    pub crash_every: f64,
    /// Mean seconds between whole-zone outages (0 = never).
    pub outage_every: f64,
    /// Number of failure domains; replica `id` lives in zone `id % zones`.
    pub zones: usize,
    /// Mean seconds between straggler episodes (0 = never).
    pub straggle_every: f64,
    /// Slowdown multiplier applied to a straggling replica's step time.
    pub straggle_factor: f64,
    /// Seconds a straggler episode lasts before the replica recovers.
    pub straggle_len: f64,
    /// Probability a scale-up attempt burns its boot latency then fails.
    pub boot_fail_prob: f64,
    /// Whether a crashed replica's in-flight requests are re-routed
    /// (health-aware fleets only) instead of counted as lost.
    pub reroute: bool,
}

const NONE: FaultProfile = FaultProfile {
    name: "none",
    crash_every: 0.0,
    outage_every: 0.0,
    zones: 1,
    straggle_every: 0.0,
    straggle_factor: 1.0,
    straggle_len: 0.0,
    boot_fail_prob: 0.0,
    reroute: false,
};

const PROFILES: [FaultProfile; 6] = [
    NONE,
    FaultProfile { name: "crashes", crash_every: 120.0, reroute: true, ..NONE },
    FaultProfile { name: "zone-outage", outage_every: 300.0, zones: 2, reroute: true, ..NONE },
    FaultProfile {
        name: "stragglers",
        straggle_every: 90.0,
        straggle_factor: 4.0,
        straggle_len: 30.0,
        ..NONE
    },
    FaultProfile {
        name: "flaky-boots",
        crash_every: 150.0,
        boot_fail_prob: 0.5,
        reroute: true,
        ..NONE
    },
    FaultProfile {
        name: "full-chaos",
        crash_every: 180.0,
        outage_every: 400.0,
        zones: 2,
        straggle_every: 120.0,
        straggle_factor: 3.0,
        straggle_len: 25.0,
        boot_fail_prob: 0.3,
        reroute: true,
        ..NONE
    },
];

/// Names of every registered profile, `"none"` first.
pub fn all_profiles() -> Vec<&'static str> {
    PROFILES.iter().map(|p| p.name).collect()
}

/// Look up a profile by name (the fleet registry pattern).
pub fn by_name(name: &str) -> Option<FaultProfile> {
    PROFILES.iter().find(|p| p.name == name).copied()
}

impl FaultProfile {
    /// Whether this profile injects anything at all. The `"none"`
    /// profile leaves the fleet loop bit-identical to a build without
    /// fault injection.
    pub fn is_active(&self) -> bool {
        self.crash_every > 0.0
            || self.outage_every > 0.0
            || self.straggle_every > 0.0
            || self.boot_fail_prob > 0.0
    }
}

/// What a fault event does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Kill one live replica (`pick % live` selects the victim).
    Crash,
    /// Kill every non-terminal replica in zone `pick % zones`.
    ZoneOutage,
    /// Slow one Active replica (`pick % active`) by `straggle_factor`.
    Straggler,
}

/// One scheduled fault. `pick` is a raw draw; the victim is resolved at
/// application time against the then-current candidate set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at: f64,
    pub kind: FaultKind,
    pub pick: u64,
}

/// One jittered-periodic event process: occurrence `k` is drawn at
/// `(k + 0.25 + 0.5·u)·every` with its victim pick, eagerly, so the
/// schedule depends only on (seed, every).
#[derive(Debug, Clone)]
struct Process {
    kind: FaultKind,
    every: f64,
    k: u64,
    rng: Rng,
    next: Option<FaultEvent>,
}

impl Process {
    fn new(kind: FaultKind, every: f64, seed: u64) -> Self {
        let mut p = Process { kind, every, k: 0, rng: Rng::new(seed), next: None };
        if every > 0.0 {
            p.advance();
        }
        p
    }

    fn advance(&mut self) {
        let at = (self.k as f64 + 0.25 + 0.5 * self.rng.f64()) * self.every;
        let pick = self.rng.next_u64();
        self.k += 1;
        self.next = Some(FaultEvent { at, kind: self.kind, pick });
    }

    fn next_at(&self) -> f64 {
        self.next.map_or(f64::INFINITY, |e| e.at)
    }
}

/// The runtime half of a profile: hands the fleet loop its fault events
/// in timestamp order and answers boot-failure draws. All randomness
/// comes from four sub-streams of the fault seed, so two fleets with the
/// same (profile, seed) see the exact same chaos regardless of routing,
/// autoscaling, or thread count.
#[derive(Debug, Clone)]
pub struct Injector {
    profile: FaultProfile,
    crash: Process,
    outage: Process,
    straggle: Process,
    boot_rng: Rng,
}

impl Injector {
    /// `seed` is the *fault* seed — callers pass
    /// `derive_seed(fleet_seed, stream::FAULTS)`.
    pub fn new(profile: FaultProfile, seed: u64) -> Self {
        Injector {
            profile,
            crash: Process::new(FaultKind::Crash, profile.crash_every, derive_seed(seed, 0)),
            outage: Process::new(FaultKind::ZoneOutage, profile.outage_every, derive_seed(seed, 1)),
            straggle: Process::new(
                FaultKind::Straggler,
                profile.straggle_every,
                derive_seed(seed, 2),
            ),
            boot_rng: Rng::new(derive_seed(seed, 3)),
        }
    }

    pub fn profile(&self) -> &FaultProfile {
        &self.profile
    }

    /// Timestamp of the earliest pending event (INFINITY when none).
    pub fn next_at(&self) -> f64 {
        self.crash.next_at().min(self.outage.next_at()).min(self.straggle.next_at())
    }

    /// Pop the earliest event if it is due at or before `t`. Ties break
    /// crash < outage < straggler, deterministically.
    pub fn pop_due(&mut self, t: f64) -> Option<FaultEvent> {
        let (at, which) = [self.crash.next_at(), self.outage.next_at(), self.straggle.next_at()]
            .into_iter()
            .enumerate()
            .map(|(i, at)| (at, i))
            .fold((f64::INFINITY, usize::MAX), |best, cand| if cand.0 < best.0 { cand } else { best });
        if at > t {
            return None;
        }
        let p = match which {
            0 => &mut self.crash,
            1 => &mut self.outage,
            _ => &mut self.straggle,
        };
        let ev = p.next;
        p.advance();
        ev
    }

    /// Deterministic per-boot failure draw. Always `false` for reliable
    /// profiles, without consuming randomness, so `boot_fail_prob = 0`
    /// profiles stay bit-identical to a fleet without an injector.
    pub fn boot_fails(&mut self) -> bool {
        self.profile.boot_fail_prob > 0.0 && self.boot_rng.chance(self.profile.boot_fail_prob)
    }
}

/// The full event timeline of (profile, seed) up to `horizon`, in
/// timestamp order — what the fleet loop will consume, exposed as a pure
/// function for tests and docs.
pub fn timeline(profile: FaultProfile, seed: u64, horizon: f64) -> Vec<FaultEvent> {
    let mut inj = Injector::new(profile, seed);
    let mut out = Vec::new();
    while let Some(ev) = inj.pop_due(horizon) {
        out.push(ev);
    }
    out
}

/// Fault accounting, embedded as `FleetSummary::faults`. All zeros for
/// fault-free runs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultTally {
    /// Replicas killed (zone-outage victims included).
    pub crashes: usize,
    /// Whole-zone outage events that fired.
    pub zone_outages: usize,
    /// Scale-up attempts that burned boot latency then failed.
    pub boot_failures: usize,
    /// Straggler episodes applied.
    pub stragglers: usize,
    /// In-flight requests re-routed off a crashed replica.
    pub rerouted: usize,
    /// Requests lost to a crash (in-flight with no re-route, or routed
    /// to a corpse by a health-blind router).
    pub lost: usize,
    /// Guardrail re-injections: displaced requests placed again after a
    /// backoff delay (`reliability` retry budgets). A request retried
    /// twice counts twice.
    pub retried: usize,
    /// Displaced requests that went on to COMPLETE after a guardrail
    /// retry — the recovered-goodput headline.
    pub recovered: usize,
    /// Hedged requests whose hedge copy finished first.
    pub hedges_won: usize,
    /// Requests terminally cancelled by guardrails (deadline-aware
    /// aborts out of retry budget + brownout rejections). Part of the
    /// conservation identity `n_total == n_done + lost + aborted`.
    pub aborted: usize,
}

impl FaultTally {
    pub fn is_zero(&self) -> bool {
        *self == FaultTally::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_every_profile() {
        for name in all_profiles() {
            let p = by_name(name).expect("registered profile resolves");
            assert_eq!(p.name, name);
        }
        assert!(by_name("meteor-strike").is_none());
        assert!(!by_name("none").unwrap().is_active());
        assert!(by_name("full-chaos").unwrap().is_active());
    }

    #[test]
    fn none_profile_has_empty_timeline() {
        assert!(timeline(by_name("none").unwrap(), 42, 10_000.0).is_empty());
        let inj = Injector::new(by_name("none").unwrap(), 42);
        assert_eq!(inj.next_at(), f64::INFINITY);
    }

    #[test]
    fn timelines_are_seed_deterministic() {
        let p = by_name("full-chaos").unwrap();
        let a = timeline(p, 7, 3_000.0);
        let b = timeline(p, 7, 3_000.0);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        let c = timeline(p, 8, 3_000.0);
        assert_ne!(a, c, "different seeds should jitter the schedule apart");
    }

    #[test]
    fn events_are_ordered_and_inside_their_jitter_windows() {
        let p = by_name("crashes").unwrap();
        let evs = timeline(p, 99, 2_000.0);
        assert!(evs.len() >= 10);
        let mut prev = 0.0;
        for (k, ev) in evs.iter().enumerate() {
            assert_eq!(ev.kind, FaultKind::Crash);
            let lo = (k as f64 + 0.25) * p.crash_every;
            let hi = (k as f64 + 0.75) * p.crash_every;
            assert!(ev.at >= lo && ev.at <= hi, "event {k} at {} outside [{lo}, {hi}]", ev.at);
            assert!(ev.at > prev);
            prev = ev.at;
        }
    }

    #[test]
    fn mixed_profile_interleaves_kinds_in_order() {
        let evs = timeline(by_name("full-chaos").unwrap(), 3, 4_000.0);
        let mut prev = 0.0;
        let mut kinds = [0usize; 3];
        for ev in &evs {
            assert!(ev.at >= prev, "timeline not sorted");
            prev = ev.at;
            kinds[match ev.kind {
                FaultKind::Crash => 0,
                FaultKind::ZoneOutage => 1,
                FaultKind::Straggler => 2,
            }] += 1;
        }
        assert!(kinds.iter().all(|&k| k > 0), "all kinds should fire: {kinds:?}");
    }

    #[test]
    fn boot_draws_match_profile_probability() {
        let mut reliable = Injector::new(by_name("crashes").unwrap(), 5);
        assert!((0..100).all(|_| !reliable.boot_fails()));
        let mut flaky = Injector::new(by_name("flaky-boots").unwrap(), 5);
        let fails = (0..10_000).filter(|_| flaky.boot_fails()).count();
        assert!((4_000..6_000).contains(&fails), "p=0.5 draw count {fails}");
    }
}
