//! The fleet layer: event-driven multi-replica serving with online
//! routing, autoscaling, and GPU-hour cost accounting.
//!
//! The paper's cluster result (Fig 12) — EconoServe needs up to 78%
//! fewer GPUs than DistServe at equal goodput — was demonstrated by a
//! *static offline* search over round-robin pre-sharded traces
//! (`cluster::replicas`). This module makes the cost story dynamic, the
//! way SageServe (arXiv 2502.14617) and Aladdin (arXiv 2405.06856)
//! argue it must be told: N per-replica [`crate::coordinator::Stepper`]
//! worlds advance on a shared clock, a fleet front door routes each
//! arrival *at its arrival time*, and an autoscaler grows and drains the
//! replica set as traffic breathes. Three pluggable axes, composed by
//! name like the `sched::by_name("<sched>+<alloc>")` grammar:
//!
//! | axis | names |
//! |------|-------|
//! | router ([`router`]) | `round-robin`, `least-queue`, `least-kvc`, `power-of-two` |
//! | autoscaler ([`autoscale`]) | `static-k`, `reactive`, `forecast` |
//! | workload ([`crate::trace::ArrivalProcess`]) | `poisson`, `mmpp`, `diurnal` |
//! | faults ([`faults`]) | `none`, `crashes`, `zone-outage`, `stragglers`, `flaky-boots`, `full-chaos` |
//! | guardrails ([`crate::reliability`]) | `off`, `full`, `+`-joined {`retry`, `hedge`, `abort`, `brownout`} |
//!
//! Fleet metrics report goodput, SLO satisfaction, **GPU-hours**, and
//! goodput-per-GPU-hour, so Fig 12 is reproducible dynamically and the
//! new cost-under-diurnal-load scenario (static peak fleet vs
//! autoscaled fleet at equal SLO attainment) is one CLI command:
//! `econoserve fleet --workload diurnal --autoscaler forecast
//! --compare-static`.
//!
//! Reproducibility: every stochastic component's seed is derived from
//! `(cfg.seed, stream)` via [`crate::util::rng::derive_seed`] — replica
//! `i` draws the same predictor stream no matter which router placed
//! which request. For *bit*-reproducible runs also set
//! `cfg.sched_time_scale = 0`: the default config charges measured
//! scheduler wall-clock into the simulated clock (the Fig 14 overhead
//! model), which varies from run to run by construction.

pub mod autoscale;
pub mod faults;
pub mod router;
pub mod sim;

pub use autoscale::{all_autoscalers, Autoscaler, ScaleKnobs, ScaleObs};
pub use faults::{all_profiles, FaultProfile, FaultTally};
pub use router::{all_routers, ReplicaSnapshot, Router};
pub use sim::run;

use crate::config::SystemConfig;
use crate::metrics::Summary;
use crate::trace::{TraceItem, TraceSpec};

/// Everything a fleet run needs besides the workload items.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Per-replica system config (the fleet derives per-replica seeds
    /// from `cfg.seed`).
    pub cfg: SystemConfig,
    /// Scheduler system in the `sched::by_name` registry grammar.
    pub system: String,
    /// Trace name (predictor calibration + capacity priors).
    pub trace: String,
    pub oracle: bool,
    /// Router registry name (`router::all_routers`).
    pub router: String,
    /// Autoscaler registry name (`autoscale::all_autoscalers`).
    pub autoscaler: String,
    /// Replicas booted (instantly routable) at t=0.
    pub init_replicas: usize,
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// Seconds from a scale-up decision to a routable replica.
    pub boot_latency: f64,
    /// Seconds between autoscaler control ticks.
    pub control_interval: f64,
    /// Sustainable per-replica serving rate (req/s) for the forecast
    /// autoscaler; 0 derives it from the trace capacity estimate.
    pub per_replica_rps: f64,
    /// Fault-injection profile name (`faults::all_profiles`); `"none"`
    /// leaves the run bit-identical to a fleet without fault injection.
    pub faults: String,
    /// Whether the control plane *sees* faults: `true` gives routers a
    /// truthful health view (crashed replicas are never picked while a
    /// healthy one exists), re-routes in-flight requests off crashed
    /// replicas (profiles with `reroute`), and boots replacements to
    /// hold `min_replicas`. `false` models a health-blind fleet: corpses
    /// stay in the routing table looking idle, their in-flight requests
    /// are lost, and nothing is replaced except by autoscaler pressure.
    /// Irrelevant under the `"none"` profile.
    pub health_aware: bool,
    /// Reliability guardrail mode (`reliability::GuardrailConfig::parse`
    /// grammar): `"off"`, `"full"`, or `+`-joined components from
    /// {`retry`, `hedge`, `abort`, `brownout`}. `"off"` leaves the run
    /// bit-identical to a fleet without the guardrail layer.
    pub guardrails: String,
    /// Hard simulated-time cap (requests unfinished at the cap count as
    /// SLO misses, like `RunLimits::max_sim_time`).
    pub max_sim_time: f64,
    /// Worker threads for concurrent replica stepping (replicas are
    /// independent between routing events, so the fleet advances all of
    /// them to each event horizon in parallel). 0 = `ECONOSERVE_THREADS`
    /// / available parallelism; 1 = serial.
    ///
    /// With `cfg.sched_time_scale == 0` thread count never changes
    /// results — replicas are data-independent while stepping — so this
    /// is purely a wall-clock knob. With measured scheduler-time
    /// charging enabled (the default config), concurrent stepping would
    /// let CPU contention bias the simulated clocks, so auto mode (0)
    /// stays serial and only an explicit `threads > 1` opts in.
    pub threads: usize,
    /// Span tracing (`None` = off). When set, every replica world gets a
    /// [`crate::telemetry::TraceRecorder`] (pid = replica id) and the
    /// event loop records routing/boot/crash/drain provenance on the
    /// replicas' control tracks; the per-replica documents are merged in
    /// replica-id order at finalize, so [`FleetResult::trace_doc`] is
    /// bit-identical at any `threads` setting. Callers pass a seed that
    /// is already stream-separated
    /// (`derive_seed(cfg.seed, stream::TRACE)`).
    pub tracing: Option<crate::telemetry::TraceConfig>,
    /// Per-replica bounded request-log capacity (0 = off). When set,
    /// each replica world keeps a [`crate::telemetry::reqlog::RequestLog`]
    /// and [`FleetResult::reqlog`] carries the merged JSONL (replica-id
    /// order, each line tagged with its replica) for
    /// `econoserve fleet --log-out`.
    pub reqlog_capacity: usize,
}

impl FleetConfig {
    /// A single-replica fleet with sensible dynamic-scaling defaults;
    /// adjust fields to taste.
    pub fn new(cfg: SystemConfig, system: &str, trace: &str) -> Self {
        FleetConfig {
            cfg,
            system: system.to_string(),
            trace: trace.to_string(),
            oracle: false,
            router: "least-queue".to_string(),
            autoscaler: "static-k".to_string(),
            init_replicas: 1,
            min_replicas: 1,
            max_replicas: 1,
            boot_latency: 10.0,
            control_interval: 5.0,
            per_replica_rps: 0.0,
            faults: "none".to_string(),
            health_aware: true,
            guardrails: "off".to_string(),
            max_sim_time: f64::INFINITY,
            threads: 0,
            tracing: None,
            reqlog_capacity: 0,
        }
    }

    /// The legacy `cluster::replicas` shape: a fixed fleet of `k`
    /// replicas behind round-robin routing.
    pub fn static_k(
        cfg: SystemConfig,
        system: &str,
        trace: &str,
        oracle: bool,
        k: usize,
        max_sim_time: f64,
    ) -> Self {
        let mut fc = Self::new(cfg, system, trace);
        fc.oracle = oracle;
        fc.router = "round-robin".to_string();
        fc.init_replicas = k;
        fc.min_replicas = k;
        fc.max_replicas = k;
        fc.boot_latency = 0.0;
        fc.max_sim_time = max_sim_time;
        fc
    }

    fn spec(&self) -> TraceSpec {
        TraceSpec::by_name(&self.trace).unwrap_or_else(TraceSpec::sharegpt)
    }

    /// Sustainable per-replica rate: explicit if set, else 80% of the
    /// analytic capacity roofline for the trace mix.
    pub fn replica_rps(&self) -> f64 {
        if self.per_replica_rps > 0.0 {
            self.per_replica_rps
        } else {
            0.8 * self.cfg.capacity_estimate(&self.spec())
        }
    }

    /// Scaling knobs shared by the autoscaler policies.
    pub fn knobs(&self) -> ScaleKnobs {
        let spec = self.spec();
        // Comfortable resident-request ceiling: how many average-mix
        // requests fit in one replica's KVC at once (prompt + half the
        // response in flight).
        let footprint = (spec.input.avg + spec.output.avg / 2.0).max(1.0);
        ScaleKnobs {
            resident_ceiling: self.cfg.kvc_tokens() as f64 / footprint,
            per_replica_rps: self.replica_rps(),
            control_interval: self.control_interval,
            boot_latency: self.boot_latency,
        }
    }
}

/// Lifecycle state of one fleet replica. Requests are only ever routed
/// to `Active` replicas; `Draining` replicas finish their in-flight work
/// and then retire (drain-before-retire).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaState {
    Booting,
    Active,
    Draining,
    Retired,
    /// Killed by fault injection (or a failed boot): GPUs released, any
    /// in-flight work lost or re-routed. Terminal, like `Retired`.
    Crashed,
}

impl ReplicaState {
    /// Terminal states: the replica is gone and is never advanced again.
    pub fn is_terminal(self) -> bool {
        matches!(self, ReplicaState::Retired | ReplicaState::Crashed)
    }
}

/// Lifecycle + routing record of one replica (tests pin the routing
/// invariants against this; the CLI prints it).
#[derive(Debug, Clone)]
pub struct ReplicaLog {
    /// When the scale-up (or initial boot) was ordered — GPU billing
    /// starts here.
    pub ordered_at: f64,
    /// When the replica became routable (`ordered_at + boot_latency`).
    pub routable_at: f64,
    pub drain_at: Option<f64>,
    /// When the replica released its GPUs (drain complete).
    pub retired_at: Option<f64>,
    pub routed: usize,
    pub first_routed_at: Option<f64>,
    pub last_routed_at: Option<f64>,
    /// When fault injection killed the replica (GPU billing stops here).
    /// For a failed boot this is the moment the boot would have
    /// completed — the warm-up was paid for, the replica never served.
    pub crashed_at: Option<f64>,
    /// Requests re-routed *onto* this replica after another crashed.
    /// Counted separately from `routed`, which tracks first routes only
    /// (so `sum(routed) == n_routed` stays an invariant under chaos).
    pub rerouted: usize,
}

/// Fleet-level outcome: the cost-and-goodput view Fig 12 is about.
/// `PartialEq` is part of the contract: the equivalence suite pins
/// parallel and sequential fleet runs to *bit-identical* summaries.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FleetSummary {
    /// Requests offered to the fleet.
    pub n_total: usize,
    /// Requests routed to some replica (< n_total only if the sim-time
    /// cap cut the run short).
    pub n_routed: usize,
    pub n_done: usize,
    /// Completions that met their SLO.
    pub slo_ok: usize,
    /// SLO-satisfying completions per second (the Fig 12 currency).
    pub goodput_rps: f64,
    pub throughput_rps: f64,
    /// SLO satisfaction over ALL offered requests (unrouted/unfinished
    /// count as violations).
    pub ssr: f64,
    pub mean_jct: f64,
    pub p95_jct: f64,
    pub end_time: f64,
    /// GPU-hours consumed: per-replica (ordered → retired/end) spans
    /// times `gpus_per_replica`. Booting time is billed — you pay for an
    /// instance while it warms up.
    pub gpu_hours: f64,
    /// SLO-satisfying completions per GPU-hour (cost efficiency).
    pub goodput_per_gpu_hour: f64,
    /// Extremes of the serving size (Active + Booting) observed at
    /// control ticks — the autoscaler-bounds invariant.
    pub peak_replicas: usize,
    pub floor_replicas: usize,
    /// Time-weighted mean replica count.
    pub mean_replicas: f64,
    pub boots: usize,
    pub retirements: usize,
    /// Fault accounting (all zeros without fault injection).
    pub faults: FaultTally,
}

/// Full fleet run result.
pub struct FleetResult {
    pub summary: FleetSummary,
    /// Per-replica serving summaries (fleet-wide time base).
    pub per_replica: Vec<Summary>,
    /// Per-replica lifecycle/routing logs, in replica-id order.
    pub replicas: Vec<ReplicaLog>,
    /// Canonical Prometheus text: every replica's telemetry registry
    /// merged in replica-id order, plus the fleet-level counters written
    /// from the authoritative summary/`FaultTally` accounting
    /// (`econoserve fleet --metrics-out`; see `docs/metrics-dictionary.md`).
    /// Replica registries are single-threaded by construction, so this
    /// string is bit-identical at any `threads` setting.
    pub metrics: String,
    /// Merged span trace (`FleetConfig::tracing` enabled): per-replica
    /// documents in replica-id order plus the control-track events, a
    /// pure function of (config, seed) — bit-identical at any `threads`
    /// setting (`econoserve fleet --trace-out`).
    pub trace_doc: Option<crate::telemetry::TraceDoc>,
    /// Merged per-replica request-log JSONL (`FleetConfig::reqlog_capacity`
    /// > 0), each line tagged `"replica":<id>`
    /// (`econoserve fleet --log-out`).
    pub reqlog: Option<String>,
}

/// A chaos run paired with its fault-free twin: the same fleet config
/// rerun under the `"none"` profile, so goodput/SSR *retention* — the
/// headline of the `econoserve fleet --chaos` scenario — is measured
/// against exactly the capacity the faults took away.
pub struct ChaosOutcome {
    pub chaos: FleetSummary,
    pub baseline: FleetSummary,
}

impl ChaosOutcome {
    /// Goodput under chaos as a fraction of fault-free goodput.
    pub fn goodput_retention(&self) -> f64 {
        self.chaos.goodput_rps / self.baseline.goodput_rps.max(1e-9)
    }

    /// SLO satisfaction under chaos as a fraction of fault-free SSR.
    pub fn ssr_retention(&self) -> f64 {
        self.chaos.ssr / self.baseline.ssr.max(1e-9)
    }
}

/// Run `fc` as configured, then once more with faults disabled, and
/// report both (see [`ChaosOutcome`]).
pub fn chaos_run(fc: &FleetConfig, items: &[TraceItem]) -> ChaosOutcome {
    let chaos = sim::run(fc, items).summary;
    let mut calm = fc.clone();
    calm.faults = "none".to_string();
    let baseline = sim::run(&calm, items).summary;
    ChaosOutcome { chaos, baseline }
}

/// Run `system` on a fixed fleet of `k` round-robin replicas — the
/// legacy `cluster::replicas::replicated_run` re-expressed on the fleet
/// (router=`round-robin`, autoscaler=`static-k`), with routing decided
/// online at arrival time instead of by index pre-sharding.
pub fn replicated_run(
    cfg: &SystemConfig,
    system: &str,
    trace: &str,
    items: &[TraceItem],
    oracle: bool,
    k: usize,
    max_sim_time: f64,
) -> FleetResult {
    assert!(k >= 1);
    let fc = FleetConfig::static_k(cfg.clone(), system, trace, oracle, k, max_sim_time);
    sim::run(&fc, items)
}

/// Minimum number of replicas `system` needs to reach `target_goodput`
/// on a static fleet (each replica occupies
/// `cfg.profile.gpus_per_replica` GPUs). The fleet-layer port of the
/// Fig 12 min-GPU search.
///
/// Candidate sizes are independent simulations, so the search fans them
/// out over [`crate::exp::map_indexed`]: one run at the cap decides
/// overall feasibility (infeasible targets still cost a single run),
/// then a bottom-up scan in worker-sized batches finds the smallest
/// feasible size. Batch boundaries never change the answer — it is the
/// smallest feasible `k` whatever the thread count — and the typical
/// Fig 12 answer (1–2 replicas) resolves in the first batch, so
/// wall-clock ≈ two fleet runs. Exact even when feasibility is
/// non-monotone (the old binary search assumed monotonicity).
///
/// Candidate runs never charge measured scheduler wall-clock into the
/// simulated clock (`sched_time_scale = 0`): a capacity decision must
/// not flip with host load or contention between concurrent candidates.
/// Caveat: if `target_goodput` was measured under measured-overhead
/// charging (a `sched_time_scale > 0` run of [`replicated_run`]), the
/// overhead-free candidates rate slightly optimistic — derive targets
/// from overhead-free runs (like the analytic DistServe baseline and
/// the test configs) for an apples-to-apples search.
#[allow(clippy::too_many_arguments)]
pub fn min_replicas_for_goodput(
    cfg: &SystemConfig,
    system: &str,
    trace: &str,
    items: &[TraceItem],
    oracle: bool,
    target_goodput: f64,
    max_replicas: usize,
    max_sim_time: f64,
) -> Option<usize> {
    if max_replicas == 0 {
        return None;
    }
    let feasible = |k: usize| -> bool {
        let mut cfg = cfg.clone();
        cfg.sched_time_scale = 0.0;
        let mut fc = FleetConfig::static_k(cfg, system, trace, oracle, k, max_sim_time);
        // The candidate-level fan-out owns the cores; each candidate's
        // replicas step serially.
        fc.threads = 1;
        sim::run(&fc, items).summary.goodput_rps >= target_goodput
    };
    if !feasible(max_replicas) {
        return None;
    }
    let threads = crate::exp::resolve_threads(0);
    let mut lo = 1usize;
    while lo < max_replicas {
        let hi = (lo + threads - 1).min(max_replicas - 1);
        let batch: Vec<usize> = (lo..=hi).collect();
        let outcomes = crate::exp::map_indexed(&batch, threads, |_, &k| feasible(k));
        if let Some(pos) = outcomes.iter().position(|&ok| ok) {
            return Some(batch[pos]);
        }
        lo = hi + 1;
    }
    Some(max_replicas)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelProfile;
    use crate::trace::TraceGen;

    #[test]
    fn more_replicas_more_goodput_under_load() {
        let mut cfg = SystemConfig::new(ModelProfile::opt_13b());
        cfg.t_p = 0.1;
        cfg.t_g = 0.025;
        cfg.sched_time_scale = 0.0;
        let gen = TraceGen::new(TraceSpec::sharegpt());
        // Overload one replica.
        let items = gen.generate(300, 12.0, 4096, 11);
        let g1 = replicated_run(&cfg, "econoserve", "sharegpt", &items, true, 1, 300.0)
            .summary
            .goodput_rps;
        let g3 = replicated_run(&cfg, "econoserve", "sharegpt", &items, true, 3, 300.0)
            .summary
            .goodput_rps;
        assert!(g3 > g1, "g1={g1} g3={g3}");
    }

    #[test]
    fn search_finds_minimum() {
        let mut cfg = SystemConfig::new(ModelProfile::opt_13b());
        cfg.t_p = 0.1;
        cfg.t_g = 0.025;
        // Overhead-free target so it matches the candidates' regime
        // (see the caveat on `min_replicas_for_goodput`).
        cfg.sched_time_scale = 0.0;
        let gen = TraceGen::new(TraceSpec::sharegpt());
        let items = gen.generate(200, 8.0, 4096, 13);
        let g2 = replicated_run(&cfg, "econoserve", "sharegpt", &items, true, 2, 300.0)
            .summary
            .goodput_rps;
        let k = min_replicas_for_goodput(
            &cfg,
            "econoserve",
            "sharegpt",
            &items,
            true,
            g2 * 0.9,
            4,
            300.0,
        )
        .expect("target must be feasible with 4 replicas");
        assert!(k <= 2, "k={k}");
    }
}
