//! Routers: the fleet front door's placement axis.
//!
//! A router sees a snapshot of every *routable* replica (Active — never
//! Booting or Draining; the sim enforces that invariant) at each arrival
//! and picks one. The menu mirrors the paper's multi-resource view at
//! fleet scale: queue-based steering balances compute pressure,
//! KVC-based steering balances the memory resource EconoServe's
//! single-replica scheduler fights for, and power-of-two-choices is the
//! classic low-coordination compromise.
//!
//! ## Health contract
//!
//! Under fault injection (`fleet::faults`) the snapshot set may include
//! crashed replicas with [`ReplicaSnapshot::healthy`] `= false`: a
//! health-aware fleet tells the truth, a health-blind one forges
//! `healthy = true` on corpses (modelling a control plane whose failure
//! detector is absent). Every router guarantees it never picks an
//! unhealthy replica while a healthy one exists; when the whole set is
//! unhealthy it degrades to its health-blind choice and the sim counts
//! the arrival as lost. With an all-healthy set each policy (including
//! the randomized one, draw for draw) is decision-identical to a fleet
//! without fault injection — the `"none"` profile changes nothing.

use crate::core::world::World;
use crate::kvc::{Allocator, ReserveClass};
use crate::util::rng::Rng;

/// Point-in-time view of one routable replica.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaSnapshot {
    /// Stable replica id (index into the fleet's replica table).
    pub id: usize,
    /// Arrived-and-unfinished requests on the replica (queued anywhere
    /// or executing — the same in-flight definition admission control
    /// uses).
    pub in_flight: usize,
    /// Free KVC tokens in the replica's normal (non-reserved) pool.
    pub free_kvc: u32,
    /// Total KVC capacity in tokens.
    pub kvc_capacity: u32,
    /// Health as reported by the fleet's failure detector. `false` only
    /// ever appears under fault injection with a health-aware control
    /// plane; see the module-level health contract.
    pub healthy: bool,
}

impl ReplicaSnapshot {
    /// Capture the routing-relevant state of one replica world — the
    /// single definition the fleet sim (routing + control ticks) and the
    /// `fleet_routing` bench all share. `healthy` is the failure
    /// detector's verdict, not derivable from the world itself.
    pub fn of_world(id: usize, w: &World, healthy: bool) -> Self {
        ReplicaSnapshot {
            id,
            in_flight: w.n_active(),
            free_kvc: w.kvc().free_tokens(ReserveClass::Normal),
            kvc_capacity: w.kvc().capacity_tokens(),
            healthy,
        }
    }
}

/// Placement policy: pick one of the routable replicas for an arrival.
/// `Send` is part of the contract (fleet runs are experiment-grid cells
/// that move across worker threads — see [`crate::exp`]).
pub trait Router: Send {
    fn name(&self) -> &'static str;

    /// Returns an index into `replicas` (guaranteed non-empty).
    fn route(&mut self, replicas: &[ReplicaSnapshot]) -> usize;
}

/// Router registry names (the `router=` axis of the fleet grammar).
pub fn all_routers() -> [&'static str; 4] {
    ["round-robin", "least-queue", "least-kvc", "power-of-two"]
}

/// Resolve a router by name. `seed` feeds the randomized policies
/// (derive it per fleet via `util::rng::derive_seed` so runs are
/// reproducible under any router).
pub fn by_name(name: &str, seed: u64) -> Option<Box<dyn Router>> {
    match name {
        "round-robin" => Some(Box::new(RoundRobin { next: 0 })),
        "least-queue" => Some(Box::new(LeastQueue)),
        "least-kvc" => Some(Box::new(LeastKvc)),
        "power-of-two" => Some(Box::new(PowerOfTwo { rng: Rng::new(seed) })),
        _ => None,
    }
}

/// Number of snapshot entries the failure detector reports healthy.
fn n_healthy(replicas: &[ReplicaSnapshot]) -> usize {
    replicas.iter().filter(|r| r.healthy).count()
}

/// Index of the k-th healthy entry (requires `k < n_healthy`). With an
/// all-healthy set this is the identity, which is what keeps every
/// policy decision-identical to the pre-fault-injection fleet.
fn kth_healthy(replicas: &[ReplicaSnapshot], k: usize) -> usize {
    replicas
        .iter()
        .enumerate()
        .filter(|(_, r)| r.healthy)
        .nth(k)
        .map(|(i, _)| i)
        .expect("kth_healthy past the healthy count")
}

/// Cycle through routable replicas in id order. With a static fleet this
/// reproduces the legacy `cluster::replicas` pre-sharding (shard
/// `i % k`), but decided *online* at arrival time, so it stays sane when
/// the routable set changes under autoscaling.
struct RoundRobin {
    next: usize,
}

impl Router for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, replicas: &[ReplicaSnapshot]) -> usize {
        let h = n_healthy(replicas);
        let pick =
            if h == 0 { self.next % replicas.len() } else { kth_healthy(replicas, self.next % h) };
        self.next = self.next.wrapping_add(1);
        pick
    }
}

/// Join the shortest queue (fewest in-flight requests; ties to the
/// lowest replica id). The paper's homogeneous cluster setup.
struct LeastQueue;

impl Router for LeastQueue {
    fn name(&self) -> &'static str {
        "least-queue"
    }

    fn route(&mut self, replicas: &[ReplicaSnapshot]) -> usize {
        let mut best = 0;
        for (i, r) in replicas.iter().enumerate().skip(1) {
            let b = &replicas[best];
            // Health dominates; among equals, fewest in-flight wins and
            // ties stay with the lowest id.
            if (r.healthy && !b.healthy) || (r.healthy == b.healthy && r.in_flight < b.in_flight)
            {
                best = i;
            }
        }
        best
    }
}

/// Steer to the replica with the most free KVC blocks — the fleet-level
/// analogue of the paper's multi-resource view: decode capacity is
/// KVC-bound long before it is compute-bound (Observation 1), so free
/// cache is the truthful congestion signal.
struct LeastKvc;

impl Router for LeastKvc {
    fn name(&self) -> &'static str {
        "least-kvc"
    }

    fn route(&mut self, replicas: &[ReplicaSnapshot]) -> usize {
        let mut best = 0;
        for (i, r) in replicas.iter().enumerate().skip(1) {
            // Health first; then most absolute free tokens; break ties
            // toward the shorter queue so an empty fleet still spreads
            // load.
            let b = &replicas[best];
            if (r.healthy && !b.healthy)
                || (r.healthy == b.healthy
                    && (r.free_kvc > b.free_kvc
                        || (r.free_kvc == b.free_kvc && r.in_flight < b.in_flight)))
            {
                best = i;
            }
        }
        best
    }
}

/// Power-of-two-choices: sample two distinct replicas, keep the one with
/// fewer in-flight requests. Near-optimal balance with O(1) state reads.
struct PowerOfTwo {
    rng: Rng,
}

impl Router for PowerOfTwo {
    fn name(&self) -> &'static str {
        "power-of-two"
    }

    fn route(&mut self, replicas: &[ReplicaSnapshot]) -> usize {
        // Sample within the healthy subset; an all-healthy set makes the
        // subset the whole slice, so the draws (and their count) match
        // the pre-fault-injection policy exactly.
        let h = n_healthy(replicas);
        if h == 0 {
            // Whole set unhealthy: degrade to the blind sample.
            let n = replicas.len();
            if n == 1 {
                return 0;
            }
            let a = self.rng.range_usize(0, n - 1);
            let mut b = self.rng.range_usize(0, n - 2);
            if b >= a {
                b += 1;
            }
            return if replicas[b].in_flight < replicas[a].in_flight { b } else { a };
        }
        if h == 1 {
            return kth_healthy(replicas, 0);
        }
        let a = self.rng.range_usize(0, h - 1);
        let mut b = self.rng.range_usize(0, h - 2);
        if b >= a {
            b += 1;
        }
        let (ia, ib) = (kth_healthy(replicas, a), kth_healthy(replicas, b));
        if replicas[ib].in_flight < replicas[ia].in_flight {
            ib
        } else {
            ia
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(id: usize, in_flight: usize, free_kvc: u32) -> ReplicaSnapshot {
        ReplicaSnapshot { id, in_flight, free_kvc, kvc_capacity: 1000, healthy: true }
    }

    fn corpse(id: usize) -> ReplicaSnapshot {
        // A dead replica looks maximally attractive to every load signal
        // (empty queue, empty cache) — exactly the trap the health
        // contract must beat.
        ReplicaSnapshot { id, in_flight: 0, free_kvc: 1000, kvc_capacity: 1000, healthy: false }
    }

    #[test]
    fn registry_resolves_all_names() {
        for name in all_routers() {
            let r = by_name(name, 1).unwrap();
            assert_eq!(r.name(), name);
        }
        assert!(by_name("shortest-job", 1).is_none());
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = by_name("round-robin", 0).unwrap();
        let reps = [snap(0, 0, 0), snap(1, 0, 0), snap(2, 0, 0)];
        let picks: Vec<usize> = (0..6).map(|_| r.route(&reps)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_queue_prefers_idle_replica() {
        let mut r = by_name("least-queue", 0).unwrap();
        let reps = [snap(0, 9, 0), snap(1, 2, 0), snap(2, 5, 0)];
        assert_eq!(r.route(&reps), 1);
    }

    #[test]
    fn least_kvc_prefers_free_cache() {
        let mut r = by_name("least-kvc", 0).unwrap();
        let reps = [snap(0, 1, 100), snap(1, 9, 800), snap(2, 1, 400)];
        assert_eq!(r.route(&reps), 1);
        // Ties break to the shorter queue.
        let reps = [snap(0, 5, 500), snap(1, 2, 500)];
        assert_eq!(r.route(&reps), 1);
    }

    #[test]
    fn power_of_two_balances_and_is_deterministic() {
        let reps = [snap(0, 100, 0), snap(1, 0, 0), snap(2, 100, 0)];
        let mut a = by_name("power-of-two", 7).unwrap();
        let mut b = by_name("power-of-two", 7).unwrap();
        let mut hits = 0;
        for _ in 0..200 {
            let pa = a.route(&reps);
            assert_eq!(pa, b.route(&reps), "same seed, same stream");
            if pa == 1 {
                hits += 1;
            }
        }
        // Replica 1 wins whenever it is sampled (~2/3 of draws).
        assert!(hits > 100, "hits={hits}");
    }

    #[test]
    fn no_router_picks_a_corpse_while_a_healthy_replica_exists() {
        // The corpse looks strictly better on every load signal; only
        // the health bit can save the arrival.
        let reps = [corpse(0), snap(1, 50, 10), corpse(2), snap(3, 80, 5)];
        for name in all_routers() {
            let mut r = by_name(name, 11).unwrap();
            for _ in 0..100 {
                let pick = r.route(&reps);
                assert!(reps[pick].healthy, "{name} routed to dead replica {pick}");
            }
        }
    }

    #[test]
    fn sole_survivor_gets_all_traffic() {
        let reps = [corpse(0), corpse(1), snap(2, 999, 0)];
        for name in all_routers() {
            let mut r = by_name(name, 3).unwrap();
            for _ in 0..20 {
                assert_eq!(r.route(&reps), 2, "{name}");
            }
        }
    }

    #[test]
    fn all_dead_set_still_returns_an_index() {
        // The sim counts these arrivals as lost; the router just must
        // not panic and must stay in bounds.
        let reps = [corpse(0), corpse(1)];
        for name in all_routers() {
            let mut r = by_name(name, 5).unwrap();
            for _ in 0..20 {
                assert!(r.route(&reps) < reps.len(), "{name}");
            }
        }
    }

    #[test]
    fn round_robin_cycles_over_survivors_only() {
        let mut r = by_name("round-robin", 0).unwrap();
        let reps = [snap(0, 0, 0), corpse(1), snap(2, 0, 0)];
        let picks: Vec<usize> = (0..6).map(|_| r.route(&reps)).collect();
        assert_eq!(picks, vec![0, 2, 0, 2, 0, 2]);
    }
}
