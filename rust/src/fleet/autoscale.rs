//! Autoscalers: the fleet's capacity axis.
//!
//! An autoscaler is consulted at every control tick with a fleet
//! observation and answers with the *desired serving size* (Active +
//! Booting replicas); the sim clamps it to `[min, max]`, boots new
//! replicas with the configured boot latency, and retires replicas by
//! drain-before-retire (no new routes, finish in-flight work, then
//! release the GPUs). Three policies:
//!
//!  * `static-k` — fixed fleet (the legacy Fig 12 capacity model);
//!  * `reactive` — threshold scaling on queue/KVC pressure with
//!    hysteresis (scale up near saturation, down when comfortably idle);
//!  * `forecast` — SageServe-style windowed arrival-rate forecasting:
//!    fits a short linear trend to recent arrival-rate buckets and
//!    provisions for the rate expected one boot-latency ahead, so
//!    capacity arrives *before* the ramp instead of after it.

use std::collections::VecDeque;

use super::router::ReplicaSnapshot;

/// Fleet state handed to the autoscaler at each control tick.
#[derive(Debug)]
pub struct ScaleObs<'a> {
    pub now: f64,
    /// Routable replicas (Active), in id order.
    pub active: &'a [ReplicaSnapshot],
    /// Replicas ordered but not yet routable.
    pub booting: usize,
    /// Replicas finishing their in-flight work before retirement.
    pub draining: usize,
    /// Replicas lost to faults since the previous control tick (crashes,
    /// zone outages, failed boots) — the drop in *effective* serving
    /// capacity a fault-aware policy should replace. Always 0 without
    /// fault injection.
    pub crashed: usize,
}

impl ScaleObs<'_> {
    /// Serving size: what the autoscaler's target is compared against.
    pub fn serving(&self) -> usize {
        self.active.len() + self.booting
    }
}

/// Capacity policy: desired serving-replica count per control tick.
/// `Send` is part of the contract (fleet runs are experiment-grid cells
/// that move across worker threads — see [`crate::exp`]).
pub trait Autoscaler: Send {
    fn name(&self) -> &'static str;

    /// Observe one routed arrival (feeds rate estimators; default no-op).
    fn on_arrival(&mut self, _t: f64) {}

    /// Desired serving size (Active + Booting), or `None` to hold. The
    /// sim clamps the answer to the fleet's `[min, max]` bounds.
    fn plan(&mut self, obs: &ScaleObs<'_>) -> Option<usize>;
}

/// Tuning shared by the scaling policies, derived once per fleet from
/// the system config and trace mix (see `FleetConfig::knobs`).
#[derive(Debug, Clone, Copy)]
pub struct ScaleKnobs {
    /// Comfortable resident-request ceiling of one replica (KVC tokens /
    /// expected per-request footprint) — normalizes queue pressure.
    pub resident_ceiling: f64,
    /// Sustainable serving rate of one replica (req/s).
    pub per_replica_rps: f64,
    /// Seconds between control ticks.
    pub control_interval: f64,
    /// Seconds from scale-up decision to a routable replica.
    pub boot_latency: f64,
}

/// Autoscaler registry names (the `autoscaler=` axis of the grammar).
pub fn all_autoscalers() -> [&'static str; 3] {
    ["static-k", "reactive", "forecast"]
}

/// Resolve an autoscaler by name with the given tuning.
pub fn by_name(name: &str, knobs: ScaleKnobs) -> Option<Box<dyn Autoscaler>> {
    match name {
        "static-k" => Some(Box::new(StaticK)),
        "reactive" => Some(Box::new(Reactive {
            knobs,
            pressure_hi: 0.70,
            pressure_lo: 0.20,
            kvc_hi: 0.85,
        })),
        "forecast" => Some(Box::new(Forecast::new(knobs))),
        _ => None,
    }
}

/// Fixed-size fleet: whatever was booted at t=0 stays.
struct StaticK;

impl Autoscaler for StaticK {
    fn name(&self) -> &'static str {
        "static-k"
    }

    fn plan(&mut self, _obs: &ScaleObs<'_>) -> Option<usize> {
        None
    }
}

/// Threshold scaling with hysteresis. Pressure is in-flight requests per
/// active replica normalized by the replica's resident ceiling — i.e.
/// "how full is the decode economy" — with KVC allocation as a second
/// trigger so memory saturation scales up even when queues look short.
struct Reactive {
    knobs: ScaleKnobs,
    pressure_hi: f64,
    pressure_lo: f64,
    kvc_hi: f64,
}

impl Autoscaler for Reactive {
    fn name(&self) -> &'static str {
        "reactive"
    }

    fn plan(&mut self, obs: &ScaleObs<'_>) -> Option<usize> {
        if obs.active.is_empty() {
            // Nothing to measure pressure on — but replicas lost to
            // faults must still be replaced, or a fully crashed fleet
            // would never recover.
            return if obs.crashed > 0 { Some(obs.serving() + obs.crashed) } else { None };
        }
        let inflight: usize = obs.active.iter().map(|r| r.in_flight).sum();
        let per = inflight as f64 / obs.active.len() as f64;
        let pressure = per / self.knobs.resident_ceiling.max(1.0);
        let kvc = obs
            .active
            .iter()
            .map(|r| 1.0 - r.free_kvc as f64 / r.kvc_capacity.max(1) as f64)
            .sum::<f64>()
            / obs.active.len() as f64;
        let serving = obs.serving();
        if pressure > self.pressure_hi || kvc > self.kvc_hi {
            // Replace fault losses on top of the pressure step, in one
            // tick — a crash under load must not cost an extra control
            // interval of under-capacity.
            Some(serving + 1 + obs.crashed)
        } else if obs.crashed > 0 {
            // No pressure signal (yet): still restore the effective
            // serving size the fleet had before the fault. Takes
            // priority over the scale-down branch so a crash never
            // coincides with a capacity cut.
            Some(serving + obs.crashed)
        } else if pressure < self.pressure_lo && kvc < self.kvc_hi * 0.5 {
            Some(serving.saturating_sub(1))
        } else {
            None
        }
    }
}

/// Windowed arrival-rate forecasting (after SageServe): bucket arrivals
/// at the control interval, fit a linear trend over the recent window,
/// and provision for the rate expected one boot-latency (plus one tick)
/// ahead at a target utilization — pre-booting ahead of ramps.
struct Forecast {
    knobs: ScaleKnobs,
    /// Completed-bucket arrival counts, oldest first: (bucket idx, n).
    counts: VecDeque<(u64, f64)>,
    window: usize,
    /// Target utilization of a replica's sustainable rate.
    headroom: f64,
}

impl Forecast {
    fn new(knobs: ScaleKnobs) -> Self {
        Forecast { knobs, counts: VecDeque::new(), window: 8, headroom: 0.75 }
    }

    fn bucket_of(&self, t: f64) -> u64 {
        (t / self.knobs.control_interval.max(1e-9)) as u64
    }

    /// Extend the bucket series (zero-filled) up to and including `idx`.
    fn tick_to(&mut self, idx: u64) {
        let mut next = match self.counts.back() {
            Some(&(last, _)) => last + 1,
            None => idx,
        };
        while next <= idx {
            self.counts.push_back((next, 0.0));
            next += 1;
        }
        // Keep the window plus the current (partial) bucket.
        while self.counts.len() > self.window + 1 {
            self.counts.pop_front();
        }
    }

    /// Predicted arrival rate `lead` seconds past `now`: max of the
    /// trend-line extrapolation and the latest complete bucket's rate
    /// (never scale down below what is arriving *right now*).
    fn predict(&mut self, now: f64) -> Option<f64> {
        self.tick_to(self.bucket_of(now));
        let dt = self.knobs.control_interval;
        // Exclude the current partial bucket from the fit.
        let cur = self.bucket_of(now);
        let pts: Vec<(f64, f64)> = self
            .counts
            .iter()
            .filter(|&&(i, _)| i < cur)
            .map(|&(i, n)| ((i as f64 + 0.5) * dt, n / dt))
            .collect();
        if pts.len() < 2 {
            return None;
        }
        let n = pts.len() as f64;
        let (sx, sy): (f64, f64) =
            pts.iter().fold((0.0, 0.0), |(a, b), &(x, y)| (a + x, b + y));
        let (mx, my) = (sx / n, sy / n);
        let (mut sxx, mut sxy) = (0.0, 0.0);
        for &(x, y) in &pts {
            sxx += (x - mx) * (x - mx);
            sxy += (x - mx) * (y - my);
        }
        let slope = if sxx > 1e-12 { sxy / sxx } else { 0.0 };
        let lead = self.knobs.boot_latency + dt;
        let trend = my + slope * (now + lead - mx);
        // Floor at the recent observed rate (two-bucket mean smooths the
        // per-bucket Poisson noise) so a noisy downward trend never
        // sheds capacity demand is still consuming.
        let latest = (pts[pts.len() - 1].1 + pts[pts.len() - 2].1) / 2.0;
        // Clamp the extrapolation: a two-point window can swing wildly.
        let cap = 2.0 * pts.iter().map(|&(_, y)| y).fold(0.0, f64::max);
        Some(trend.clamp(0.0, cap).max(latest))
    }
}

impl Autoscaler for Forecast {
    fn name(&self) -> &'static str {
        "forecast"
    }

    fn on_arrival(&mut self, t: f64) {
        self.tick_to(self.bucket_of(t));
        if let Some(back) = self.counts.back_mut() {
            back.1 += 1.0;
        }
    }

    fn plan(&mut self, obs: &ScaleObs<'_>) -> Option<usize> {
        let rate = self.predict(obs.now)?;
        let per = (self.knobs.per_replica_rps * self.headroom).max(1e-9);
        Some((rate / per).ceil().max(1.0) as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn knobs() -> ScaleKnobs {
        ScaleKnobs {
            resident_ceiling: 40.0,
            per_replica_rps: 5.0,
            control_interval: 5.0,
            boot_latency: 10.0,
        }
    }

    fn snap(in_flight: usize, free_kvc: u32) -> ReplicaSnapshot {
        ReplicaSnapshot { id: 0, in_flight, free_kvc, kvc_capacity: 1000, healthy: true }
    }

    #[test]
    fn registry_resolves_all_names() {
        for name in all_autoscalers() {
            assert_eq!(by_name(name, knobs()).unwrap().name(), name);
        }
        assert!(by_name("oracle", knobs()).is_none());
    }

    #[test]
    fn static_k_always_holds() {
        let mut s = by_name("static-k", knobs()).unwrap();
        let active = [snap(500, 0)];
        let obs = ScaleObs { now: 1.0, active: &active, booting: 0, draining: 0, crashed: 0 };
        assert_eq!(s.plan(&obs), None);
    }

    #[test]
    fn reactive_scales_with_pressure() {
        let mut s = by_name("reactive", knobs()).unwrap();
        // 35/40 resident: saturated, scale up.
        let hot = [snap(35, 100)];
        let obs = ScaleObs { now: 1.0, active: &hot, booting: 0, draining: 0, crashed: 0 };
        assert_eq!(s.plan(&obs), Some(2));
        // 2/40 resident and empty cache: scale down.
        let cold = [snap(2, 950), snap(1, 990)];
        let obs = ScaleObs { now: 2.0, active: &cold, booting: 0, draining: 0, crashed: 0 };
        assert_eq!(s.plan(&obs), Some(1));
        // Mid-band: hold.
        let mid = [snap(16, 500)];
        let obs = ScaleObs { now: 3.0, active: &mid, booting: 0, draining: 0, crashed: 0 };
        assert_eq!(s.plan(&obs), None);
    }

    #[test]
    fn reactive_scales_up_on_kvc_saturation_alone() {
        let mut s = by_name("reactive", knobs()).unwrap();
        let hot = [snap(4, 50)]; // short queue, 95% allocated cache
        let obs = ScaleObs { now: 1.0, active: &hot, booting: 1, draining: 0, crashed: 0 };
        assert_eq!(s.plan(&obs), Some(3), "booting replica counts toward serving");
    }

    #[test]
    fn reactive_replaces_fault_losses() {
        let mut s = by_name("reactive", knobs()).unwrap();
        // Mid-band load (would hold) + 2 replicas lost since last tick:
        // restore the pre-fault serving size.
        let mid = [snap(16, 500)];
        let obs = ScaleObs { now: 1.0, active: &mid, booting: 0, draining: 0, crashed: 2 };
        assert_eq!(s.plan(&obs), Some(3));
        // Saturated + a loss: pressure step and replacement in one tick.
        let hot = [snap(35, 100)];
        let obs = ScaleObs { now: 2.0, active: &hot, booting: 0, draining: 0, crashed: 1 };
        assert_eq!(s.plan(&obs), Some(3));
        // Whole fleet dead: still asks for the replacements.
        let obs = ScaleObs { now: 3.0, active: &[], booting: 0, draining: 0, crashed: 2 };
        assert_eq!(s.plan(&obs), Some(2));
    }

    #[test]
    fn forecast_preboots_ahead_of_a_ramp() {
        let k = knobs();
        let mut s = by_name("forecast", k).unwrap();
        // Ramp: bucket rates 1, 2, 3, 4 req/s over 4 complete buckets.
        let mut t = 0.0;
        for bucket in 0..4u64 {
            let n = bucket + 1;
            for j in 0..n * 5 {
                t = bucket as f64 * 5.0 + j as f64 * 5.0 / (n * 5) as f64;
                s.on_arrival(t);
            }
        }
        let active = [snap(5, 800)];
        let obs = ScaleObs { now: 20.0, active: &active, booting: 0, draining: 0, crashed: 0 };
        let want = s.plan(&obs).unwrap();
        // Trend reaches ~7 req/s one lead ahead; at 3.75 effective rps
        // per replica that is 2 replicas — more than the last bucket
        // alone (4 rps -> 2) would *not* show, so check the floor: the
        // forecaster must ask for at least the extrapolated demand.
        assert!(want >= 2, "want={want}");
        let _ = t;
    }

    #[test]
    fn forecast_holds_without_history() {
        let mut s = by_name("forecast", knobs()).unwrap();
        let active = [snap(0, 1000)];
        let obs = ScaleObs { now: 0.1, active: &active, booting: 0, draining: 0, crashed: 0 };
        assert_eq!(s.plan(&obs), None, "no complete buckets yet");
    }
}
