//! The fleet event loop: N replica steppers on a shared clock, a
//! routing front door, and autoscaler-driven replica lifecycle.
//!
//! The loop is discrete-event over six event sources — the next
//! arrival, the next boot completion, the next autoscaler control tick,
//! the next fault event (`fleet::faults`, when a profile is active),
//! the next straggler recovery, and the next reliability-guardrail
//! deadline (retry backoff expiry or hedge fire, when guardrails are
//! enabled). At each event time every live replica is advanced to the
//! event (via [`Stepper::advance_to`], whose idle clock is clamped to
//! the horizon so injections are never in a replica's past) —
//! concurrently across worker threads (`FleetConfig::threads`;
//! replicas are data-independent between events, so parallel stepping
//! is bit-identical to serial) — then the event is applied:
//!
//!  * **arrival** — snapshot the routable replicas, let the router pick
//!    one, inject the request at its true arrival time. Booting and
//!    draining replicas are *never* in the candidate set; crashed
//!    replicas appear only under fault injection, flagged unhealthy for
//!    a health-aware fleet and forged healthy for a health-blind one
//!    (see the health contract in [`super::router`]). A brownout
//!    guardrail gates admission before routing (tiered shedding under
//!    pressure, counted in `FaultTally::aborted`).
//!  * **boot completion** — `Booting -> Active`, or `-> Crashed` for a
//!    boot the fault injector doomed (the latency was burned, the
//!    replica never serves).
//!  * **control tick** — first the deadline-aware abort sweep (if
//!    enabled) cancels provably hopeless decodes and files them with
//!    the retry machinery; then the brownout controller re-reads fleet
//!    pressure; then the autoscaler is consulted: scale up by booting
//!    fresh replicas (`boot_latency` until routable, billed from the
//!    order), scale down by draining the least-loaded Active replicas
//!    (drain-before-retire: they finish in-flight work, then release
//!    their GPUs). Targets are clamped to `[min, max]`. The observation
//!    carries the replicas lost to faults since the previous tick, so
//!    fault-aware policies re-provision for *effective* capacity.
//!  * **fault event** — crash a replica (in-flight work re-routed,
//!    retried, or lost via [`crate::core::world::World::crash_all`]),
//!    crash a whole zone, or start a straggler episode (the replica's
//!    batch durations dilate by the profile factor until the episode
//!    ends). A health-aware fleet additionally boots replacements
//!    whenever the serving size falls below `min_replicas`.
//!  * **guardrail deadline** — a retry whose backoff expired is
//!    re-routed (original arrival, hence original SLO deadline), or a
//!    straggling request's hedge copy is launched on a second replica
//!    (first completion wins; the loser is cancelled as soon as it is
//!    safe to do so, releasing its KVC).
//!
//! Every guardrail decision is a pure function of (config, seed):
//! retries draw jitter from the dedicated
//! [`crate::util::rng::stream::GUARDRAILS`] stream, hedge/abort/
//! brownout decisions read simulation state that is thread-invariant,
//! and `guardrails == "off"` takes every guardrail branch out of the
//! loop — such runs are bit-identical to a fleet without the subsystem.

use std::collections::BTreeMap;

use crate::coordinator::Stepper;
use crate::core::ReqId;
use crate::reliability::{self, Brownout, DisplaceOrigin, GuardrailConfig, GuardrailStats};
use crate::telemetry::span::{to_us, TraceEvent, FLEET_TID};
use crate::telemetry::trace::{TraceDoc, TraceRecorder};
use crate::trace::TraceItem;
use crate::util::rng::{derive_seed, stream, Rng};
use crate::util::stats::Samples;

use super::autoscale::{self, ScaleObs};
use super::faults::{self, FaultKind, FaultTally, Injector};
use super::router::{self, ReplicaSnapshot};
use super::{FleetConfig, FleetResult, FleetSummary, ReplicaLog, ReplicaState};

struct Replica {
    stepper: Stepper,
    state: ReplicaState,
    log: ReplicaLog,
    /// Fault injector's verdict on this boot: the warm-up completes,
    /// then the replica lands Crashed instead of Active.
    doomed: bool,
    /// End of the current straggler episode (INFINITY = healthy speed).
    slow_until: f64,
}

impl Replica {
    fn boot(fc: &FleetConfig, id: usize, now: f64, latency: f64, doomed: bool) -> Self {
        let mut cfg = fc.cfg.clone();
        // Deterministic per-replica streams: replica i's predictor (and
        // any scheduler-internal randomness) is a pure function of
        // (base seed, i), independent of routing decisions.
        cfg.seed = derive_seed(fc.cfg.seed, stream::replica(id));
        let mut stepper = Stepper::new(cfg, &fc.system, &fc.trace, fc.oracle, &[]);
        stepper.sync_clock(now);
        if let Some(tc) = fc.tracing {
            stepper.world.enable_tracing(tc, id as u32, &fc.system);
        }
        if fc.reqlog_capacity > 0 {
            stepper.world.enable_reqlog(fc.reqlog_capacity);
        }
        Replica {
            stepper,
            state: if latency <= 0.0 { ReplicaState::Active } else { ReplicaState::Booting },
            log: ReplicaLog {
                ordered_at: now,
                routable_at: now + latency,
                drain_at: None,
                retired_at: None,
                routed: 0,
                first_routed_at: None,
                last_routed_at: None,
                crashed_at: None,
                rerouted: 0,
            },
            // An instant boot cannot fail: the failure lands at
            // `routable_at`, and a same-instant failure would let a
            // doomed-boot/replacement cycle spin without advancing time.
            doomed: doomed && latency > 0.0,
            slow_until: f64::INFINITY,
        }
    }

    fn snapshot(&self, id: usize, healthy: bool) -> ReplicaSnapshot {
        ReplicaSnapshot::of_world(id, &self.stepper.world, healthy)
    }

    /// Drain-before-retire completion: once a draining replica's last
    /// in-flight request finishes, release its GPUs. Billed until the
    /// actual completion time recovered from the records (the idle clock
    /// has since been dragged to the fleet horizon), never earlier than
    /// the drain decision at `fallback`.
    fn retire_if_drained(&mut self, fallback: f64) {
        if self.state != ReplicaState::Draining || !self.stepper.world.all_done() {
            return;
        }
        self.state = ReplicaState::Retired;
        let drained_at = self.log.drain_at.unwrap_or(fallback);
        let last_done = self
            .stepper
            .world
            .recs
            .iter()
            .filter_map(|rec| rec.done_at)
            .fold(drained_at, f64::max);
        self.log.retired_at = Some(last_done);
    }

    /// Kill this replica at `t`: terminal state, GPU billing stops, the
    /// world's unfinished requests come back as re-routable items (the
    /// caller decides re-route vs retry vs lost).
    fn crash(&mut self, t: f64) -> Vec<TraceItem> {
        self.state = ReplicaState::Crashed;
        self.log.crashed_at = Some(t);
        self.slow_until = f64::INFINITY;
        self.stepper.world.crash_all()
    }
}

/// Request lineage key (see [`reliability::lineage_key`]).
type Key = (u64, u32, u32);
/// Where one copy of a request lives: (replica index, request id on
/// that replica's world).
type Placement = (usize, ReqId);

/// A displaced request waiting out its retry backoff.
#[derive(Clone, Copy)]
struct RetryEntry {
    key: Key,
    item: TraceItem,
    origin: DisplaceOrigin,
    due: f64,
}

/// Lifecycle of one hedged request.
#[derive(Clone, Copy)]
enum HedgeState {
    /// Primary routed; the hedge copy fires at `fire_at` unless the
    /// primary completes first.
    Pending { item: TraceItem, fire_at: f64, primary: Placement },
    /// Both copies in flight; first completion wins.
    Outstanding { primary: Placement, hedge: Placement },
    /// One copy died with its replica; the survivor carries the request
    /// alone (its crash or completion settles the lineage).
    HalfDead { live: Placement, live_is_hedge: bool },
}

/// A loser copy that could not be cancelled yet (unsafe phase); retried
/// every iteration until the cancel lands or the copy terminates on its
/// own.
#[derive(Clone, Copy)]
struct PendingCancel {
    key: Key,
    target: Placement,
}

/// All guardrail state for one fleet run.
struct Guardrails {
    g: GuardrailConfig,
    /// Backoff jitter; dedicated stream so enabling guardrails never
    /// perturbs the fault or router timelines.
    rng: Rng,
    /// Retry attempts consumed per lineage (keys are item coordinates,
    /// so the map iterates deterministically).
    attempts: BTreeMap<Key, u32>,
    retry_q: Vec<RetryEntry>,
    /// Every (replica, id) a retry was injected at — scanned at the end
    /// for `FaultTally::recovered` (displaced requests that completed
    /// after a retry).
    retry_marks: Vec<Placement>,
    hedges: BTreeMap<Key, HedgeState>,
    cancels: Vec<PendingCancel>,
    brownout: Brownout,
    stats: GuardrailStats,
}

impl Guardrails {
    fn new(g: GuardrailConfig, seed: u64) -> Self {
        let brownout = Brownout::new(&g);
        Guardrails {
            g,
            rng: Rng::new(derive_seed(seed, stream::GUARDRAILS)),
            attempts: BTreeMap::new(),
            retry_q: Vec::new(),
            retry_marks: Vec::new(),
            hedges: BTreeMap::new(),
            cancels: Vec::new(),
            brownout,
            stats: GuardrailStats::default(),
        }
    }

    /// Earliest retry backoff expiry (an event source).
    fn next_retry_at(&self) -> f64 {
        self.retry_q.iter().map(|e| e.due).fold(f64::INFINITY, f64::min)
    }

    /// Earliest pending hedge fire (an event source).
    fn next_hedge_at(&self) -> f64 {
        if !self.g.hedge {
            return f64::INFINITY;
        }
        self.hedges
            .values()
            .filter_map(|st| match st {
                HedgeState::Pending { fire_at, .. } => Some(*fire_at),
                _ => None,
            })
            .fold(f64::INFINITY, f64::min)
    }
}

/// Settle one crash- or abort-displaced request against the guardrail
/// state: a pending loser-cancel is consumed by the death itself, a
/// hedge pair collapses to its survivor, and whatever remains is either
/// queued for a budgeted retry or settled terminally (legacy re-route /
/// `lost` for crashes, `aborted` for aborts).
///
/// Lineage keys are the item's immutable coordinates, so two distinct
/// requests with bit-identical (arrival, prompt_len, true_rl) would
/// share a lineage; fleet traces have continuous Poisson arrivals, so
/// collisions do not occur in practice (and the hedge map's
/// entry-or-insert guards the pathological case).
#[allow(clippy::too_many_arguments)]
fn handle_displaced(
    gr: &mut Guardrails,
    rid: usize,
    it: TraceItem,
    origin: DisplaceOrigin,
    t: f64,
    cfg: &crate::config::SystemConfig,
    do_reroute: bool,
    legacy_reroute: &mut Vec<TraceItem>,
    tally: &mut FaultTally,
) {
    let key = reliability::lineage_key(&it);

    // A loser copy awaiting cancellation: its death IS the cancel.
    if let Some(pos) = gr.cancels.iter().position(|c| c.key == key && c.target.0 == rid) {
        gr.cancels.remove(pos);
        gr.stats.hedges_lost += 1;
        return;
    }

    // Collapse the hedge pair, if this lineage has one.
    if let Some(state) = gr.hedges.get(&key).copied() {
        match state {
            HedgeState::Pending { primary, .. } => {
                if primary.0 == rid {
                    // Sole copy died before the hedge fired; the retry
                    // machinery below takes over.
                    gr.hedges.remove(&key);
                }
            }
            HedgeState::Outstanding { primary, hedge } => {
                if hedge.0 == rid {
                    // The hedge copy died; the primary carries on alone.
                    gr.stats.hedges_lost += 1;
                    gr.hedges
                        .insert(key, HedgeState::HalfDead { live: primary, live_is_hedge: false });
                    return;
                }
                if primary.0 == rid {
                    // The primary died; the hedge copy carries on alone.
                    gr.hedges
                        .insert(key, HedgeState::HalfDead { live: hedge, live_is_hedge: true });
                    return;
                }
            }
            HedgeState::HalfDead { live, live_is_hedge } => {
                if live.0 == rid {
                    // Both copies are now dead; exactly one displacement
                    // (this one) proceeds to the retry machinery.
                    if live_is_hedge {
                        gr.stats.hedges_lost += 1;
                    }
                    gr.hedges.remove(&key);
                }
            }
        }
    }

    if !gr.g.retry {
        match origin {
            // Without the retry guardrail, crash displacement follows
            // the legacy chaos-layer path exactly (immediate re-route
            // or lost) — enabling hedging alone must never downgrade a
            // crash-displaced request's handling.
            DisplaceOrigin::Crash if do_reroute => legacy_reroute.push(it),
            DisplaceOrigin::Crash => tally.lost += 1,
            DisplaceOrigin::Abort => {
                tally.aborted += 1;
                gr.stats.aborted_deadline += 1;
            }
        }
        return;
    }

    let k = gr.attempts.entry(key).or_insert(0);
    let deadline = it.arrival + cfg.slo_budget(it.true_rl);
    let feasible = origin == DisplaceOrigin::Crash
        || gr.g.retry_feasible(t, &it, cfg.t_p, cfg.t_g, deadline);
    if *k < gr.g.max_retries && feasible {
        *k += 1;
        let u = gr.rng.f64();
        let due = t + gr.g.backoff(*k - 1, u);
        gr.retry_q.push(RetryEntry { key, item: it, origin, due });
    } else {
        match origin {
            DisplaceOrigin::Crash => tally.lost += 1,
            DisplaceOrigin::Abort => {
                tally.aborted += 1;
                gr.stats.aborted_deadline += 1;
            }
        }
    }
}

/// Scan every hedge pair for completions (first finisher wins, the
/// loser is cancelled), then drive the pending loser-cancellations that
/// were waiting for a safe phase. Runs after each advance; reads and
/// mutates only single-threaded state, so the outcome is bit-identical
/// at any thread count.
fn resolve_hedges(gr: &mut Guardrails, replicas: &mut [Replica], tally: &mut FaultTally) {
    enum Act {
        Drop(Key),
        HalfLiveDone { key: Key, live_is_hedge: bool },
        PrimaryWon { key: Key, loser: Placement },
        HedgeWon { key: Key, loser: Placement },
        BothDone { key: Key, winner_is_hedge: bool, loser: Placement },
    }
    let mut acts: Vec<Act> = Vec::new();
    for (&key, st) in gr.hedges.iter() {
        match *st {
            HedgeState::Pending { primary, .. } => {
                if replicas[primary.0].stepper.world.recs[primary.1].done_at.is_some() {
                    acts.push(Act::Drop(key));
                }
            }
            HedgeState::Outstanding { primary, hedge } => {
                let pd = replicas[primary.0].stepper.world.recs[primary.1].done_at;
                let hd = replicas[hedge.0].stepper.world.recs[hedge.1].done_at;
                match (pd, hd) {
                    (Some(_), None) => acts.push(Act::PrimaryWon { key, loser: hedge }),
                    (None, Some(_)) => acts.push(Act::HedgeWon { key, loser: primary }),
                    (Some(p), Some(h)) => {
                        // Both copies finished inside one advance
                        // window: earlier completion wins, placement
                        // order breaks exact ties — deterministic
                        // either way.
                        let hedge_wins = h < p || (h == p && hedge < primary);
                        if hedge_wins {
                            acts.push(Act::BothDone { key, winner_is_hedge: true, loser: primary });
                        } else {
                            acts.push(Act::BothDone { key, winner_is_hedge: false, loser: hedge });
                        }
                    }
                    (None, None) => {}
                }
            }
            HedgeState::HalfDead { live, live_is_hedge } => {
                if replicas[live.0].stepper.world.recs[live.1].done_at.is_some() {
                    acts.push(Act::HalfLiveDone { key, live_is_hedge });
                }
            }
        }
    }
    for act in acts {
        match act {
            Act::Drop(key) => {
                gr.hedges.remove(&key);
            }
            Act::HalfLiveDone { key, live_is_hedge } => {
                if live_is_hedge {
                    tally.hedges_won += 1;
                }
                gr.hedges.remove(&key);
            }
            Act::PrimaryWon { key, loser } => {
                gr.hedges.remove(&key);
                gr.cancels.push(PendingCancel { key, target: loser });
            }
            Act::HedgeWon { key, loser } => {
                tally.hedges_won += 1;
                gr.hedges.remove(&key);
                gr.cancels.push(PendingCancel { key, target: loser });
            }
            Act::BothDone { key, winner_is_hedge, loser } => {
                if winner_is_hedge {
                    tally.hedges_won += 1;
                }
                // The loser's completion is voided (it stays terminal
                // but no longer counts as done); the completion
                // counters it already bumped are reconciled via
                // `hedges_total{outcome="duplicate"}`.
                replicas[loser.0].stepper.world.void_completion(loser.1);
                gr.stats.hedges_dup += 1;
                gr.hedges.remove(&key);
            }
        }
    }
    // Drive the pending cancels: each either lands now (safe phase),
    // resolves because the copy terminated on its own, or waits for a
    // later iteration.
    let mut idx = 0;
    while idx < gr.cancels.len() {
        let c = gr.cancels[idx];
        let r = &mut replicas[c.target.0];
        if r.state.is_terminal() {
            // Crash settled it (normally consumed by handle_displaced;
            // defensive for a crash landing after the pair resolved).
            gr.stats.hedges_lost += 1;
            gr.cancels.remove(idx);
            continue;
        }
        let world = &mut r.stepper.world;
        if world.recs[c.target.1].done_at.is_some() {
            // The loser outran the cancel and completed: a duplicate,
            // voided exactly like the same-window race above.
            world.void_completion(c.target.1);
            gr.stats.hedges_dup += 1;
            gr.cancels.remove(idx);
        } else if world.recs[c.target.1].is_done() {
            // Terminal without a completion (aborted elsewhere).
            gr.stats.hedges_lost += 1;
            gr.cancels.remove(idx);
        } else if world.cancel_if_safe(c.target.1) {
            gr.stats.hedges_lost += 1;
            gr.cancels.remove(idx);
        } else {
            idx += 1;
        }
    }
}

/// Minimum simulated seconds a replica must be behind the horizon
/// before its advance counts as parallel-worthy work. Fleet events
/// (arrivals, boots, control ticks) are often microseconds to
/// milliseconds apart — spawning scoped threads to advance replicas by
/// a sliver costs more than the sliver — so parallel stepping only
/// engages when at least two replicas have a real stretch to cover
/// (compare the coordinator's 0.05 s idle quantum). The gate reads
/// simulation state only, so it fires identically at any thread count.
const PAR_MIN_DELTA: f64 = 0.02;

/// Advance every non-terminal replica to `horizon` — in parallel when
/// more than one worker is available AND at least two live replicas are
/// more than [`PAR_MIN_DELTA`] behind the horizon (see above; tiny
/// deltas step serially to dodge thread spawn/join overhead on every
/// event). Replicas are data-independent between routing events
/// (injections and snapshots happen single-threaded in the event loop),
/// so the post-state is bit-identical at any thread count; `threads` is
/// purely a wall-clock knob. This loop is the fleet's dominant cost —
/// each replica runs its whole plan/price/apply iteration chain to the
/// horizon — and it is why [`crate::coordinator::Stepper`] (scheduler,
/// allocator, predictor boxes included) must be `Send`.
fn advance_live(replicas: &mut [Replica], horizon: f64, threads: usize) {
    if threads > 1 {
        let mut lagging = 0usize;
        for r in replicas.iter() {
            if !r.state.is_terminal() && horizon - r.stepper.world.clock > PAR_MIN_DELTA {
                lagging += 1;
                if lagging >= 2 {
                    break;
                }
            }
        }
        if lagging >= 2 {
            let mut live: Vec<&mut Replica> =
                replicas.iter_mut().filter(|r| !r.state.is_terminal()).collect();
            crate::exp::for_each_mut(&mut live, threads, |r| r.stepper.advance_to(horizon));
            return;
        }
    }
    // Serial fast path: in place, no allocation (the common case — and
    // the only case at threads == 1, keeping the PR 3 zero-allocation
    // property of the event loop intact).
    for r in replicas.iter_mut() {
        if !r.state.is_terminal() {
            r.stepper.advance_to(horizon);
        }
    }
}

/// Push one control-track instant (pid = the replica the event
/// concerns, tid = the reserved fleet-control track). No-op when
/// tracing is off, so the untraced loop carries zero overhead.
fn ctrl_instant(ctrl: &mut Option<Box<TraceRecorder>>, name: &'static str, t: f64, pid: usize) {
    if let Some(tr) = ctrl.as_mut() {
        tr.push_raw(TraceEvent::instant(name, to_us(t), pid as u32, FLEET_TID));
    }
}

/// Push one control-track span (boot warm-ups, drains).
fn ctrl_span(
    ctrl: &mut Option<Box<TraceRecorder>>,
    name: &'static str,
    t0: f64,
    t1: f64,
    pid: usize,
) {
    if let Some(tr) = ctrl.as_mut() {
        tr.push_raw(TraceEvent::span(name, to_us(t0), to_us(t1), pid as u32, FLEET_TID));
    }
}

/// Crash one replica and stage its unfinished requests, tagged with the
/// dead replica's index (the guardrail layer needs the provenance to
/// collapse hedge pairs); the caller settles them via
/// [`handle_displaced`] or the legacy re-route/lost path.
fn kill_replica(
    rid: usize,
    r: &mut Replica,
    t: f64,
    displaced: &mut Vec<(usize, TraceItem)>,
    tally: &mut FaultTally,
    ctrl: &mut Option<Box<TraceRecorder>>,
) {
    ctrl_instant(ctrl, "crash", t, rid);
    let lost = r.crash(t);
    displaced.extend(lost.into_iter().map(|it| (rid, it)));
    tally.crashes += 1;
}

/// Apply one fault event against the current replica table. Victim
/// resolution (`pick % candidates`) reads simulation state that is
/// thread-invariant, so the outcome is bit-identical at any thread
/// count. Returns how many replicas were killed by this event.
#[allow(clippy::too_many_arguments)]
fn apply_fault(
    ev: faults::FaultEvent,
    replicas: &mut [Replica],
    profile: &faults::FaultProfile,
    displaced: &mut Vec<(usize, TraceItem)>,
    tally: &mut FaultTally,
    t: f64,
    ctrl: &mut Option<Box<TraceRecorder>>,
) -> usize {
    let mut killed = 0usize;
    match ev.kind {
        FaultKind::Crash => {
            // One live (serving or draining) replica dies.
            let candidates: Vec<usize> = replicas
                .iter()
                .enumerate()
                .filter(|(_, r)| {
                    matches!(r.state, ReplicaState::Active | ReplicaState::Draining)
                })
                .map(|(id, _)| id)
                .collect();
            if let Some(&victim) =
                candidates.get((ev.pick % candidates.len().max(1) as u64) as usize)
            {
                kill_replica(victim, &mut replicas[victim], t, displaced, tally, ctrl);
                killed = 1;
            }
        }
        FaultKind::ZoneOutage => {
            // Every non-terminal replica in the zone dies, booting ones
            // included (a failure domain takes warm-ups down with it).
            tally.zone_outages += 1;
            let zone = (ev.pick % profile.zones.max(1) as u64) as usize;
            for (id, r) in replicas.iter_mut().enumerate() {
                if !r.state.is_terminal() && id % profile.zones.max(1) == zone {
                    kill_replica(id, r, t, displaced, tally, ctrl);
                    killed += 1;
                }
            }
        }
        FaultKind::Straggler => {
            // One Active replica runs slow for the episode.
            let candidates: Vec<usize> = replicas
                .iter()
                .enumerate()
                .filter(|(_, r)| r.state == ReplicaState::Active)
                .map(|(id, _)| id)
                .collect();
            if let Some(&victim) =
                candidates.get((ev.pick % candidates.len().max(1) as u64) as usize)
            {
                let r = &mut replicas[victim];
                r.stepper.set_slowdown(profile.straggle_factor);
                r.slow_until = t + profile.straggle_len;
                tally.stragglers += 1;
            }
        }
    }
    killed
}

/// Run a fleet over `items` (sorted by arrival, as every trace
/// generator produces them).
pub fn run(fc: &FleetConfig, items: &[TraceItem]) -> FleetResult {
    assert!(fc.min_replicas >= 1, "a fleet needs at least one replica");
    assert!(fc.min_replicas <= fc.max_replicas);
    assert!(
        fc.control_interval > 0.0,
        "control_interval must be positive (the event loop ticks on it)"
    );
    debug_assert!(items.windows(2).all(|w| w[0].arrival <= w[1].arrival));

    let mut router = router::by_name(&fc.router, derive_seed(fc.cfg.seed, stream::ROUTER))
        .unwrap_or_else(|| panic!("unknown router '{}'", fc.router));
    let mut scaler = autoscale::by_name(&fc.autoscaler, fc.knobs())
        .unwrap_or_else(|| panic!("unknown autoscaler '{}'", fc.autoscaler));
    let profile = faults::by_name(&fc.faults)
        .unwrap_or_else(|| panic!("unknown fault profile '{}'", fc.faults));
    // The "none" profile takes every chaos-gated branch out of the loop:
    // such runs are bit-identical to a fleet without fault injection.
    let chaos = profile.is_active();
    let mut injector = Injector::new(profile, derive_seed(fc.cfg.seed, stream::FAULTS));
    let mut tally = FaultTally::default();
    let guard = GuardrailConfig::parse(&fc.guardrails)
        .unwrap_or_else(|| panic!("unknown guardrail mode '{}'", fc.guardrails));
    // Like chaos above: "off" takes every guardrail branch out of the
    // loop, and the GUARDRAILS rng stream is never touched.
    let guard_on = guard.is_active();
    let mut gr = Guardrails::new(guard, fc.cfg.seed);
    let knobs = fc.knobs();
    // Whether crash-displaced work re-routes under the LEGACY path
    // (health-aware fleet + reroute profile); with the retry guardrail
    // the same displacements go through the backoff queue instead.
    let do_reroute = fc.health_aware && profile.reroute;
    // Replicas lost to faults since the last control tick (autoscaler
    // observation), the displaced staging buffer (tagged with the dead
    // replica), and the legacy re-route staging buffer.
    let mut crashed_since_tick = 0usize;
    let mut displaced: Vec<(usize, TraceItem)> = Vec::new();
    let mut reroute_buf: Vec<TraceItem> = Vec::new();

    // Concurrent stepping under MEASURED scheduler-time charging
    // (sched_time_scale > 0) would let CPU contention between replicas
    // bias the simulated clocks and make results thread-count-dependent
    // — so auto mode (threads == 0) stays serial for such configs, and
    // only an explicit threads > 1 request opts in (documented caveat
    // on `FleetConfig::threads`). Deterministic configs (scale == 0)
    // parallelize freely: thread count cannot change their results.
    let threads = if fc.cfg.sched_time_scale > 0.0 && fc.threads == 0 {
        1
    } else {
        crate::exp::resolve_threads(fc.threads)
    };
    // Fleet-control span recorder: routing/boot/crash/drain provenance
    // on the replicas' control tracks (plus brownout sheds, which have
    // no replica and ride on pid 0). Single-threaded like everything
    // else in the event loop, merged into the replica documents at
    // finalize — so the trace bytes stay thread-invariant.
    let mut ctrl: Option<Box<TraceRecorder>> =
        fc.tracing.map(|tc| Box::new(TraceRecorder::new(tc, 0, "fleet")));
    let init = fc.init_replicas.clamp(fc.min_replicas, fc.max_replicas);
    let mut replicas: Vec<Replica> =
        (0..init).map(|i| Replica::boot(fc, i, 0.0, 0.0, false)).collect();
    for id in 0..init {
        ctrl_span(&mut ctrl, "boot", 0.0, 0.0, id);
    }
    let mut boots = init;
    let mut routed = 0usize;
    let mut peak = init;
    let mut floor = init;
    let mut next_ctl = fc.control_interval;
    let mut i = 0usize;
    let mut clock = 0.0f64;
    let mut snaps: Vec<ReplicaSnapshot> = Vec::new();

    loop {
        let work_left = i < items.len()
            || replicas.iter().any(|r| !r.stepper.world.all_done())
            || (guard_on && !gr.retry_q.is_empty());
        if !work_left {
            break;
        }
        let t_arr = if i < items.len() { items[i].arrival } else { f64::INFINITY };
        let t_boot = replicas
            .iter()
            .filter(|r| r.state == ReplicaState::Booting)
            .map(|r| r.log.routable_at)
            .fold(f64::INFINITY, f64::min);
        let t_fault = if chaos { injector.next_at() } else { f64::INFINITY };
        let t_recover = replicas
            .iter()
            .filter(|r| !r.state.is_terminal())
            .map(|r| r.slow_until)
            .fold(f64::INFINITY, f64::min);
        let t_guard = if guard_on {
            gr.next_retry_at().min(gr.next_hedge_at())
        } else {
            f64::INFINITY
        };
        let t = t_arr
            .min(t_boot)
            .min(next_ctl)
            .min(t_fault)
            .min(t_recover)
            .min(t_guard)
            .max(clock);
        if t > fc.max_sim_time {
            advance_live(&mut replicas, fc.max_sim_time, threads);
            clock = clock.max(fc.max_sim_time);
            break;
        }
        clock = t;

        advance_live(&mut replicas, t, threads);
        for (id, r) in replicas.iter_mut().enumerate() {
            if r.state == ReplicaState::Booting && r.log.routable_at <= t {
                if r.doomed {
                    // The warm-up was paid for; the replica never
                    // serves. Counts toward the autoscaler's crash
                    // observation so the capacity is re-ordered.
                    r.state = ReplicaState::Crashed;
                    r.log.crashed_at = Some(r.log.routable_at);
                    tally.boot_failures += 1;
                    crashed_since_tick += 1;
                    ctrl_instant(&mut ctrl, "crash", r.log.routable_at, id);
                } else {
                    r.state = ReplicaState::Active;
                }
            }
            r.retire_if_drained(t);
        }

        // Settle hedge races from the advance just completed BEFORE new
        // faults land: a completion that beat a crash wins.
        if guard_on && gr.g.hedge {
            resolve_hedges(&mut gr, &mut replicas, &mut tally);
        }

        if chaos {
            // Straggler recoveries due at t come first, so an episode
            // scheduled to start at the same instant is not erased.
            for r in &mut replicas {
                if !r.state.is_terminal() && r.slow_until <= t {
                    r.stepper.set_slowdown(1.0);
                    r.slow_until = f64::INFINITY;
                }
            }
            while let Some(ev) = injector.pop_due(t) {
                let killed = apply_fault(
                    ev,
                    &mut replicas,
                    &profile,
                    &mut displaced,
                    &mut tally,
                    t,
                    &mut ctrl,
                );
                crashed_since_tick += killed;
            }
            // Settle crash-displaced requests: through the guardrail
            // machinery (hedge collapse + budgeted retries) when
            // enabled, else the legacy immediate re-route / lost path.
            for (rid, it) in std::mem::take(&mut displaced) {
                if guard_on {
                    handle_displaced(
                        &mut gr,
                        rid,
                        it,
                        DisplaceOrigin::Crash,
                        t,
                        &fc.cfg,
                        do_reroute,
                        &mut reroute_buf,
                        &mut tally,
                    );
                } else if do_reroute {
                    reroute_buf.push(it);
                } else {
                    tally.lost += 1;
                }
            }
            // Re-route requests caught on crashed replicas (health-aware
            // fleets with a reroute profile): each keeps its ORIGINAL
            // arrival, so `World::push_item` re-derives the same SLO
            // deadline (idempotent re-route). Counted in `rerouted`, not
            // `routed` — first-route accounting is untouched.
            for it in reroute_buf.drain(..) {
                snaps.clear();
                for (id, r) in replicas.iter().enumerate() {
                    if r.state == ReplicaState::Active {
                        snaps.push(r.snapshot(id, true));
                    }
                }
                if snaps.is_empty() {
                    tally.lost += 1;
                    continue;
                }
                let pick = snaps[router.route(&snaps)].id;
                ctrl_instant(&mut ctrl, "route", t, pick);
                let r = &mut replicas[pick];
                debug_assert_eq!(r.state, ReplicaState::Active);
                r.stepper.inject(&it);
                r.log.rerouted += 1;
                tally.rerouted += 1;
            }
            // A health-aware control plane notices the dead capacity
            // immediately and orders replacements up to the floor —
            // which may themselves be doomed (boot-failure retries).
            if fc.health_aware {
                let mut serving = replicas
                    .iter()
                    .filter(|r| {
                        matches!(r.state, ReplicaState::Active | ReplicaState::Booting)
                    })
                    .count();
                while serving < fc.min_replicas {
                    let id = replicas.len();
                    let doomed = injector.boot_fails();
                    replicas.push(Replica::boot(fc, id, t, fc.boot_latency, doomed));
                    ctrl_span(&mut ctrl, "boot", t, t + fc.boot_latency, id);
                    boots += 1;
                    serving += 1;
                }
            }
        }

        // Fire retries whose backoff expired. Runs outside the chaos
        // gate: abort-displaced retries exist without a fault profile.
        if guard_on && !gr.retry_q.is_empty() {
            let mut idx = 0;
            while idx < gr.retry_q.len() {
                if gr.retry_q[idx].due > t {
                    idx += 1;
                    continue;
                }
                let e = gr.retry_q.remove(idx);
                snaps.clear();
                for (id, r) in replicas.iter().enumerate() {
                    if r.state == ReplicaState::Active {
                        snaps.push(r.snapshot(id, true));
                    }
                }
                if snaps.is_empty() {
                    // Nowhere to land. Re-defer on a fresh backoff —
                    // consuming an attempt, so a dead fleet cannot spin
                    // a retry forever — or settle terminally.
                    let k = gr.attempts.entry(e.key).or_insert(0);
                    if *k < gr.g.max_retries {
                        *k += 1;
                        let u = gr.rng.f64();
                        let due = t + gr.g.backoff(*k - 1, u);
                        gr.retry_q.push(RetryEntry { due, ..e });
                    } else {
                        match e.origin {
                            DisplaceOrigin::Crash => tally.lost += 1,
                            DisplaceOrigin::Abort => {
                                tally.aborted += 1;
                                gr.stats.aborted_deadline += 1;
                            }
                        }
                    }
                    continue;
                }
                let pick = snaps[router.route(&snaps)].id;
                ctrl_instant(&mut ctrl, "retry", t, pick);
                let r = &mut replicas[pick];
                debug_assert_eq!(r.state, ReplicaState::Active);
                let id = r.stepper.inject(&e.item);
                r.log.rerouted += 1;
                tally.retried += 1;
                gr.retry_marks.push((pick, id));
            }
        }

        // Launch hedge copies whose straggler delay expired.
        if guard_on && gr.g.hedge {
            let due: Vec<Key> = gr
                .hedges
                .iter()
                .filter_map(|(&k, st)| match *st {
                    HedgeState::Pending { fire_at, .. } if fire_at <= t => Some(k),
                    _ => None,
                })
                .collect();
            for key in due {
                let Some(HedgeState::Pending { item, primary, .. }) =
                    gr.hedges.get(&key).copied()
                else {
                    continue;
                };
                if replicas[primary.0].stepper.world.recs[primary.1].done_at.is_some() {
                    gr.hedges.remove(&key);
                    continue;
                }
                snaps.clear();
                for (id, r) in replicas.iter().enumerate() {
                    if r.state == ReplicaState::Active && id != primary.0 {
                        snaps.push(r.snapshot(id, true));
                    }
                }
                if snaps.is_empty() {
                    // No second replica to hedge on: re-arm one delay
                    // out and re-check then.
                    gr.hedges.insert(
                        key,
                        HedgeState::Pending { item, fire_at: t + gr.g.hedge_delay, primary },
                    );
                    continue;
                }
                let pick = snaps[router.route(&snaps)].id;
                ctrl_instant(&mut ctrl, "hedge", t, pick);
                let r = &mut replicas[pick];
                let hid = r.stepper.inject(&item);
                r.log.rerouted += 1;
                gr.stats.hedges_launched += 1;
                gr.hedges.insert(key, HedgeState::Outstanding { primary, hedge: (pick, hid) });
            }
        }

        // Route every arrival due at this event time, re-snapshotting
        // between picks so balance-sensitive routers see their own
        // effect.
        while i < items.len() && items[i].arrival <= t {
            // The autoscaler observes OFFERED load — brownout-shed
            // arrivals included, so recovery capacity is provisioned
            // for the demand that will return.
            scaler.on_arrival(items[i].arrival);
            if guard_on && gr.g.brownout && !gr.brownout.admits(items[i].prompt_len) {
                // Tier 1 sheds the batch class, tier 2 rejects all. In
                // the served system this surfaces as HTTP 503 +
                // Retry-After; here the arrival is terminal.
                if let Some(tr) = ctrl.as_mut() {
                    // Shed before it ever got a request id: counted
                    // under the `brownout_shed` skip reason.
                    tr.shed(items[i].arrival);
                }
                tally.aborted += 1;
                gr.stats.aborted_brownout += 1;
                i += 1;
                continue;
            }
            snaps.clear();
            for (id, r) in replicas.iter().enumerate() {
                match r.state {
                    ReplicaState::Active => snaps.push(r.snapshot(id, true)),
                    // Under fault injection, crashed replicas stay in
                    // the routing table: a health-aware fleet sees the
                    // truth (and its routers skip them), a health-blind
                    // one sees a forged healthy bit — and a corpse
                    // looks idle, which is exactly the trap.
                    ReplicaState::Crashed if chaos => {
                        snaps.push(r.snapshot(id, !fc.health_aware))
                    }
                    _ => {}
                }
            }
            if snaps.is_empty() {
                assert!(chaos, "no routable replica (min_replicas >= 1)");
                // Whole fleet dead or booting: the arrival has nowhere
                // to go.
                tally.lost += 1;
                i += 1;
                continue;
            }
            let pick = snaps[router.route(&snaps)].id;
            ctrl_instant(&mut ctrl, "route", items[i].arrival, pick);
            let r = &mut replicas[pick];
            r.log.routed += 1;
            r.log.first_routed_at.get_or_insert(items[i].arrival);
            r.log.last_routed_at = Some(items[i].arrival);
            routed += 1;
            if r.state == ReplicaState::Active {
                let id = r.stepper.inject(&items[i]);
                if guard_on && gr.g.hedge {
                    // Arm the straggler hedge; `or_insert` guards the
                    // (trace-pathological) case of two requests with
                    // bit-identical coordinates.
                    gr.hedges.entry(reliability::lineage_key(&items[i])).or_insert(
                        HedgeState::Pending {
                            item: items[i],
                            fire_at: items[i].arrival + gr.g.hedge_delay,
                            primary: (pick, id),
                        },
                    );
                }
            } else {
                // Routed to a corpse (health-blind, or no survivor to
                // prefer): the request is gone.
                tally.lost += 1;
            }
            i += 1;
        }

        if next_ctl <= t {
            // Deadline-aware abort sweep first: cancelling provably
            // hopeless decodes frees KVC before the snapshot below, so
            // the brownout controller and autoscaler both see the
            // post-abort state.
            if guard_on && gr.g.abort {
                for rid in 0..replicas.len() {
                    if !matches!(
                        replicas[rid].state,
                        ReplicaState::Active | ReplicaState::Draining
                    ) {
                        continue;
                    }
                    let aborted =
                        replicas[rid].stepper.world.abort_hopeless(fc.oracle, gr.g.abort_slack);
                    displaced.extend(aborted.into_iter().map(|it| (rid, it)));
                }
                for (rid, it) in std::mem::take(&mut displaced) {
                    handle_displaced(
                        &mut gr,
                        rid,
                        it,
                        DisplaceOrigin::Abort,
                        t,
                        &fc.cfg,
                        do_reroute,
                        &mut reroute_buf,
                        &mut tally,
                    );
                }
            }
            snaps.clear();
            for (id, r) in replicas.iter().enumerate() {
                if r.state == ReplicaState::Active {
                    snaps.push(r.snapshot(id, true));
                }
            }
            if guard_on && gr.g.brownout {
                let p = reliability::fleet_pressure(&snaps, knobs.resident_ceiling);
                gr.brownout.update(p);
            }
            let booting =
                replicas.iter().filter(|r| r.state == ReplicaState::Booting).count();
            let draining =
                replicas.iter().filter(|r| r.state == ReplicaState::Draining).count();
            let obs = ScaleObs {
                now: t,
                active: &snaps,
                booting,
                draining,
                // A health-blind control plane is blind end to end: the
                // autoscaler is never told about crash losses either
                // (only ordinary pressure-driven scaling remains).
                crashed: if fc.health_aware { crashed_since_tick } else { 0 },
            };
            crashed_since_tick = 0;
            if let Some(target) = scaler.plan(&obs) {
                let target = target.clamp(fc.min_replicas, fc.max_replicas);
                let serving = snaps.len() + booting;
                if target > serving {
                    for _ in serving..target {
                        let id = replicas.len();
                        let doomed = chaos && injector.boot_fails();
                        replicas.push(Replica::boot(fc, id, t, fc.boot_latency, doomed));
                        ctrl_span(&mut ctrl, "boot", t, t + fc.boot_latency, id);
                        boots += 1;
                    }
                } else if target < serving {
                    // Drain Active replicas only (a boot in flight cannot
                    // be cancelled), least-loaded first, never below one
                    // routable replica.
                    let mut excess = serving - target;
                    let mut order: Vec<usize> = snaps.iter().map(|s| s.id).collect();
                    order.sort_by_key(|&id| replicas[id].stepper.world.n_active());
                    let mut active_left = snaps.len();
                    for id in order {
                        if excess == 0 || active_left <= 1 {
                            break;
                        }
                        replicas[id].state = ReplicaState::Draining;
                        replicas[id].log.drain_at = Some(t);
                        excess -= 1;
                        active_left -= 1;
                    }
                }
            }
            let serving_now = replicas
                .iter()
                .filter(|r| matches!(r.state, ReplicaState::Active | ReplicaState::Booting))
                .count();
            peak = peak.max(serving_now);
            floor = floor.min(serving_now);
            next_ctl += fc.control_interval;
        }
    }

    // Settle guardrail state left at exit: hedge races from the final
    // advance, then any retries still waiting out a backoff when the
    // trace ran dry or the cap hit (they settle terminally — there is
    // no later event to fire them).
    if guard_on {
        if gr.g.hedge {
            resolve_hedges(&mut gr, &mut replicas, &mut tally);
        }
        for e in gr.retry_q.drain(..) {
            match e.origin {
                DisplaceOrigin::Crash => tally.lost += 1,
                DisplaceOrigin::Abort => {
                    tally.aborted += 1;
                    gr.stats.aborted_deadline += 1;
                }
            }
        }
        tally.recovered = gr
            .retry_marks
            .iter()
            .filter(|&&(rid, id)| replicas[rid].stepper.world.recs[id].done_at.is_some())
            .count();
        gr.stats.brownout_peak = gr.brownout.peak();
        debug_assert_eq!(
            tally.aborted,
            gr.stats.aborted_deadline + gr.stats.aborted_brownout,
            "abort tally must decompose by reason"
        );
    }

    // Drains still pending at exit — ordered at the final control tick
    // (natural completion) or finishing during the final advance (cap
    // exit) — retire here so their GPU billing stops at the true finish
    // time and `retirements` stays consistent with the logs.
    for r in &mut replicas {
        r.retire_if_drained(clock);
    }

    finalize(
        fc,
        &mut replicas,
        items.len(),
        routed,
        clock,
        boots,
        peak,
        floor,
        tally,
        &gr.stats,
        ctrl,
    )
}

#[allow(clippy::too_many_arguments)]
fn finalize(
    fc: &FleetConfig,
    replicas: &mut [Replica],
    n_total: usize,
    n_routed: usize,
    end_time: f64,
    boots: usize,
    peak: usize,
    floor: usize,
    tally: FaultTally,
    gstats: &GuardrailStats,
    ctrl: Option<Box<TraceRecorder>>,
) -> FleetResult {
    let gpus = fc.cfg.profile.gpus_per_replica as f64;
    let mut jct = Samples::new();
    let mut n_done = 0usize;
    let mut slo_ok = 0usize;
    let mut last_done = 0.0f64;
    for r in replicas.iter() {
        // Requests lost to a crash carry `done_at = None` (no `jct()`),
        // so they are excluded here and count as SLO misses — and a
        // re-routed (or hedged: the loser's completion is voided)
        // request is only ever counted on the replica that actually
        // finished it.
        for rec in &r.stepper.world.recs {
            if let Some(j) = rec.jct() {
                n_done += 1;
                jct.push(j);
                if rec.met_slo() {
                    slo_ok += 1;
                }
                last_done = last_done.max(rec.done_at.unwrap_or(0.0));
            }
        }
    }
    // Fleet span: when the work actually finished (matching the legacy
    // per-shard semantics) for runs that completed everything; the last
    // event time for runs cut short by the sim-time cap (or with
    // requests lost to crashes).
    let finished = n_done == n_total && n_routed == n_total;
    let span = if finished && last_done > 0.0 {
        last_done
    } else {
        end_time.max(last_done)
    }
    .max(1e-9);
    let mut gpu_seconds = 0.0;
    let mut retirements = 0usize;
    let mut per_replica = Vec::with_capacity(replicas.len());
    let mut logs = Vec::with_capacity(replicas.len());
    for r in replicas.iter() {
        // A crashed replica's GPUs are released at the crash.
        let life_end = r.log.crashed_at.or(r.log.retired_at).unwrap_or(span);
        gpu_seconds += (life_end - r.log.ordered_at).max(0.0) * gpus;
        if r.log.retired_at.is_some() {
            retirements += 1;
        }
        per_replica.push(r.stepper.summary_at(span));
        logs.push(r.log.clone());
    }
    let gpu_hours = gpu_seconds / 3600.0;
    let metrics = fleet_metrics_text(replicas, boots, retirements, &tally, gstats);
    // Assemble the merged span trace: per-replica documents in
    // replica-id order (each named for Perfetto's track labels), then
    // the control recorder's routing/boot/crash/drain events, with
    // drain spans materialized from the lifecycle logs now that both
    // endpoints are known. Pure single-threaded bookkeeping over
    // thread-invariant state, so the bytes never depend on `threads`.
    let trace_doc = fc.tracing.map(|tc| {
        let mut doc = TraceDoc::new(tc.sample);
        for (id, r) in replicas.iter_mut().enumerate() {
            doc.name_process(id as u32, &format!("replica-{id}"));
            if let Some(d) = r.stepper.world.take_trace() {
                doc.merge(d);
            }
        }
        if let Some(mut tr) = ctrl {
            for (id, r) in replicas.iter().enumerate() {
                if let (Some(d0), Some(d1)) = (r.log.drain_at, r.log.retired_at) {
                    tr.push_raw(TraceEvent::span(
                        "drain",
                        to_us(d0),
                        to_us(d1),
                        id as u32,
                        FLEET_TID,
                    ));
                }
            }
            doc.merge(tr.finish());
        }
        doc
    });
    // Merged request-log JSONL, replica-id order, each line tagged with
    // the replica that served it (per-world ids collide across replicas).
    let reqlog = if fc.reqlog_capacity > 0 {
        let mut out = String::new();
        for (id, r) in replicas.iter().enumerate() {
            if let Some(log) = r.stepper.world.reqlog() {
                for ev in log.recent(usize::MAX) {
                    let line = ev.to_json_line();
                    out.push_str(&format!("{{\"replica\":{id},"));
                    out.push_str(&line[1..]);
                    out.push('\n');
                }
            }
        }
        Some(out)
    } else {
        None
    };
    FleetResult {
        summary: FleetSummary {
            n_total,
            n_routed,
            n_done,
            slo_ok,
            goodput_rps: slo_ok as f64 / span,
            throughput_rps: n_done as f64 / span,
            ssr: slo_ok as f64 / n_total.max(1) as f64,
            mean_jct: jct.mean(),
            p95_jct: jct.p95(),
            end_time: span,
            gpu_hours,
            goodput_per_gpu_hour: if gpu_hours > 0.0 {
                slo_ok as f64 / gpu_hours
            } else {
                0.0
            },
            peak_replicas: peak,
            floor_replicas: floor,
            mean_replicas: gpu_seconds / gpus / span,
            boots,
            retirements,
            faults: tally,
        },
        per_replica,
        replicas: logs,
        metrics,
        trace_doc,
        reqlog,
    }
}

/// Merge every replica's telemetry registry (in replica-id order — the
/// merge is commutative sample-addition, but a fixed order keeps the
/// code path itself deterministic) and overlay the fleet-level counters
/// written from the authoritative tallies. Each replica's registry was
/// only ever touched by its own single-threaded world, so the rendered
/// text is a pure function of (config, seed): bit-identical at any
/// thread count — `tests/equivalence.rs` pins this.
fn fleet_metrics_text(
    replicas: &[Replica],
    boots: usize,
    retirements: usize,
    tally: &FaultTally,
    gstats: &GuardrailStats,
) -> String {
    use crate::telemetry::{FleetMetrics, Snapshot};
    let mut merged: Option<Snapshot> = None;
    for r in replicas {
        let snap = Snapshot::parse(&r.stepper.metrics_text())
            .expect("registry render is valid exposition text");
        match &mut merged {
            None => merged = Some(snap),
            Some(m) => m.merge(&snap).expect("replica registries share one vocabulary"),
        }
    }
    let fleet = FleetMetrics::on(crate::telemetry::Registry::new());
    fleet.crashes.add(tally.crashes as u64);
    fleet.zone_outages.add(tally.zone_outages as u64);
    fleet.stragglers.add(tally.stragglers as u64);
    fleet.boot_failures.add(tally.boot_failures as u64);
    fleet.requests_lost.add(tally.lost as u64);
    fleet.reroutes.add(tally.rerouted as u64);
    fleet.boots.add(boots as u64);
    fleet.retirements.add(retirements as u64);
    fleet.retries.add(tally.retried as u64);
    fleet.hedges_launched.add(gstats.hedges_launched as u64);
    fleet.hedges_won.add(tally.hedges_won as u64);
    fleet.hedges_lost.add(gstats.hedges_lost as u64);
    fleet.hedges_dup.add(gstats.hedges_dup as u64);
    fleet.aborts_deadline.add(gstats.aborted_deadline as u64);
    fleet.aborts_brownout.add(gstats.aborted_brownout as u64);
    fleet.brownout_level.set(gstats.brownout_peak as f64);
    let fleet_snap = Snapshot::parse(&fleet.registry().render())
        .expect("fleet registry render is valid exposition text");
    match merged {
        None => fleet_snap.render(),
        Some(mut m) => {
            m.merge(&fleet_snap).expect("fleet families are disjoint from sim families");
            m.render()
        }
    }
}
