//! The fleet event loop: N replica steppers on a shared clock, a
//! routing front door, and autoscaler-driven replica lifecycle.
//!
//! The loop is discrete-event over three event sources — the next
//! arrival, the next boot completion, and the next autoscaler control
//! tick. At each event time every live replica is advanced to the event
//! (via [`Stepper::advance_to`], whose idle clock is clamped to the
//! horizon so injections are never in a replica's past) — concurrently
//! across worker threads (`FleetConfig::threads`; replicas are
//! data-independent between events, so parallel stepping is
//! bit-identical to serial) — then the event is applied:
//!
//!  * **arrival** — snapshot the Active replicas, let the router pick
//!    one, inject the request at its true arrival time. Booting and
//!    draining replicas are *never* in the candidate set.
//!  * **boot completion** — `Booting -> Active`.
//!  * **control tick** — consult the autoscaler; scale up by booting
//!    fresh replicas (`boot_latency` until routable, billed from the
//!    order), scale down by draining the least-loaded Active replicas
//!    (drain-before-retire: they finish in-flight work, then release
//!    their GPUs). Targets are clamped to `[min, max]`.

use crate::coordinator::Stepper;
use crate::trace::TraceItem;
use crate::util::rng::derive_seed;
use crate::util::stats::Samples;

use super::autoscale::{self, ScaleObs};
use super::router::{self, ReplicaSnapshot};
use super::{FleetConfig, FleetResult, FleetSummary, ReplicaLog, ReplicaState};

/// Seed stream for the router's RNG (replica streams are `1 + id`).
const ROUTER_STREAM: u64 = 0xF1EE7;

struct Replica {
    stepper: Stepper,
    state: ReplicaState,
    log: ReplicaLog,
}

impl Replica {
    fn boot(fc: &FleetConfig, id: usize, now: f64, latency: f64) -> Self {
        let mut cfg = fc.cfg.clone();
        // Deterministic per-replica streams: replica i's predictor (and
        // any scheduler-internal randomness) is a pure function of
        // (base seed, i), independent of routing decisions.
        cfg.seed = derive_seed(fc.cfg.seed, 1 + id as u64);
        let mut stepper = Stepper::new(cfg, &fc.system, &fc.trace, fc.oracle, &[]);
        stepper.sync_clock(now);
        Replica {
            stepper,
            state: if latency <= 0.0 { ReplicaState::Active } else { ReplicaState::Booting },
            log: ReplicaLog {
                ordered_at: now,
                routable_at: now + latency,
                drain_at: None,
                retired_at: None,
                routed: 0,
                first_routed_at: None,
                last_routed_at: None,
            },
        }
    }

    fn snapshot(&self, id: usize) -> ReplicaSnapshot {
        ReplicaSnapshot::of_world(id, &self.stepper.world)
    }

    /// Drain-before-retire completion: once a draining replica's last
    /// in-flight request finishes, release its GPUs. Billed until the
    /// actual completion time recovered from the records (the idle clock
    /// has since been dragged to the fleet horizon), never earlier than
    /// the drain decision at `fallback`.
    fn retire_if_drained(&mut self, fallback: f64) {
        if self.state != ReplicaState::Draining || !self.stepper.world.all_done() {
            return;
        }
        self.state = ReplicaState::Retired;
        let drained_at = self.log.drain_at.unwrap_or(fallback);
        let last_done = self
            .stepper
            .world
            .recs
            .iter()
            .filter_map(|rec| rec.done_at)
            .fold(drained_at, f64::max);
        self.log.retired_at = Some(last_done);
    }
}

/// Minimum simulated seconds a replica must be behind the horizon
/// before its advance counts as parallel-worthy work. Fleet events
/// (arrivals, boots, control ticks) are often microseconds to
/// milliseconds apart — spawning scoped threads to advance replicas by
/// a sliver costs more than the sliver — so parallel stepping only
/// engages when at least two replicas have a real stretch to cover
/// (compare the coordinator's 0.05 s idle quantum). The gate reads
/// simulation state only, so it fires identically at any thread count.
const PAR_MIN_DELTA: f64 = 0.02;

/// Advance every non-retired replica to `horizon` — in parallel when
/// more than one worker is available AND at least two live replicas are
/// more than [`PAR_MIN_DELTA`] behind the horizon (see above; tiny
/// deltas step serially to dodge thread spawn/join overhead on every
/// event). Replicas are data-independent between routing events
/// (injections and snapshots happen single-threaded in the event loop),
/// so the post-state is bit-identical at any thread count; `threads` is
/// purely a wall-clock knob. This loop is the fleet's dominant cost —
/// each replica runs its whole plan/price/apply iteration chain to the
/// horizon — and it is why [`crate::coordinator::Stepper`] (scheduler,
/// allocator, predictor boxes included) must be `Send`.
fn advance_live(replicas: &mut [Replica], horizon: f64, threads: usize) {
    if threads > 1 {
        let mut lagging = 0usize;
        for r in replicas.iter() {
            if r.state != ReplicaState::Retired
                && horizon - r.stepper.world.clock > PAR_MIN_DELTA
            {
                lagging += 1;
                if lagging >= 2 {
                    break;
                }
            }
        }
        if lagging >= 2 {
            let mut live: Vec<&mut Replica> = replicas
                .iter_mut()
                .filter(|r| r.state != ReplicaState::Retired)
                .collect();
            crate::exp::for_each_mut(&mut live, threads, |r| r.stepper.advance_to(horizon));
            return;
        }
    }
    // Serial fast path: in place, no allocation (the common case — and
    // the only case at threads == 1, keeping the PR 3 zero-allocation
    // property of the event loop intact).
    for r in replicas.iter_mut() {
        if r.state != ReplicaState::Retired {
            r.stepper.advance_to(horizon);
        }
    }
}

/// Run a fleet over `items` (sorted by arrival, as every trace
/// generator produces them).
pub fn run(fc: &FleetConfig, items: &[TraceItem]) -> FleetResult {
    assert!(fc.min_replicas >= 1, "a fleet needs at least one replica");
    assert!(fc.min_replicas <= fc.max_replicas);
    assert!(
        fc.control_interval > 0.0,
        "control_interval must be positive (the event loop ticks on it)"
    );
    debug_assert!(items.windows(2).all(|w| w[0].arrival <= w[1].arrival));

    let mut router = router::by_name(&fc.router, derive_seed(fc.cfg.seed, ROUTER_STREAM))
        .unwrap_or_else(|| panic!("unknown router '{}'", fc.router));
    let mut scaler = autoscale::by_name(&fc.autoscaler, fc.knobs())
        .unwrap_or_else(|| panic!("unknown autoscaler '{}'", fc.autoscaler));

    // Concurrent stepping under MEASURED scheduler-time charging
    // (sched_time_scale > 0) would let CPU contention between replicas
    // bias the simulated clocks and make results thread-count-dependent
    // — so auto mode (threads == 0) stays serial for such configs, and
    // only an explicit threads > 1 request opts in (documented caveat
    // on `FleetConfig::threads`). Deterministic configs (scale == 0)
    // parallelize freely: thread count cannot change their results.
    let threads = if fc.cfg.sched_time_scale > 0.0 && fc.threads == 0 {
        1
    } else {
        crate::exp::resolve_threads(fc.threads)
    };
    let init = fc.init_replicas.clamp(fc.min_replicas, fc.max_replicas);
    let mut replicas: Vec<Replica> =
        (0..init).map(|i| Replica::boot(fc, i, 0.0, 0.0)).collect();
    let mut boots = init;
    let mut peak = init;
    let mut floor = init;
    let mut next_ctl = fc.control_interval;
    let mut i = 0usize;
    let mut clock = 0.0f64;
    let mut snaps: Vec<ReplicaSnapshot> = Vec::new();

    loop {
        let work_left =
            i < items.len() || replicas.iter().any(|r| !r.stepper.world.all_done());
        if !work_left {
            break;
        }
        let t_arr = if i < items.len() { items[i].arrival } else { f64::INFINITY };
        let t_boot = replicas
            .iter()
            .filter(|r| r.state == ReplicaState::Booting)
            .map(|r| r.log.routable_at)
            .fold(f64::INFINITY, f64::min);
        let t = t_arr.min(t_boot).min(next_ctl).max(clock);
        if t > fc.max_sim_time {
            advance_live(&mut replicas, fc.max_sim_time, threads);
            clock = clock.max(fc.max_sim_time);
            break;
        }
        clock = t;

        advance_live(&mut replicas, t, threads);
        for r in &mut replicas {
            if r.state == ReplicaState::Booting && r.log.routable_at <= t {
                r.state = ReplicaState::Active;
            }
            r.retire_if_drained(t);
        }

        // Route every arrival due at this event time, re-snapshotting
        // between picks so balance-sensitive routers see their own
        // effect.
        while i < items.len() && items[i].arrival <= t {
            snaps.clear();
            for (id, r) in replicas.iter().enumerate() {
                if r.state == ReplicaState::Active {
                    snaps.push(r.snapshot(id));
                }
            }
            assert!(!snaps.is_empty(), "no routable replica (min_replicas >= 1)");
            let pick = snaps[router.route(&snaps)].id;
            let r = &mut replicas[pick];
            r.stepper.inject(&items[i]);
            r.log.routed += 1;
            r.log.first_routed_at.get_or_insert(items[i].arrival);
            r.log.last_routed_at = Some(items[i].arrival);
            scaler.on_arrival(items[i].arrival);
            i += 1;
        }

        if next_ctl <= t {
            snaps.clear();
            for (id, r) in replicas.iter().enumerate() {
                if r.state == ReplicaState::Active {
                    snaps.push(r.snapshot(id));
                }
            }
            let booting =
                replicas.iter().filter(|r| r.state == ReplicaState::Booting).count();
            let draining =
                replicas.iter().filter(|r| r.state == ReplicaState::Draining).count();
            let obs = ScaleObs { now: t, active: &snaps, booting, draining };
            if let Some(target) = scaler.plan(&obs) {
                let target = target.clamp(fc.min_replicas, fc.max_replicas);
                let serving = snaps.len() + booting;
                if target > serving {
                    for _ in serving..target {
                        let id = replicas.len();
                        replicas.push(Replica::boot(fc, id, t, fc.boot_latency));
                        boots += 1;
                    }
                } else if target < serving {
                    // Drain Active replicas only (a boot in flight cannot
                    // be cancelled), least-loaded first, never below one
                    // routable replica.
                    let mut excess = serving - target;
                    let mut order: Vec<usize> = snaps.iter().map(|s| s.id).collect();
                    order.sort_by_key(|&id| replicas[id].stepper.world.n_active());
                    let mut active_left = snaps.len();
                    for id in order {
                        if excess == 0 || active_left <= 1 {
                            break;
                        }
                        replicas[id].state = ReplicaState::Draining;
                        replicas[id].log.drain_at = Some(t);
                        excess -= 1;
                        active_left -= 1;
                    }
                }
            }
            let serving_now = replicas
                .iter()
                .filter(|r| matches!(r.state, ReplicaState::Active | ReplicaState::Booting))
                .count();
            peak = peak.max(serving_now);
            floor = floor.min(serving_now);
            next_ctl += fc.control_interval;
        }
    }

    // Drains still pending at exit — ordered at the final control tick
    // (natural completion) or finishing during the final advance (cap
    // exit) — retire here so their GPU billing stops at the true finish
    // time and `retirements` stays consistent with the logs.
    for r in &mut replicas {
        r.retire_if_drained(clock);
    }

    finalize(fc, &replicas, items.len(), i, clock, boots, peak, floor)
}

#[allow(clippy::too_many_arguments)]
fn finalize(
    fc: &FleetConfig,
    replicas: &[Replica],
    n_total: usize,
    n_routed: usize,
    end_time: f64,
    boots: usize,
    peak: usize,
    floor: usize,
) -> FleetResult {
    let gpus = fc.cfg.profile.gpus_per_replica as f64;
    let mut jct = Samples::new();
    let mut n_done = 0usize;
    let mut slo_ok = 0usize;
    let mut last_done = 0.0f64;
    for r in replicas {
        for rec in &r.stepper.world.recs {
            if let Some(j) = rec.jct() {
                n_done += 1;
                jct.push(j);
                if rec.met_slo() {
                    slo_ok += 1;
                }
                last_done = last_done.max(rec.done_at.unwrap_or(0.0));
            }
        }
    }
    // Fleet span: when the work actually finished (matching the legacy
    // per-shard semantics) for runs that completed everything; the last
    // event time for runs cut short by the sim-time cap.
    let finished = n_done == n_total && n_routed == n_total;
    let span = if finished && last_done > 0.0 {
        last_done
    } else {
        end_time.max(last_done)
    }
    .max(1e-9);
    let mut gpu_seconds = 0.0;
    let mut retirements = 0usize;
    let mut per_replica = Vec::with_capacity(replicas.len());
    let mut logs = Vec::with_capacity(replicas.len());
    for r in replicas {
        let life_end = r.log.retired_at.unwrap_or(span);
        gpu_seconds += (life_end - r.log.ordered_at).max(0.0) * gpus;
        if r.log.retired_at.is_some() {
            retirements += 1;
        }
        per_replica.push(r.stepper.summary_at(span));
        logs.push(r.log.clone());
    }
    let gpu_hours = gpu_seconds / 3600.0;
    FleetResult {
        summary: FleetSummary {
            n_total,
            n_routed,
            n_done,
            slo_ok,
            goodput_rps: slo_ok as f64 / span,
            throughput_rps: n_done as f64 / span,
            ssr: slo_ok as f64 / n_total.max(1) as f64,
            mean_jct: jct.mean(),
            p95_jct: jct.p95(),
            end_time: span,
            gpu_hours,
            goodput_per_gpu_hour: if gpu_hours > 0.0 {
                slo_ok as f64 / gpu_hours
            } else {
                0.0
            },
            peak_replicas: peak,
            floor_replicas: floor,
            mean_replicas: gpu_seconds / gpus / span,
            boots,
            retirements,
        },
        per_replica,
        replicas: logs,
    }
}
