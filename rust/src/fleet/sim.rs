//! The fleet event loop: N replica steppers on a shared clock, a
//! routing front door, and autoscaler-driven replica lifecycle.
//!
//! The loop is discrete-event over five event sources — the next
//! arrival, the next boot completion, the next autoscaler control tick,
//! the next fault event (`fleet::faults`, when a profile is active), and
//! the next straggler recovery. At each event time every live replica is
//! advanced to the event (via [`Stepper::advance_to`], whose idle clock
//! is clamped to the horizon so injections are never in a replica's
//! past) — concurrently across worker threads (`FleetConfig::threads`;
//! replicas are data-independent between events, so parallel stepping is
//! bit-identical to serial) — then the event is applied:
//!
//!  * **arrival** — snapshot the routable replicas, let the router pick
//!    one, inject the request at its true arrival time. Booting and
//!    draining replicas are *never* in the candidate set; crashed
//!    replicas appear only under fault injection, flagged unhealthy for
//!    a health-aware fleet and forged healthy for a health-blind one
//!    (see the health contract in [`super::router`]).
//!  * **boot completion** — `Booting -> Active`, or `-> Crashed` for a
//!    boot the fault injector doomed (the latency was burned, the
//!    replica never serves).
//!  * **control tick** — consult the autoscaler; scale up by booting
//!    fresh replicas (`boot_latency` until routable, billed from the
//!    order), scale down by draining the least-loaded Active replicas
//!    (drain-before-retire: they finish in-flight work, then release
//!    their GPUs). Targets are clamped to `[min, max]`. The observation
//!    carries the replicas lost to faults since the previous tick, so
//!    fault-aware policies re-provision for *effective* capacity.
//!  * **fault event** — crash a replica (in-flight work re-routed or
//!    lost via [`crate::core::world::World::crash_all`]), crash a whole
//!    zone, or start a straggler episode (the replica's batch durations
//!    dilate by the profile factor until the episode ends). A
//!    health-aware fleet additionally boots replacements whenever the
//!    serving size falls below `min_replicas`.

use crate::coordinator::Stepper;
use crate::trace::TraceItem;
use crate::util::rng::{derive_seed, stream};
use crate::util::stats::Samples;

use super::autoscale::{self, ScaleObs};
use super::faults::{self, FaultKind, FaultTally, Injector};
use super::router::{self, ReplicaSnapshot};
use super::{FleetConfig, FleetResult, FleetSummary, ReplicaLog, ReplicaState};

struct Replica {
    stepper: Stepper,
    state: ReplicaState,
    log: ReplicaLog,
    /// Fault injector's verdict on this boot: the warm-up completes,
    /// then the replica lands Crashed instead of Active.
    doomed: bool,
    /// End of the current straggler episode (INFINITY = healthy speed).
    slow_until: f64,
}

impl Replica {
    fn boot(fc: &FleetConfig, id: usize, now: f64, latency: f64, doomed: bool) -> Self {
        let mut cfg = fc.cfg.clone();
        // Deterministic per-replica streams: replica i's predictor (and
        // any scheduler-internal randomness) is a pure function of
        // (base seed, i), independent of routing decisions.
        cfg.seed = derive_seed(fc.cfg.seed, stream::replica(id));
        let mut stepper = Stepper::new(cfg, &fc.system, &fc.trace, fc.oracle, &[]);
        stepper.sync_clock(now);
        Replica {
            stepper,
            state: if latency <= 0.0 { ReplicaState::Active } else { ReplicaState::Booting },
            log: ReplicaLog {
                ordered_at: now,
                routable_at: now + latency,
                drain_at: None,
                retired_at: None,
                routed: 0,
                first_routed_at: None,
                last_routed_at: None,
                crashed_at: None,
                rerouted: 0,
            },
            // An instant boot cannot fail: the failure lands at
            // `routable_at`, and a same-instant failure would let a
            // doomed-boot/replacement cycle spin without advancing time.
            doomed: doomed && latency > 0.0,
            slow_until: f64::INFINITY,
        }
    }

    fn snapshot(&self, id: usize, healthy: bool) -> ReplicaSnapshot {
        ReplicaSnapshot::of_world(id, &self.stepper.world, healthy)
    }

    /// Drain-before-retire completion: once a draining replica's last
    /// in-flight request finishes, release its GPUs. Billed until the
    /// actual completion time recovered from the records (the idle clock
    /// has since been dragged to the fleet horizon), never earlier than
    /// the drain decision at `fallback`.
    fn retire_if_drained(&mut self, fallback: f64) {
        if self.state != ReplicaState::Draining || !self.stepper.world.all_done() {
            return;
        }
        self.state = ReplicaState::Retired;
        let drained_at = self.log.drain_at.unwrap_or(fallback);
        let last_done = self
            .stepper
            .world
            .recs
            .iter()
            .filter_map(|rec| rec.done_at)
            .fold(drained_at, f64::max);
        self.log.retired_at = Some(last_done);
    }

    /// Kill this replica at `t`: terminal state, GPU billing stops, the
    /// world's unfinished requests come back as re-routable items (the
    /// caller decides re-route vs lost).
    fn crash(&mut self, t: f64) -> Vec<TraceItem> {
        self.state = ReplicaState::Crashed;
        self.log.crashed_at = Some(t);
        self.slow_until = f64::INFINITY;
        self.stepper.world.crash_all()
    }
}

/// Minimum simulated seconds a replica must be behind the horizon
/// before its advance counts as parallel-worthy work. Fleet events
/// (arrivals, boots, control ticks) are often microseconds to
/// milliseconds apart — spawning scoped threads to advance replicas by
/// a sliver costs more than the sliver — so parallel stepping only
/// engages when at least two replicas have a real stretch to cover
/// (compare the coordinator's 0.05 s idle quantum). The gate reads
/// simulation state only, so it fires identically at any thread count.
const PAR_MIN_DELTA: f64 = 0.02;

/// Advance every non-terminal replica to `horizon` — in parallel when
/// more than one worker is available AND at least two live replicas are
/// more than [`PAR_MIN_DELTA`] behind the horizon (see above; tiny
/// deltas step serially to dodge thread spawn/join overhead on every
/// event). Replicas are data-independent between routing events
/// (injections and snapshots happen single-threaded in the event loop),
/// so the post-state is bit-identical at any thread count; `threads` is
/// purely a wall-clock knob. This loop is the fleet's dominant cost —
/// each replica runs its whole plan/price/apply iteration chain to the
/// horizon — and it is why [`crate::coordinator::Stepper`] (scheduler,
/// allocator, predictor boxes included) must be `Send`.
fn advance_live(replicas: &mut [Replica], horizon: f64, threads: usize) {
    if threads > 1 {
        let mut lagging = 0usize;
        for r in replicas.iter() {
            if !r.state.is_terminal() && horizon - r.stepper.world.clock > PAR_MIN_DELTA {
                lagging += 1;
                if lagging >= 2 {
                    break;
                }
            }
        }
        if lagging >= 2 {
            let mut live: Vec<&mut Replica> =
                replicas.iter_mut().filter(|r| !r.state.is_terminal()).collect();
            crate::exp::for_each_mut(&mut live, threads, |r| r.stepper.advance_to(horizon));
            return;
        }
    }
    // Serial fast path: in place, no allocation (the common case — and
    // the only case at threads == 1, keeping the PR 3 zero-allocation
    // property of the event loop intact).
    for r in replicas.iter_mut() {
        if !r.state.is_terminal() {
            r.stepper.advance_to(horizon);
        }
    }
}

/// Crash one replica and file its unfinished requests: into the
/// re-route buffer (health-aware fleet, reroute profile) or straight
/// into the lost tally.
fn kill_replica(
    r: &mut Replica,
    t: f64,
    do_reroute: bool,
    reroute_buf: &mut Vec<TraceItem>,
    tally: &mut FaultTally,
) {
    let lost = r.crash(t);
    if do_reroute {
        reroute_buf.extend(lost);
    } else {
        tally.lost += lost.len();
    }
    tally.crashes += 1;
}

/// Apply one fault event against the current replica table. Victim
/// resolution (`pick % candidates`) reads simulation state that is
/// thread-invariant, so the outcome is bit-identical at any thread
/// count. Returns how many replicas were killed by this event.
fn apply_fault(
    ev: faults::FaultEvent,
    replicas: &mut [Replica],
    profile: &faults::FaultProfile,
    reroute_buf: &mut Vec<TraceItem>,
    tally: &mut FaultTally,
    do_reroute: bool,
    t: f64,
) -> usize {
    let mut killed = 0usize;
    match ev.kind {
        FaultKind::Crash => {
            // One live (serving or draining) replica dies.
            let candidates: Vec<usize> = replicas
                .iter()
                .enumerate()
                .filter(|(_, r)| {
                    matches!(r.state, ReplicaState::Active | ReplicaState::Draining)
                })
                .map(|(id, _)| id)
                .collect();
            if let Some(&victim) =
                candidates.get((ev.pick % candidates.len().max(1) as u64) as usize)
            {
                kill_replica(&mut replicas[victim], t, do_reroute, reroute_buf, tally);
                killed = 1;
            }
        }
        FaultKind::ZoneOutage => {
            // Every non-terminal replica in the zone dies, booting ones
            // included (a failure domain takes warm-ups down with it).
            tally.zone_outages += 1;
            let zone = (ev.pick % profile.zones.max(1) as u64) as usize;
            for (id, r) in replicas.iter_mut().enumerate() {
                if !r.state.is_terminal() && id % profile.zones.max(1) == zone {
                    kill_replica(r, t, do_reroute, reroute_buf, tally);
                    killed += 1;
                }
            }
        }
        FaultKind::Straggler => {
            // One Active replica runs slow for the episode.
            let candidates: Vec<usize> = replicas
                .iter()
                .enumerate()
                .filter(|(_, r)| r.state == ReplicaState::Active)
                .map(|(id, _)| id)
                .collect();
            if let Some(&victim) =
                candidates.get((ev.pick % candidates.len().max(1) as u64) as usize)
            {
                let r = &mut replicas[victim];
                r.stepper.set_slowdown(profile.straggle_factor);
                r.slow_until = t + profile.straggle_len;
                tally.stragglers += 1;
            }
        }
    }
    killed
}

/// Run a fleet over `items` (sorted by arrival, as every trace
/// generator produces them).
pub fn run(fc: &FleetConfig, items: &[TraceItem]) -> FleetResult {
    assert!(fc.min_replicas >= 1, "a fleet needs at least one replica");
    assert!(fc.min_replicas <= fc.max_replicas);
    assert!(
        fc.control_interval > 0.0,
        "control_interval must be positive (the event loop ticks on it)"
    );
    debug_assert!(items.windows(2).all(|w| w[0].arrival <= w[1].arrival));

    let mut router = router::by_name(&fc.router, derive_seed(fc.cfg.seed, stream::ROUTER))
        .unwrap_or_else(|| panic!("unknown router '{}'", fc.router));
    let mut scaler = autoscale::by_name(&fc.autoscaler, fc.knobs())
        .unwrap_or_else(|| panic!("unknown autoscaler '{}'", fc.autoscaler));
    let profile = faults::by_name(&fc.faults)
        .unwrap_or_else(|| panic!("unknown fault profile '{}'", fc.faults));
    // The "none" profile takes every chaos-gated branch out of the loop:
    // such runs are bit-identical to a fleet without fault injection.
    let chaos = profile.is_active();
    let mut injector = Injector::new(profile, derive_seed(fc.cfg.seed, stream::FAULTS));
    let mut tally = FaultTally::default();
    // Replicas lost to faults since the last control tick (autoscaler
    // observation) and the re-route staging buffer.
    let mut crashed_since_tick = 0usize;
    let mut reroute_buf: Vec<TraceItem> = Vec::new();

    // Concurrent stepping under MEASURED scheduler-time charging
    // (sched_time_scale > 0) would let CPU contention between replicas
    // bias the simulated clocks and make results thread-count-dependent
    // — so auto mode (threads == 0) stays serial for such configs, and
    // only an explicit threads > 1 request opts in (documented caveat
    // on `FleetConfig::threads`). Deterministic configs (scale == 0)
    // parallelize freely: thread count cannot change their results.
    let threads = if fc.cfg.sched_time_scale > 0.0 && fc.threads == 0 {
        1
    } else {
        crate::exp::resolve_threads(fc.threads)
    };
    let init = fc.init_replicas.clamp(fc.min_replicas, fc.max_replicas);
    let mut replicas: Vec<Replica> =
        (0..init).map(|i| Replica::boot(fc, i, 0.0, 0.0, false)).collect();
    let mut boots = init;
    let mut routed = 0usize;
    let mut peak = init;
    let mut floor = init;
    let mut next_ctl = fc.control_interval;
    let mut i = 0usize;
    let mut clock = 0.0f64;
    let mut snaps: Vec<ReplicaSnapshot> = Vec::new();

    loop {
        let work_left =
            i < items.len() || replicas.iter().any(|r| !r.stepper.world.all_done());
        if !work_left {
            break;
        }
        let t_arr = if i < items.len() { items[i].arrival } else { f64::INFINITY };
        let t_boot = replicas
            .iter()
            .filter(|r| r.state == ReplicaState::Booting)
            .map(|r| r.log.routable_at)
            .fold(f64::INFINITY, f64::min);
        let t_fault = if chaos { injector.next_at() } else { f64::INFINITY };
        let t_recover = replicas
            .iter()
            .filter(|r| !r.state.is_terminal())
            .map(|r| r.slow_until)
            .fold(f64::INFINITY, f64::min);
        let t = t_arr.min(t_boot).min(next_ctl).min(t_fault).min(t_recover).max(clock);
        if t > fc.max_sim_time {
            advance_live(&mut replicas, fc.max_sim_time, threads);
            clock = clock.max(fc.max_sim_time);
            break;
        }
        clock = t;

        advance_live(&mut replicas, t, threads);
        for r in &mut replicas {
            if r.state == ReplicaState::Booting && r.log.routable_at <= t {
                if r.doomed {
                    // The warm-up was paid for; the replica never
                    // serves. Counts toward the autoscaler's crash
                    // observation so the capacity is re-ordered.
                    r.state = ReplicaState::Crashed;
                    r.log.crashed_at = Some(r.log.routable_at);
                    tally.boot_failures += 1;
                    crashed_since_tick += 1;
                } else {
                    r.state = ReplicaState::Active;
                }
            }
            r.retire_if_drained(t);
        }

        if chaos {
            // Straggler recoveries due at t come first, so an episode
            // scheduled to start at the same instant is not erased.
            for r in &mut replicas {
                if !r.state.is_terminal() && r.slow_until <= t {
                    r.stepper.set_slowdown(1.0);
                    r.slow_until = f64::INFINITY;
                }
            }
            while let Some(ev) = injector.pop_due(t) {
                let killed = apply_fault(
                    ev,
                    &mut replicas,
                    &profile,
                    &mut reroute_buf,
                    &mut tally,
                    fc.health_aware && profile.reroute,
                    t,
                );
                crashed_since_tick += killed;
            }
            // Re-route requests caught on crashed replicas (health-aware
            // fleets with a reroute profile): each keeps its ORIGINAL
            // arrival, so `World::push_item` re-derives the same SLO
            // deadline (idempotent re-route). Counted in `rerouted`, not
            // `routed` — first-route accounting is untouched.
            for it in reroute_buf.drain(..) {
                snaps.clear();
                for (id, r) in replicas.iter().enumerate() {
                    if r.state == ReplicaState::Active {
                        snaps.push(r.snapshot(id, true));
                    }
                }
                if snaps.is_empty() {
                    tally.lost += 1;
                    continue;
                }
                let pick = snaps[router.route(&snaps)].id;
                let r = &mut replicas[pick];
                r.stepper.inject(&it);
                r.log.rerouted += 1;
                tally.rerouted += 1;
            }
            // A health-aware control plane notices the dead capacity
            // immediately and orders replacements up to the floor —
            // which may themselves be doomed (boot-failure retries).
            if fc.health_aware {
                let mut serving = replicas
                    .iter()
                    .filter(|r| {
                        matches!(r.state, ReplicaState::Active | ReplicaState::Booting)
                    })
                    .count();
                while serving < fc.min_replicas {
                    let id = replicas.len();
                    let doomed = injector.boot_fails();
                    replicas.push(Replica::boot(fc, id, t, fc.boot_latency, doomed));
                    boots += 1;
                    serving += 1;
                }
            }
        }

        // Route every arrival due at this event time, re-snapshotting
        // between picks so balance-sensitive routers see their own
        // effect.
        while i < items.len() && items[i].arrival <= t {
            snaps.clear();
            for (id, r) in replicas.iter().enumerate() {
                match r.state {
                    ReplicaState::Active => snaps.push(r.snapshot(id, true)),
                    // Under fault injection, crashed replicas stay in
                    // the routing table: a health-aware fleet sees the
                    // truth (and its routers skip them), a health-blind
                    // one sees a forged healthy bit — and a corpse
                    // looks idle, which is exactly the trap.
                    ReplicaState::Crashed if chaos => {
                        snaps.push(r.snapshot(id, !fc.health_aware))
                    }
                    _ => {}
                }
            }
            scaler.on_arrival(items[i].arrival);
            if snaps.is_empty() {
                assert!(chaos, "no routable replica (min_replicas >= 1)");
                // Whole fleet dead or booting: the arrival has nowhere
                // to go.
                tally.lost += 1;
                i += 1;
                continue;
            }
            let pick = snaps[router.route(&snaps)].id;
            let r = &mut replicas[pick];
            r.log.routed += 1;
            r.log.first_routed_at.get_or_insert(items[i].arrival);
            r.log.last_routed_at = Some(items[i].arrival);
            routed += 1;
            if r.state == ReplicaState::Active {
                r.stepper.inject(&items[i]);
            } else {
                // Routed to a corpse (health-blind, or no survivor to
                // prefer): the request is gone.
                tally.lost += 1;
            }
            i += 1;
        }

        if next_ctl <= t {
            snaps.clear();
            for (id, r) in replicas.iter().enumerate() {
                if r.state == ReplicaState::Active {
                    snaps.push(r.snapshot(id, true));
                }
            }
            let booting =
                replicas.iter().filter(|r| r.state == ReplicaState::Booting).count();
            let draining =
                replicas.iter().filter(|r| r.state == ReplicaState::Draining).count();
            let obs = ScaleObs {
                now: t,
                active: &snaps,
                booting,
                draining,
                // A health-blind control plane is blind end to end: the
                // autoscaler is never told about crash losses either
                // (only ordinary pressure-driven scaling remains).
                crashed: if fc.health_aware { crashed_since_tick } else { 0 },
            };
            crashed_since_tick = 0;
            if let Some(target) = scaler.plan(&obs) {
                let target = target.clamp(fc.min_replicas, fc.max_replicas);
                let serving = snaps.len() + booting;
                if target > serving {
                    for _ in serving..target {
                        let id = replicas.len();
                        let doomed = chaos && injector.boot_fails();
                        replicas.push(Replica::boot(fc, id, t, fc.boot_latency, doomed));
                        boots += 1;
                    }
                } else if target < serving {
                    // Drain Active replicas only (a boot in flight cannot
                    // be cancelled), least-loaded first, never below one
                    // routable replica.
                    let mut excess = serving - target;
                    let mut order: Vec<usize> = snaps.iter().map(|s| s.id).collect();
                    order.sort_by_key(|&id| replicas[id].stepper.world.n_active());
                    let mut active_left = snaps.len();
                    for id in order {
                        if excess == 0 || active_left <= 1 {
                            break;
                        }
                        replicas[id].state = ReplicaState::Draining;
                        replicas[id].log.drain_at = Some(t);
                        excess -= 1;
                        active_left -= 1;
                    }
                }
            }
            let serving_now = replicas
                .iter()
                .filter(|r| matches!(r.state, ReplicaState::Active | ReplicaState::Booting))
                .count();
            peak = peak.max(serving_now);
            floor = floor.min(serving_now);
            next_ctl += fc.control_interval;
        }
    }

    // Drains still pending at exit — ordered at the final control tick
    // (natural completion) or finishing during the final advance (cap
    // exit) — retire here so their GPU billing stops at the true finish
    // time and `retirements` stays consistent with the logs.
    for r in &mut replicas {
        r.retire_if_drained(clock);
    }

    finalize(fc, &replicas, items.len(), routed, clock, boots, peak, floor, tally)
}

#[allow(clippy::too_many_arguments)]
fn finalize(
    fc: &FleetConfig,
    replicas: &[Replica],
    n_total: usize,
    n_routed: usize,
    end_time: f64,
    boots: usize,
    peak: usize,
    floor: usize,
    tally: FaultTally,
) -> FleetResult {
    let gpus = fc.cfg.profile.gpus_per_replica as f64;
    let mut jct = Samples::new();
    let mut n_done = 0usize;
    let mut slo_ok = 0usize;
    let mut last_done = 0.0f64;
    for r in replicas {
        // Requests lost to a crash carry `done_at = None` (no `jct()`),
        // so they are excluded here and count as SLO misses — and a
        // re-routed request is only ever counted on the replica that
        // actually finished it.
        for rec in &r.stepper.world.recs {
            if let Some(j) = rec.jct() {
                n_done += 1;
                jct.push(j);
                if rec.met_slo() {
                    slo_ok += 1;
                }
                last_done = last_done.max(rec.done_at.unwrap_or(0.0));
            }
        }
    }
    // Fleet span: when the work actually finished (matching the legacy
    // per-shard semantics) for runs that completed everything; the last
    // event time for runs cut short by the sim-time cap (or with
    // requests lost to crashes).
    let finished = n_done == n_total && n_routed == n_total;
    let span = if finished && last_done > 0.0 {
        last_done
    } else {
        end_time.max(last_done)
    }
    .max(1e-9);
    let mut gpu_seconds = 0.0;
    let mut retirements = 0usize;
    let mut per_replica = Vec::with_capacity(replicas.len());
    let mut logs = Vec::with_capacity(replicas.len());
    for r in replicas {
        // A crashed replica's GPUs are released at the crash.
        let life_end = r.log.crashed_at.or(r.log.retired_at).unwrap_or(span);
        gpu_seconds += (life_end - r.log.ordered_at).max(0.0) * gpus;
        if r.log.retired_at.is_some() {
            retirements += 1;
        }
        per_replica.push(r.stepper.summary_at(span));
        logs.push(r.log.clone());
    }
    let gpu_hours = gpu_seconds / 3600.0;
    let metrics = fleet_metrics_text(replicas, boots, retirements, &tally);
    FleetResult {
        summary: FleetSummary {
            n_total,
            n_routed,
            n_done,
            slo_ok,
            goodput_rps: slo_ok as f64 / span,
            throughput_rps: n_done as f64 / span,
            ssr: slo_ok as f64 / n_total.max(1) as f64,
            mean_jct: jct.mean(),
            p95_jct: jct.p95(),
            end_time: span,
            gpu_hours,
            goodput_per_gpu_hour: if gpu_hours > 0.0 {
                slo_ok as f64 / gpu_hours
            } else {
                0.0
            },
            peak_replicas: peak,
            floor_replicas: floor,
            mean_replicas: gpu_seconds / gpus / span,
            boots,
            retirements,
            faults: tally,
        },
        per_replica,
        replicas: logs,
        metrics,
    }
}

/// Merge every replica's telemetry registry (in replica-id order — the
/// merge is commutative sample-addition, but a fixed order keeps the
/// code path itself deterministic) and overlay the fleet-level counters
/// written from the authoritative tallies. Each replica's registry was
/// only ever touched by its own single-threaded world, so the rendered
/// text is a pure function of (config, seed): bit-identical at any
/// thread count — `tests/equivalence.rs` pins this.
fn fleet_metrics_text(
    replicas: &[Replica],
    boots: usize,
    retirements: usize,
    tally: &FaultTally,
) -> String {
    use crate::telemetry::{FleetMetrics, Snapshot};
    let mut merged: Option<Snapshot> = None;
    for r in replicas {
        let snap = Snapshot::parse(&r.stepper.metrics_text())
            .expect("registry render is valid exposition text");
        match &mut merged {
            None => merged = Some(snap),
            Some(m) => m.merge(&snap).expect("replica registries share one vocabulary"),
        }
    }
    let fleet = FleetMetrics::on(crate::telemetry::Registry::new());
    fleet.crashes.add(tally.crashes as u64);
    fleet.zone_outages.add(tally.zone_outages as u64);
    fleet.stragglers.add(tally.stragglers as u64);
    fleet.boot_failures.add(tally.boot_failures as u64);
    fleet.requests_lost.add(tally.lost as u64);
    fleet.reroutes.add(tally.rerouted as u64);
    fleet.boots.add(boots as u64);
    fleet.retirements.add(retirements as u64);
    let fleet_snap = Snapshot::parse(&fleet.registry().render())
        .expect("fleet registry render is valid exposition text");
    match merged {
        None => fleet_snap.render(),
        Some(mut m) => {
            m.merge(&fleet_snap).expect("fleet families are disjoint from sim families");
            m.render()
        }
    }
}
