//! MultiRes (a.k.a. *UnsyncCoupled*, adapted from Tiresias [32] to LLM
//! serving as described in §2.1): after each iteration, while resources
//! remain, compute for every queued request the Euclidean distance between
//! its (GPU, KVC) demand and the available (GPU, KVC), pick the closest,
//! and repeat — an O(n²) scan that is exactly the paper's "high scheduling
//! time" culprit (34% of JCT, Fig 1e).
//!
//! Paired with **exact-allocation**: an admitted request leases prompt +
//! padded predicted RL, so allocation never fails; requests run to
//! completion without preemption.

use super::Scheduler;
use crate::core::world::IterCtx;
use crate::core::{BatchPlan, BatchTask, IndexedList, PreemptKind, ReqId};
use crate::kvc::{Allocator, Demand, ReserveClass};

pub struct MultiRes {
    queued: Vec<ReqId>,
    running: IndexedList,
}

impl MultiRes {
    pub fn new() -> Self {
        MultiRes { queued: Vec::new(), running: IndexedList::new() }
    }

    /// (gpu_demand_tokens, kvc_demand_tokens) of a queued request.
    /// Includes dropped-KV recompute work (offload-free preemption).
    fn demand_point(ctx: &IterCtx<'_>, id: ReqId) -> (f64, f64) {
        let rec = ctx.rec(id);
        let prefill_work = rec.req.prompt_len - rec.prompt_done + rec.lost_kv;
        let gpu = prefill_work.max(1) as f64;
        let kvc = (prefill_work + rec.predicted_remaining() + 1) as f64;
        (gpu, kvc)
    }
}

impl Default for MultiRes {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for MultiRes {
    fn name(&self) -> &'static str {
        "multires"
    }

    fn plan(&mut self, ctx: &mut IterCtx<'_>) -> BatchPlan {
        while let Some(id) = ctx.pop_arrival() {
            self.queued.push(id);
        }
        self.running.retain(|id| !ctx.world().recs[id].is_done());

        // Under-predicted GTs (non-oracle runs): extend the lease in
        // place if possible, otherwise send back to the queue (their KV
        // stays resident; they re-enter via the distance scan).
        let mut under = std::mem::take(&mut ctx.events.reached_prediction);
        let bs = ctx.cfg().block_size;
        for &id in &under {
            let rec = ctx.rec_mut(id);
            rec.predicted_base = rec.generated;
            rec.predicted_rl = bs;
            if !ctx.alloc().extend(id, bs + 1, ReserveClass::Reserved).ok() {
                // Offload-free drop: release the KV, recompute at re-admission.
                if self.running.remove(id) {
                    ctx.preempt(id, PreemptKind::DropRecompute);
                    self.queued.push(id);
                }
            }
        }
        under.clear();
        ctx.events.reached_prediction = under;

        // Current iteration's resource availability.
        let tfs = ctx.cfg().profile.tfs as f64;
        let max_total = ctx.cfg().profile.max_total_len;
        let mut gpu_avail = tfs - self.running.len() as f64; // decodes cost 1 token each
        let cap = ctx.cfg().kvc_tokens() as f64;

        // O(n²) selection: repeatedly rescan the whole queue for the
        // min-distance request that fits. This cost is *measured* by the
        // coordinator and charged to the clock (Fig 14).
        loop {
            let kvc_avail = ctx.kvc().free_tokens(ReserveClass::Reserved) as f64;
            let mut best: Option<(usize, f64)> = None;
            for (idx, &id) in self.queued.iter().enumerate() {
                let (g, k) = Self::demand_point(ctx, id);
                if g > gpu_avail || k > kvc_avail {
                    continue;
                }
                // Normalized Euclidean distance to the available point.
                let dg = (gpu_avail - g) / tfs.max(1.0);
                let dk = (kvc_avail - k) / cap.max(1.0);
                let dist = (dg * dg + dk * dk).sqrt();
                if best.map(|(_, d)| dist < d).unwrap_or(true) {
                    best = Some((idx, dist));
                }
            }
            let Some((idx, _)) = best else { break };
            let id = self.queued.swap_remove(idx);
            let (g, _) = Self::demand_point(ctx, id);
            let demand = Demand::of(ctx.rec(id), max_total);
            if !ctx.alloc().admit(id, demand, ReserveClass::Reserved).ok() {
                // Exact-allocation was fit-checked above; another policy on
                // the allocation axis may still reject — requeue and stop.
                self.queued.push(id);
                break;
            }
            ctx.mark_exec_start(id);
            gpu_avail -= g;
            self.running.push(id);
        }

        let mut plan = ctx.take_plan();
        for id in self.running.iter() {
            let rec = ctx.rec(id);
            if rec.lost_kv > 0 {
                plan.tasks.push(BatchTask::Prefill { id, chunk: rec.lost_kv });
            } else if rec.prompt_done < rec.req.prompt_len {
                plan.tasks
                    .push(BatchTask::Prefill { id, chunk: rec.req.prompt_len - rec.prompt_done });
            } else {
                plan.tasks.push(BatchTask::Decode { id });
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::coordinator::{run, RunLimits};
    use crate::core::world::World;
    use crate::engine::SimEngine;
    use crate::predictor::OraclePredictor;
    use crate::sched::plan_iteration;
    use crate::trace::TraceItem;

    fn world(items: &[TraceItem], kvc_tokens: u64) -> World {
        let mut profile = ModelProfile::opt_13b();
        profile.kvc_bytes = 819_200 * kvc_tokens;
        let mut cfg = SystemConfig::new(profile);
        cfg.padding_ratio = 0.0;
        let p = Box::new(OraclePredictor::new(1));
        World::new(cfg, items, p) // default allocator IS exact
    }

    #[test]
    fn never_fails_allocation() {
        let items: Vec<TraceItem> = (0..60)
            .map(|i| TraceItem {
                arrival: i as f64 * 0.005,
                prompt_len: 30 + (i as u32 % 7) * 25,
                true_rl: 10 + (i as u32 % 9) * 15,
            })
            .collect();
        let mut w = world(&items, 2048);
        let mut s = MultiRes::new();
        let e = SimEngine::new();
        let res = run(&mut w, &mut s, &e, RunLimits::default());
        assert_eq!(res.summary.n_done, 60);
        assert_eq!(w.kvc().stats().failures, 0, "exact-allocation must never fail");
        assert_eq!(w.col.preemptions, 0);
    }

    #[test]
    fn prefers_best_fit_under_scarcity() {
        // KVC has room for the small request but not the big one; MultiRes
        // must pick the small one even though the big one arrived first.
        let items = vec![
            TraceItem { arrival: 0.0, prompt_len: 1500, true_rl: 400 }, // too big
            TraceItem { arrival: 0.0, prompt_len: 64, true_rl: 32 },
        ];
        let mut w = world(&items, 512); // 512 tokens of KVC
        w.drain_arrivals();
        let mut s = MultiRes::new();
        let b = plan_iteration(&mut w, &mut s);
        assert_eq!(b.tasks.len(), 1);
        assert_eq!(b.tasks[0].id(), 1);
    }
}
