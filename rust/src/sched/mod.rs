//! Schedulers: the *batching-policy* axis of Table 1 — the paper's
//! EconoServe and every baseline it is compared against (§2.2).
//!
//! A scheduler is called once per iteration boundary through the typed
//! contract: it receives an [`IterCtx`] (previous-iteration events, clock,
//! queue views, typed request-state mutators, and the installed
//! [`crate::kvc::Allocator`]) and returns a [`BatchPlan`]. All KVC
//! capacity flows through the allocator handle — schedulers never touch
//! block accounting — so the two Table-1 axes compose freely:
//!
//! **Batching axis** (this module):
//!
//! | module        | system            | batching                     |
//! |---------------|-------------------|------------------------------|
//! | `orca`        | ORCA [11]         | FCFS, fixed batch            |
//! | `srtf`        | SRTF baseline     | preemptive shortest-first    |
//! | `fastserve`   | FastServe [12]    | 5-level skip-join MLFQ       |
//! | `vllm`        | vLLM [13]         | FCFS + swap preemption       |
//! | `sarathi`     | Sarathi-Serve [15]| chunked prefill, TFS budget  |
//! | `multires`    | MultiRes [32]     | O(n²) dual-resource fit      |
//! | `sync_coupled`| SyncCoupled (§2.2)| same-RL groups, coupled      |
//! | `econoserve`  | EconoServe (§3)   | SyncDecoupled (+O ordering)  |
//!
//! **Allocation axis** (`crate::kvc`): `max`, `block`, `exact`, and the
//! `pipelined-*` wrappers (§3.2 KVC pipelining over any of the three).
//!
//! DistServe (disaggregated prefill/decode) lives in [`crate::cluster`]
//! because it spans two engines.

pub mod econoserve;
pub mod fastserve;
pub mod multires;
pub mod orca;
pub mod sarathi;
pub mod srtf;
pub mod sync_coupled;
pub mod vllm;

use std::collections::VecDeque;

use crate::core::world::{IterCtx, World};
use crate::core::{BatchPlan, PreemptKind, ReqId};
use crate::kvc::{Allocator, ReserveClass};

/// Iteration-level scheduler interface (the typed policy contract).
///
/// `Send` is part of the contract: a scheduler is boxed inside a
/// simulation (`coordinator::Stepper`, `sched::System`) that the
/// parallel experiment engine ([`crate::exp`]) moves across worker
/// threads — keep implementations free of non-`Send` state (`Rc`,
/// `RefCell`, raw pointers).
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;

    /// Form the plan for the next iteration. `ctx.events` holds the
    /// previous iteration's outcomes; implementations own queue state,
    /// and draw all KVC capacity through `ctx.alloc()`.
    fn plan(&mut self, ctx: &mut IterCtx<'_>) -> BatchPlan;
}

/// A resolved `<sched>+<alloc>` combination from [`by_name`].
pub struct System {
    pub sched: Box<dyn Scheduler>,
    /// Allocator registry name (install with `World::set_allocator`).
    pub alloc: &'static str,
}

/// The Table-1 default allocator pairing for a scheduler name.
pub fn default_alloc(sched: &str) -> Option<&'static str> {
    Some(match sched {
        "orca" | "orca16" | "srtf" | "fastserve" => "max",
        "vllm" | "sarathi" => "block",
        "multires" | "sync_coupled" | "econoserve-d" | "econoserve-sd" | "econoserve-sdo" => {
            "exact"
        }
        "econoserve" => "pipelined-exact",
        _ => return None,
    })
}

/// Construct a system by name (the figure drivers' registry).
///
/// Grammar: `"<sched>"` or `"<sched>+<alloc>"`. The bare scheduler name
/// resolves to its Table-1 default allocator (`default_alloc`); the
/// two-part form pins any allocator from `kvc::all_allocators()` — e.g.
/// `"vllm+exact"` or `"sarathi+pipelined-exact"` — so grid points are
/// runnable from `main.rs` and the figure drivers.
///
/// Caveat: schedulers without mid-flight lease growth or a preemption
/// recovery path (the max-allocation family, and the exact-allocation
/// group under `block`) rely on an admission-complete lease. Pairing
/// them with an allocator that leases less (e.g. `orca+block`) runs on
/// the allocator's implicit reserve-class rescue and aborts with a KVC
/// overflow once even the reserve is exhausted — sustained overload
/// needs a supported pairing (see `benches/sched_hotpath.rs::allocs_for`).
pub fn by_name(name: &str) -> Option<System> {
    let (sched_name, alloc_req) = match name.split_once('+') {
        Some((s, a)) => (s, Some(a)),
        None => (name, None),
    };
    let alloc = match alloc_req {
        None => default_alloc(sched_name)?,
        Some(a) => crate::kvc::canonical_alloc_name(a)?,
    };
    let sched: Box<dyn Scheduler> = match sched_name {
        "orca" => Box::new(orca::Orca::new(8)),
        "orca16" => Box::new(orca::Orca::new(16)),
        "srtf" => Box::new(srtf::Srtf::new(8)),
        "fastserve" => Box::new(fastserve::FastServe::new(8, 5)),
        "vllm" => Box::new(vllm::Vllm::new()),
        "sarathi" => Box::new(sarathi::Sarathi::new()),
        "multires" => Box::new(multires::MultiRes::new()),
        "sync_coupled" => Box::new(sync_coupled::SyncCoupled::new()),
        // EconoServe ablation ladder (§4 Compared Methods).
        "econoserve-d" => Box::new(econoserve::EconoServe::variant_d()),
        "econoserve-sd" => Box::new(econoserve::EconoServe::variant_sd()),
        "econoserve-sdo" => Box::new(econoserve::EconoServe::variant_sdo()),
        "econoserve" => Box::new(econoserve::EconoServe::full()),
        _ => return None,
    };
    Some(System { sched, alloc })
}

/// Shared vLLM-family mechanics: resume swapped-out sequences while
/// their context fits again (swap-ins take precedence over admission).
/// Charges the PCIe swap-in cost to the plan and returns the resumed
/// ids; the caller routes them back into its own run queues.
pub(crate) fn swap_in_ready(
    ctx: &mut IterCtx<'_>,
    swapped: &mut VecDeque<ReqId>,
    plan: &mut BatchPlan,
) -> Vec<ReqId> {
    let mut resumed = Vec::new();
    while let Some(&id) = swapped.front() {
        let need = ctx.rec(id).context_tokens() + 1;
        if !ctx.alloc().grow_to(id, need, ReserveClass::Reserved).ok() {
            break;
        }
        swapped.pop_front();
        let restored = ctx.rec(id).swapped_tokens;
        ctx.alloc().restore(id, restored.min(need));
        plan.extra_time += ctx.swap_in_cost(id);
        ctx.rec_mut(id).swapped_tokens = 0;
        ctx.mark_exec_start(id);
        resumed.push(id);
    }
    resumed
}

/// Shared vLLM-family recovery for a failed decode-time lease grow: the
/// engine stalls while the LATEST-arrived running sequence's KV streams
/// out over PCIe (vLLM v0 swaps synchronously with the scheduler loop;
/// the paper measures these preemption delays at up to 20% of JCT,
/// Fig 1e). Returns the victim so the caller can stop when the growing
/// sequence preempted itself.
pub(crate) fn swap_out_latest(
    ctx: &mut IterCtx<'_>,
    running: &mut Vec<ReqId>,
    swapped: &mut VecDeque<ReqId>,
    plan: &mut BatchPlan,
) -> ReqId {
    let victim = *running.last().expect("lease-grow failure with empty running set");
    plan.extra_time += ctx.rec(victim).context_tokens() as f64
        * ctx.cfg().profile.kv_bytes_per_token() as f64
        / ctx.cfg().pcie_bw;
    running.pop();
    ctx.preempt(victim, PreemptKind::Swap);
    swapped.push_back(victim);
    victim
}

/// Run one planning step: open the iteration context, let the scheduler
/// plan, and fold its preemption/eviction record into the plan. This is
/// the only way a scheduler touches a [`World`].
///
/// When span tracing is enabled this shared path also emits the
/// per-iteration scheduler decision records: `IterCtx::finish_into`
/// classifies every queued request the plan skipped (`kvc_exhausted` /
/// `batch_full` / `ordering` / `waiting_held`), so all schedulers get
/// decision provenance without per-scheduler edits; a scheduler can
/// override the classification for a request it knows better about via
/// `IterCtx::note_skip`.
pub fn plan_iteration(world: &mut World, sched: &mut dyn Scheduler) -> BatchPlan {
    let mut ctx = world.begin_iter();
    let mut plan = sched.plan(&mut ctx);
    ctx.finish_into(&mut plan);
    plan
}

/// All single-GPU system names in the paper's comparison order.
pub fn all_systems() -> &'static [&'static str] {
    &[
        "orca",
        "srtf",
        "fastserve",
        "vllm",
        "sarathi",
        "multires",
        "sync_coupled",
        "econoserve-d",
        "econoserve-sd",
        "econoserve-sdo",
        "econoserve",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_systems() {
        for name in all_systems() {
            let sys = by_name(name).unwrap_or_else(|| panic!("{name}"));
            assert_eq!(sys.alloc, default_alloc(name).unwrap(), "{name}");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn registry_resolves_sched_alloc_grid() {
        let combos = [
            ("vllm+exact", "exact"),
            ("sarathi+pipelined-exact", "pipelined-exact"),
            ("orca+block", "block"),
            ("econoserve+exact", "exact"),
            ("sync_coupled+pipelined-max", "pipelined-max"),
        ];
        for (name, alloc) in combos {
            let sys = by_name(name).unwrap_or_else(|| panic!("{name}"));
            assert_eq!(sys.alloc, alloc, "{name}");
        }
        assert!(by_name("vllm+paged").is_none(), "unknown allocator must not resolve");
        assert!(by_name("nope+exact").is_none(), "unknown scheduler must not resolve");
    }

    #[test]
    fn default_pairings_match_table1() {
        assert_eq!(default_alloc("orca"), Some("max"));
        assert_eq!(default_alloc("vllm"), Some("block"));
        assert_eq!(default_alloc("multires"), Some("exact"));
        assert_eq!(default_alloc("econoserve"), Some("pipelined-exact"));
        assert_eq!(default_alloc("nope"), None);
    }
}
