//! Schedulers: the paper's EconoServe and every baseline it is compared
//! against (Table 1 / §2.2).
//!
//! A scheduler is called once per iteration boundary. It consumes the
//! events of the previous iteration from `world.events`, mutates its own
//! queue state, performs all KVC allocation, and returns the next batch.
//!
//! | module        | system            | allocation | batching             |
//! |---------------|-------------------|------------|----------------------|
//! | `orca`        | ORCA [11]         | max        | FCFS, fixed batch    |
//! | `srtf`        | SRTF baseline     | max        | preemptive shortest  |
//! | `fastserve`   | FastServe [12]    | max        | 5-level MLFQ         |
//! | `vllm`        | vLLM [13]         | block      | FCFS + swap preempt  |
//! | `sarathi`     | Sarathi-Serve [15]| block      | chunked prefill, TFS |
//! | `multires`    | MultiRes [32]     | exact      | O(n²) dual-resource  |
//! | `sync_coupled`| SyncCoupled (§2.2)| exact      | same-RL groups       |
//! | `econoserve`  | EconoServe (§3)   | exact      | SyncDecoupled (+O,+P)|
//!
//! DistServe (disaggregated prefill/decode) lives in [`crate::cluster`]
//! because it spans two engines.

pub mod econoserve;
pub mod fastserve;
pub mod multires;
pub mod orca;
pub mod sarathi;
pub mod srtf;
pub mod sync_coupled;
pub mod vllm;

use crate::core::world::World;
use crate::core::Batch;

/// Iteration-level scheduler interface.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Form the batch for the next iteration. `world.events` holds the
    /// previous iteration's outcomes; implementations own queue state and
    /// all KVC allocation decisions.
    fn step(&mut self, world: &mut World) -> Batch;
}

/// Construct a scheduler by system name (the figure drivers' registry).
/// `block_size` is used by schedulers that need a grouping quantum.
pub fn by_name(name: &str) -> Option<Box<dyn Scheduler>> {
    let s: Box<dyn Scheduler> = match name {
        "orca" => Box::new(orca::Orca::new(8)),
        "orca16" => Box::new(orca::Orca::new(16)),
        "srtf" => Box::new(srtf::Srtf::new(8)),
        "fastserve" => Box::new(fastserve::FastServe::new(8, 5)),
        "vllm" => Box::new(vllm::Vllm::new()),
        "sarathi" => Box::new(sarathi::Sarathi::new()),
        "multires" => Box::new(multires::MultiRes::new()),
        "sync_coupled" => Box::new(sync_coupled::SyncCoupled::new()),
        // EconoServe ablation ladder (§4 Compared Methods).
        "econoserve-d" => Box::new(econoserve::EconoServe::variant_d()),
        "econoserve-sd" => Box::new(econoserve::EconoServe::variant_sd()),
        "econoserve-sdo" => Box::new(econoserve::EconoServe::variant_sdo()),
        "econoserve" => Box::new(econoserve::EconoServe::full()),
        _ => return None,
    };
    Some(s)
}

/// All single-GPU system names in the paper's comparison order.
pub fn all_systems() -> &'static [&'static str] {
    &[
        "orca",
        "srtf",
        "fastserve",
        "vllm",
        "sarathi",
        "multires",
        "sync_coupled",
        "econoserve-d",
        "econoserve-sd",
        "econoserve-sdo",
        "econoserve",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_systems() {
        for name in all_systems() {
            assert!(by_name(name).is_some(), "{name}");
        }
        assert!(by_name("nope").is_none());
    }
}
