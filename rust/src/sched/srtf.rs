//! SRTF baseline (§2.1 "Schedulers" item 2): shortest-remaining-time-first
//! at iteration level, paired with **max-allocation**. Preemptive: each
//! iteration the `batch_size` requests with the least predicted remaining
//! work run; paused requests keep their (max) lease, mirroring the KVC
//! pressure the paper attributes to this family.

use super::Scheduler;
use crate::core::world::IterCtx;
use crate::core::{BatchPlan, BatchTask, ReqId};
use crate::kvc::{Allocator, Demand, ReserveClass};

pub struct Srtf {
    batch_size: usize,
    /// Admitted (holding an admission lease), not yet completed.
    admitted: Vec<ReqId>,
}

impl Srtf {
    pub fn new(batch_size: usize) -> Self {
        Srtf { batch_size, admitted: Vec::new() }
    }

    /// Remaining service estimate: unprocessed prompt tokens + predicted
    /// remaining response tokens.
    fn remaining(ctx: &IterCtx<'_>, id: ReqId) -> u64 {
        let rec = ctx.rec(id);
        (rec.req.prompt_len - rec.prompt_done) as u64 + rec.predicted_remaining() as u64
    }
}

impl Scheduler for Srtf {
    fn name(&self) -> &'static str {
        "srtf"
    }

    fn plan(&mut self, ctx: &mut IterCtx<'_>) -> BatchPlan {
        self.admitted.retain(|id| !ctx.world().recs[*id].is_done());

        // Admit whatever fits (admission itself is not size-limited; the
        // BATCH each iteration is).
        while let Some(head) = ctx.peek_arrival() {
            let demand = Demand::of(ctx.rec(head), ctx.cfg().profile.max_total_len);
            if !ctx.alloc().admit(head, demand, ReserveClass::Reserved).ok() {
                break;
            }
            ctx.pop_arrival();
            self.admitted.push(head);
        }

        // Pick the batch_size shortest-remaining admitted requests via
        // partial selection (O(n) + O(k log k)) instead of re-sorting the
        // whole admitted set every iteration; only the winners need an
        // order, the paused tail does not.
        let k = self.batch_size.min(self.admitted.len());
        if k > 0 && k < self.admitted.len() {
            self.admitted
                .select_nth_unstable_by_key(k - 1, |&id| (Srtf::remaining(ctx, id), id));
        }
        self.admitted[..k].sort_unstable_by_key(|&id| (Srtf::remaining(ctx, id), id));
        let mut plan = ctx.take_plan();
        for &id in self.admitted.iter().take(self.batch_size) {
            ctx.mark_exec_start(id);
            let rec = ctx.rec(id);
            if rec.prompt_done < rec.req.prompt_len {
                plan.tasks
                    .push(BatchTask::Prefill { id, chunk: rec.req.prompt_len - rec.prompt_done });
            } else {
                plan.tasks.push(BatchTask::Decode { id });
            }
        }
        // Paused (not selected) requests are "preempted" in paper terms but
        // keep their lease; track pause spans for metrics.
        let paused: Vec<ReqId> = self.admitted.iter().skip(self.batch_size).copied().collect();
        for id in paused {
            ctx.pause(id);
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::core::world::World;
    use crate::engine::{Engine, SimEngine};
    use crate::predictor::OraclePredictor;
    use crate::sched::plan_iteration;
    use crate::trace::TraceItem;

    fn world(items: &[TraceItem]) -> World {
        let mut profile = ModelProfile::opt_13b();
        profile.max_total_len = 256;
        profile.kvc_bytes = 819_200 * 4096;
        let cfg = SystemConfig::new(profile);
        let p = Box::new(OraclePredictor::new(1));
        let mut w = World::new(cfg, items, p);
        w.set_allocator("max");
        w
    }

    #[test]
    fn shortest_runs_first() {
        let mut w = world(&[
            TraceItem { arrival: 0.0, prompt_len: 64, true_rl: 100 },
            TraceItem { arrival: 0.0, prompt_len: 8, true_rl: 4 },
        ]);
        w.drain_arrivals();
        let mut s = Srtf::new(1);
        let b = plan_iteration(&mut w, &mut s);
        assert_eq!(b.tasks.len(), 1);
        assert_eq!(b.tasks[0].id(), 1, "short job must be chosen");
    }

    #[test]
    fn all_complete_eventually() {
        let items: Vec<TraceItem> = (0..10)
            .map(|i| TraceItem {
                arrival: i as f64 * 0.01,
                prompt_len: 10 + (i as u32 % 3) * 20,
                true_rl: 3 + (i as u32 % 5) * 10,
            })
            .collect();
        let mut w = world(&items);
        let mut s = Srtf::new(4);
        let e = SimEngine::new();
        for _ in 0..10_000 {
            w.drain_arrivals();
            let b = plan_iteration(&mut w, &mut s);
            if b.is_empty() {
                if let Some(t) = w.next_arrival() {
                    w.clock = t;
                    continue;
                }
                break;
            }
            let (dur, util) = e.iteration_cost(&b, &w);
            w.apply_plan(&b, dur, util);
        }
        assert!(w.all_done());
    }
}
