//! SRTF baseline (§2.1 "Schedulers" item 2): shortest-remaining-time-first
//! at iteration level with **max-allocation**. Preemptive: each iteration
//! the `batch_size` requests with the least predicted remaining work run;
//! paused requests keep their (max) allocation, mirroring the KVC pressure
//! the paper attributes to this family.

use super::Scheduler;
use crate::core::world::World;
use crate::core::{Batch, BatchTask, Phase, ReqId};
use crate::kvc::Priority;

pub struct Srtf {
    batch_size: usize,
    /// Admitted (holding a max-allocation), not yet completed.
    admitted: Vec<ReqId>,
}

impl Srtf {
    pub fn new(batch_size: usize) -> Self {
        Srtf { batch_size, admitted: Vec::new() }
    }

    /// Remaining service estimate: unprocessed prompt tokens + predicted
    /// remaining response tokens.
    fn remaining(world: &World, id: ReqId) -> u64 {
        let rec = &world.recs[id];
        (rec.req.prompt_len - rec.prompt_done) as u64 + rec.predicted_remaining() as u64
    }
}

impl Scheduler for Srtf {
    fn name(&self) -> &'static str {
        "srtf"
    }

    fn step(&mut self, world: &mut World) -> Batch {
        self.admitted.retain(|id| !world.recs[*id].is_done());

        // Admit whatever fits (admission itself is not size-limited; the
        // BATCH each iteration is).
        while let Some(&head) = world.inbox.front() {
            let max_alloc = world.cfg.profile.max_total_len;
            if world.pool.alloc_tokens(head, max_alloc, Priority::Reserved).is_err() {
                break;
            }
            world.inbox.pop_front();
            self.admitted.push(head);
        }

        // Pick the batch_size shortest-remaining admitted requests.
        self.admitted.sort_by_key(|&id| Srtf::remaining(world, id));
        let mut batch = Batch::default();
        for &id in self.admitted.iter().take(self.batch_size) {
            world.mark_exec_start(id);
            let rec = &world.recs[id];
            if rec.prompt_done < rec.req.prompt_len {
                batch
                    .tasks
                    .push(BatchTask::Prefill { id, chunk: rec.req.prompt_len - rec.prompt_done });
            } else {
                batch.tasks.push(BatchTask::Decode { id });
            }
        }
        // Paused (not selected) requests are "preempted" in paper terms but
        // keep their allocation; track pause spans for metrics.
        for &id in self.admitted.iter().skip(self.batch_size) {
            let now = world.clock;
            let rec = &mut world.recs[id];
            if rec.phase == Phase::Decoding || rec.phase == Phase::Prefilling {
                rec.phase = Phase::Preempted;
                rec.preempted_since.get_or_insert(now);
            }
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::engine::{Engine, SimEngine};
    use crate::predictor::OraclePredictor;
    use crate::trace::TraceItem;

    fn world(items: &[TraceItem]) -> World {
        let mut profile = ModelProfile::opt_13b();
        profile.max_total_len = 256;
        profile.kvc_bytes = 819_200 * 4096;
        let cfg = SystemConfig::new(profile);
        let p = Box::new(OraclePredictor::new(1));
        World::new(cfg, items, p)
    }

    #[test]
    fn shortest_runs_first() {
        let mut w = world(&[
            TraceItem { arrival: 0.0, prompt_len: 64, true_rl: 100 },
            TraceItem { arrival: 0.0, prompt_len: 8, true_rl: 4 },
        ]);
        w.drain_arrivals();
        let mut s = Srtf::new(1);
        let b = s.step(&mut w);
        assert_eq!(b.tasks.len(), 1);
        assert_eq!(b.tasks[0].id(), 1, "short job must be chosen");
    }

    #[test]
    fn all_complete_eventually() {
        let items: Vec<TraceItem> = (0..10)
            .map(|i| TraceItem {
                arrival: i as f64 * 0.01,
                prompt_len: 10 + (i as u32 % 3) * 20,
                true_rl: 3 + (i as u32 % 5) * 10,
            })
            .collect();
        let mut w = world(&items);
        let mut s = Srtf::new(4);
        let e = SimEngine::new();
        for _ in 0..10_000 {
            w.drain_arrivals();
            let b = s.step(&mut w);
            if b.is_empty() {
                if let Some(t) = w.next_arrival() {
                    w.clock = t;
                    continue;
                }
                break;
            }
            let (dur, util) = e.iteration_cost(&b, &w);
            w.execute_iteration(&b, dur, util);
        }
        assert!(w.all_done());
    }
}
