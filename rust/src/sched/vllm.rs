//! vLLM [13]: continuous batching with PagedAttention-style
//! **block-allocation** (its Table-1 default) and swap-based preemption.
//!
//! Mechanics modelled (vLLM v0 scheduler):
//!  * FCFS waiting queue; *prefill-prioritizing*: when admissible prompts
//!    are waiting, an iteration runs prefills only (up to
//!    `max_batched_tokens`), stalling decodes — the paper's "vLLM does not
//!    aim to fully utilize GPU".
//!  * Decode iterations grow each running sequence by one token, growing
//!    its lease when it crosses a block boundary. On a failed grow the
//!    LATEST-arrived running sequence is preempted by swapping its KV to
//!    CPU memory (Fig 1d/1e's failures + delay). Under `vllm+exact` the
//!    admission lease covers the predicted span, so mid-flight grows stop
//!    failing — the Table-1 grid made runnable.
//!  * Swapped sequences have priority over new admissions; swap-in cost
//!    (PCIe) is charged to the iteration that resumes them.

use std::collections::VecDeque;

use super::Scheduler;
use crate::core::world::IterCtx;
use crate::core::{BatchPlan, BatchTask, ReqId};
use crate::kvc::{Allocator, Demand, ReserveClass};

pub struct Vllm {
    waiting: VecDeque<ReqId>,
    running: Vec<ReqId>, // FCFS order (arrival order preserved)
    swapped: VecDeque<ReqId>,
    /// Cap on tokens per prefill iteration (vLLM max_num_batched_tokens);
    /// None = use profile TFS.
    pub max_batched_tokens: Option<u32>,
    /// Cap on concurrently running sequences (vLLM max_num_seqs).
    pub max_num_seqs: usize,
}

impl Vllm {
    pub fn new() -> Self {
        Vllm {
            waiting: VecDeque::new(),
            running: Vec::new(),
            swapped: VecDeque::new(),
            max_batched_tokens: None,
            max_num_seqs: 256,
        }
    }
}

impl Default for Vllm {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Vllm {
    fn name(&self) -> &'static str {
        "vllm"
    }

    fn plan(&mut self, ctx: &mut IterCtx<'_>) -> BatchPlan {
        while let Some(id) = ctx.pop_arrival() {
            self.waiting.push_back(id);
        }
        self.running.retain(|id| !ctx.world().recs[*id].is_done());

        let budget = self.max_batched_tokens.unwrap_or(ctx.cfg().profile.tfs);
        let mut plan = ctx.take_plan();

        // 1) Swap-ins take precedence (resumed sequences rejoin running).
        for id in super::swap_in_ready(ctx, &mut self.swapped, &mut plan) {
            self.running.push(id);
        }

        // 2) Prefill-prioritizing admission: if prompts are admissible,
        //    run a prefill-only iteration.
        let mut prefill_tokens = 0u32;
        let mut admitted = Vec::new();
        while self.running.len() + admitted.len() < self.max_num_seqs {
            let Some(&head) = self.waiting.front() else { break };
            let plen = ctx.rec(head).req.prompt_len;
            if prefill_tokens + plen > budget && prefill_tokens > 0 {
                break;
            }
            // Admission lease for the prompt (+1 for the first token).
            let demand = Demand {
                immediate: plen + 1,
                predicted: ctx.rec(head).predicted_remaining(),
                max_total: ctx.cfg().profile.max_total_len,
            };
            if !ctx.alloc().admit(head, demand, ReserveClass::Reserved).ok() {
                break;
            }
            self.waiting.pop_front();
            ctx.mark_exec_start(head);
            prefill_tokens += plen;
            admitted.push(head);
            if prefill_tokens >= budget {
                break;
            }
        }
        if !admitted.is_empty() {
            for id in admitted {
                let chunk = ctx.rec(id).req.prompt_len;
                plan.tasks.push(BatchTask::Prefill { id, chunk });
                self.running.push(id);
            }
            return plan; // prefill-only iteration (decode stall)
        }

        // 3) Decode iteration: every running sequence advances one token;
        //    grow leases, preempting the latest arrival on failure.
        let mut i = 0;
        while i < self.running.len() {
            let id = self.running[i];
            let need = ctx.rec(id).context_tokens() + 1;
            if ctx.alloc().grow_to(id, need, ReserveClass::Reserved).ok() {
                i += 1;
            } else {
                ctx.note_alloc_failed(id);
                let victim =
                    super::swap_out_latest(ctx, &mut self.running, &mut self.swapped, &mut plan);
                if victim == id {
                    break; // the sequence itself was the victim
                }
            }
        }
        for &id in &self.running {
            plan.tasks.push(BatchTask::Decode { id });
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::coordinator::{run, RunLimits};
    use crate::core::world::World;
    use crate::engine::SimEngine;
    use crate::predictor::OraclePredictor;
    use crate::sched::plan_iteration;
    use crate::trace::TraceItem;

    fn tight_world(items: &[TraceItem], kvc_tokens: u64) -> World {
        let mut profile = ModelProfile::opt_13b();
        profile.kvc_bytes = 819_200 * kvc_tokens;
        let mut cfg = SystemConfig::new(profile);
        cfg.reserve_frac = 0.0;
        let p = Box::new(OraclePredictor::new(1));
        let mut w = World::new(cfg, items, p);
        w.set_allocator("block");
        w
    }

    #[test]
    fn prefill_iteration_runs_alone() {
        let items = vec![
            TraceItem { arrival: 0.0, prompt_len: 32, true_rl: 10 },
            TraceItem { arrival: 0.0, prompt_len: 32, true_rl: 10 },
        ];
        let mut w = tight_world(&items, 4096);
        w.drain_arrivals();
        let mut s = Vllm::new();
        let b = plan_iteration(&mut w, &mut s);
        assert_eq!(b.prefill_tokens(), 64);
        assert_eq!(b.decode_count(), 0, "prefill-only iteration");
        // Next step: decodes.
        let (dur, u) = crate::engine::Engine::iteration_cost(&SimEngine::new(), &b, &w);
        w.apply_plan(&b, dur, u);
        let b2 = plan_iteration(&mut w, &mut s);
        assert_eq!(b2.decode_count(), 2);
    }

    #[test]
    fn kvc_exhaustion_triggers_swap_preemption() {
        // KVC of 128 tokens, two requests needing ~96 each => thrash.
        let items = vec![
            TraceItem { arrival: 0.0, prompt_len: 32, true_rl: 64 },
            TraceItem { arrival: 0.0, prompt_len: 32, true_rl: 64 },
        ];
        let mut w = tight_world(&items, 128);
        let mut s = Vllm::new();
        let e = SimEngine::new();
        let res = run(&mut w, &mut s, &e, RunLimits::default());
        assert_eq!(res.summary.n_done, 2);
        assert!(w.col.swap_preemptions > 0, "expected swaps under pressure");
        assert!(res.summary.alloc_failure_frac > 0.0);
    }

    #[test]
    fn completes_without_pressure() {
        let items: Vec<TraceItem> = (0..40)
            .map(|i| TraceItem {
                arrival: i as f64 * 0.01,
                prompt_len: 16 + (i as u32 % 5) * 16,
                true_rl: 4 + (i as u32 % 6) * 8,
            })
            .collect();
        let mut w = tight_world(&items, 16384);
        let mut s = Vllm::new();
        let e = SimEngine::new();
        let res = run(&mut w, &mut s, &e, RunLimits::default());
        assert_eq!(res.summary.n_done, 40);
        assert_eq!(w.col.swap_preemptions, 0);
    }

    #[test]
    fn exact_allocation_eliminates_midflight_failures() {
        // The same pressure scenario as above, but on the `vllm+exact`
        // grid point: admission leases the predicted span, so decode
        // growth never fails (admission head-of-line blocks instead).
        let items = vec![
            TraceItem { arrival: 0.0, prompt_len: 32, true_rl: 64 },
            TraceItem { arrival: 0.0, prompt_len: 32, true_rl: 64 },
        ];
        let mut w = tight_world(&items, 128);
        w.set_allocator("exact");
        let mut s = Vllm::new();
        let e = SimEngine::new();
        let res = run(&mut w, &mut s, &e, RunLimits::default());
        assert_eq!(res.summary.n_done, 2);
        assert_eq!(w.col.swap_preemptions, 0, "exact admission must prevent swaps");
        assert_eq!(res.summary.alloc_failure_frac, 0.0);
    }
}
