//! vLLM [13]: continuous batching with PagedAttention-style
//! **block-allocation** and swap-based preemption.
//!
//! Mechanics modelled (vLLM v0 scheduler):
//!  * FCFS waiting queue; *prefill-prioritizing*: when admissible prompts
//!    are waiting, an iteration runs prefills only (up to
//!    `max_batched_tokens`), stalling decodes — the paper's "vLLM does not
//!    aim to fully utilize GPU".
//!  * Decode iterations grow each running sequence by one token,
//!    allocating a new block when it crosses a block boundary. On
//!    allocation failure the LATEST-arrived running sequence is preempted
//!    by swapping its KV to CPU memory (Fig 1d/1e's failures + delay).
//!  * Swapped sequences have priority over new admissions; swap-in cost
//!    (PCIe) is charged to the iteration that resumes them.

use std::collections::VecDeque;

use super::Scheduler;
use crate::core::world::{PreemptKind, World};
use crate::core::{Batch, BatchTask, ReqId};
use crate::kvc::Priority;

pub struct Vllm {
    waiting: VecDeque<ReqId>,
    running: Vec<ReqId>, // FCFS order (arrival order preserved)
    swapped: VecDeque<ReqId>,
    /// Cap on tokens per prefill iteration (vLLM max_num_batched_tokens);
    /// None = use profile TFS.
    pub max_batched_tokens: Option<u32>,
    /// Cap on concurrently running sequences (vLLM max_num_seqs).
    pub max_num_seqs: usize,
}

impl Vllm {
    pub fn new() -> Self {
        Vllm {
            waiting: VecDeque::new(),
            running: Vec::new(),
            swapped: VecDeque::new(),
            max_batched_tokens: None,
            max_num_seqs: 256,
        }
    }
}

impl Default for Vllm {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Vllm {
    fn name(&self) -> &'static str {
        "vllm"
    }

    fn step(&mut self, world: &mut World) -> Batch {
        while let Some(id) = world.inbox.pop_front() {
            self.waiting.push_back(id);
        }
        self.running.retain(|id| !world.recs[*id].is_done());

        let budget = self.max_batched_tokens.unwrap_or(world.cfg.profile.tfs);
        let mut batch = Batch::default();

        // 1) Swap-ins take precedence (resumed sequences rejoin running).
        while let Some(&id) = self.swapped.front() {
            let need = world.recs[id].context_tokens() + 1;
            if world.pool.alloc_tokens(id, need, Priority::Reserved).is_err() {
                break;
            }
            self.swapped.pop_front();
            let restored = world.recs[id].swapped_tokens;
            world.pool.restore_written(id, restored.min(need));
            batch.extra_time += world.swap_in_cost(id);
            world.recs[id].swapped_tokens = 0;
            world.mark_exec_start(id);
            self.running.push(id);
        }

        // 2) Prefill-prioritizing admission: if prompts are admissible,
        //    run a prefill-only iteration.
        let mut prefill_tokens = 0u32;
        let mut admitted = Vec::new();
        while self.running.len() + admitted.len() < self.max_num_seqs {
            let Some(&head) = self.waiting.front() else { break };
            let plen = world.recs[head].req.prompt_len;
            if prefill_tokens + plen > budget && prefill_tokens > 0 {
                break;
            }
            // Block-allocation for the prompt (+1 for the first token).
            if world.pool.alloc_tokens(head, plen + 1, Priority::Reserved).is_err() {
                break;
            }
            self.waiting.pop_front();
            world.mark_exec_start(head);
            prefill_tokens += plen;
            admitted.push(head);
            if prefill_tokens >= budget {
                break;
            }
        }
        if !admitted.is_empty() {
            for id in admitted {
                let chunk = world.recs[id].req.prompt_len;
                batch.tasks.push(BatchTask::Prefill { id, chunk });
                self.running.push(id);
            }
            return batch; // prefill-only iteration (decode stall)
        }

        // 3) Decode iteration: every running sequence advances one token;
        //    grow allocations, preempting the latest arrival on failure.
        let mut i = 0;
        while i < self.running.len() {
            let id = self.running[i];
            let need = world.recs[id].context_tokens() + 1;
            match world.pool.ensure_capacity(id, need, Priority::Reserved) {
                Ok(_) => i += 1,
                Err(_) => {
                    world.col.alloc_failed_reqs.insert(id);
                    // The engine stalls while the victim's KV streams out
                    // over PCIe (vLLM v0 swaps synchronously with the
                    // scheduler loop; the paper measures these preemption
                    // delays at up to 20% of JCT, Fig 1e).
                    let victim_peek = *self.running.last().unwrap();
                    batch.extra_time += world.recs[victim_peek].context_tokens() as f64
                        * world.cfg.profile.kv_bytes_per_token() as f64
                        / world.cfg.pcie_bw;
                    // Preempt from the back (latest arrival) until it fits.
                    let victim = *self.running.last().unwrap();
                    self.running.pop();
                    world.preempt(victim, PreemptKind::Swap);
                    self.swapped.push_back(victim);
                    if victim == id {
                        break; // the sequence itself was the victim
                    }
                }
            }
        }
        for &id in &self.running {
            batch.tasks.push(BatchTask::Decode { id });
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::coordinator::{run, RunLimits};
    use crate::engine::SimEngine;
    use crate::predictor::OraclePredictor;
    use crate::trace::TraceItem;

    fn tight_world(items: &[TraceItem], kvc_tokens: u64) -> World {
        let mut profile = ModelProfile::opt_13b();
        profile.kvc_bytes = 819_200 * kvc_tokens;
        let mut cfg = SystemConfig::new(profile);
        cfg.reserve_frac = 0.0;
        let p = Box::new(OraclePredictor::new(1));
        World::new(cfg, items, p)
    }

    #[test]
    fn prefill_iteration_runs_alone() {
        let items = vec![
            TraceItem { arrival: 0.0, prompt_len: 32, true_rl: 10 },
            TraceItem { arrival: 0.0, prompt_len: 32, true_rl: 10 },
        ];
        let mut w = tight_world(&items, 4096);
        w.drain_arrivals();
        let mut s = Vllm::new();
        let b = s.step(&mut w);
        assert_eq!(b.prefill_tokens(), 64);
        assert_eq!(b.decode_count(), 0, "prefill-only iteration");
        // Next step: decodes.
        let (dur, u) = crate::engine::Engine::iteration_cost(&SimEngine::new(), &b, &w);
        w.execute_iteration(&b, dur, u);
        let b2 = s.step(&mut w);
        assert_eq!(b2.decode_count(), 2);
    }

    #[test]
    fn kvc_exhaustion_triggers_swap_preemption() {
        // KVC of 128 tokens, two requests needing ~96 each => thrash.
        let items = vec![
            TraceItem { arrival: 0.0, prompt_len: 32, true_rl: 64 },
            TraceItem { arrival: 0.0, prompt_len: 32, true_rl: 64 },
        ];
        let mut w = tight_world(&items, 128);
        let mut s = Vllm::new();
        let e = SimEngine::new();
        let res = run(&mut w, &mut s, &e, RunLimits::default());
        assert_eq!(res.summary.n_done, 2);
        assert!(w.col.swap_preemptions > 0, "expected swaps under pressure");
        assert!(res.summary.alloc_failure_frac > 0.0);
    }

    #[test]
    fn completes_without_pressure() {
        let items: Vec<TraceItem> = (0..40)
            .map(|i| TraceItem {
                arrival: i as f64 * 0.01,
                prompt_len: 16 + (i as u32 % 5) * 16,
                true_rl: 4 + (i as u32 % 6) * 8,
            })
            .collect();
        let mut w = tight_world(&items, 16384);
        let mut s = Vllm::new();
        let e = SimEngine::new();
        let res = run(&mut w, &mut s, &e, RunLimits::default());
        assert_eq!(res.summary.n_done, 40);
        assert_eq!(w.col.swap_preemptions, 0);
    }
}
