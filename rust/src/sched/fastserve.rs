//! FastServe [12]: preemptive scheduling with a skip-join Multi-Level
//! Feedback Queue (MLFQ) to attack head-of-line blocking, paired with
//! **max-allocation** like ORCA.
//!
//! Model (faithful to the paper's mechanism at the granularity our
//! iteration simulation needs):
//!  * `levels` priority queues; quantum of level i is `base_quantum * 2^i`
//!    iterations of service.
//!  * New requests *skip-join* the level whose quantum covers their first
//!    iteration (prompt processing) time, so long prompts don't monopolize
//!    the top queue.
//!  * Each iteration runs up to `batch_size` requests from the highest
//!    non-empty levels; a request that exhausts its level quantum is
//!    demoted one level.
//!  * Paused requests keep their admission lease (FastServe keeps KV
//!    resident; its proactive offloading is not modelled — the paper's
//!    comparison also runs it KV-resident).

use std::collections::VecDeque;

use super::Scheduler;
use crate::core::world::IterCtx;
use crate::core::{BatchPlan, BatchTask, ReqId};
use crate::kvc::{Allocator, Demand, ReserveClass};

pub struct FastServe {
    batch_size: usize,
    levels: Vec<VecDeque<ReqId>>,
    /// Iterations of service consumed at the current level, per request —
    /// a dense slab keyed by `ReqId` (O(1) lookup, no association scan).
    service: Vec<u32>,
    base_quantum: u32,
}

impl FastServe {
    pub fn new(batch_size: usize, levels: usize) -> Self {
        FastServe {
            batch_size,
            levels: (0..levels).map(|_| VecDeque::new()).collect(),
            service: Vec::new(),
            base_quantum: 2,
        }
    }

    fn quantum(&self, level: usize) -> u32 {
        self.base_quantum << level
    }

    /// Skip-join: place a new request at the level whose quantum covers
    /// its prefill cost (measured in "iterations" ~ prompt_len / TFS).
    fn join_level(&self, ctx: &IterCtx<'_>, id: ReqId) -> usize {
        let prefill_iters =
            (ctx.rec(id).req.prompt_len / ctx.cfg().profile.tfs.max(1)).max(1);
        let mut lvl = 0;
        while lvl + 1 < self.levels.len() && self.quantum(lvl) < prefill_iters {
            lvl += 1;
        }
        lvl
    }

    fn service_mut(&mut self, id: ReqId) -> &mut u32 {
        if id >= self.service.len() {
            self.service.resize(id + 1, 0);
        }
        &mut self.service[id]
    }

    fn service_of(&self, id: ReqId) -> u32 {
        self.service.get(id).copied().unwrap_or(0)
    }
}

impl Scheduler for FastServe {
    fn name(&self) -> &'static str {
        "fastserve"
    }

    fn plan(&mut self, ctx: &mut IterCtx<'_>) -> BatchPlan {
        // Admission lease (head-of-line on KVC exhaustion).
        while let Some(head) = ctx.peek_arrival() {
            let demand = Demand::of(ctx.rec(head), ctx.cfg().profile.max_total_len);
            if !ctx.alloc().admit(head, demand, ReserveClass::Reserved).ok() {
                break;
            }
            ctx.pop_arrival();
            let lvl = self.join_level(ctx, head);
            self.levels[lvl].push_back(head);
        }

        // Drop finished requests from all levels (service-slab entries of
        // finished ids are dead weight, never read again).
        for q in &mut self.levels {
            q.retain(|id| !ctx.world().recs[*id].is_done());
        }

        // Demote quantum-exhausted requests (done lazily before selection).
        for lvl in 0..self.levels.len().saturating_sub(1) {
            let quantum = self.quantum(lvl);
            let mut i = 0;
            while i < self.levels[lvl].len() {
                let id = self.levels[lvl][i];
                if self.service_of(id) >= quantum {
                    self.levels[lvl].remove(i);
                    self.levels[lvl + 1].push_back(id);
                    *self.service_mut(id) = 0;
                } else {
                    i += 1;
                }
            }
        }

        // Select from the highest non-empty levels.
        let mut plan = ctx.take_plan();
        let mut selected: Vec<ReqId> = Vec::new();
        'outer: for q in &self.levels {
            for &id in q {
                if selected.len() >= self.batch_size {
                    break 'outer;
                }
                selected.push(id);
            }
        }
        for id in selected {
            ctx.mark_exec_start(id);
            *self.service_mut(id) += 1;
            let rec = ctx.rec(id);
            if rec.prompt_done < rec.req.prompt_len {
                plan.tasks
                    .push(BatchTask::Prefill { id, chunk: rec.req.prompt_len - rec.prompt_done });
            } else {
                plan.tasks.push(BatchTask::Decode { id });
            }
        }
        // Mark non-selected in-flight requests as paused.
        let chosen: std::collections::HashSet<ReqId> =
            plan.tasks.iter().map(|t| t.id()).collect();
        let paused: Vec<ReqId> = self
            .levels
            .iter()
            .flat_map(|q| q.iter().copied())
            .filter(|id| !chosen.contains(id))
            .collect();
        for id in paused {
            ctx.pause(id);
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::coordinator::{run, RunLimits};
    use crate::core::world::World;
    use crate::engine::SimEngine;
    use crate::predictor::OraclePredictor;
    use crate::trace::TraceItem;

    fn world(items: &[TraceItem]) -> World {
        let mut profile = ModelProfile::opt_13b();
        profile.max_total_len = 512;
        profile.kvc_bytes = 819_200 * 8192;
        let cfg = SystemConfig::new(profile);
        let p = Box::new(OraclePredictor::new(1));
        let mut w = World::new(cfg, items, p);
        w.set_allocator("max");
        w
    }

    #[test]
    fn long_prompts_skip_join_lower_level() {
        let mut w = world(&[
            TraceItem { arrival: 0.0, prompt_len: 8, true_rl: 4 },
            TraceItem { arrival: 0.0, prompt_len: 4096, true_rl: 4 },
        ]);
        // tfs=2048 so a 4096-token prompt needs ~2 iterations.
        w.drain_arrivals();
        let s = FastServe::new(8, 5);
        let ctx = w.begin_iter();
        assert_eq!(s.join_level(&ctx, 0), 0);
        // 4096/2048 = 2 <= quantum(0)=2 -> level 0 for id 1 too.
        assert_eq!(s.join_level(&ctx, 1), 0);
    }

    #[test]
    fn short_jobs_preempt_long_ones() {
        // A long job running alone, then a short one arrives: the short
        // one must finish well before the long one.
        let items = vec![
            TraceItem { arrival: 0.0, prompt_len: 64, true_rl: 400 },
            TraceItem { arrival: 0.5, prompt_len: 8, true_rl: 5 },
        ];
        let mut w = world(&items);
        let mut s = FastServe::new(1, 5);
        let e = SimEngine::new();
        let res = run(&mut w, &mut s, &e, RunLimits::default());
        assert_eq!(res.summary.n_done, 2);
        let jct_short = w.recs[1].jct().unwrap();
        let jct_long = w.recs[0].jct().unwrap();
        assert!(
            jct_short < jct_long / 3.0,
            "short={jct_short:.2} long={jct_long:.2}"
        );
    }

    #[test]
    fn completes_mixed_load() {
        let items: Vec<TraceItem> = (0..30)
            .map(|i| TraceItem {
                arrival: i as f64 * 0.02,
                prompt_len: 8 + (i as u32 % 4) * 30,
                true_rl: 2 + (i as u32 % 7) * 12,
            })
            .collect();
        let mut w = world(&items);
        let mut s = FastServe::new(8, 5);
        let e = SimEngine::new();
        let res = run(&mut w, &mut s, &e, RunLimits::default());
        assert_eq!(res.summary.n_done, 30);
    }
}
