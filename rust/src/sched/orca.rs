//! ORCA [11]: iteration-level scheduling, FCFS admission, fixed maximum
//! batch size. Paired with **max-allocation** (Table 1 row): each admitted
//! request leases KVC for the model's maximum total sequence length, so
//! allocation can never fail mid-flight but KVC is massively
//! over-provisioned, which throttles the batch size and GPU utilization.

use super::Scheduler;
use crate::core::world::IterCtx;
use crate::core::{BatchPlan, BatchTask, Phase, ReqId};
use crate::kvc::{Allocator, Demand, ReserveClass};

pub struct Orca {
    batch_size: usize,
    running: Vec<ReqId>,
}

impl Orca {
    pub fn new(batch_size: usize) -> Self {
        Orca { batch_size, running: Vec::new() }
    }
}

impl Scheduler for Orca {
    fn name(&self) -> &'static str {
        "orca"
    }

    fn plan(&mut self, ctx: &mut IterCtx<'_>) -> BatchPlan {
        // Completed requests leave the batch (iteration-level scheduling).
        self.running.retain(|id| !ctx.world().recs[*id].is_done());

        // FCFS admission up to the fixed batch size; head-of-line blocks
        // when the admission lease does not fit.
        while self.running.len() < self.batch_size {
            let Some(head) = ctx.peek_arrival() else { break };
            let demand = Demand::of(ctx.rec(head), ctx.cfg().profile.max_total_len);
            if !ctx.alloc().admit(head, demand, ReserveClass::Reserved).ok() {
                break;
            }
            ctx.pop_arrival();
            ctx.mark_exec_start(head);
            self.running.push(head);
        }

        let mut plan = ctx.take_plan();
        for &id in &self.running {
            let rec = ctx.rec(id);
            if rec.prompt_done < rec.req.prompt_len {
                // Whole-prompt prefill in one iteration (no chunking).
                plan.tasks
                    .push(BatchTask::Prefill { id, chunk: rec.req.prompt_len - rec.prompt_done });
            } else if rec.phase != Phase::Done {
                plan.tasks.push(BatchTask::Decode { id });
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::core::world::World;
    use crate::predictor::OraclePredictor;
    use crate::sched::plan_iteration;
    use crate::trace::TraceItem;

    fn small_world(n: usize) -> World {
        let mut profile = ModelProfile::opt_13b();
        profile.max_total_len = 512;
        profile.kvc_bytes = 819_200 * 2048; // 2048 tokens => 4 max-allocs
        let cfg = SystemConfig::new(profile);
        let items: Vec<TraceItem> = (0..n)
            .map(|i| TraceItem { arrival: i as f64 * 1e-6, prompt_len: 16, true_rl: 4 })
            .collect();
        let p = Box::new(OraclePredictor::new(1));
        let mut w = World::new(cfg, &items, p);
        w.set_allocator("max");
        w
    }

    #[test]
    fn max_allocation_limits_admission() {
        let mut w = small_world(10);
        w.clock = 1.0;
        w.drain_arrivals();
        let mut s = Orca::new(8);
        let b = plan_iteration(&mut w, &mut s);
        // KVC fits 2048/512 = 4 max-allocations even though batch size is 8.
        assert_eq!(b.len(), 4);
        assert_eq!(w.inbox.len(), 6);
    }

    #[test]
    fn completes_and_refills() {
        let mut w = small_world(6);
        w.clock = 1.0;
        w.drain_arrivals();
        let mut s = Orca::new(2);
        // Drive to completion manually.
        let engine = crate::engine::SimEngine::new();
        for _ in 0..200 {
            let b = plan_iteration(&mut w, &mut s);
            if b.is_empty() {
                break;
            }
            let (dur, util) = crate::engine::Engine::iteration_cost(&engine, &b, &w);
            w.apply_plan(&b, dur, util);
        }
        assert!(w.recs.iter().all(|r| r.is_done()));
        // Max-alloc fully released.
        assert_eq!(w.kvc().total_allocated(), 0);
    }
}
