//! ORCA [11]: iteration-level scheduling, FCFS admission, fixed maximum
//! batch size, **max-allocation** — each admitted request reserves KVC for
//! the model's maximum total sequence length, so allocation can never fail
//! mid-flight but KVC is massively over-provisioned, which throttles the
//! batch size and GPU utilization (the paper's Table 1 row).

use super::Scheduler;
use crate::core::world::World;
use crate::core::{Batch, BatchTask, Phase, ReqId};
use crate::kvc::Priority;

pub struct Orca {
    batch_size: usize,
    running: Vec<ReqId>,
}

impl Orca {
    pub fn new(batch_size: usize) -> Self {
        Orca { batch_size, running: Vec::new() }
    }
}

impl Scheduler for Orca {
    fn name(&self) -> &'static str {
        "orca"
    }

    fn step(&mut self, world: &mut World) -> Batch {
        // Completed requests leave the batch (iteration-level scheduling).
        self.running.retain(|id| !world.recs[*id].is_done());

        // FCFS admission up to the fixed batch size; head-of-line blocks
        // when the max-allocation does not fit.
        while self.running.len() < self.batch_size {
            let Some(&head) = world.inbox.front() else { break };
            let max_alloc = world.cfg.profile.max_total_len;
            if world.pool.alloc_tokens(head, max_alloc, Priority::Reserved).is_err() {
                break;
            }
            world.inbox.pop_front();
            world.mark_exec_start(head);
            self.running.push(head);
        }

        let mut batch = Batch::default();
        for &id in &self.running {
            let rec = &world.recs[id];
            if rec.prompt_done < rec.req.prompt_len {
                // Whole-prompt prefill in one iteration (no chunking).
                batch
                    .tasks
                    .push(BatchTask::Prefill { id, chunk: rec.req.prompt_len - rec.prompt_done });
            } else if rec.phase != Phase::Done {
                batch.tasks.push(BatchTask::Decode { id });
            }
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::predictor::OraclePredictor;
    use crate::trace::TraceItem;

    fn small_world(n: usize) -> World {
        let mut profile = ModelProfile::opt_13b();
        profile.max_total_len = 512;
        profile.kvc_bytes = 819_200 * 2048; // 2048 tokens => 4 max-allocs
        let cfg = SystemConfig::new(profile);
        let items: Vec<TraceItem> = (0..n)
            .map(|i| TraceItem { arrival: i as f64 * 1e-6, prompt_len: 16, true_rl: 4 })
            .collect();
        let p = Box::new(OraclePredictor::new(1));
        World::new(cfg, &items, p)
    }

    #[test]
    fn max_allocation_limits_admission() {
        let mut w = small_world(10);
        w.clock = 1.0;
        w.drain_arrivals();
        let mut s = Orca::new(8);
        let b = s.step(&mut w);
        // KVC fits 2048/512 = 4 max-allocations even though batch size is 8.
        assert_eq!(b.len(), 4);
        assert_eq!(w.inbox.len(), 6);
    }

    #[test]
    fn completes_and_refills() {
        let mut w = small_world(6);
        w.clock = 1.0;
        w.drain_arrivals();
        let mut s = Orca::new(2);
        // Drive to completion manually.
        let engine = crate::engine::SimEngine::new();
        for _ in 0..200 {
            let b = s.step(&mut w);
            if b.is_empty() {
                break;
            }
            let (dur, util) = crate::engine::Engine::iteration_cost(&engine, &b, &w);
            w.execute_iteration(&b, dur, util);
        }
        assert!(w.recs.iter().all(|r| r.is_done()));
        // Max-alloc fully released.
        assert_eq!(w.pool.total_allocated(), 0);
    }
}
