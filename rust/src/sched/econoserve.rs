//! ECONOSERVE (§3): the paper's scheduler, with its ablation ladder.
//!
//! Components (each gated by a flag so the §4 variants fall out):
//!
//!  * **Decoupling** (always on — variant `-D` baseline): separate PT and
//!    GT waiting queues. GTs are responsible for *fully allocating the
//!    KVC* (exact-allocation of the padded predicted RL); PTs are
//!    responsible for *filling the GPU* up to the target forward size,
//!    drawing KVC from the PT reservation. PTs can therefore be added in
//!    EVERY iteration (Fig 8b), fixing the GT-domination issue.
//!  * **Time-synced batching** (`synced`, `-SD`): the GT queue is grouped
//!    by (padded, quantized) predicted RL; whole groups are admitted and
//!    complete together, so scheduling is per-group (low overhead).
//!    Under-provisioned members first try the reserved KVC, then are
//!    re-grouped at a re-predicted RL with their KV kept resident
//!    (offload-free, Observation 4).
//!  * **Ordering** (`ordering`, `-SDO`): both queues ordered by (deadline
//!    bucket ↑, occupied KVC ↓, length ↓) with binary-search gap filling
//!    (§3.4).
//!  * **KVC pipelining** (`pipe`, full system): each admitted hosting GT
//!    lends the second half of its span to a guest GT whose predicted RL
//!    fits `span/2 − b`, recursively (§3.2, Fig 7). Guests consume NO new
//!    KVC blocks. The buffer `b` is `buffer_frac × hosting RL`.

use std::collections::BTreeMap;
use std::collections::VecDeque;

use super::Scheduler;
use crate::config::PreemptMode;
use crate::core::world::World;
use crate::core::{Batch, BatchTask, Phase, ReqId};
use crate::kvc::Priority;
use crate::ordering::best_fit_leq;

pub struct EconoServe {
    synced: bool,
    ordering: bool,
    pipe: bool,
    /// Waiting PTs (not yet started prefilling).
    pt_queue: Vec<ReqId>,
    /// PTs currently prefilling (chunked), in admission order. Also holds
    /// preempted GTs doing KV recompute.
    running_pts: VecDeque<ReqId>,
    /// Waiting GTs: predicted remaining RL -> FIFO queue.
    gt_groups: BTreeMap<u32, VecDeque<ReqId>>,
    /// GTs currently decoding (hosts and guests alike).
    running_gts: Vec<ReqId>,
    /// Group sizes admitted together (Fig 2 instrumentation).
    pub group_sizes: Vec<u32>,
    /// Count of GTs rescued by the reserve vs re-queued (Fig 5b).
    pub reserve_rescues: u64,
    pub requeues: u64,
    /// Guests placed by KVC pipelining (instrumentation).
    pub guests_placed: u64,
    /// Admission retry gate: skip the O(queue) group scan when nothing
    /// changed since the last failed attempt (keeps the per-iteration
    /// scheduling cost O(running), the paper's low-overhead claim).
    gate: AdmitGate,
}

#[derive(Default)]
struct AdmitGate {
    /// (free tokens, queue version, clock) at the last failed admission.
    failed_at: Option<(u32, u64, f64)>,
    version: u64,
}

impl EconoServe {
    fn with_flags(synced: bool, ordering: bool, pipe: bool) -> Self {
        EconoServe {
            synced,
            ordering,
            pipe,
            pt_queue: Vec::new(),
            running_pts: VecDeque::new(),
            gt_groups: BTreeMap::new(),
            running_gts: Vec::new(),
            group_sizes: Vec::new(),
            reserve_rescues: 0,
            requeues: 0,
            guests_placed: 0,
            gate: AdmitGate::default(),
        }
    }

    /// `UnsyncedDecoupled`: decoupling + exact-allocation only.
    pub fn variant_d() -> Self {
        Self::with_flags(false, false, false)
    }

    /// `SyncDecoupled`: + time-synced GT groups.
    pub fn variant_sd() -> Self {
        Self::with_flags(true, false, false)
    }

    /// + task Ordering.
    pub fn variant_sdo() -> Self {
        Self::with_flags(true, true, false)
    }

    /// Full system: + KVC pipelining.
    pub fn full() -> Self {
        Self::with_flags(true, true, true)
    }

    fn enqueue_gt(&mut self, world: &World, id: ReqId) {
        let rl = world.recs[id].predicted_remaining().max(1);
        self.gt_groups.entry(rl).or_default().push_back(id);
        self.gate.version += 1;
    }

    /// Handle the previous iteration's events.
    fn process_events(&mut self, world: &mut World) {
        let events = world.take_events();
        self.running_gts.retain(|id| !world.recs[*id].is_done());
        self.running_pts.retain(|id| !world.recs[*id].is_done());

        // PTs that finished prefilling become queued GTs.
        let finished: Vec<ReqId> = events.finished_prefill.clone();
        for id in finished {
            if let Some(pos) = self.running_pts.iter().position(|x| *x == id) {
                self.running_pts.remove(pos);
            }
            self.enqueue_gt(world, id);
        }

        // Recompute done: the GT resumes decoding.
        let recomputed: Vec<ReqId> = events.recompute_done.clone();
        for id in recomputed {
            if let Some(pos) = self.running_pts.iter().position(|x| *x == id) {
                self.running_pts.remove(pos);
            }
            debug_assert!(!self.running_gts.contains(&id), "dup push at recompute_done for {id}");
            self.running_gts.push(id);
        }

        // Under-provisioned GTs (§3.3.2): reserve first, then offload-free
        // re-queue at the re-predicted remaining RL. A GT can appear both
        // here and in evicted_guests within one iteration — handle once.
        let mut handled: std::collections::HashSet<ReqId> = std::collections::HashSet::new();
        let under: Vec<ReqId> = events.reached_prediction.clone();
        for id in under {
            if world.recs[id].is_done() || !handled.insert(id) {
                continue;
            }
            let new_rem = world.re_predict(id);
            let use_reserve = matches!(
                world.cfg.preempt_mode,
                PreemptMode::ReservedThenFree | PreemptMode::OffloadSwap
            );
            let rescued = use_reserve
                && !world.pipes.is_guest(id)
                && world.pool.alloc_tokens(id, new_rem + 1, Priority::Reserved).is_ok();
            if rescued {
                self.reserve_rescues += 1;
                // Span extends; guests were placed against the OLD span, so
                // their offsets stay valid (the head only moves forward).
                world.recs[id].gt_span_len += new_rem;
            } else {
                // Offload-free: stop decoding, KEEP the written KV resident
                // (trim over-provisioned blocks), re-enter the GT queue.
                if let Some(pos) = self.running_gts.iter().position(|x| *x == id) {
                    self.running_gts.remove(pos);
                }
                // Guests lose their borrowed space (host keeps running).
                if world.pipes.is_guest(id) {
                    world.pipes.release_guest(id);
                    let dropped = world.pool.clear_guest_tokens(id);
                    world.recs[id].lost_kv += dropped;
                } else {
                    // Detach this host's guests first: they keep decoding in
                    // space that remains allocated? No — the host's blocks
                    // are being trimmed, so re-home or evict its guests.
                    self.detach_guests_for_trim(world, id);
                    world.pool.trim_to_written(id);
                }
                let now = world.clock;
                let rec = &mut world.recs[id];
                rec.phase = Phase::GtQueued;
                rec.preempted_since.get_or_insert(now);
                rec.preempt_count += 1;
                world.col.preemptions += 1;
                self.requeues += 1;
                self.enqueue_gt(world, id);
            }
        }

        // Evicted guests re-enter the GT queue (they carry lost_kv that is
        // recomputed when they are re-admitted).
        let evicted: Vec<ReqId> = events.evicted_guests.clone();
        for id in evicted {
            if world.recs[id].is_done() || !handled.insert(id) {
                continue;
            }
            if let Some(pos) = self.running_gts.iter().position(|x| *x == id) {
                self.running_gts.remove(pos);
            }
            world.re_predict(id);
            self.enqueue_gt(world, id);
        }
    }

    /// Re-home or evict the direct guests of `host` before its unused
    /// span is trimmed away.
    fn detach_guests_for_trim(&mut self, world: &mut World, host: ReqId) {
        let guests = world.pipes.remove_host(host);
        for g in guests {
            if world.recs[g].is_done() {
                continue;
            }
            let moved = world.pool.alloc_of(g).map(|a| a.guest_written).unwrap_or(0);
            let need = moved + world.recs[g].predicted_remaining() + 1;
            if world.pool.alloc_tokens(g, need, Priority::Reserved).is_ok() {
                world.pool.clear_guest_tokens(g);
                if moved > 0 {
                    world.pool.write_tokens(g, moved);
                }
            } else {
                // Same as a world eviction: drop guest KV, re-queue.
                if let Some(pos) = self.running_gts.iter().position(|x| *x == g) {
                    self.running_gts.remove(pos);
                }
                let dropped = world.pool.clear_guest_tokens(g);
                let now = world.clock;
                let rec = &mut world.recs[g];
                rec.lost_kv += dropped;
                rec.phase = Phase::GtQueued;
                rec.preempted_since.get_or_insert(now);
                rec.preempt_count += 1;
                world.col.preemptions += 1;
                world.col.pipeline_evictions += 1;
                self.enqueue_gt(world, g);
            }
        }
    }

    /// Admit one GT from a group: exact-alloc its remaining span
    /// (+ pending recompute work). Returns false on KVC exhaustion.
    fn admit_gt(&mut self, world: &mut World, id: ReqId) -> bool {
        let rec = &world.recs[id];
        let remaining = rec.predicted_remaining().max(1);
        let need = rec.lost_kv + remaining + 1;
        if world.pool.alloc_tokens(id, need, Priority::Normal).is_err() {
            return false;
        }
        world.mark_exec_start(id);
        let rec = &mut world.recs[id];
        rec.gt_span_base = rec.generated;
        rec.gt_span_len = remaining;
        if rec.lost_kv > 0 {
            // Needs recompute first: treat like prefill work.
            self.running_pts.push_front(id);
        } else {
            rec.phase = Phase::Decoding;
            debug_assert!(!self.running_gts.contains(&id), "dup push at admit_gt for {id}");
            self.running_gts.push(id);
        }
        true
    }

    /// Time-synced group admission: pick groups (ordered or FCFS-oldest),
    /// admit members until the KVC is fully allocated; split when needed.
    fn admit_gt_groups(&mut self, world: &mut World) {
        // Retry gate: if the last attempt failed and neither the free
        // space, the queue, nor (materially) the clock has changed, the
        // scan would fail again — skip it.
        if let Some((free, ver, at)) = self.gate.failed_at {
            if world.pool.free_tokens(Priority::Normal) == free
                && ver == self.gate.version
                && world.clock - at < 0.05
            {
                return;
            }
        }
        let mut any_admitted = false;
        let mut tried: std::collections::BTreeSet<u32> = std::collections::BTreeSet::new();
        loop {
            if self.gt_groups.is_empty() || self.gt_groups.keys().all(|k| tried.contains(k)) {
                break;
            }
            // Choose the next group.
            let key = if self.ordering {
                // Highest-priority member across group heads, honoring the
                // 3-factor order; then prefer the LONGEST RL group (factor 3)
                // via best-fit against the available KVC.
                let avail = world.pool.free_tokens(Priority::Normal);
                let mut pairs: Vec<(u32, usize)> = self
                    .gt_groups
                    .keys()
                    .filter(|rl| !tried.contains(rl))
                    .map(|rl| (*rl, *rl as usize))
                    .collect();
                pairs.sort_by(|a, b| b.0.cmp(&a.0)); // descending RL
                match best_fit_leq(&pairs, avail.saturating_sub(1)) {
                    Some(pos) => pairs[pos].0,
                    None => break,
                }
            } else {
                // FCFS: group whose head arrived earliest.
                match self
                    .gt_groups
                    .iter()
                    .filter(|(rl, _)| !tried.contains(rl))
                    .min_by(|(_, a), (_, b)| {
                        let ta = world.recs[*a.front().unwrap()].req.arrival;
                        let tb = world.recs[*b.front().unwrap()].req.arrival;
                        ta.partial_cmp(&tb).unwrap()
                    })
                    .map(|(rl, _)| *rl)
                {
                    Some(rl) => rl,
                    None => break,
                }
            };

            let mut admitted = 0u32;
            let mut kvc_full = false;
            let mut hosts: Vec<ReqId> = Vec::new();
            // Admit every READY member of the group (prediction available —
            // the predictor runs concurrently with waiting/prefill,
            // §3.3.2); unready members stay queued without head-of-line
            // blocking the rest of the group or other groups.
            let mut idx = 0;
            while idx < self.gt_groups.get(&key).map(|q| q.len()).unwrap_or(0) {
                let cand = self.gt_groups[&key][idx];
                if world.pred_ready[cand] > world.clock {
                    idx += 1;
                    continue;
                }
                if !self.admit_gt(world, cand) {
                    kvc_full = true;
                    break;
                }
                self.gt_groups.get_mut(&key).unwrap().remove(idx);
                hosts.push(cand);
                admitted += 1;
            }
            if admitted > 0 {
                self.group_sizes.push(admitted);
            }
            self.gt_groups.retain(|_, q| !q.is_empty());
            // Groups whose every member is merely "not ready yet" must not
            // stop admission of other groups; only KVC exhaustion does.
            tried.insert(key);

            // Newly admitted hosts lend immediately via the same
            // frontier pass (lend_running_spans runs again below when the
            // queue still has candidates).
            let _ = hosts;

            any_admitted |= admitted > 0;
            if kvc_full {
                break; // KVC fully allocated
            }
            if self.gt_groups.keys().all(|k| tried.contains(k)) {
                break; // nothing admissible remains
            }
        }
        self.gate.failed_at = if any_admitted || self.gt_groups.is_empty() {
            None
        } else {
            Some((
                world.pool.free_tokens(Priority::Normal),
                self.gate.version,
                world.clock,
            ))
        };
    }

    /// Continuous lending (KVCPipe, §3.2 generalized): every running GT
    /// (hosts AND guests — nesting falls out naturally) lends the unused
    /// tail of its span to queued GTs, RIGHT-ALIGNED: a guest of length g
    /// goes at [frontier - g, frontier), where `frontier` is the lowest
    /// offset already lent. Safety is the same invariant as Fig 7 — the
    /// guest finishes after g iterations while the writer's head needs
    /// gap - g >= g + b more iterations to reach it (g <= gap/2 - b) —
    /// but right-alignment keeps the remaining gap contiguous, so a span
    /// keeps absorbing guests as its head advances, packing far more of
    /// the allocated-but-unwritten space than midpoint halving.
    fn lend_running_spans(&mut self, world: &mut World) {
        if self.gt_groups.is_empty() {
            return;
        }
        let writers: Vec<ReqId> = self.running_gts.clone();
        for writer in writers {
            if self.gt_groups.is_empty() {
                break;
            }
            if world.recs[writer].lost_kv > 0 || world.recs[writer].is_done() {
                continue;
            }
            let head = world.recs[writer].generated - world.recs[writer].gt_span_base;
            let span = world.recs[writer].gt_span_len;
            let mut frontier = world
                .pipes
                .guests_of(writer)
                .iter()
                .filter_map(|g| world.pipes.host_of(*g).map(|s| s.offset))
                .min()
                .unwrap_or(span);
            loop {
                let gap = frontier.saturating_sub(head);
                let b_tok = (world.cfg.buffer_frac * gap as f64).ceil() as u32;
                let target = (gap / 2).saturating_sub(b_tok);
                if target < 4 {
                    break;
                }
                let candidate = self
                    .gt_groups
                    .range(..=target)
                    .rev()
                    .find_map(|(rl, q)| {
                        q.iter()
                            .position(|&id| {
                                world.pred_ready[id] <= world.clock
                                    && world.recs[id].lost_kv == 0
                                    && !world.recs[id].is_done()
                            })
                            .map(|pos| (*rl, pos))
                    });
                let Some((rl, pos)) = candidate else { break };
                let guest = self.gt_groups.get_mut(&rl).unwrap().remove(pos).unwrap();
                if self.gt_groups[&rl].is_empty() {
                    self.gt_groups.remove(&rl);
                }
                frontier -= rl;
                world.pipes.add_guest(guest, writer, frontier, rl);
                self.guests_placed += 1;
                self.gate.version += 1;
                world.mark_exec_start(guest);
                let rec = &mut world.recs[guest];
                rec.gt_span_base = rec.generated;
                rec.gt_span_len = rl;
                rec.phase = Phase::Decoding;
                debug_assert!(!self.running_gts.contains(&guest));
                self.running_gts.push(guest);
            }
        }
    }

    /// Unsynced GT admission (variant -D): individual exact-allocations in
    /// queue order.
    fn admit_gts_unsynced(&mut self, world: &mut World) {
        let mut ids: Vec<ReqId> =
            self.gt_groups.values().flat_map(|q| q.iter().copied()).collect();
        ids.sort_by(|a, b| {
            world.recs[*a].req.arrival.partial_cmp(&world.recs[*b].req.arrival).unwrap()
        });
        for id in ids {
            if world.pred_ready[id] > world.clock {
                continue;
            }
            if !self.admit_gt(world, id) {
                break;
            }
            let rl = world.recs[id].predicted_remaining().max(1);
            // Remove from its group queue.
            for (_, q) in self.gt_groups.iter_mut() {
                if let Some(pos) = q.iter().position(|x| *x == id) {
                    q.remove(pos);
                    break;
                }
            }
            let _ = rl;
        }
        self.gt_groups.retain(|_, q| !q.is_empty());
    }

    /// PT admission: fill the GPU to TFS with prompt chunks, drawing KVC
    /// from the reservation (and beyond, if free).
    fn admit_pts(&mut self, world: &mut World, batch: &mut Batch) {
        let tfs = world.cfg.profile.tfs;
        let mut used = batch.forward_size();

        // Continue in-flight prefills (and recomputes) first.
        let inflight: Vec<ReqId> = self.running_pts.iter().copied().collect();
        for id in inflight {
            if used >= tfs {
                break;
            }
            let rec = &world.recs[id];
            let left = if rec.lost_kv > 0 {
                rec.lost_kv
            } else {
                rec.req.prompt_len - rec.prompt_done
            };
            let chunk = left.min(tfs - used);
            if chunk == 0 {
                continue;
            }
            if rec.lost_kv == 0
                && world.pool.alloc_tokens(id, chunk, Priority::Reserved).is_err()
            {
                world.col.alloc_failed_reqs.insert(id);
                continue;
            }
            batch.tasks.push(BatchTask::Prefill { id, chunk });
            used += chunk;
        }

        // Admit new PTs — but only while the GT queue's idle prompt KV
        // stays within the PT reservation. Prefilling beyond that point
        // converts pool capacity into idle waiting-GT KV (the GT queue
        // cannot drain faster than completions), strangling throughput;
        // keeping the backlog in the PT queue costs no KVC.
        let waiting_held: u32 = self
            .gt_groups
            .values()
            .flatten()
            .map(|&id| world.occupied_kvc(id))
            .sum();
        let stage_cap = ((world.cfg.kvc_tokens() as f64 * world.cfg.gt_stage_frac) as u32)
            .max(world.pool.reserve_tokens());
        if waiting_held > stage_cap {
            return;
        }
        // Selection is a repeated linear min-scan (we admit only a handful
        // per iteration, so this is cheaper than re-sorting every step).
        while used < tfs && !self.pt_queue.is_empty() {
            let pos = if self.ordering {
                (0..self.pt_queue.len())
                    .min_by_key(|&i| {
                        let id = self.pt_queue[i];
                        let rec = &world.recs[id];
                        crate::ordering::order_key(
                            world,
                            id,
                            rec.req.prompt_len - rec.prompt_done,
                        )
                    })
                    .unwrap()
            } else {
                0 // FCFS (queue is in arrival order)
            };
            let id = self.pt_queue[pos];
            let rec = &world.recs[id];
            let left = rec.req.prompt_len - rec.prompt_done;
            let chunk = left.min(tfs - used);
            if chunk == 0 {
                break;
            }
            if world.pool.alloc_tokens(id, chunk, Priority::Reserved).is_err() {
                break; // KVC exhausted even with the reservation
            }
            self.pt_queue.remove(pos);
            world.mark_exec_start(id);
            self.running_pts.push_back(id);
            batch.tasks.push(BatchTask::Prefill { id, chunk });
            used += chunk;
        }
    }
}

impl Drop for EconoServe {
    fn drop(&mut self) {
        if std::env::var("ECONO_DEBUG").is_ok() {
            eprintln!(
                "[econoserve debug] rescues={} requeues={} guests={} groups_left={} pts_left={}",
                self.reserve_rescues,
                self.requeues,
                self.guests_placed,
                self.gt_groups.values().map(|q| q.len()).sum::<usize>(),
                self.pt_queue.len(),
            );
        }
    }
}

impl Scheduler for EconoServe {
    fn name(&self) -> &'static str {
        match (self.synced, self.ordering, self.pipe) {
            (false, _, _) => "econoserve-d",
            (true, false, _) => "econoserve-sd",
            (true, true, false) => "econoserve-sdo",
            (true, true, true) => "econoserve",
        }
    }

    fn step(&mut self, world: &mut World) -> Batch {
        while let Some(id) = world.inbox.pop_front() {
            self.pt_queue.push(id);
        }
        self.process_events(world);

        // ② KVC pipelining FIRST: queued GTs whose predicted RL fits the
        // unused tail of a running host's span ride along for free. Doing
        // this before direct admission means short-RL GTs consume NO new
        // blocks, leaving the pool for long GTs and PTs — this is what
        // lifts effective packing density back to block-allocation levels
        // (§3.2's purpose).
        if self.pipe {
            self.lend_running_spans(world);
        }

        // ① Fill KVC with GTs.
        if self.synced {
            self.admit_gt_groups(world);
        } else {
            self.admit_gts_unsynced(world);
        }
        if self.pipe {
            // Freshly admitted hosts have whole spans to lend.
            self.lend_running_spans(world);
        }

        // Order GT queue state doesn't affect the running set; build batch.
        let mut batch = Batch::default();
        for &id in &self.running_gts {
            batch.tasks.push(BatchTask::Decode { id });
        }

        // ③ Fill the GPU with PTs up to TFS.
        self.admit_pts(world, &mut batch);

        // Pressure-relief valve: queued GTs keep their prompt KV resident
        // (Observation 5 makes that a feature), but under sustained
        // overload the whole pool can end up held by WAITING GTs, leaving
        // nothing schedulable. If that happens, offload-free-drop the KV
        // of the largest waiting holder (recomputed on admission) so the
        // head group can fit — the same §3.3.2 mechanism applied as a
        // deadlock guard.
        if batch.is_empty() && !self.gt_groups.is_empty() {
            let victim = self
                .gt_groups
                .values()
                .flat_map(|q| q.iter().copied())
                .filter(|id| world.pool.written_tokens(*id) > 0)
                .max_by_key(|id| world.pool.written_tokens(*id));
            if let Some(v) = victim {
                let (_, written) = world.pool.release(v);
                world.recs[v].lost_kv += written;
                world.col.preemptions += 1;
                self.requeues += 1;
            }
        }

        #[cfg(debug_assertions)]
        {
            let mut seen = std::collections::HashSet::new();
            for t in &batch.tasks {
                assert!(
                    seen.insert(t.id()),
                    "duplicate task for req {} in batch: task={t:?} in_gts={} in_pts={} in_groups={}",
                    t.id(),
                    self.running_gts.iter().filter(|x| **x == t.id()).count(),
                    self.running_pts.iter().filter(|x| **x == t.id()).count(),
                    self.gt_groups.values().flatten().filter(|x| **x == t.id()).count(),
                );
                assert!(
                    world.pool.alloc_of(t.id()).is_some() || world.pipes.is_guest(t.id()),
                    "req {} batched without allocation (phase {:?})",
                    t.id(),
                    world.recs[t.id()].phase
                );
            }
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::coordinator::{run, RunLimits};
    use crate::engine::{Engine, SimEngine};
    use crate::predictor::{OraclePredictor, SimPredictor};
    use crate::trace::TraceItem;

    fn world(items: &[TraceItem], kvc_tokens: u64, oracle: bool) -> World {
        let mut profile = ModelProfile::opt_13b();
        profile.kvc_bytes = 819_200 * kvc_tokens;
        let mut cfg = SystemConfig::new(profile);
        cfg.padding_ratio = 0.10;
        cfg.reserve_frac = 0.05;
        if oracle {
            World::new(cfg, items, Box::new(OraclePredictor::new(32)))
        } else {
            World::new(cfg, items, Box::new(SimPredictor::for_trace("sharegpt", 32, 7)))
        }
    }

    fn drive(w: &mut World, s: &mut EconoServe, iters: usize) {
        let e = SimEngine::new();
        for _ in 0..iters {
            w.drain_arrivals();
            let b = s.step(w);
            if b.is_empty() {
                if let Some(t) = w.next_arrival() {
                    w.clock = t;
                    continue;
                }
                break;
            }
            let (d, u) = e.iteration_cost(&b, w);
            w.execute_iteration(&b, d, u);
        }
    }

    #[test]
    fn pts_added_every_iteration_with_reserve() {
        // Saturate KVC with GTs, then check a late PT still gets prefilled
        // (the decoupling + reservation headline property, Fig 8b).
        let mut items: Vec<TraceItem> = (0..40)
            .map(|i| TraceItem { arrival: i as f64 * 1e-3, prompt_len: 32, true_rl: 200 })
            .collect();
        items.push(TraceItem { arrival: 1.0, prompt_len: 64, true_rl: 8 });
        let mut w = world(&items, 4096, true);
        let mut s = EconoServe::full();
        let e = SimEngine::new();
        let mut late_pt_prefilled_alongside_decodes = false;
        for _ in 0..3000 {
            w.drain_arrivals();
            let b = s.step(&mut w);
            if b.is_empty() {
                match w.next_arrival() {
                    Some(t) => {
                        w.clock = t;
                        continue;
                    }
                    None => break,
                }
            }
            if w.clock >= 1.0
                && b.decode_count() > 0
                && b.tasks.iter().any(|t| matches!(t, BatchTask::Prefill { id: 40, .. }))
            {
                late_pt_prefilled_alongside_decodes = true;
            }
            let (d, u) = e.iteration_cost(&b, &w);
            w.execute_iteration(&b, d, u);
            if w.all_done() {
                break;
            }
        }
        assert!(late_pt_prefilled_alongside_decodes, "PT never joined a decode iteration");
        assert!(w.all_done());
    }

    #[test]
    fn same_rl_gts_form_groups() {
        let items: Vec<TraceItem> = (0..12)
            .map(|i| TraceItem { arrival: i as f64 * 1e-3, prompt_len: 16, true_rl: 60 })
            .collect();
        let mut w = world(&items, 8192, true);
        let mut s = EconoServe::variant_sd();
        drive(&mut w, &mut s, 4000);
        assert!(w.all_done());
        assert!(
            s.group_sizes.iter().any(|g| *g >= 4),
            "expected a multi-member group, got {:?}",
            s.group_sizes
        );
    }

    #[test]
    fn kvc_pipelining_hosts_guests() {
        // Long-RL hosts admitted first; short-RL guests should ride along
        // without new allocations.
        let mut items: Vec<TraceItem> = (0..6)
            .map(|i| TraceItem { arrival: i as f64 * 1e-4, prompt_len: 16, true_rl: 256 })
            .collect();
        for i in 0..6 {
            items.push(TraceItem {
                arrival: 0.01 + i as f64 * 1e-4,
                prompt_len: 16,
                true_rl: 60, // fits 256/2 - b
            });
        }
        let mut w = world(&items, 3000, true);
        let mut s = EconoServe::full();
        let e = SimEngine::new();
        let mut saw_guest = false;
        for _ in 0..5000 {
            w.drain_arrivals();
            let b = s.step(&mut w);
            if w.pipes.guest_count() > 0 {
                saw_guest = true;
            }
            if b.is_empty() {
                match w.next_arrival() {
                    Some(t) => {
                        w.clock = t;
                        continue;
                    }
                    None => break,
                }
            }
            let (d, u) = e.iteration_cost(&b, &w);
            w.execute_iteration(&b, d, u);
            if w.all_done() {
                break;
            }
        }
        assert!(saw_guest, "pipelining never hosted a guest");
        assert!(w.all_done());
        assert_eq!(w.col.pipeline_evictions, 0, "oracle predictions => no evictions");
    }

    #[test]
    fn underprediction_rescued_or_requeued() {
        let items: Vec<TraceItem> = (0..30)
            .map(|i| TraceItem {
                arrival: i as f64 * 0.01,
                prompt_len: 24,
                true_rl: 40 + (i as u32 % 11) * 29,
            })
            .collect();
        let mut w = world(&items, 4096, false); // noisy predictor
        let mut s = EconoServe::full();
        let e = SimEngine::new();
        let res = run(&mut w, &mut s, &e, RunLimits::default());
        assert_eq!(res.summary.n_done, 30);
        assert!(
            s.reserve_rescues + s.requeues > 0,
            "noisy predictions must trigger misprediction handling"
        );
    }

    #[test]
    fn all_variants_complete() {
        let items: Vec<TraceItem> = (0..25)
            .map(|i| TraceItem {
                arrival: i as f64 * 0.02,
                prompt_len: 16 + (i as u32 % 5) * 24,
                true_rl: 10 + (i as u32 % 7) * 20,
            })
            .collect();
        for mk in [
            EconoServe::variant_d as fn() -> EconoServe,
            EconoServe::variant_sd,
            EconoServe::variant_sdo,
            EconoServe::full,
        ] {
            let mut w = world(&items, 8192, true);
            let mut s = mk();
            let e = SimEngine::new();
            let res = run(&mut w, &mut s, &e, RunLimits::default());
            assert_eq!(res.summary.n_done, 25, "variant {} incomplete", s.name());
        }
    }
}
