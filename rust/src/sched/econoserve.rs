//! ECONOSERVE (§3): the paper's scheduler, with its ablation ladder.
//!
//! Components (each gated by a flag so the §4 variants fall out):
//!
//!  * **Decoupling** (always on — variant `-D` baseline): separate PT and
//!    GT waiting queues. GTs are responsible for *fully allocating the
//!    KVC* (exact-allocation leases of the padded predicted RL); PTs are
//!    responsible for *filling the GPU* up to the target forward size,
//!    drawing KVC from the PT reservation. PTs can therefore be added in
//!    EVERY iteration (Fig 8b), fixing the GT-domination issue.
//!  * **Time-synced batching** (`synced`, `-SD`): the GT queue is grouped
//!    by (padded, quantized) predicted RL; whole groups are admitted and
//!    complete together, so scheduling is per-group (low overhead).
//!    Under-provisioned members first try the reserved KVC, then are
//!    re-grouped at a re-predicted RL with their KV kept resident
//!    (offload-free, Observation 4).
//!  * **Ordering** (`ordering`, `-SDO`): both queues ordered by (deadline
//!    bucket ↑, occupied KVC ↓, length ↓). The PT queue is an
//!    incremental [`BucketQueue`] (no per-iteration re-sort); GT group
//!    selection is a best-fit range query on the RL-keyed group map —
//!    §3.4's "binary search for the closest length" served directly from
//!    the ordered structure.
//!  * **KVC pipelining** (full system): handled on the *allocation axis* —
//!    the scheduler offers every queued GT to running spans through the
//!    allocator's lending API; under `pipelined-exact` (the full system's
//!    default pairing) guests ride in a host's span for free, while the
//!    plain `exact` allocator (the `-SDO` pairing) lends nothing, so the
//!    ablation falls out of the registry rather than a scheduler flag.
//!
//! Hot-path contracts (see docs/API.md "Hot-path complexity contracts"):
//! membership tests and removals on the running sets are O(1)
//! ([`IndexedList`]), PT selection is O(log n) ([`BucketQueue`]), GT
//! group choice is O(log groups), and the queued-GT KVC footprint used
//! by the admission gate is maintained incrementally instead of being
//! re-summed every iteration.

use std::collections::{BTreeMap, BTreeSet, HashSet, VecDeque};

use super::Scheduler;
use crate::config::PreemptMode;
use crate::core::world::IterCtx;
use crate::core::{BatchPlan, BatchTask, IndexedList, Phase, ReqId};
use crate::kvc::{Allocator, Demand, ReserveClass};
use crate::ordering::{BucketQueue, QueuePolicy};

pub struct EconoServe {
    /// Registry label (the ablation rung; behaviour differences between
    /// `-SDO` and the full system live on the allocation axis).
    label: &'static str,
    synced: bool,
    ordering: bool,
    /// Waiting PTs (not yet started prefilling), bucket-ordered (§3.4) or
    /// FCFS depending on the `ordering` flag.
    pt_queue: BucketQueue,
    /// PTs currently prefilling (chunked), in admission order. Also holds
    /// preempted GTs doing KV recompute.
    running_pts: IndexedList,
    /// Waiting GTs: predicted remaining RL -> FIFO queue.
    gt_groups: BTreeMap<u32, VecDeque<ReqId>>,
    /// GTs currently decoding (hosts and guests alike).
    running_gts: IndexedList,
    /// Arrival-ordered view of the queued GTs, maintained only for the
    /// unsynced `-D` rung (replaces its per-iteration arrival re-sort).
    arrival_fifo: BTreeSet<(u64, ReqId)>,
    /// Per-id arrival-time bits for `arrival_fifo` removal without a ctx.
    fifo_key: Vec<u64>,
    /// Occupied-KVC snapshot per queued GT, and their running total: the
    /// PT admission gate's "idle waiting-GT KV" figure in O(1) instead of
    /// an every-iteration sweep over the queue.
    held_snap: Vec<u32>,
    waiting_held: u64,
    /// Group sizes admitted together (Fig 2 instrumentation).
    pub group_sizes: Vec<u32>,
    /// Count of GTs rescued by the reserve vs re-queued (Fig 5b).
    pub reserve_rescues: u64,
    pub requeues: u64,
    /// Guests placed by KVC pipelining (instrumentation).
    pub guests_placed: u64,
    /// Admission retry gate: skip the group scan when nothing changed
    /// since the last failed attempt (keeps the per-iteration scheduling
    /// cost O(running), the paper's low-overhead claim).
    gate: AdmitGate,
    /// Reusable scratch (zero-allocation steady state).
    tried: BTreeSet<u32>,
    handled: HashSet<ReqId>,
}

#[derive(Default)]
struct AdmitGate {
    /// (free tokens, queue version, clock) at the last failed admission.
    failed_at: Option<(u32, u64, f64)>,
    version: u64,
}

impl EconoServe {
    fn with_flags(label: &'static str, synced: bool, ordering: bool) -> Self {
        EconoServe {
            label,
            synced,
            ordering,
            pt_queue: BucketQueue::new(if ordering {
                QueuePolicy::EconoServe
            } else {
                QueuePolicy::Fcfs
            }),
            running_pts: IndexedList::new(),
            gt_groups: BTreeMap::new(),
            running_gts: IndexedList::new(),
            arrival_fifo: BTreeSet::new(),
            fifo_key: Vec::new(),
            held_snap: Vec::new(),
            waiting_held: 0,
            group_sizes: Vec::new(),
            reserve_rescues: 0,
            requeues: 0,
            guests_placed: 0,
            gate: AdmitGate::default(),
            tried: BTreeSet::new(),
            handled: HashSet::new(),
        }
    }

    /// `UnsyncedDecoupled`: decoupling + exact-allocation only.
    pub fn variant_d() -> Self {
        Self::with_flags("econoserve-d", false, false)
    }

    /// `SyncDecoupled`: + time-synced GT groups.
    pub fn variant_sd() -> Self {
        Self::with_flags("econoserve-sd", true, false)
    }

    /// + task Ordering.
    pub fn variant_sdo() -> Self {
        Self::with_flags("econoserve-sdo", true, true)
    }

    /// Full system: + KVC pipelining (via the `pipelined-exact` pairing).
    pub fn full() -> Self {
        Self::with_flags("econoserve", true, true)
    }

    fn ensure_slabs(&mut self, id: ReqId) {
        if id >= self.held_snap.len() {
            self.held_snap.resize(id + 1, 0);
            self.fifo_key.resize(id + 1, 0);
        }
    }

    /// Bookkeeping shared by every GT-queue insertion: occupied-KVC
    /// snapshot (admission-gate total) and the unsynced arrival index.
    fn enqueue_bookkeeping(&mut self, ctx: &IterCtx<'_>, id: ReqId) {
        self.ensure_slabs(id);
        let occ = ctx.world().occupied_kvc(id);
        self.held_snap[id] = occ;
        self.waiting_held += occ as u64;
        if !self.synced {
            let bits = ctx.rec(id).req.arrival.to_bits();
            self.fifo_key[id] = bits;
            self.arrival_fifo.insert((bits, id));
        }
        self.gate.version += 1;
    }

    /// Bookkeeping shared by every GT-queue removal (O(log n), no ctx
    /// needed — the snapshot carries everything).
    fn dequeue_bookkeeping(&mut self, id: ReqId) {
        if id < self.held_snap.len() {
            self.waiting_held -= self.held_snap[id] as u64;
            self.held_snap[id] = 0;
        }
        if !self.synced {
            let bits = self.fifo_key.get(id).copied().unwrap_or(0);
            self.arrival_fifo.remove(&(bits, id));
        }
    }

    fn enqueue_gt(&mut self, ctx: &IterCtx<'_>, id: ReqId) {
        let rl = ctx.rec(id).predicted_remaining().max(1);
        self.gt_groups.entry(rl).or_default().push_back(id);
        self.enqueue_bookkeeping(ctx, id);
    }

    /// Put a lend-refused candidate back at the FRONT of its group.
    fn requeue_front(&mut self, ctx: &IterCtx<'_>, rl: u32, id: ReqId) {
        self.gt_groups.entry(rl).or_default().push_front(id);
        self.enqueue_bookkeeping(ctx, id);
    }

    /// Remove a queued GT from its RL group (scans ONE group's deque —
    /// the slow path used by unsynced admission, lending and tests; the
    /// synced admission loop removes by index directly).
    fn remove_from_group(&mut self, rl: u32, id: ReqId) -> bool {
        let Some(q) = self.gt_groups.get_mut(&rl) else { return false };
        let found = q.iter().enumerate().find(|(_, x)| **x == id).map(|(i, _)| i);
        let Some(i) = found else { return false };
        q.remove(i);
        if q.is_empty() {
            self.gt_groups.remove(&rl);
        }
        self.dequeue_bookkeeping(id);
        self.gate.version += 1;
        true
    }

    /// Handle the previous iteration's events. Event vectors are taken
    /// out, iterated, and handed back cleared so their capacity is reused
    /// next iteration.
    fn process_events(&mut self, ctx: &mut IterCtx<'_>) {
        self.running_gts.retain(|id| !ctx.world().recs[id].is_done());
        self.running_pts.retain(|id| !ctx.world().recs[id].is_done());

        // PTs that finished prefilling become queued GTs.
        let mut ev = std::mem::take(&mut ctx.events.finished_prefill);
        for &id in &ev {
            self.running_pts.remove(id);
            self.enqueue_gt(ctx, id);
        }
        ev.clear();
        ctx.events.finished_prefill = ev;

        // Recompute done: the GT resumes decoding.
        let mut ev = std::mem::take(&mut ctx.events.recompute_done);
        for &id in &ev {
            self.running_pts.remove(id);
            debug_assert!(!self.running_gts.contains(id), "dup push at recompute_done for {id}");
            self.running_gts.push(id);
        }
        ev.clear();
        ctx.events.recompute_done = ev;

        // Under-provisioned GTs (§3.3.2): reserve first, then offload-free
        // re-queue at the re-predicted remaining RL. A GT can appear both
        // here and in evicted_guests within one iteration — handle once.
        self.handled.clear();
        let mut ev = std::mem::take(&mut ctx.events.reached_prediction);
        for &id in &ev {
            if ctx.rec(id).is_done() || !self.handled.insert(id) {
                continue;
            }
            let new_rem = ctx.re_predict(id);
            let use_reserve = matches!(
                ctx.cfg().preempt_mode,
                PreemptMode::ReservedThenFree | PreemptMode::OffloadSwap
            );
            let rescued = use_reserve
                && !ctx.kvc().is_guest(id)
                && ctx.alloc().extend(id, new_rem + 1, ReserveClass::Reserved).ok();
            if rescued {
                self.reserve_rescues += 1;
                // Span extends; guests were placed against the OLD span, so
                // their offsets stay valid (the head only moves forward).
                ctx.rec_mut(id).gt_span_len += new_rem;
            } else {
                // Offload-free: stop decoding, KEEP the written KV resident
                // (trim over-provisioned blocks), re-enter the GT queue.
                self.running_gts.remove(id);
                if ctx.kvc().is_guest(id) {
                    // Guests lose their borrowed space (host keeps running).
                    ctx.evict_guest(id);
                } else {
                    // Re-home or drop this host's guests first, then trim
                    // the over-provisioned tail of its own lease.
                    self.detach_guests_for_trim(ctx, id);
                    ctx.alloc().shrink_to_written(id);
                }
                ctx.requeue_gt(id);
                self.requeues += 1;
                self.enqueue_gt(ctx, id);
            }
        }
        ev.clear();
        ctx.events.reached_prediction = ev;

        // Evicted guests re-enter the GT queue (they carry lost_kv that is
        // recomputed when they are re-admitted).
        let mut ev = std::mem::take(&mut ctx.events.evicted_guests);
        for &id in &ev {
            if ctx.rec(id).is_done() || !self.handled.insert(id) {
                continue;
            }
            self.running_gts.remove(id);
            ctx.re_predict(id);
            self.enqueue_gt(ctx, id);
        }
        ev.clear();
        ctx.events.evicted_guests = ev;
    }

    /// Re-home or drop the direct guests of `host` before its unused
    /// span is trimmed away.
    fn detach_guests_for_trim(&mut self, ctx: &mut IterCtx<'_>, host: ReqId) {
        let guests = ctx.alloc().detach_host(host);
        for g in guests {
            if ctx.rec(g).is_done() {
                continue;
            }
            let need = ctx.kvc().guest_written(g) + ctx.rec(g).predicted_remaining() + 1;
            if ctx.alloc().adopt(g, need).ok() {
                continue; // transferred onto its own lease
            }
            // Same as a world eviction: drop guest KV, re-queue.
            self.running_gts.remove(g);
            ctx.evict_guest(g);
            ctx.requeue_gt(g);
            ctx.metrics_mut().pipeline_evictions += 1;
            self.enqueue_gt(ctx, g);
        }
    }

    /// Admit one GT from a group: exact-alloc its remaining span
    /// (+ pending recompute work). Returns false on KVC exhaustion.
    /// Queue removal and its bookkeeping are the CALLER's job.
    fn admit_gt(&mut self, ctx: &mut IterCtx<'_>, id: ReqId) -> bool {
        let remaining = ctx.rec(id).predicted_remaining().max(1);
        let demand = Demand {
            immediate: ctx.rec(id).lost_kv,
            predicted: remaining,
            max_total: ctx.cfg().profile.max_total_len,
        };
        if !ctx.alloc().admit(id, demand, ReserveClass::Normal).ok() {
            return false;
        }
        ctx.mark_exec_start(id);
        let rec = ctx.rec_mut(id);
        rec.gt_span_base = rec.generated;
        rec.gt_span_len = remaining;
        if rec.lost_kv > 0 {
            // Needs recompute first: treat like prefill work.
            self.running_pts.push_front(id);
        } else {
            rec.phase = Phase::Decoding;
            debug_assert!(!self.running_gts.contains(id), "dup push at admit_gt for {id}");
            self.running_gts.push(id);
        }
        true
    }

    /// Time-synced group admission: pick groups (ordered or FCFS-oldest),
    /// admit members until the KVC is fully allocated; split when needed.
    fn admit_gt_groups(&mut self, ctx: &mut IterCtx<'_>) {
        // Retry gate: if the last attempt failed and neither the free
        // space, the queue, nor (materially) the clock has changed, the
        // scan would fail again — skip it.
        if let Some((free, ver, at)) = self.gate.failed_at {
            if ctx.kvc().free_tokens(ReserveClass::Normal) == free
                && ver == self.gate.version
                && ctx.clock() - at < 0.05
            {
                return;
            }
        }
        let mut any_admitted = false;
        self.tried.clear();
        loop {
            if self.gt_groups.is_empty() {
                break;
            }
            // Choose the next group.
            let chosen = if self.ordering {
                // Best fit straight off the ordered group map (§3.4): the
                // LONGEST RL group that fits the available KVC, skipping
                // groups already tried this round. O(log groups + tried).
                let avail = ctx.kvc().free_tokens(ReserveClass::Normal);
                let cap = avail.saturating_sub(1);
                self.gt_groups
                    .range(..=cap)
                    .rev()
                    .map(|(rl, _)| *rl)
                    .find(|rl| !self.tried.contains(rl))
            } else {
                // FCFS: group whose head arrived earliest (O(groups)).
                let mut best: Option<(f64, u32)> = None;
                for (rl, q) in self.gt_groups.iter() {
                    if self.tried.contains(rl) {
                        continue;
                    }
                    let head = *q.front().expect("empty group retained");
                    let ta = ctx.rec(head).req.arrival;
                    if best.map(|(t, _)| ta <= t).unwrap_or(true) {
                        best = Some((ta, *rl));
                    }
                }
                best.map(|(_, rl)| rl)
            };
            let Some(key) = chosen else { break };

            let mut admitted = 0u32;
            let mut kvc_full = false;
            // Admit every READY member of the group (prediction available —
            // the predictor runs concurrently with waiting/prefill,
            // §3.3.2); unready members stay queued without head-of-line
            // blocking the rest of the group or other groups.
            let mut idx = 0;
            while idx < self.gt_groups.get(&key).map(|q| q.len()).unwrap_or(0) {
                let cand = self.gt_groups[&key][idx];
                if !ctx.pred_ready(cand) {
                    idx += 1;
                    continue;
                }
                if !self.admit_gt(ctx, cand) {
                    kvc_full = true;
                    break;
                }
                self.gt_groups.get_mut(&key).expect("group vanished").remove(idx);
                self.dequeue_bookkeeping(cand);
                admitted += 1;
            }
            if admitted > 0 {
                self.group_sizes.push(admitted);
            }
            self.gt_groups.retain(|_, q| !q.is_empty());
            // Groups whose every member is merely "not ready yet" must not
            // stop admission of other groups; only KVC exhaustion does.
            self.tried.insert(key);

            any_admitted |= admitted > 0;
            if kvc_full {
                break; // KVC fully allocated
            }
            if self.gt_groups.keys().all(|k| self.tried.contains(k)) {
                break; // nothing admissible remains
            }
        }
        self.gate.failed_at = if any_admitted || self.gt_groups.is_empty() {
            None
        } else {
            Some((
                ctx.kvc().free_tokens(ReserveClass::Normal),
                self.gate.version,
                ctx.clock(),
            ))
        };
    }

    /// Continuous lending (KVCPipe, §3.2 generalized): every running GT
    /// (hosts AND guests — nesting falls out naturally) offers the unused
    /// tail of its span to queued GTs through the allocator's lending API,
    /// RIGHT-ALIGNED: a guest of length g goes at [frontier - g, frontier),
    /// where `frontier` is the lowest offset already lent. Safety is the
    /// same invariant as Fig 7 — the guest finishes after g iterations
    /// while the writer's head needs gap - g >= g + b more iterations to
    /// reach it (g <= gap/2 - b) — but right-alignment keeps the remaining
    /// gap contiguous, so a span keeps absorbing guests as its head
    /// advances. Under a non-pipelined allocator `lend_capacity` is 0 and
    /// this is a no-op — the `-SDO` ablation rung.
    fn lend_running_spans(&mut self, ctx: &mut IterCtx<'_>) {
        if self.gt_groups.is_empty() {
            return;
        }
        let buffer_frac = ctx.cfg().buffer_frac;
        // Index loop: pushes during the loop append (stable raw slots),
        // so no snapshot clone of the running set is needed.
        let n_writers = self.running_gts.raw_len();
        for wi in 0..n_writers {
            if self.gt_groups.is_empty() {
                break;
            }
            let Some(writer) = self.running_gts.get_raw(wi) else { continue };
            if ctx.rec(writer).lost_kv > 0 || ctx.rec(writer).is_done() {
                continue;
            }
            let head = ctx.rec(writer).generated - ctx.rec(writer).gt_span_base;
            let span = ctx.rec(writer).gt_span_len;
            loop {
                let target = ctx.kvc().lend_capacity(writer, span, head, buffer_frac);
                if target < 4 {
                    break;
                }
                // Longest queued GT with rl <= target whose member is
                // ready and clean (first such member per group, FIFO).
                let mut candidate: Option<(u32, ReqId)> = None;
                'groups: for (rl, q) in self.gt_groups.range(..=target).rev() {
                    for &gid in q.iter() {
                        if ctx.pred_ready(gid)
                            && ctx.rec(gid).lost_kv == 0
                            && !ctx.rec(gid).is_done()
                        {
                            candidate = Some((*rl, gid));
                            break 'groups;
                        }
                    }
                }
                let Some((rl, guest)) = candidate else { break };
                self.remove_from_group(rl, guest);
                if !ctx.alloc().lend(writer, span, head, buffer_frac, guest, rl).ok() {
                    // The mechanism re-checked the invariant and refused:
                    // put the candidate back and stop lending this span.
                    self.requeue_front(ctx, rl, guest);
                    break;
                }
                self.guests_placed += 1;
                ctx.mark_exec_start(guest);
                let rec = ctx.rec_mut(guest);
                rec.gt_span_base = rec.generated;
                rec.gt_span_len = rl;
                rec.phase = Phase::Decoding;
                debug_assert!(!self.running_gts.contains(guest));
                self.running_gts.push(guest);
            }
        }
    }

    /// Unsynced GT admission (variant -D): individual exact leases in
    /// arrival order, served from the incremental arrival index instead
    /// of a per-iteration re-sort.
    fn admit_gts_unsynced(&mut self, ctx: &mut IterCtx<'_>) {
        let mut cursor: Option<(u64, ReqId)> = None;
        loop {
            let next = match cursor {
                None => self.arrival_fifo.iter().next().copied(),
                Some(c) => self
                    .arrival_fifo
                    .range((std::ops::Bound::Excluded(c), std::ops::Bound::Unbounded))
                    .next()
                    .copied(),
            };
            let Some((bits, id)) = next else { break };
            cursor = Some((bits, id));
            if !ctx.pred_ready(id) {
                continue;
            }
            let rl = ctx.rec(id).predicted_remaining().max(1);
            if !self.admit_gt(ctx, id) {
                break;
            }
            self.remove_from_group(rl, id);
        }
    }

    /// PT admission: fill the GPU to TFS with prompt chunks, drawing KVC
    /// from the reservation (and beyond, if free).
    fn admit_pts(&mut self, ctx: &mut IterCtx<'_>, plan: &mut BatchPlan) {
        let tfs = ctx.cfg().profile.tfs;
        let mut used = plan.forward_size();

        // Continue in-flight prefills (and recomputes) first. Index loop:
        // nothing is removed from running_pts inside it.
        let n_inflight = self.running_pts.raw_len();
        for i in 0..n_inflight {
            if used >= tfs {
                break;
            }
            let Some(id) = self.running_pts.get_raw(i) else { continue };
            let rec = ctx.rec(id);
            let lost = rec.lost_kv;
            let left = if lost > 0 { lost } else { rec.req.prompt_len - rec.prompt_done };
            let chunk = left.min(tfs - used);
            if chunk == 0 {
                continue;
            }
            if lost == 0 && !ctx.alloc().extend(id, chunk, ReserveClass::Reserved).ok() {
                ctx.note_alloc_failed(id);
                continue;
            }
            plan.tasks.push(BatchTask::Prefill { id, chunk });
            used += chunk;
        }

        // Admit new PTs — but only while the GT queue's idle prompt KV
        // stays within the PT reservation. Prefilling beyond that point
        // converts KVC capacity into idle waiting-GT KV (the GT queue
        // cannot drain faster than completions), strangling throughput;
        // keeping the backlog in the PT queue costs no KVC. The footprint
        // total is maintained incrementally at GT enqueue/dequeue.
        let stage_cap = ((ctx.cfg().kvc_tokens() as f64 * ctx.cfg().gt_stage_frac) as u32)
            .max(ctx.kvc().reserve_tokens());
        if self.waiting_held > stage_cap as u64 {
            return;
        }
        // Selection is an O(log n) bucket-queue pop per admitted PT
        // (ordered variant) or FIFO (FCFS variant) — no scans.
        while used < tfs && !self.pt_queue.is_empty() {
            let clock = ctx.clock();
            let Some(id) = self.pt_queue.peek_first(clock) else { break };
            let rec = ctx.rec(id);
            let left = rec.req.prompt_len - rec.prompt_done;
            let chunk = left.min(tfs - used);
            if chunk == 0 {
                break;
            }
            if !ctx.alloc().extend(id, chunk, ReserveClass::Reserved).ok() {
                break; // KVC exhausted even with the reservation
            }
            self.pt_queue.pop_first(clock);
            ctx.mark_exec_start(id);
            self.running_pts.push(id);
            plan.tasks.push(BatchTask::Prefill { id, chunk });
            used += chunk;
        }
    }
}

impl Drop for EconoServe {
    fn drop(&mut self) {
        if std::env::var("ECONO_DEBUG").is_ok() {
            eprintln!(
                "[econoserve debug] rescues={} requeues={} guests={} groups_left={} pts_left={}",
                self.reserve_rescues,
                self.requeues,
                self.guests_placed,
                self.gt_groups.values().map(|q| q.len()).sum::<usize>(),
                self.pt_queue.len(),
            );
        }
    }
}

impl Scheduler for EconoServe {
    fn name(&self) -> &'static str {
        self.label
    }

    fn plan(&mut self, ctx: &mut IterCtx<'_>) -> BatchPlan {
        while let Some(id) = ctx.pop_arrival() {
            let (deadline, len) = {
                let rec = ctx.rec(id);
                (rec.req.deadline, rec.req.prompt_len - rec.prompt_done)
            };
            self.pt_queue.push(id, 0, deadline, 0, len, ctx.clock());
        }
        self.process_events(ctx);

        // ② KVC pipelining FIRST: queued GTs whose predicted RL fits the
        // unused tail of a running host's span ride along for free. Doing
        // this before direct admission means short-RL GTs consume NO new
        // blocks, leaving capacity for long GTs and PTs — this is what
        // lifts effective packing density back to block-allocation levels
        // (§3.2's purpose). A no-op under non-lending allocators.
        self.lend_running_spans(ctx);

        // ① Fill KVC with GTs.
        if self.synced {
            self.admit_gt_groups(ctx);
        } else {
            self.admit_gts_unsynced(ctx);
        }
        // Freshly admitted hosts have whole spans to lend.
        self.lend_running_spans(ctx);

        // Order GT queue state doesn't affect the running set; build plan
        // from the recycled buffer (zero-allocation steady state).
        let mut plan = ctx.take_plan();
        for id in self.running_gts.iter() {
            plan.tasks.push(BatchTask::Decode { id });
        }

        // ③ Fill the GPU with PTs up to TFS.
        self.admit_pts(ctx, &mut plan);

        // Pressure-relief valve: queued GTs keep their prompt KV resident
        // (Observation 5 makes that a feature), but under sustained
        // overload the whole KVC can end up held by WAITING GTs, leaving
        // nothing schedulable. If that happens, offload-free-drop the KV
        // of the largest waiting holder (recomputed on admission) so the
        // head group can fit — the same §3.3.2 mechanism applied as a
        // deadlock guard.
        if plan.is_empty() && !self.gt_groups.is_empty() {
            let victim = self
                .gt_groups
                .values()
                .flat_map(|q| q.iter().copied())
                .filter(|id| ctx.kvc().written(*id) > 0)
                .max_by_key(|id| ctx.kvc().written(*id));
            if let Some(v) = victim {
                let rel = ctx.alloc().release(v);
                ctx.rec_mut(v).lost_kv += rel.written;
                // The still-queued victim's resident footprint fell to 0.
                if v < self.held_snap.len() {
                    self.waiting_held -= self.held_snap[v] as u64;
                    self.held_snap[v] = 0;
                }
                ctx.metrics_mut().preemptions += 1;
                self.requeues += 1;
            }
        }

        #[cfg(debug_assertions)]
        {
            let mut seen = std::collections::HashSet::new();
            for t in &plan.tasks {
                assert!(
                    seen.insert(t.id()),
                    "duplicate task for req {} in plan: task={t:?} in_gts={} in_pts={} in_groups={}",
                    t.id(),
                    self.running_gts.contains(t.id()),
                    self.running_pts.contains(t.id()),
                    self.gt_groups.values().flatten().filter(|x| **x == t.id()).count(),
                );
                assert!(
                    ctx.kvc().lease_of(t.id()).is_some() || ctx.kvc().is_guest(t.id()),
                    "req {} batched without a lease (phase {:?})",
                    t.id(),
                    ctx.rec(t.id()).phase
                );
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::coordinator::{run, RunLimits};
    use crate::core::world::World;
    use crate::engine::{Engine, SimEngine};
    use crate::predictor::{OraclePredictor, SimPredictor};
    use crate::sched::plan_iteration;
    use crate::trace::TraceItem;

    fn world(items: &[TraceItem], kvc_tokens: u64, oracle: bool) -> World {
        let mut profile = ModelProfile::opt_13b();
        profile.kvc_bytes = 819_200 * kvc_tokens;
        let mut cfg = SystemConfig::new(profile);
        cfg.padding_ratio = 0.10;
        cfg.reserve_frac = 0.05;
        let mut w = if oracle {
            World::new(cfg, items, Box::new(OraclePredictor::new(32)))
        } else {
            World::new(cfg, items, Box::new(SimPredictor::for_trace("sharegpt", 32, 7)))
        };
        w.set_allocator("pipelined-exact");
        w
    }

    fn drive(w: &mut World, s: &mut EconoServe, iters: usize) {
        let e = SimEngine::new();
        for _ in 0..iters {
            w.drain_arrivals();
            let b = plan_iteration(w, s);
            if b.is_empty() {
                if let Some(t) = w.next_arrival() {
                    w.clock = t;
                    continue;
                }
                break;
            }
            let (d, u) = e.iteration_cost(&b, w);
            w.apply_plan(&b, d, u);
        }
    }

    #[test]
    fn pts_added_every_iteration_with_reserve() {
        // Saturate KVC with GTs, then check a late PT still gets prefilled
        // (the decoupling + reservation headline property, Fig 8b).
        let mut items: Vec<TraceItem> = (0..40)
            .map(|i| TraceItem { arrival: i as f64 * 1e-3, prompt_len: 32, true_rl: 200 })
            .collect();
        items.push(TraceItem { arrival: 1.0, prompt_len: 64, true_rl: 8 });
        let mut w = world(&items, 4096, true);
        let mut s = EconoServe::full();
        let e = SimEngine::new();
        let mut late_pt_prefilled_alongside_decodes = false;
        for _ in 0..3000 {
            w.drain_arrivals();
            let b = plan_iteration(&mut w, &mut s);
            if b.is_empty() {
                match w.next_arrival() {
                    Some(t) => {
                        w.clock = t;
                        continue;
                    }
                    None => break,
                }
            }
            if w.clock >= 1.0
                && b.decode_count() > 0
                && b.tasks.iter().any(|t| matches!(t, BatchTask::Prefill { id: 40, .. }))
            {
                late_pt_prefilled_alongside_decodes = true;
            }
            let (d, u) = e.iteration_cost(&b, &w);
            w.apply_plan(&b, d, u);
            if w.all_done() {
                break;
            }
        }
        assert!(late_pt_prefilled_alongside_decodes, "PT never joined a decode iteration");
        assert!(w.all_done());
    }

    #[test]
    fn same_rl_gts_form_groups() {
        let items: Vec<TraceItem> = (0..12)
            .map(|i| TraceItem { arrival: i as f64 * 1e-3, prompt_len: 16, true_rl: 60 })
            .collect();
        let mut w = world(&items, 8192, true);
        w.set_allocator("exact"); // the -SD rung pairs with plain exact
        let mut s = EconoServe::variant_sd();
        drive(&mut w, &mut s, 4000);
        assert!(w.all_done());
        assert!(
            s.group_sizes.iter().any(|g| *g >= 4),
            "expected a multi-member group, got {:?}",
            s.group_sizes
        );
    }

    #[test]
    fn kvc_pipelining_hosts_guests() {
        // Long-RL hosts admitted first; short-RL guests should ride along
        // without new leases.
        let mut items: Vec<TraceItem> = (0..6)
            .map(|i| TraceItem { arrival: i as f64 * 1e-4, prompt_len: 16, true_rl: 256 })
            .collect();
        for i in 0..6 {
            items.push(TraceItem {
                arrival: 0.01 + i as f64 * 1e-4,
                prompt_len: 16,
                true_rl: 60, // fits 256/2 - b
            });
        }
        let mut w = world(&items, 3000, true);
        let mut s = EconoServe::full();
        let e = SimEngine::new();
        let mut saw_guest = false;
        for _ in 0..5000 {
            w.drain_arrivals();
            let b = plan_iteration(&mut w, &mut s);
            if w.kvc().guest_count() > 0 {
                saw_guest = true;
            }
            if b.is_empty() {
                match w.next_arrival() {
                    Some(t) => {
                        w.clock = t;
                        continue;
                    }
                    None => break,
                }
            }
            let (d, u) = e.iteration_cost(&b, &w);
            w.apply_plan(&b, d, u);
            if w.all_done() {
                break;
            }
        }
        assert!(saw_guest, "pipelining never hosted a guest");
        assert!(w.all_done());
        assert_eq!(w.col.pipeline_evictions, 0, "oracle predictions => no evictions");
    }

    #[test]
    fn sdo_rung_with_plain_exact_never_lends() {
        // The ablation now falls out of the allocation axis: the same
        // scheduler code under the plain `exact` allocator must place no
        // guests.
        let items: Vec<TraceItem> = (0..10)
            .map(|i| TraceItem { arrival: i as f64 * 1e-3, prompt_len: 16, true_rl: 120 })
            .collect();
        let mut w = world(&items, 2048, true);
        w.set_allocator("exact");
        let mut s = EconoServe::variant_sdo();
        drive(&mut w, &mut s, 6000);
        assert!(w.all_done());
        assert_eq!(s.guests_placed, 0, "plain exact must not host guests");
    }

    #[test]
    fn underprediction_rescued_or_requeued() {
        let items: Vec<TraceItem> = (0..30)
            .map(|i| TraceItem {
                arrival: i as f64 * 0.01,
                prompt_len: 24,
                true_rl: 40 + (i as u32 % 11) * 29,
            })
            .collect();
        let mut w = world(&items, 4096, false); // noisy predictor
        let mut s = EconoServe::full();
        let e = SimEngine::new();
        let res = run(&mut w, &mut s, &e, RunLimits::default());
        assert_eq!(res.summary.n_done, 30);
        assert!(
            s.reserve_rescues + s.requeues > 0,
            "noisy predictions must trigger misprediction handling"
        );
    }

    #[test]
    fn waiting_held_gate_tracks_queue_footprint() {
        // The incremental waiting-GT footprint must equal a fresh sweep
        // over the queued GTs at every iteration boundary.
        let items: Vec<TraceItem> = (0..25)
            .map(|i| TraceItem {
                arrival: i as f64 * 0.005,
                prompt_len: 24 + (i as u32 % 4) * 16,
                true_rl: 30 + (i as u32 % 6) * 25,
            })
            .collect();
        let mut w = world(&items, 2048, true);
        let mut s = EconoServe::full();
        let e = SimEngine::new();
        for _ in 0..2500 {
            w.drain_arrivals();
            let b = plan_iteration(&mut w, &mut s);
            let sweep: u64 = s
                .gt_groups
                .values()
                .flatten()
                .map(|&id| w.occupied_kvc(id) as u64)
                .sum();
            assert_eq!(s.waiting_held, sweep, "incremental footprint drifted");
            if b.is_empty() {
                match w.next_arrival() {
                    Some(t) => {
                        w.clock = t;
                        continue;
                    }
                    None => break,
                }
            }
            let (d, u) = e.iteration_cost(&b, &w);
            w.apply_plan(&b, d, u);
            if w.all_done() {
                break;
            }
        }
        assert!(w.all_done());
        assert_eq!(s.waiting_held, 0, "empty queue must carry no footprint");
    }

    #[test]
    fn evicted_guest_is_requeued_and_completes() {
        // The §3.2 failure path end-to-end: a guest whose slot the host's
        // write head overruns is evicted by the world (offload-free), the
        // scheduler re-queues it from the evicted_guests event, and it
        // still completes after recompute.
        let items = vec![
            TraceItem { arrival: 0.0, prompt_len: 8, true_rl: 64 }, // host
            TraceItem { arrival: 0.0, prompt_len: 8, true_rl: 40 }, // guest
        ];
        let mut w = world(&items, 4096, true);
        let host = 0;
        let guest = 1;
        // Hold the guest back from normal admission until we mis-place it.
        w.pred_ready[guest] = 1e9;
        let mut s = EconoServe::full();
        let e = SimEngine::new();
        // Prefill both and admit the host as a GT via the normal flow.
        for _ in 0..4 {
            w.drain_arrivals();
            let b = plan_iteration(&mut w, &mut s);
            if b.is_empty() {
                w.clock += 0.01;
                continue;
            }
            let (d, u) = e.iteration_cost(&b, &w);
            w.apply_plan(&b, d, u);
        }
        assert!(s.running_gts.contains(host), "host must be decoding");
        assert!(!s.running_gts.contains(guest), "guest must still be queued");
        // Force the failure: place the guest at an offset the host's head
        // will overrun long before the guest finishes (an under-predicted
        // guest in a too-small slot). Mirror the scheduler bookkeeping a
        // lend would have done.
        let rl = s
            .gt_groups
            .iter()
            .find(|(_, q)| q.contains(&guest))
            .map(|(rl, _)| *rl)
            .expect("guest must be queued in a group");
        assert!(s.remove_from_group(rl, guest));
        w.pred_ready[guest] = 0.0; // readmittable after the eviction
        w.kvc_mut().host_at(guest, host, 2, 8);
        let base = w.recs[guest].generated;
        w.recs[guest].gt_span_base = base;
        w.recs[guest].gt_span_len = 8;
        w.recs[guest].phase = Phase::Decoding;
        s.running_gts.push(guest);
        let mut evicted_seen = false;
        for _ in 0..4000 {
            w.drain_arrivals();
            let b = plan_iteration(&mut w, &mut s);
            if b.is_empty() {
                if w.all_done() {
                    break;
                }
                w.clock += 0.01;
                continue;
            }
            let (d, u) = e.iteration_cost(&b, &w);
            w.apply_plan(&b, d, u);
            if !w.events.evicted_guests.is_empty() {
                evicted_seen = true;
            }
            if w.all_done() {
                break;
            }
        }
        assert!(evicted_seen, "host head never overran the mis-placed guest");
        assert!(w.col.pipeline_evictions >= 1);
        assert!(w.all_done(), "evicted guest must be re-queued and complete");
        assert_eq!(w.kvc().guest_count(), 0);
        assert_eq!(w.kvc().total_allocated(), 0);
    }

    #[test]
    fn heavy_tail_overruns_evict_requeue_and_complete() {
        // The organic variant of the forced §3.2 failure path above:
        // under the heavy-tail predictor fault profile (per-prediction
        // 4x blunders in either direction), divided predictions
        // under-reserve spans — hosts reach their prediction end and are
        // rescued or re-queued, and heads plow through guests riding in
        // their tails. With adaptive headroom live, the whole
        // overrun → evict → requeue → completion lifecycle must still
        // finish every request, with evictions inside the budget.
        use crate::predictor::faults::{by_name, FaultyPredictor};
        use crate::util::rng::{derive_seed, stream};
        let items: Vec<TraceItem> = (0..150)
            .map(|i| TraceItem {
                arrival: i as f64 * 0.01,
                prompt_len: 16,
                true_rl: 60 + (i as u32 % 5) * 40,
            })
            .collect();
        let mut profile = ModelProfile::opt_13b();
        profile.kvc_bytes = 819_200 * 4096;
        let mut cfg = SystemConfig::new(profile);
        cfg.padding_ratio = 0.10;
        cfg.reserve_frac = 0.05;
        cfg.headroom = "adaptive".to_string();
        let fp = by_name("heavy-tail").expect("registry profile");
        let pred = Box::new(FaultyPredictor::new(
            Box::new(OraclePredictor::new(32)),
            fp,
            derive_seed(7, stream::PREDICTOR),
            32,
        ));
        let mut w = World::new(cfg, &items, pred);
        w.set_allocator("pipelined-exact");
        let mut s = EconoServe::full();
        let e = SimEngine::new();
        let res = run(&mut w, &mut s, &e, RunLimits::default());
        assert_eq!(res.summary.n_done, 150, "heavy-tail run incomplete");
        assert!(
            s.reserve_rescues + s.requeues > 0,
            "4x blunders triggered no misprediction handling"
        );
        assert!(
            w.col.max_iter_evictions <= 4,
            "eviction budget violated: {} in one iteration",
            w.col.max_iter_evictions
        );
        // Clean exit: no leaked guests or leases after the storm.
        assert_eq!(w.kvc().guest_count(), 0);
        assert_eq!(w.kvc().total_allocated(), 0);
    }

    #[test]
    fn all_variants_complete() {
        let items: Vec<TraceItem> = (0..25)
            .map(|i| TraceItem {
                arrival: i as f64 * 0.02,
                prompt_len: 16 + (i as u32 % 5) * 24,
                true_rl: 10 + (i as u32 % 7) * 20,
            })
            .collect();
        for (mk, alloc) in [
            (EconoServe::variant_d as fn() -> EconoServe, "exact"),
            (EconoServe::variant_sd, "exact"),
            (EconoServe::variant_sdo, "exact"),
            (EconoServe::full, "pipelined-exact"),
        ] {
            let mut w = world(&items, 8192, true);
            w.set_allocator(alloc);
            let mut s = mk();
            let e = SimEngine::new();
            let res = run(&mut w, &mut s, &e, RunLimits::default());
            assert_eq!(res.summary.n_done, 25, "variant {} incomplete", s.name());
        }
    }
}
