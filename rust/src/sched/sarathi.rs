//! Sarathi-Serve [15]: stall-free chunked prefill with a per-iteration
//! token budget (the target forward size, TFS), paired with
//! **block-allocation**.
//!
//! Each iteration:
//!  1. all running decodes join the batch (no generation stalls),
//!  2. the remaining token budget is filled with prompt *chunks* from
//!     partially-prefilled and newly admitted requests,
//!  3. under block-allocation a lease grows block-granularly and can fail
//!     mid-flight (Fig 1d); the latest-arrived running sequence is then
//!     preempted (swap). Under `sarathi+exact` (or `pipelined-exact`)
//!     admission leases the predicted span instead, so mid-flight growth
//!     stops failing; the pipelined wrapper's lending surface is inert
//!     here — Sarathi never offers spans to guests (only EconoServe
//!     drives the lend API).

use std::collections::VecDeque;

use super::Scheduler;
use crate::core::world::IterCtx;
use crate::core::{BatchPlan, BatchTask, IndexedList, PreemptKind, ReqId};
use crate::kvc::{Allocator, Demand, ReserveClass};

pub struct Sarathi {
    waiting: VecDeque<ReqId>,
    /// Sequences mid-prefill (chunked), in admission order (O(1) removal
    /// when a prefill finishes).
    prefilling: IndexedList,
    /// Sequences decoding, in admission order.
    decoding: Vec<ReqId>,
    swapped: VecDeque<ReqId>,
    pub max_num_seqs: usize,
}

impl Sarathi {
    pub fn new() -> Self {
        Sarathi {
            waiting: VecDeque::new(),
            prefilling: IndexedList::new(),
            decoding: Vec::new(),
            swapped: VecDeque::new(),
            max_num_seqs: 256,
        }
    }

    /// Next prompt chunk for `id` within the remaining budget, securing
    /// capacity for it. `admission` switches between the admission-time
    /// lease (policy-sized) and a mid-flight extension.
    fn chunk_for(
        ctx: &mut IterCtx<'_>,
        id: ReqId,
        used: &mut u32,
        budget: u32,
        admission: bool,
    ) -> Option<BatchTask> {
        let rec = ctx.rec(id);
        let left = rec.req.prompt_len - rec.prompt_done;
        let room = budget.saturating_sub(*used);
        let chunk = left.min(room);
        if chunk == 0 {
            return None;
        }
        let granted = if admission {
            let demand = Demand {
                immediate: chunk,
                predicted: ctx.rec(id).predicted_remaining(),
                max_total: ctx.cfg().profile.max_total_len,
            };
            ctx.alloc().admit(id, demand, ReserveClass::Reserved).ok()
        } else {
            ctx.alloc().extend(id, chunk, ReserveClass::Reserved).ok()
        };
        if !granted {
            ctx.note_alloc_failed(id);
            return None;
        }
        *used += chunk;
        Some(BatchTask::Prefill { id, chunk })
    }
}

impl Default for Sarathi {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Sarathi {
    fn name(&self) -> &'static str {
        "sarathi"
    }

    fn plan(&mut self, ctx: &mut IterCtx<'_>) -> BatchPlan {
        while let Some(id) = ctx.pop_arrival() {
            self.waiting.push_back(id);
        }
        self.decoding.retain(|id| !ctx.world().recs[*id].is_done());
        // Promote finished prefills to decode (O(1) removals; the event
        // vector is handed back cleared so its capacity is reused).
        let mut finished = std::mem::take(&mut ctx.events.finished_prefill);
        for &id in &finished {
            self.prefilling.remove(id);
            if !ctx.rec(id).is_done() {
                self.decoding.push(id);
            }
        }
        finished.clear();
        ctx.events.finished_prefill = finished;

        let budget = ctx.cfg().profile.tfs;
        let mut plan = ctx.take_plan();

        // 1) Swap-ins first. Half-prefilled victims resume prefilling;
        //    others decode.
        for id in super::swap_in_ready(ctx, &mut self.swapped, &mut plan) {
            if ctx.rec(id).prompt_done < ctx.rec(id).req.prompt_len {
                self.prefilling.push_front(id);
            } else {
                self.decoding.push(id);
            }
        }

        // 2) Decodes join first (stall-free), growing block-wise.
        let mut i = 0;
        while i < self.decoding.len() {
            let id = self.decoding[i];
            let need = ctx.rec(id).context_tokens() + 1;
            if ctx.alloc().grow_to(id, need, ReserveClass::Reserved).ok() {
                i += 1;
            } else {
                ctx.note_alloc_failed(id);
                let victim =
                    super::swap_out_latest(ctx, &mut self.decoding, &mut self.swapped, &mut plan);
                if victim == id {
                    break;
                }
            }
        }
        for &id in &self.decoding {
            plan.tasks.push(BatchTask::Decode { id });
        }

        // 3) Fill the remaining budget with prompt chunks.
        let mut used = plan.forward_size();

        // Continue in-flight prefills first (raw index loop: nothing is
        // removed from the list inside it).
        for idx in 0..self.prefilling.raw_len() {
            let Some(id) = self.prefilling.get_raw(idx) else { continue };
            if let Some(t) = Sarathi::chunk_for(ctx, id, &mut used, budget, false) {
                plan.tasks.push(t);
            }
            if used >= budget {
                break;
            }
        }
        // Then admit new prompts.
        while used < budget
            && self.prefilling.len() + self.decoding.len() < self.max_num_seqs
        {
            let Some(&head) = self.waiting.front() else { break };
            // Admission gate: the first chunk's lease must be grantable.
            match Sarathi::chunk_for(ctx, head, &mut used, budget, true) {
                Some(t) => {
                    self.waiting.pop_front();
                    ctx.mark_exec_start(head);
                    self.prefilling.push(head);
                    plan.tasks.push(t);
                }
                None => break,
            }
        }

        // Deadlock guard: every in-flight prefill is blocked on KVC and no
        // decode can run — swap out the most recent prefill to free space
        // (Sarathi's watermark would have prevented admission; recover).
        if plan.is_empty() {
            if let Some(victim) = self.prefilling.pop_back() {
                ctx.preempt(victim, PreemptKind::Swap);
                self.swapped.push_back(victim);
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::coordinator::{run, RunLimits};
    use crate::core::world::World;
    use crate::engine::SimEngine;
    use crate::predictor::OraclePredictor;
    use crate::sched::plan_iteration;
    use crate::trace::TraceItem;

    fn world(items: &[TraceItem], kvc_tokens: u64, tfs: u32) -> World {
        let mut profile = ModelProfile::opt_13b();
        profile.kvc_bytes = 819_200 * kvc_tokens;
        profile.tfs = tfs;
        let mut cfg = SystemConfig::new(profile);
        cfg.reserve_frac = 0.0;
        let p = Box::new(OraclePredictor::new(1));
        let mut w = World::new(cfg, items, p);
        w.set_allocator("block");
        w
    }

    #[test]
    fn chunks_long_prompt_across_iterations() {
        let items = vec![TraceItem { arrival: 0.0, prompt_len: 300, true_rl: 4 }];
        let mut w = world(&items, 4096, 128);
        w.drain_arrivals();
        let mut s = Sarathi::new();
        let b1 = plan_iteration(&mut w, &mut s);
        assert_eq!(b1.prefill_tokens(), 128, "first chunk fills TFS");
        let e = SimEngine::new();
        let (d, u) = crate::engine::Engine::iteration_cost(&e, &b1, &w);
        w.apply_plan(&b1, d, u);
        let b2 = plan_iteration(&mut w, &mut s);
        assert_eq!(b2.prefill_tokens(), 128);
    }

    #[test]
    fn decodes_not_stalled_by_prefill() {
        let items = vec![
            TraceItem { arrival: 0.0, prompt_len: 64, true_rl: 50 },
            TraceItem { arrival: 0.1, prompt_len: 500, true_rl: 4 },
        ];
        let mut w = world(&items, 8192, 128);
        let mut s = Sarathi::new();
        let e = SimEngine::new();
        // Run a few iterations past the second arrival.
        for _ in 0..8 {
            w.drain_arrivals();
            if w.clock < 0.1 {
                w.clock = 0.1;
                continue;
            }
            let b = plan_iteration(&mut w, &mut s);
            let (d, u) = crate::engine::Engine::iteration_cost(&e, &b, &w);
            w.apply_plan(&b, d, u);
            if b.prefill_tokens() > 0 && b.decode_count() > 0 {
                return; // mixed batch observed: stall-free
            }
        }
        panic!("never saw a mixed prefill+decode batch");
    }

    #[test]
    fn completes_under_pressure_with_swaps() {
        let items: Vec<TraceItem> = (0..12)
            .map(|i| TraceItem { arrival: i as f64 * 0.02, prompt_len: 40, true_rl: 60 })
            .collect();
        let mut w = world(&items, 512, 2048);
        let mut s = Sarathi::new();
        let e = SimEngine::new();
        let res = run(&mut w, &mut s, &e, RunLimits::default());
        assert_eq!(res.summary.n_done, 12);
        assert!(w.col.preemptions > 0);
    }
}
