//! Sarathi-Serve [15]: stall-free chunked prefill with a per-iteration
//! token budget (the target forward size, TFS), **block-allocation**.
//!
//! Each iteration:
//!  1. all running decodes join the batch (no generation stalls),
//!  2. the remaining token budget is filled with prompt *chunks* from
//!     partially-prefilled and newly admitted requests,
//!  3. allocation is block-granular and can fail mid-flight (Fig 1d);
//!     the latest-arrived running sequence is then preempted (swap).

use std::collections::VecDeque;

use super::Scheduler;
use crate::core::world::{PreemptKind, World};
use crate::core::{Batch, BatchTask, ReqId};
use crate::kvc::Priority;

pub struct Sarathi {
    waiting: VecDeque<ReqId>,
    /// Sequences mid-prefill (chunked), in admission order.
    prefilling: VecDeque<ReqId>,
    /// Sequences decoding, in admission order.
    decoding: Vec<ReqId>,
    swapped: VecDeque<ReqId>,
    pub max_num_seqs: usize,
}

impl Sarathi {
    pub fn new() -> Self {
        Sarathi {
            waiting: VecDeque::new(),
            prefilling: VecDeque::new(),
            decoding: Vec::new(),
            swapped: VecDeque::new(),
            max_num_seqs: 256,
        }
    }
}

impl Default for Sarathi {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Sarathi {
    fn name(&self) -> &'static str {
        "sarathi"
    }

    fn step(&mut self, world: &mut World) -> Batch {
        while let Some(id) = world.inbox.pop_front() {
            self.waiting.push_back(id);
        }
        self.decoding.retain(|id| !world.recs[*id].is_done());
        // Promote finished prefills to decode (consume events: empty-batch
        // steps skip execute_iteration, so stale events must not linger).
        let finished: Vec<ReqId> = world.take_events().finished_prefill;
        for id in finished {
            if let Some(pos) = self.prefilling.iter().position(|x| *x == id) {
                self.prefilling.remove(pos);
            }
            if !world.recs[id].is_done() {
                self.decoding.push(id);
            }
        }

        let budget = world.cfg.profile.tfs;
        let mut batch = Batch::default();

        // 1) Swap-ins first.
        while let Some(&id) = self.swapped.front() {
            let need = world.recs[id].context_tokens() + 1;
            if world.pool.alloc_tokens(id, need, Priority::Reserved).is_err() {
                break;
            }
            self.swapped.pop_front();
            let restored = world.recs[id].swapped_tokens;
            world.pool.restore_written(id, restored.min(need));
            batch.extra_time += world.swap_in_cost(id);
            world.recs[id].swapped_tokens = 0;
            world.mark_exec_start(id);
            // Half-prefilled victims resume prefilling; others decode.
            if world.recs[id].prompt_done < world.recs[id].req.prompt_len {
                self.prefilling.push_front(id);
            } else {
                self.decoding.push(id);
            }
        }

        // 2) Decodes join first (stall-free), growing block-wise.
        let mut i = 0;
        while i < self.decoding.len() {
            let id = self.decoding[i];
            let need = world.recs[id].context_tokens() + 1;
            match world.pool.ensure_capacity(id, need, Priority::Reserved) {
                Ok(_) => i += 1,
                Err(_) => {
                    world.col.alloc_failed_reqs.insert(id);
                    // The engine stalls while the victim's KV streams out
                    // over PCIe (vLLM v0 swaps synchronously with the
                    // scheduler loop; the paper measures these preemption
                    // delays at up to 20% of JCT, Fig 1e).
                    let victim_peek = *self.decoding.last().unwrap();
                    batch.extra_time += world.recs[victim_peek].context_tokens() as f64
                        * world.cfg.profile.kv_bytes_per_token() as f64
                        / world.cfg.pcie_bw;
                    let victim = *self.decoding.last().unwrap();
                    self.decoding.pop();
                    world.preempt(victim, PreemptKind::Swap);
                    self.swapped.push_back(victim);
                    if victim == id {
                        break;
                    }
                }
            }
        }
        for &id in &self.decoding {
            batch.tasks.push(BatchTask::Decode { id });
        }

        // 3) Fill the remaining budget with prompt chunks.
        let mut used = batch.forward_size();
        let chunk_for = |world: &mut World, id: ReqId, used: &mut u32| -> Option<BatchTask> {
            let rec = &world.recs[id];
            let left = rec.req.prompt_len - rec.prompt_done;
            let room = budget.saturating_sub(*used);
            let chunk = left.min(room);
            if chunk == 0 {
                return None;
            }
            if world.pool.alloc_tokens(id, chunk, Priority::Reserved).is_err() {
                world.col.alloc_failed_reqs.insert(id);
                return None;
            }
            *used += chunk;
            Some(BatchTask::Prefill { id, chunk })
        };

        // Continue in-flight prefills first.
        for idx in 0..self.prefilling.len() {
            let id = self.prefilling[idx];
            if let Some(t) = chunk_for(world, id, &mut used) {
                batch.tasks.push(t);
            }
            if used >= budget {
                break;
            }
        }
        // Then admit new prompts.
        while used < budget
            && self.prefilling.len() + self.decoding.len() < self.max_num_seqs
        {
            let Some(&head) = self.waiting.front() else { break };
            // Admission gate: one block must be allocatable.
            match chunk_for(world, head, &mut used) {
                Some(t) => {
                    self.waiting.pop_front();
                    world.mark_exec_start(head);
                    self.prefilling.push_back(head);
                    batch.tasks.push(t);
                }
                None => break,
            }
        }

        // Deadlock guard: every in-flight prefill is blocked on KVC and no
        // decode can run — swap out the most recent prefill to free space
        // (Sarathi's watermark would have prevented admission; recover).
        if batch.is_empty() {
            if let Some(victim) = self.prefilling.pop_back() {
                world.preempt(victim, PreemptKind::Swap);
                self.swapped.push_back(victim);
            }
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::coordinator::{run, RunLimits};
    use crate::engine::SimEngine;
    use crate::predictor::OraclePredictor;
    use crate::trace::TraceItem;

    fn world(items: &[TraceItem], kvc_tokens: u64, tfs: u32) -> World {
        let mut profile = ModelProfile::opt_13b();
        profile.kvc_bytes = 819_200 * kvc_tokens;
        profile.tfs = tfs;
        let mut cfg = SystemConfig::new(profile);
        cfg.reserve_frac = 0.0;
        let p = Box::new(OraclePredictor::new(1));
        World::new(cfg, items, p)
    }

    #[test]
    fn chunks_long_prompt_across_iterations() {
        let items = vec![TraceItem { arrival: 0.0, prompt_len: 300, true_rl: 4 }];
        let mut w = world(&items, 4096, 128);
        w.drain_arrivals();
        let mut s = Sarathi::new();
        let b1 = s.step(&mut w);
        assert_eq!(b1.prefill_tokens(), 128, "first chunk fills TFS");
        let e = SimEngine::new();
        let (d, u) = crate::engine::Engine::iteration_cost(&e, &b1, &w);
        w.execute_iteration(&b1, d, u);
        let b2 = s.step(&mut w);
        assert_eq!(b2.prefill_tokens(), 128);
    }

    #[test]
    fn decodes_not_stalled_by_prefill() {
        let items = vec![
            TraceItem { arrival: 0.0, prompt_len: 64, true_rl: 50 },
            TraceItem { arrival: 0.1, prompt_len: 500, true_rl: 4 },
        ];
        let mut w = world(&items, 8192, 128);
        let mut s = Sarathi::new();
        let e = SimEngine::new();
        // Run a few iterations past the second arrival.
        for _ in 0..8 {
            w.drain_arrivals();
            if w.clock < 0.1 {
                w.clock = 0.1;
                continue;
            }
            let b = s.step(&mut w);
            let (d, u) = crate::engine::Engine::iteration_cost(&e, &b, &w);
            w.execute_iteration(&b, d, u);
            if b.prefill_tokens() > 0 && b.decode_count() > 0 {
                return; // mixed batch observed: stall-free
            }
        }
        panic!("never saw a mixed prefill+decode batch");
    }

    #[test]
    fn completes_under_pressure_with_swaps() {
        let items: Vec<TraceItem> = (0..12)
            .map(|i| TraceItem { arrival: i as f64 * 0.02, prompt_len: 40, true_rl: 60 })
            .collect();
        let mut w = world(&items, 512, 2048);
        let mut s = Sarathi::new();
        let e = SimEngine::new();
        let res = run(&mut w, &mut s, &e, RunLimits::default());
        assert_eq!(res.summary.n_done, 12);
        assert!(w.col.preemptions > 0);
    }
}
