//! SyncCoupled (§2.2): time-synced batching WITHOUT decoupling.
//!
//! Queued requests are grouped by (padded, quantized) predicted RL; whole
//! groups are admitted with **exact-allocation** leases (prompt +
//! predicted RL each) until the KVC is fully allocated, splitting a group
//! when only part of it fits. Group members start together and (prediction
//! permitting) finish together, so scheduling work is per-group rather
//! than per-request — that is what collapses MultiRes's O(n²) scheduling
//! time. Because admission is coupled (a request brings BOTH its prompt
//! work and its KVC demand), prompts can only enter when a group
//! completes, so TFS is rarely reached (Observation 3 / Fig 1c).

use std::collections::BTreeMap;
use std::collections::VecDeque;

use super::Scheduler;
use crate::core::world::IterCtx;
use crate::core::{BatchPlan, BatchTask, IndexedList, PreemptKind, ReqId};
use crate::kvc::{Allocator, Demand, ReserveClass};

pub struct SyncCoupled {
    /// predicted RL -> FIFO of queued requests with that prediction.
    groups: BTreeMap<u32, VecDeque<ReqId>>,
    running: IndexedList,
    /// Group-size observations (Fig 2): members admitted together.
    pub group_sizes: Vec<u32>,
}

impl SyncCoupled {
    pub fn new() -> Self {
        SyncCoupled {
            groups: BTreeMap::new(),
            running: IndexedList::new(),
            group_sizes: Vec::new(),
        }
    }

    fn enqueue(&mut self, ctx: &IterCtx<'_>, id: ReqId) {
        let rl = ctx.rec(id).predicted_remaining().max(1);
        self.groups.entry(rl).or_default().push_back(id);
    }

    /// Oldest arrival among group heads == next group FCFS-wise.
    fn next_group(&self, ctx: &IterCtx<'_>) -> Option<u32> {
        self.groups
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .min_by(|(_, a), (_, b)| {
                let ta = ctx.rec(*a.front().unwrap()).req.arrival;
                let tb = ctx.rec(*b.front().unwrap()).req.arrival;
                ta.total_cmp(&tb)
            })
            .map(|(rl, _)| *rl)
    }
}

impl Default for SyncCoupled {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for SyncCoupled {
    fn name(&self) -> &'static str {
        "sync_coupled"
    }

    fn plan(&mut self, ctx: &mut IterCtx<'_>) -> BatchPlan {
        while let Some(id) = ctx.pop_arrival() {
            self.enqueue(ctx, id);
        }
        self.running.retain(|id| !ctx.world().recs[id].is_done());

        // Under-predicted members: extend the lease in place or re-group
        // at the re-predicted remaining RL.
        let mut under = std::mem::take(&mut ctx.events.reached_prediction);
        let bs = ctx.cfg().block_size;
        for &id in &under {
            let rec = ctx.rec_mut(id);
            rec.predicted_base = rec.generated;
            rec.predicted_rl = bs;
            if !ctx.alloc().extend(id, bs + 1, ReserveClass::Reserved).ok() {
                // Offload-free drop: release KV, recompute at re-admission.
                self.running.remove(id);
                ctx.preempt(id, PreemptKind::DropRecompute);
                self.enqueue(ctx, id);
            }
        }
        under.clear();
        ctx.events.reached_prediction = under;

        // Group admission while KVC allows (FCFS over group heads).
        let max_total = ctx.cfg().profile.max_total_len;
        loop {
            let Some(rl) = self.next_group(ctx) else { break };
            let mut admitted_from_group = 0u32;
            loop {
                let Some(&head) = self.groups[&rl].front() else { break };
                let demand = Demand::of(ctx.rec(head), max_total);
                if !ctx.alloc().admit(head, demand, ReserveClass::Reserved).ok() {
                    break;
                }
                self.groups.get_mut(&rl).unwrap().pop_front();
                ctx.mark_exec_start(head);
                self.running.push(head);
                admitted_from_group += 1;
            }
            if admitted_from_group > 0 {
                self.group_sizes.push(admitted_from_group);
            }
            if self.groups.get(&rl).map(|q| !q.is_empty()).unwrap_or(false) {
                break; // group split: KVC is full
            }
            if admitted_from_group == 0 {
                break;
            }
            self.groups.retain(|_, q| !q.is_empty());
        }
        self.groups.retain(|_, q| !q.is_empty());

        let mut plan = ctx.take_plan();
        for id in self.running.iter() {
            let rec = ctx.rec(id);
            if rec.lost_kv > 0 {
                plan.tasks.push(BatchTask::Prefill { id, chunk: rec.lost_kv });
            } else if rec.prompt_done < rec.req.prompt_len {
                plan.tasks
                    .push(BatchTask::Prefill { id, chunk: rec.req.prompt_len - rec.prompt_done });
            } else {
                plan.tasks.push(BatchTask::Decode { id });
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::coordinator::{run, RunLimits};
    use crate::core::world::World;
    use crate::engine::SimEngine;
    use crate::predictor::OraclePredictor;
    use crate::sched::plan_iteration;
    use crate::trace::TraceItem;

    fn world(items: &[TraceItem], kvc_tokens: u64, quantum: u32) -> World {
        let mut profile = ModelProfile::opt_13b();
        profile.kvc_bytes = 819_200 * kvc_tokens;
        let mut cfg = SystemConfig::new(profile);
        cfg.padding_ratio = 0.0;
        let p = Box::new(OraclePredictor::new(quantum));
        World::new(cfg, items, p) // default allocator IS exact
    }

    #[test]
    fn same_rl_requests_admitted_as_group() {
        // Four requests, all predicted RL 32 (quantized).
        let items: Vec<TraceItem> = (0..4)
            .map(|i| TraceItem { arrival: i as f64 * 1e-4, prompt_len: 16, true_rl: 30 })
            .collect();
        let mut w = world(&items, 4096, 32);
        w.clock = 0.1;
        w.drain_arrivals();
        let mut s = SyncCoupled::new();
        let b = plan_iteration(&mut w, &mut s);
        assert_eq!(b.len(), 4);
        assert_eq!(s.group_sizes, vec![4]);
    }

    #[test]
    fn group_splits_when_kvc_tight() {
        let items: Vec<TraceItem> = (0..8)
            .map(|i| TraceItem { arrival: i as f64 * 1e-4, prompt_len: 64, true_rl: 60 })
            .collect();
        // Each needs ~128 tokens; pool of 512 fits 3-4.
        let mut w = world(&items, 512, 32);
        w.clock = 0.1;
        w.drain_arrivals();
        let mut s = SyncCoupled::new();
        let b = plan_iteration(&mut w, &mut s);
        assert!(b.len() >= 2 && b.len() <= 4, "admitted {}", b.len());
        assert!(!s.groups.is_empty(), "rest of the group still queued");
    }

    #[test]
    fn completes_mixed_groups() {
        let items: Vec<TraceItem> = (0..40)
            .map(|i| TraceItem {
                arrival: i as f64 * 0.01,
                prompt_len: 16 + (i as u32 % 3) * 16,
                true_rl: 20 + (i as u32 % 4) * 30,
            })
            .collect();
        let mut w = world(&items, 8192, 32);
        let mut s = SyncCoupled::new();
        let e = SimEngine::new();
        let res = run(&mut w, &mut s, &e, RunLimits::default());
        assert_eq!(res.summary.n_done, 40);
        assert_eq!(w.kvc().stats().failures, 0);
    }
}
