//! Bounded structured per-request event log.
//!
//! Every request that crosses the serving surface gets lifecycle events
//! keyed by its engine-assigned request id: `submit`, `first_token`,
//! `finish`, `reject`, ... Events live in a fixed-capacity ring (oldest
//! dropped first) so the log is safe to leave on under sustained load,
//! and render as one JSON object per line (`render_jsonl`) — the
//! structured-log shape scrapers and `grep` both like.
//!
//! The log is `Send + Sync` (a mutexed ring); pushes are O(1) amortized
//! and never allocate beyond the event's own strings.

use std::collections::VecDeque;
use std::sync::Mutex;

/// One structured event in a request's lifecycle.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestEvent {
    /// Engine-assigned request id.
    pub id: u64,
    /// Seconds since the log's owner started (monotonic, caller-supplied
    /// so simulated and wall clocks both work).
    pub t_s: f64,
    /// Lifecycle stage: `submit`, `first_token`, `finish`, `reject`,
    /// `cancel`, `rate_limited`, ...
    pub stage: &'static str,
    /// Free-form detail (finish reason, token counts, client key, ...).
    pub detail: String,
}

impl RequestEvent {
    /// Render as one JSON object (stable key order).
    pub fn to_json_line(&self) -> String {
        format!(
            "{{\"id\":{},\"t_s\":{:.6},\"stage\":\"{}\",\"detail\":\"{}\"}}",
            self.id,
            self.t_s,
            self.stage,
            escape_json(&self.detail)
        )
    }
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Fixed-capacity, thread-safe ring of [`RequestEvent`]s.
#[derive(Debug)]
pub struct RequestLog {
    inner: Mutex<Ring>,
}

#[derive(Debug)]
struct Ring {
    cap: usize,
    events: VecDeque<RequestEvent>,
    dropped: u64,
}

impl RequestLog {
    /// A log that keeps at most `cap` events (cap 0 disables storage but
    /// still counts drops).
    pub fn with_capacity(cap: usize) -> Self {
        RequestLog { inner: Mutex::new(Ring { cap, events: VecDeque::new(), dropped: 0 }) }
    }

    /// Record one lifecycle event.
    pub fn log(&self, id: u64, t_s: f64, stage: &'static str, detail: impl Into<String>) {
        let ev = RequestEvent { id, t_s, stage, detail: detail.into() };
        let mut ring = crate::util::sync::lock(&self.inner);
        while ring.events.len() >= ring.cap.max(1) {
            ring.events.pop_front();
            ring.dropped += 1;
        }
        if ring.cap > 0 {
            ring.events.push_back(ev);
        } else {
            ring.dropped += 1;
        }
    }

    /// The most recent `n` events, oldest first.
    pub fn recent(&self, n: usize) -> Vec<RequestEvent> {
        let ring = crate::util::sync::lock(&self.inner);
        let skip = ring.events.len().saturating_sub(n);
        ring.events.iter().skip(skip).cloned().collect()
    }

    /// All retained events for one request id, oldest first.
    pub fn for_request(&self, id: u64) -> Vec<RequestEvent> {
        let ring = crate::util::sync::lock(&self.inner);
        ring.events.iter().filter(|e| e.id == id).cloned().collect()
    }

    /// Events evicted (or discarded by a zero-capacity log) so far.
    pub fn dropped(&self) -> u64 {
        crate::util::sync::lock(&self.inner).dropped
    }

    pub fn len(&self) -> usize {
        crate::util::sync::lock(&self.inner).events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Render the retained window as JSON lines, oldest first.
    pub fn render_jsonl(&self) -> String {
        let ring = crate::util::sync::lock(&self.inner);
        let mut out = String::new();
        for e in &ring.events {
            out.push_str(&e.to_json_line());
            out.push('\n');
        }
        out
    }
}

impl Default for RequestLog {
    fn default() -> Self {
        RequestLog::with_capacity(4096)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_evicts_oldest_and_counts_drops() {
        let log = RequestLog::with_capacity(3);
        for i in 0..5u64 {
            log.log(i, i as f64, "submit", format!("n{i}"));
        }
        assert_eq!(log.len(), 3);
        assert_eq!(log.dropped(), 2);
        let recent = log.recent(10);
        assert_eq!(recent.first().unwrap().id, 2, "oldest surviving event");
        assert_eq!(recent.last().unwrap().id, 4);
    }

    #[test]
    fn per_request_filter_keeps_order() {
        let log = RequestLog::with_capacity(16);
        log.log(7, 0.0, "submit", "prompt_len=4");
        log.log(8, 0.1, "submit", "prompt_len=9");
        log.log(7, 0.5, "first_token", "");
        log.log(7, 1.0, "finish", "reason=complete tokens=12");
        let evs = log.for_request(7);
        assert_eq!(evs.len(), 3);
        assert_eq!(
            evs.iter().map(|e| e.stage).collect::<Vec<_>>(),
            vec!["submit", "first_token", "finish"]
        );
        assert!(log.for_request(99).is_empty());
    }

    #[test]
    fn jsonl_is_escaped_and_line_per_event() {
        let log = RequestLog::with_capacity(4);
        log.log(1, 0.25, "finish", "said \"hi\"\nback\\slash");
        let text = log.render_jsonl();
        assert_eq!(text.lines().count(), 1);
        assert!(
            text.contains("\"detail\":\"said \\\"hi\\\"\\nback\\\\slash\""),
            "{text}"
        );
        assert!(text.starts_with("{\"id\":1,\"t_s\":0.250000,"), "{text}");
    }

    #[test]
    fn zero_capacity_log_discards_everything() {
        let log = RequestLog::with_capacity(0);
        log.log(1, 0.0, "submit", "");
        assert!(log.is_empty());
        assert_eq!(log.dropped(), 1);
    }
}
