//! The pre-registered metric vocabulary shared by the simulator and the
//! real server — one set of family names, documented exhaustively in
//! `docs/metrics-dictionary.md`.
//!
//! Three bundles over the same [`Registry`]:
//!  * [`SimMetrics`] — what one `World` (sim) or one `RealServer`
//!    (server) records per iteration / per request. Both paths register
//!    the same families so a sweep's `--metrics-out` and a live
//!    `GET /metrics` expose one vocabulary.
//!  * [`FleetMetrics`] — fleet-level counters (faults, reroutes, boots)
//!    written once at fleet finalize from the authoritative
//!    `FaultTally`/summary, so counter totals reconcile exactly with
//!    `FleetSummary`.
//!  * [`ServerMetrics`] — the HTTP-only surface (per-route request
//!    counts, rate-limit rejections) layered on top of a [`SimMetrics`]
//!    bundle.
//!
//! Handles are registered once and cloned into the hot path; nothing
//! here locks or allocates after construction (except the per-route
//! HTTP counter, which interns lazily on first sight of a route).

use std::sync::Arc;

use super::{Buckets, Counter, Gauge, Histogram, Registry};

/// Core serving metrics recorded by both execution paths.
#[derive(Debug, Clone)]
pub struct SimMetrics {
    registry: Arc<Registry>,
    /// `econoserve_iterations_total` — engine iterations executed.
    pub iterations: Counter,
    /// `econoserve_tokens_total{phase="prefill"|"decode"}`.
    pub tokens_prefill: Counter,
    pub tokens_decode: Counter,
    /// `econoserve_requests_total{outcome="done"|"rejected"|"cancelled"}`.
    pub requests_done: Counter,
    pub requests_rejected: Counter,
    pub requests_cancelled: Counter,
    /// `econoserve_slo_total{outcome="hit"|"miss"}` over finished requests.
    pub slo_hit: Counter,
    pub slo_miss: Counter,
    /// `econoserve_kvc_alloc_total{outcome="granted"|"hosted"|"exhausted"}`.
    pub alloc_granted: Counter,
    pub alloc_hosted: Counter,
    pub alloc_exhausted: Counter,
    /// `econoserve_preemptions_total`.
    pub preemptions: Counter,
    /// `econoserve_predictions_total{verdict="close"|"off"}` — RL
    /// predictions made, split by whether they landed within one quantum
    /// of the quantized truth (synced from the predictor's own
    /// accounting, so fault-wrapper fallbacks are counted too).
    pub pred_close: Counter,
    pub pred_off: Counter,
    /// `econoserve_prediction_provision_total{outcome="under"|"over"}` —
    /// completed requests whose initial padded prediction under- or
    /// over-provisioned the true RL (Fig 5a accounting).
    pub pred_under: Counter,
    pub pred_over: Counter,
    /// `econoserve_prediction_error_ratio` — true/raw-predicted RL ratio
    /// at completion (1.0 = exact; > 1 the predictor under-shot).
    pub prediction_error: Histogram,
    /// `econoserve_padding_ratio` — the padding ratio in force (static
    /// sweet spot, or the adaptive headroom controller's current value).
    pub padding_ratio: Gauge,
    /// `econoserve_eviction_storms_total` — iterations whose overrun
    /// sweep hit the eviction budget and deferred at least one eviction.
    pub eviction_storms: Counter,
    /// `econoserve_batch_occupancy` — tasks per executed iteration.
    pub batch_occupancy: Histogram,
    /// `econoserve_kvc_utilization` — written-KVC fraction per iteration.
    pub kvc_utilization: Histogram,
    /// `econoserve_queue_depth` — instantaneous waiting requests.
    pub queue_depth: Gauge,
    /// Per-request timing histograms (seconds).
    pub request_latency: Histogram,
    pub ttft: Histogram,
    pub tbt: Histogram,
}

impl SimMetrics {
    /// Register the vocabulary on a fresh private registry.
    pub fn new() -> Self {
        Self::on(Registry::new())
    }

    /// Register the vocabulary on an existing registry (the server
    /// shares one registry between engine and HTTP threads).
    pub fn on(registry: Arc<Registry>) -> Self {
        let r = &registry;
        let m = SimMetrics {
            iterations: r.counter(
                "econoserve_iterations_total",
                "Engine iterations executed",
                &[],
            ),
            tokens_prefill: r.counter(
                "econoserve_tokens_total",
                "Tokens processed, by phase",
                &[("phase", "prefill")],
            ),
            tokens_decode: r.counter(
                "econoserve_tokens_total",
                "Tokens processed, by phase",
                &[("phase", "decode")],
            ),
            requests_done: r.counter(
                "econoserve_requests_total",
                "Requests by terminal outcome",
                &[("outcome", "done")],
            ),
            requests_rejected: r.counter(
                "econoserve_requests_total",
                "Requests by terminal outcome",
                &[("outcome", "rejected")],
            ),
            requests_cancelled: r.counter(
                "econoserve_requests_total",
                "Requests by terminal outcome",
                &[("outcome", "cancelled")],
            ),
            slo_hit: r.counter(
                "econoserve_slo_total",
                "Finished requests by SLO outcome",
                &[("outcome", "hit")],
            ),
            slo_miss: r.counter(
                "econoserve_slo_total",
                "Finished requests by SLO outcome",
                &[("outcome", "miss")],
            ),
            alloc_granted: r.counter(
                "econoserve_kvc_alloc_total",
                "KVC allocation attempts by outcome",
                &[("outcome", "granted")],
            ),
            alloc_hosted: r.counter(
                "econoserve_kvc_alloc_total",
                "KVC allocation attempts by outcome",
                &[("outcome", "hosted")],
            ),
            alloc_exhausted: r.counter(
                "econoserve_kvc_alloc_total",
                "KVC allocation attempts by outcome",
                &[("outcome", "exhausted")],
            ),
            preemptions: r.counter(
                "econoserve_preemptions_total",
                "Requests preempted out of the running batch",
                &[],
            ),
            pred_close: r.counter(
                "econoserve_predictions_total",
                "RL predictions by closeness verdict",
                &[("verdict", "close")],
            ),
            pred_off: r.counter(
                "econoserve_predictions_total",
                "RL predictions by closeness verdict",
                &[("verdict", "off")],
            ),
            pred_under: r.counter(
                "econoserve_prediction_provision_total",
                "Completed requests by initial provisioning verdict",
                &[("outcome", "under")],
            ),
            pred_over: r.counter(
                "econoserve_prediction_provision_total",
                "Completed requests by initial provisioning verdict",
                &[("outcome", "over")],
            ),
            prediction_error: r.histogram(
                "econoserve_prediction_error_ratio",
                "True RL / raw predicted RL at completion",
                Buckets::exponential(0.125, 2.0, 8),
                &[],
            ),
            padding_ratio: r.gauge(
                "econoserve_padding_ratio",
                "Padding ratio in force (static or adaptive)",
                &[],
            ),
            eviction_storms: r.counter(
                "econoserve_eviction_storms_total",
                "Iterations whose overrun sweep hit the eviction budget",
                &[],
            ),
            batch_occupancy: r.histogram(
                "econoserve_batch_occupancy",
                "Tasks per executed iteration",
                Buckets::exponential(1.0, 2.0, 12),
                &[],
            ),
            kvc_utilization: r.histogram(
                "econoserve_kvc_utilization",
                "Written-KVC fraction per iteration",
                Buckets::linear(0.1, 0.1, 10),
                &[],
            ),
            queue_depth: r.gauge(
                "econoserve_queue_depth",
                "Requests waiting for a batch slot",
                &[],
            ),
            request_latency: r.histogram(
                "econoserve_request_latency_seconds",
                "Submission-to-completion latency",
                Buckets::exponential(0.01, 2.0, 16),
                &[],
            ),
            ttft: r.histogram(
                "econoserve_ttft_seconds",
                "Time to first token",
                Buckets::exponential(0.005, 2.0, 14),
                &[],
            ),
            tbt: r.histogram(
                "econoserve_tbt_seconds",
                "Mean time between tokens per finished request",
                Buckets::exponential(0.001, 2.0, 12),
                &[],
            ),
            registry,
        };
        m
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Canonical Prometheus text for the whole registry.
    pub fn render(&self) -> String {
        self.registry.render()
    }
}

impl Default for SimMetrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Fleet-level counters, written once at finalize from the
/// authoritative fleet accounting so totals reconcile exactly with
/// `FleetSummary` (`faults_lost_total == faults.lost`, ...).
#[derive(Debug, Clone)]
pub struct FleetMetrics {
    registry: Arc<Registry>,
    /// `econoserve_faults_total{kind=...}`.
    pub crashes: Counter,
    pub zone_outages: Counter,
    pub stragglers: Counter,
    pub boot_failures: Counter,
    /// `econoserve_requests_lost_total` — in-flight requests lost to
    /// crashes and never re-routed.
    pub requests_lost: Counter,
    /// `econoserve_reroutes_total` — in-flight requests re-routed off a
    /// crashed replica.
    pub reroutes: Counter,
    /// `econoserve_replica_boots_total` / `econoserve_replica_retirements_total`.
    pub boots: Counter,
    pub retirements: Counter,
    /// `econoserve_retries_total` — guardrail re-injections of displaced
    /// requests (`faults.retried`).
    pub retries: Counter,
    /// `econoserve_hedges_total{outcome=...}` — hedge copies by fate.
    /// `launched` counts dispatches; `won` first-finishes by the hedge;
    /// `lost` copies cancelled after the other side won or died;
    /// `duplicate` same-window double completions whose loser was voided
    /// in the summary (its sim counters remain monotonic history — the
    /// reconciliation tests add `duplicate` back to `n_done`).
    pub hedges_launched: Counter,
    pub hedges_won: Counter,
    pub hedges_lost: Counter,
    pub hedges_dup: Counter,
    /// `econoserve_aborts_total{reason=...}` — terminal guardrail
    /// cancellations; `deadline` + `brownout` sum to `faults.aborted`.
    pub aborts_deadline: Counter,
    pub aborts_brownout: Counter,
    /// `econoserve_brownout_level` — highest brownout tier the run
    /// reached (0 normal, 1 shed batch class, 2 reject).
    pub brownout_level: Gauge,
}

impl FleetMetrics {
    pub fn on(registry: Arc<Registry>) -> Self {
        let r = &registry;
        FleetMetrics {
            crashes: r.counter(
                "econoserve_faults_total",
                "Injected faults by kind",
                &[("kind", "crash")],
            ),
            zone_outages: r.counter(
                "econoserve_faults_total",
                "Injected faults by kind",
                &[("kind", "zone_outage")],
            ),
            stragglers: r.counter(
                "econoserve_faults_total",
                "Injected faults by kind",
                &[("kind", "straggler")],
            ),
            boot_failures: r.counter(
                "econoserve_faults_total",
                "Injected faults by kind",
                &[("kind", "boot_failure")],
            ),
            requests_lost: r.counter(
                "econoserve_requests_lost_total",
                "In-flight requests lost to replica crashes",
                &[],
            ),
            reroutes: r.counter(
                "econoserve_reroutes_total",
                "In-flight requests re-routed off crashed replicas",
                &[],
            ),
            boots: r.counter(
                "econoserve_replica_boots_total",
                "Replica scale-up boots",
                &[],
            ),
            retirements: r.counter(
                "econoserve_replica_retirements_total",
                "Replica drain-and-retire events",
                &[],
            ),
            retries: r.counter(
                "econoserve_retries_total",
                "Guardrail re-injections of displaced requests",
                &[],
            ),
            hedges_launched: r.counter(
                "econoserve_hedges_total",
                "Hedge copies by outcome",
                &[("outcome", "launched")],
            ),
            hedges_won: r.counter(
                "econoserve_hedges_total",
                "Hedge copies by outcome",
                &[("outcome", "won")],
            ),
            hedges_lost: r.counter(
                "econoserve_hedges_total",
                "Hedge copies by outcome",
                &[("outcome", "lost")],
            ),
            hedges_dup: r.counter(
                "econoserve_hedges_total",
                "Hedge copies by outcome",
                &[("outcome", "duplicate")],
            ),
            aborts_deadline: r.counter(
                "econoserve_aborts_total",
                "Terminal guardrail cancellations by reason",
                &[("reason", "deadline")],
            ),
            aborts_brownout: r.counter(
                "econoserve_aborts_total",
                "Terminal guardrail cancellations by reason",
                &[("reason", "brownout")],
            ),
            brownout_level: r.gauge(
                "econoserve_brownout_level",
                "Highest brownout tier reached (0 normal, 1 shed, 2 reject)",
                &[],
            ),
            registry,
        }
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }
}

/// HTTP-surface metrics layered over [`SimMetrics`] on the same
/// registry (the server's `GET /metrics` exposes both).
#[derive(Debug, Clone)]
pub struct ServerMetrics {
    /// The shared serving vocabulary (requests, latency, occupancy...).
    pub core: SimMetrics,
    /// `econoserve_rate_limited_total` — admissions refused by the
    /// token-bucket limiter.
    pub rate_limited: Counter,
    /// `econoserve_http_connections_active` — open client connections.
    pub connections_active: Gauge,
    /// `econoserve_reqlog_dropped_total` — request-log events evicted by
    /// the bounded ring (synced from `RequestLog::dropped()` before each
    /// scrape, so the counter stays monotonic and matches the log).
    pub reqlog_dropped: Counter,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self::on(Registry::new())
    }

    pub fn on(registry: Arc<Registry>) -> Self {
        let rate_limited = registry.counter(
            "econoserve_rate_limited_total",
            "Requests refused by the per-key rate limiter",
            &[],
        );
        let connections_active = registry.gauge(
            "econoserve_http_connections_active",
            "Open client connections",
            &[],
        );
        let reqlog_dropped = registry.counter(
            "econoserve_reqlog_dropped_total",
            "Request-log events evicted by the bounded ring",
            &[],
        );
        ServerMetrics {
            core: SimMetrics::on(registry),
            rate_limited,
            connections_active,
            reqlog_dropped,
        }
    }

    pub fn registry(&self) -> &Arc<Registry> {
        self.core.registry()
    }

    /// Count one HTTP exchange: `econoserve_http_requests_total{route,status}`.
    /// Interns lazily — route strings form a small fixed set.
    pub fn http_observe(&self, route: &str, status: u16) {
        self.registry()
            .counter(
                "econoserve_http_requests_total",
                "HTTP requests by route and status",
                &[("route", route), ("status", &status.to_string())],
            )
            .inc();
    }
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Snapshot;

    #[test]
    fn sim_vocabulary_renders_and_round_trips() {
        let m = SimMetrics::new();
        m.iterations.inc();
        m.tokens_prefill.add(128);
        m.tokens_decode.add(32);
        m.requests_done.add(3);
        m.slo_hit.add(2);
        m.slo_miss.inc();
        m.batch_occupancy.observe(4.0);
        m.kvc_utilization.observe(0.55);
        m.queue_depth.set(2.0);
        m.request_latency.observe(1.2);
        let text = m.render();
        let snap = Snapshot::parse(&text).expect("valid exposition");
        assert_eq!(snap.render(), text);
        assert_eq!(snap.value("econoserve_requests_total", &[("outcome", "done")]), Some(3.0));
        assert_eq!(snap.value("econoserve_tokens_total", &[("phase", "prefill")]), Some(128.0));
        assert_eq!(snap.value("econoserve_slo_total", &[("outcome", "miss")]), Some(1.0));
    }

    #[test]
    fn sim_and_server_share_family_names() {
        // The parity contract: the server-side bundle registers the sim
        // vocabulary verbatim (plus its HTTP-only families), so a sweep
        // snapshot and a live scrape merge cleanly.
        let sim = SimMetrics::new();
        sim.requests_done.inc();
        let srv = ServerMetrics::new();
        srv.core.requests_done.inc();
        srv.http_observe("/v1/generate", 200);
        let mut a = Snapshot::parse(&sim.render()).unwrap();
        let b = Snapshot::parse(&srv.registry().render()).unwrap();
        a.merge(&b).expect("kinds agree across paths");
        assert_eq!(a.value("econoserve_requests_total", &[("outcome", "done")]), Some(2.0));
        assert_eq!(
            a.value(
                "econoserve_http_requests_total",
                &[("route", "/v1/generate"), ("status", "200")]
            ),
            Some(1.0)
        );
    }

    #[test]
    fn fleet_counters_register_on_shared_registry() {
        let sim = SimMetrics::new();
        let fleet = FleetMetrics::on(sim.registry().clone());
        fleet.crashes.add(2);
        fleet.requests_lost.add(5);
        let snap = Snapshot::parse(&sim.render()).unwrap();
        assert_eq!(snap.value("econoserve_faults_total", &[("kind", "crash")]), Some(2.0));
        assert_eq!(snap.value("econoserve_requests_lost_total", &[]), Some(5.0));
    }
}
