//! Deterministic, bounded span recorder over simulated time (and, for
//! the HTTP server, wall time) — the request-lifecycle tracing layer.
//!
//! A [`TraceRecorder`] lives inside one `World` (pid = replica id) or
//! one control loop (the fleet event loop, the HTTP server). It keeps a
//! tiny per-request state machine — [`SpanState`] — and turns every
//! state change into a Chrome trace-event `X` span, so each traced
//! request's spans *partition* `[submit, finish]` with no gap or
//! overlap (the span-conservation property pinned in `tests/trace.rs`).
//! Scheduler decision records ("why was this queued request skipped?")
//! arrive through [`TraceRecorder::skip`] from the shared
//! `IterCtx::finish_into` plumbing, so all schedulers emit them without
//! per-scheduler edits.
//!
//! Determinism contract: recorders are per-world single-threaded state,
//! timestamps are integer microseconds of the simulated clock, and the
//! fleet merges per-replica documents in replica-id order — so the
//! rendered bytes are a pure function of (config, seed), bit-identical
//! at any `ECONOSERVE_THREADS` (pinned in `tests/equivalence.rs`).
//! Head-sampling draws from the dedicated `stream::TRACE` rng stream
//! and hashes *request content* (arrival, prompt length, true response
//! length), so a retry or hedge copy of a sampled request is sampled on
//! every replica it visits, at every thread count.
//!
//! Outcome totals ([`TraceDoc::outcomes`]) are counted for **all**
//! requests, sampled or not, which is what lets `econoserve tracelint`
//! reconcile a trace against `econoserve_requests_total{outcome}` even
//! for sampled million-request runs.

use super::span::{
    to_us, ArgValue, Outcome, SkipReason, SpanState, TraceEvent, FLEET_TID, SCHED_TID,
};
use crate::util::json::Json;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Config + sampling
// ---------------------------------------------------------------------------

/// Tracing knobs. `seed` must already be stream-separated (callers pass
/// `derive_seed(cfg.seed, stream::TRACE)`), so two worlds with the same
/// base seed sample the same logical requests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceConfig {
    /// Head-sampling rate in `[0, 1]`: fraction of requests that get
    /// per-span events. Aggregate outcome/skip totals always cover all
    /// requests.
    pub sample: f64,
    /// Hard cap on buffered events; beyond it events are dropped and
    /// counted (`TraceDoc::dropped`), never reallocated unboundedly.
    pub max_events: usize,
    /// Stream-separated sampling seed (`derive_seed(seed, stream::TRACE)`).
    pub seed: u64,
}

impl TraceConfig {
    pub fn new(seed: u64) -> Self {
        TraceConfig { sample: 1.0, max_events: 1_000_000, seed }
    }

    pub fn with_sample(mut self, sample: f64) -> Self {
        self.sample = sample.clamp(0.0, 1.0);
        self
    }
}

/// Content hash used for head-sampling: identical for every copy of the
/// same logical request (retry and hedge copies keep the original
/// arrival/prompt/response-length triple), independent of replica,
/// thread count, and submission order.
pub fn sample_key(seed: u64, arrival: f64, prompt_len: u64, true_rl: u64) -> u64 {
    let a = Rng::new(seed ^ arrival.to_bits()).next_u64();
    Rng::new(a ^ (prompt_len << 32) ^ true_rl).next_u64()
}

fn sample_threshold(sample: f64) -> u128 {
    if sample >= 1.0 {
        1u128 << 64
    } else if sample <= 0.0 {
        0
    } else {
        (sample * (1u128 << 64) as f64) as u128
    }
}

// ---------------------------------------------------------------------------
// Recorder
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct ReqState {
    sampled: bool,
    state: SpanState,
    /// Start of the open segment, in seconds on the recorder's clock.
    since: f64,
    closed: bool,
}

/// Per-world (or per-control-loop) span recorder. Single-threaded by
/// construction; the fleet merges finished [`TraceDoc`]s in replica-id
/// order instead of sharing one recorder.
#[derive(Debug, Clone)]
pub struct TraceRecorder {
    cfg: TraceConfig,
    threshold: u128,
    pid: u32,
    system: String,
    states: Vec<Option<ReqState>>,
    events: Vec<TraceEvent>,
    dropped: u64,
    outcomes: [u64; 4],
    skips: [u64; 5],
}

impl TraceRecorder {
    pub fn new(cfg: TraceConfig, pid: u32, system: &str) -> Self {
        TraceRecorder {
            cfg,
            threshold: sample_threshold(cfg.sample),
            pid,
            system: system.to_string(),
            states: Vec::new(),
            events: Vec::new(),
            dropped: 0,
            outcomes: [0; 4],
            skips: [0; 5],
        }
    }

    pub fn pid(&self) -> u32 {
        self.pid
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn events_len(&self) -> usize {
        self.events.len()
    }

    /// Would a request with this content triple get per-span events?
    pub fn sampled_content(&self, arrival: f64, prompt_len: u64, true_rl: u64) -> bool {
        (sample_key(self.cfg.seed, arrival, prompt_len, true_rl) as u128) < self.threshold
    }

    pub fn is_sampled(&self, id: usize) -> bool {
        matches!(self.states.get(id), Some(Some(s)) if s.sampled)
    }

    fn push(&mut self, ev: TraceEvent) {
        if self.events.len() >= self.cfg.max_events {
            self.dropped += 1;
        } else {
            self.events.push(ev);
        }
    }

    fn slot(&mut self, id: usize) -> &mut Option<ReqState> {
        if id >= self.states.len() {
            self.states.resize(id + 1, None);
        }
        &mut self.states[id]
    }

    /// Register a request at submit time, deciding sampling from its
    /// content triple. Idempotent per id.
    pub fn on_submit(&mut self, id: usize, t: f64, arrival: f64, prompt_len: u64, true_rl: u64) {
        let sampled = self.sampled_content(arrival, prompt_len, true_rl);
        self.on_submit_sampled(id, t, sampled);
    }

    /// Register a request with an explicit sampling decision (the HTTP
    /// server traces every request it is asked to).
    pub fn on_submit_sampled(&mut self, id: usize, t: f64, sampled: bool) {
        let slot = self.slot(id);
        if slot.is_none() {
            *slot = Some(ReqState { sampled, state: SpanState::Queued, since: t, closed: false });
        }
    }

    /// Close the open segment (if it rounds to a nonzero duration) and
    /// open a new one in `next`. Called from `World::apply_plan` and the
    /// preemption/eviction hooks; monotone `t` keeps the partition exact.
    pub fn transition(&mut self, id: usize, t: f64, next: SpanState) {
        let pid = self.pid;
        let Some(Some(st)) = self.states.get_mut(id) else { return };
        if st.closed {
            return;
        }
        let (t0, t1) = (to_us(st.since), to_us(t));
        let emit = st.sampled && t1 > t0;
        let name = st.state.as_str();
        st.state = next;
        st.since = t;
        if emit {
            self.push(TraceEvent::span(name, t0, t1, pid, id as u64));
        }
    }

    /// Terminal outcome: closes the final segment, emits the outcome
    /// instant (sampled requests), and counts the outcome for **all**
    /// requests — the totals `tracelint` reconciles against
    /// `requests_total{outcome}`.
    pub fn terminal(&mut self, id: usize, t: f64, outcome: Outcome) {
        let pid = self.pid;
        let idx = outcome as usize;
        let Some(Some(st)) = self.states.get_mut(id) else {
            self.outcomes[idx] += 1;
            return;
        };
        if st.closed {
            return;
        }
        st.closed = true;
        self.outcomes[idx] += 1;
        if !st.sampled {
            return;
        }
        // Crash victims that never arrived close at their (future)
        // submit time: an empty partition, not a negative span.
        let end = if t > st.since { t } else { st.since };
        let (t0, t1) = (to_us(st.since), to_us(end));
        let name = st.state.as_str();
        if t1 > t0 {
            self.push(TraceEvent::span(name, t0, t1, pid, id as u64));
        }
        self.push(TraceEvent::instant(outcome.as_str(), t1, pid, id as u64));
    }

    /// Scheduler decision record: the request was queued and skipped
    /// this iteration for `reason`. Counted for all requests; sampled
    /// requests additionally get an instant on their track, and their
    /// waiting segment is relabelled between `queued` and `stalled_kvc`
    /// so waiting time is attributed to the binding resource.
    pub fn skip(&mut self, id: usize, t: f64, reason: SkipReason) {
        self.skips[reason as usize] += 1;
        let Some(Some(st)) = self.states.get(id) else { return };
        if st.closed {
            return;
        }
        match (reason, st.state) {
            (SkipReason::KvcExhausted, SpanState::Queued) => {
                self.transition(id, t, SpanState::StalledKvc);
            }
            (SkipReason::BatchFull | SkipReason::Ordering, SpanState::StalledKvc) => {
                self.transition(id, t, SpanState::Queued);
            }
            _ => {}
        }
        let Some(Some(st)) = self.states.get(id) else { return };
        if st.sampled {
            let ev = TraceEvent::instant("skip", to_us(t), self.pid, id as u64)
                .with_arg("reason", ArgValue::Str(reason.as_str().to_string()));
            self.push(ev);
        }
    }

    /// A request was shed before it ever got an id (brownout admission
    /// gate): counted under `brownout_shed` with an instant on the
    /// control track.
    pub fn shed(&mut self, t: f64) {
        self.skips[SkipReason::BrownoutShed as usize] += 1;
        let ev = TraceEvent::instant("skip", to_us(t), self.pid, FLEET_TID)
            .with_arg("reason", ArgValue::Str(SkipReason::BrownoutShed.as_str().to_string()));
        self.push(ev);
    }

    /// Per-iteration record on the scheduler track: batch composition
    /// (prefill vs decode membership) and the iteration's KVC lease
    /// tally (granted / hosted / exhausted `AllocOutcome`s).
    #[allow(clippy::too_many_arguments)]
    pub fn iteration(
        &mut self,
        t0: f64,
        t1: f64,
        prefill: u64,
        decode: u64,
        granted: u64,
        hosted: u64,
        exhausted: u64,
    ) {
        let ev = TraceEvent::span("iteration", to_us(t0), to_us(t1), self.pid, SCHED_TID)
            .with_arg("prefill", ArgValue::U64(prefill))
            .with_arg("decode", ArgValue::U64(decode))
            .with_arg("kvc_granted", ArgValue::U64(granted))
            .with_arg("kvc_hosted", ArgValue::U64(hosted))
            .with_arg("kvc_exhausted", ArgValue::U64(exhausted));
        self.push(ev);
    }

    /// KVC lease-release / eviction marker on a sampled request's track
    /// (lease grants are visible in the iteration record's tally).
    pub fn lease_event(&mut self, id: usize, t: f64, name: &'static str) {
        if self.is_sampled(id) {
            self.push(TraceEvent::instant(name, to_us(t), self.pid, id as u64));
        }
    }

    /// Raw event escape hatch for control tracks (fleet routing, boot,
    /// crash, drain; HTTP connection events).
    pub fn push_raw(&mut self, ev: TraceEvent) {
        self.push(ev);
    }

    /// Finish: consume the recorder into its mergeable document.
    pub fn finish(self) -> TraceDoc {
        let mut skips = std::collections::BTreeMap::new();
        if self.skips.iter().any(|&n| n > 0) {
            skips.insert(self.system.clone(), self.skips);
        }
        TraceDoc {
            events: self.events,
            outcomes: self.outcomes,
            skips,
            dropped: self.dropped,
            sample: self.cfg.sample,
        }
    }

    /// Snapshot without consuming (the HTTP server's `GET /trace`).
    pub fn doc(&self) -> TraceDoc {
        self.clone().finish()
    }

    pub fn outcomes(&self) -> [u64; 4] {
        self.outcomes
    }

    pub fn skip_counts(&self) -> [u64; 5] {
        self.skips
    }
}

// ---------------------------------------------------------------------------
// Document: merge + export
// ---------------------------------------------------------------------------

/// A finished trace: events plus the aggregate metadata the exports
/// embed. Mergeable (fleet: replica docs in id order; sweep: cell docs
/// in grid order with pid offsets), so the merged bytes stay a pure
/// function of (config, seed).
#[derive(Debug, Clone, Default)]
pub struct TraceDoc {
    pub events: Vec<TraceEvent>,
    /// Terminal outcomes for all requests: done/rejected/cancelled/lost.
    pub outcomes: [u64; 4],
    /// Skip-reason totals keyed by system name (`sched+alloc`), so a
    /// merged sweep document keeps a per-scheduler breakdown.
    pub skips: std::collections::BTreeMap<String, [u64; 5]>,
    pub dropped: u64,
    pub sample: f64,
}

impl TraceDoc {
    pub fn new(sample: f64) -> Self {
        TraceDoc { sample, ..TraceDoc::default() }
    }

    /// Shift every pid by `offset` (sweep cells get disjoint pid bands).
    pub fn shift_pids(&mut self, offset: u32) {
        for ev in &mut self.events {
            ev.pid += offset;
        }
    }

    /// Name a process (replica / cell) for Perfetto's track labels.
    pub fn name_process(&mut self, pid: u32, name: &str) {
        self.events.push(TraceEvent::meta("process_name", pid, 0, name));
        self.events.push(TraceEvent::meta("thread_name", pid, SCHED_TID, "scheduler"));
        self.events.push(TraceEvent::meta("thread_name", pid, FLEET_TID, "control"));
    }

    pub fn merge(&mut self, other: TraceDoc) {
        self.events.extend(other.events);
        for i in 0..4 {
            self.outcomes[i] += other.outcomes[i];
        }
        for (sys, counts) in other.skips {
            let slot = self.skips.entry(sys).or_insert([0; 5]);
            for i in 0..5 {
                slot[i] += counts[i];
            }
        }
        self.dropped += other.dropped;
    }

    fn render_meta(&self, out: &mut String) {
        // Shortest-round-trip f64 Display is deterministic and parses
        // back exactly; 0 and 1 render without a decimal point.
        out.push_str("{\"sample\":");
        out.push_str(&self.sample.to_string());
        out.push_str(",\"dropped_events\":");
        out.push_str(&self.dropped.to_string());
        out.push_str(",\"outcomes\":{");
        for (i, o) in Outcome::ALL.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('"');
            out.push_str(o.as_str());
            out.push_str("\":");
            out.push_str(&self.outcomes[*o as usize].to_string());
        }
        out.push_str("},\"skips\":{");
        for (i, (sys, counts)) in self.skips.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('"');
            out.push_str(sys);
            out.push_str("\":{");
            for (j, r) in SkipReason::ALL.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push('"');
                out.push_str(r.as_str());
                out.push_str("\":");
                out.push_str(&counts[*r as usize].to_string());
            }
            out.push('}');
        }
        out.push_str("}}");
    }

    /// Chrome trace-event JSON (object form, Perfetto-loadable). The
    /// aggregate metadata rides in a top-level `econoserve` key, which
    /// the format explicitly allows and viewers ignore.
    pub fn to_chrome_string(&self) -> String {
        let mut out = String::with_capacity(64 + self.events.len() * 96);
        out.push_str("{\"displayTimeUnit\":\"ms\",\"econoserve\":");
        self.render_meta(&mut out);
        out.push_str(",\"traceEvents\":[");
        for (i, ev) in self.events.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('\n');
            ev.render(&mut out);
        }
        out.push_str("\n]}\n");
        out
    }

    /// JSONL export: one metadata header line (`{"meta":...}`), then one
    /// event object per line — the streaming-friendly flavor.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::with_capacity(32 + self.events.len() * 96);
        out.push_str("{\"meta\":");
        self.render_meta(&mut out);
        out.push_str("}\n");
        for ev in &self.events {
            ev.render(&mut out);
            out.push('\n');
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Lint
// ---------------------------------------------------------------------------

/// What `lint` verified, for reporting.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LintReport {
    pub events: usize,
    pub request_tracks: usize,
    /// Outcome instants found on request tracks (sampled requests only).
    pub span_outcomes: [u64; 4],
    /// Aggregate outcome totals from the embedded metadata (all
    /// requests).
    pub meta_outcomes: [u64; 4],
    pub sample: f64,
    pub dropped: u64,
}

fn ev_u64(ev: &Json, key: &str) -> Result<u64, String> {
    ev.get(key)
        .and_then(|v| v.as_i64())
        .and_then(|v| u64::try_from(v).ok())
        .ok_or_else(|| format!("event missing non-negative integer '{}'", key))
}

/// Strict structural check of a Chrome trace-event document produced by
/// [`TraceDoc::to_chrome_string`]:
///
/// * every event has a known phase, name vocabulary, and integer times;
/// * per request track, `X` spans are **exactly contiguous** (each
///   starts at the previous end — the span-conservation property) and
///   carry only [`SpanState`] names;
/// * at most one terminal-outcome instant per track, positioned at the
///   final span's end;
/// * scheduler/control tracks are monotone (spans never overlap);
/// * when nothing was dropped and sampling is 1.0, outcome instants
///   reconcile with the metadata outcome totals.
///
/// Contiguity/outcome-position checks are skipped when
/// `dropped_events > 0` (the cap cuts spans mid-lifecycle by design).
pub fn lint(text: &str) -> Result<LintReport, String> {
    let doc = Json::parse(text)?;
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or("missing 'traceEvents' array")?;
    let meta = doc.get("econoserve").ok_or("missing 'econoserve' metadata object")?;
    let mut rep = LintReport {
        events: events.len(),
        sample: meta.at(&["sample"]).ok().and_then(|v| v.as_f64()).ok_or("meta missing sample")?,
        dropped: meta
            .at(&["dropped_events"])
            .ok()
            .and_then(|v| v.as_i64())
            .ok_or("meta missing dropped_events")? as u64,
        ..LintReport::default()
    };
    for (i, o) in Outcome::ALL.iter().enumerate() {
        rep.meta_outcomes[i] = meta
            .at(&["outcomes", o.as_str()])
            .map_err(|e| format!("meta outcomes: {}", e))?
            .as_i64()
            .ok_or_else(|| format!("meta outcome '{}' not an integer", o.as_str()))?
            as u64;
    }
    if let Some(Json::Obj(systems)) = meta.get("skips") {
        for (sys, counts) in systems {
            for r in SkipReason::ALL {
                counts
                    .get(r.as_str())
                    .and_then(|v| v.as_i64())
                    .ok_or_else(|| format!("meta skips[{}] missing '{}'", sys, r.as_str()))?;
            }
        }
    } else {
        return Err("meta missing 'skips' object".into());
    }

    // (pid, tid) -> list of (ts, dur, name) X spans, plus instants.
    use std::collections::BTreeMap;
    let mut spans: BTreeMap<(u64, u64), Vec<(u64, u64, String)>> = BTreeMap::new();
    let mut instants: BTreeMap<(u64, u64), Vec<(u64, String, Option<String>)>> = BTreeMap::new();
    for (i, ev) in events.iter().enumerate() {
        let fail = |msg: String| format!("event #{}: {}", i, msg);
        let name = ev
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| fail("missing string 'name'".into()))?
            .to_string();
        let ph = ev
            .get("ph")
            .and_then(|v| v.as_str())
            .ok_or_else(|| fail("missing string 'ph'".into()))?;
        let pid = ev_u64(ev, "pid").map_err(&fail)?;
        let tid = ev_u64(ev, "tid").map_err(&fail)?;
        match ph {
            "M" => continue,
            "X" => {
                let ts = ev_u64(ev, "ts").map_err(&fail)?;
                let dur = ev_u64(ev, "dur").map_err(&fail)?;
                let request_track = tid <= u32::MAX as u64;
                if request_track && SpanState::parse(&name).is_none() {
                    return Err(fail(format!("unknown span state '{}' on request track", name)));
                }
                if !request_track && name != "iteration" && !CONTROL_SPANS.contains(&name.as_str())
                {
                    return Err(fail(format!("unknown control span '{}'", name)));
                }
                spans.entry((pid, tid)).or_default().push((ts, dur, name));
            }
            "i" => {
                let ts = ev_u64(ev, "ts").map_err(&fail)?;
                let reason = ev
                    .at(&["args", "reason"])
                    .ok()
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string());
                if name == "skip" {
                    let r = reason
                        .as_deref()
                        .ok_or_else(|| fail("skip instant missing args.reason".into()))?;
                    if SkipReason::parse(r).is_none() {
                        return Err(fail(format!("unknown skip reason '{}'", r)));
                    }
                } else if Outcome::parse(&name).is_none()
                    && !CONTROL_INSTANTS.contains(&name.as_str())
                {
                    return Err(fail(format!("unknown instant '{}'", name)));
                }
                instants.entry((pid, tid)).or_default().push((ts, name, reason));
            }
            other => return Err(fail(format!("unknown phase '{}'", other))),
        }
    }

    let strict = rep.dropped == 0;
    for ((pid, tid), track) in &spans {
        let request_track = *tid <= u32::MAX as u64;
        if request_track {
            rep.request_tracks += 1;
        }
        let mut end = 0u64;
        for (j, (ts, dur, name)) in track.iter().enumerate() {
            if strict && request_track && j > 0 && *ts != end {
                return Err(format!(
                    "track pid={} tid={}: span '{}' starts at {} but previous ends at {} \
                     (gap/overlap in lifecycle partition)",
                    pid, tid, name, ts, end
                ));
            }
            if *ts < end && strict {
                return Err(format!(
                    "track pid={} tid={}: span '{}' at {} overlaps previous end {}",
                    pid, tid, name, ts, end
                ));
            }
            end = ts + dur;
        }
    }
    for ((pid, tid), track) in &instants {
        let request_track = *tid <= u32::MAX as u64;
        if !request_track {
            continue;
        }
        let mut terminal: Option<(u64, Outcome)> = None;
        for (ts, name, _) in track {
            if let Some(o) = Outcome::parse(name) {
                if terminal.is_some() {
                    return Err(format!(
                        "track pid={} tid={}: multiple terminal outcomes",
                        pid, tid
                    ));
                }
                terminal = Some((*ts, o));
                rep.span_outcomes[o as usize] += 1;
            }
        }
        if strict {
            if let (Some((ts, _)), Some(track_spans)) = (terminal, spans.get(&(*pid, *tid))) {
                let end = track_spans.last().map(|(t, d, _)| t + d).unwrap_or(ts);
                if ts != end {
                    return Err(format!(
                        "track pid={} tid={}: outcome at {} but spans end at {}",
                        pid, tid, ts, end
                    ));
                }
            }
        }
    }
    if strict && rep.sample >= 1.0 && rep.span_outcomes != rep.meta_outcomes {
        return Err(format!(
            "outcome instants {:?} disagree with metadata outcome totals {:?} at sample=1",
            rep.span_outcomes, rep.meta_outcomes
        ));
    }
    Ok(rep)
}

const CONTROL_SPANS: [&str; 2] = ["boot", "drain"];
const CONTROL_INSTANTS: [&str; 6] = ["route", "crash", "retry", "hedge", "kvc_release", "kvc_evict"];

// ---------------------------------------------------------------------------
// Report
// ---------------------------------------------------------------------------

/// Render the per-request time-attribution table plus the
/// per-scheduler skip-reason breakdown — "where did each request's
/// lifetime go, and what was the binding constraint?".
pub fn report(text: &str) -> Result<String, String> {
    let rep = lint(text)?;
    let doc = Json::parse(text)?;
    let events = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap_or(&[]);

    use std::collections::BTreeMap;
    // (pid, tid) -> [us per state; 5], outcome
    let mut rows: BTreeMap<(u64, u64), ([u64; 5], Option<&str>)> = BTreeMap::new();
    for ev in events {
        let (Some(name), Some(ph)) =
            (ev.get("name").and_then(|v| v.as_str()), ev.get("ph").and_then(|v| v.as_str()))
        else {
            continue;
        };
        let pid = ev_u64(ev, "pid").unwrap_or(0);
        let tid = ev_u64(ev, "tid").unwrap_or(0);
        if tid > u32::MAX as u64 {
            continue;
        }
        let row = rows.entry((pid, tid)).or_default();
        match ph {
            "X" => {
                if let Some(state) = SpanState::parse(name) {
                    row.0[state as usize] += ev_u64(ev, "dur").unwrap_or(0);
                }
            }
            "i" => {
                if Outcome::parse(name).is_some() {
                    row.1 = Some(name);
                }
            }
            _ => {}
        }
    }

    let mut out = String::new();
    out.push_str(&format!(
        "trace-report: {} events, {} traced requests (sample={}, dropped={})\n\n",
        rep.events, rows.len(), rep.sample, rep.dropped
    ));
    out.push_str(
        "request          total_ms   queued  prefill   decode  stalled_kvc  preempted  outcome\n",
    );
    const MAX_ROWS: usize = 40;
    let ms = |us: u64| us as f64 / 1e3;
    let mut totals = [0u64; 5];
    for (i, ((pid, tid), (per_state, outcome))) in rows.iter().enumerate() {
        for (t, v) in totals.iter_mut().zip(per_state) {
            *t += v;
        }
        if i >= MAX_ROWS {
            continue;
        }
        let total: u64 = per_state.iter().sum();
        out.push_str(&format!(
            "{:<16} {:>8.1} {:>8.1} {:>8.1} {:>8.1} {:>12.1} {:>10.1}  {}\n",
            format!("{}:{}", pid, tid),
            ms(total),
            ms(per_state[SpanState::Queued as usize]),
            ms(per_state[SpanState::Prefill as usize]),
            ms(per_state[SpanState::Decode as usize]),
            ms(per_state[SpanState::StalledKvc as usize]),
            ms(per_state[SpanState::Preempted as usize]),
            outcome.unwrap_or("-"),
        ));
    }
    if rows.len() > MAX_ROWS {
        out.push_str(&format!("... ({} more requests)\n", rows.len() - MAX_ROWS));
    }
    let grand: u64 = totals.iter().sum();
    out.push_str(&format!(
        "{:<16} {:>8.1} {:>8.1} {:>8.1} {:>8.1} {:>12.1} {:>10.1}\n",
        "TOTAL",
        ms(grand),
        ms(totals[SpanState::Queued as usize]),
        ms(totals[SpanState::Prefill as usize]),
        ms(totals[SpanState::Decode as usize]),
        ms(totals[SpanState::StalledKvc as usize]),
        ms(totals[SpanState::Preempted as usize]),
    ));

    out.push_str("\noutcomes (all requests): ");
    for (i, o) in Outcome::ALL.iter().enumerate() {
        if i > 0 {
            out.push_str("  ");
        }
        out.push_str(&format!("{}={}", o.as_str(), rep.meta_outcomes[*o as usize]));
    }
    out.push('\n');

    out.push_str("\nscheduler skip decisions (request-iterations, by reason):\n");
    if let Ok(Json::Obj(systems)) = doc.at(&["econoserve", "skips"]) {
        if systems.is_empty() {
            out.push_str("  (none recorded)\n");
        }
        for (sys, counts) in systems {
            out.push_str(&format!("  {:<28}", sys));
            for r in SkipReason::ALL {
                let n = counts.get(r.as_str()).and_then(|v| v.as_i64()).unwrap_or(0);
                out.push_str(&format!(" {}={}", r.as_str(), n));
            }
            out.push('\n');
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Reconciliation helper
// ---------------------------------------------------------------------------

/// Read one counter sample from canonical Prometheus exposition text
/// (as produced by `Registry::render`): `prom_counter(text,
/// "econoserve_requests_total", "{outcome=\"done\"}")`. Pass `""` for
/// unlabelled families.
pub fn prom_counter(text: &str, family: &str, labels: &str) -> Option<u64> {
    let needle = format!("{}{} ", family, labels);
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(&needle) {
            return rest.trim().parse::<f64>().ok().map(|v| v as u64);
        }
    }
    None
}

/// Check that a trace's aggregate outcome totals reconcile with the
/// `econoserve_requests_total{outcome}` counters of a metrics snapshot.
/// `lost` is trace-only (crash victims increment no sim counter), so
/// only done/rejected/cancelled participate.
pub fn reconcile(rep: &LintReport, metrics_text: &str) -> Result<(), String> {
    for (o, idx) in
        [(Outcome::Done, 0usize), (Outcome::Rejected, 1), (Outcome::Cancelled, 2)]
    {
        let labels = format!("{{outcome=\"{}\"}}", o.as_str());
        let counter =
            prom_counter(metrics_text, "econoserve_requests_total", &labels).unwrap_or(0);
        if counter != rep.meta_outcomes[idx] {
            return Err(format!(
                "trace outcome '{}' = {} but requests_total{} = {}",
                o.as_str(),
                rep.meta_outcomes[idx],
                labels,
                counter
            ));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_doc() -> TraceDoc {
        let mut r = TraceRecorder::new(TraceConfig::new(7), 0, "orca+max");
        r.on_submit(0, 0.0, 0.0, 64, 8);
        r.skip(0, 0.5, SkipReason::BatchFull);
        r.transition(0, 1.0, SpanState::Prefill);
        r.transition(0, 1.5, SpanState::Queued);
        r.skip(0, 1.5, SkipReason::KvcExhausted);
        r.transition(0, 2.0, SpanState::Decode);
        r.iteration(1.0, 1.5, 1, 0, 2, 1, 1);
        r.terminal(0, 3.0, Outcome::Done);
        r.on_submit(1, 0.25, 0.25, 32, 4);
        r.terminal(1, 0.25, Outcome::Rejected);
        let mut doc = r.finish();
        doc.name_process(0, "replica0");
        doc
    }

    #[test]
    fn recorder_partitions_lifecycle_and_lints() {
        let doc = mini_doc();
        assert_eq!(doc.outcomes, [1, 1, 0, 0]);
        assert_eq!(doc.skips["orca+max"][SkipReason::BatchFull as usize], 1);
        assert_eq!(doc.skips["orca+max"][SkipReason::KvcExhausted as usize], 1);
        let text = doc.to_chrome_string();
        let rep = lint(&text).expect("lint");
        assert_eq!(rep.span_outcomes, [1, 1, 0, 0]);
        assert_eq!(rep.meta_outcomes, [1, 1, 0, 0]);
        assert_eq!(rep.request_tracks, 1); // request 1 has zero-length life
        // The kvc_exhausted skip relabelled the waiting segment.
        assert!(text.contains("\"stalled_kvc\""), "{text}");
    }

    #[test]
    fn lint_rejects_gap_and_overlap() {
        let mut doc = TraceDoc::new(1.0);
        doc.events.push(TraceEvent::span("queued", 0, 10, 0, 1));
        doc.events.push(TraceEvent::span("decode", 12, 20, 0, 1));
        let err = lint(&doc.to_chrome_string()).unwrap_err();
        assert!(err.contains("gap/overlap"), "{err}");

        let mut doc2 = TraceDoc::new(1.0);
        doc2.events.push(TraceEvent::span("queued", 0, 10, 0, 1));
        doc2.events.push(TraceEvent::span("queued", 5, 10, 0, 1));
        assert!(lint(&doc2.to_chrome_string()).is_err());
    }

    #[test]
    fn lint_rejects_unknown_vocabulary() {
        let mut doc = TraceDoc::new(1.0);
        doc.events.push(TraceEvent::span("mystery", 0, 10, 0, 1));
        let err = lint(&doc.to_chrome_string()).unwrap_err();
        assert!(err.contains("unknown span state"), "{err}");
    }

    #[test]
    fn sampling_is_content_deterministic() {
        let cfg = TraceConfig::new(42).with_sample(0.5);
        let r1 = TraceRecorder::new(cfg, 0, "s");
        let r2 = TraceRecorder::new(cfg, 3, "s");
        let mut kept = 0;
        for i in 0..1000u64 {
            let (arr, pl, rl) = (i as f64 * 0.1, 64 + i, 8 + i % 32);
            assert_eq!(r1.sampled_content(arr, pl, rl), r2.sampled_content(arr, pl, rl));
            kept += r1.sampled_content(arr, pl, rl) as u64;
        }
        // Head sampling at 0.5 keeps roughly half.
        assert!((300..700).contains(&kept), "kept={kept}");
        // Unsampled requests still count in aggregates.
        let mut r = TraceRecorder::new(TraceConfig::new(42).with_sample(0.0), 0, "s");
        r.on_submit(0, 0.0, 0.0, 64, 8);
        r.terminal(0, 1.0, Outcome::Done);
        let doc = r.finish();
        assert_eq!(doc.outcomes[0], 1);
        assert!(doc.events.is_empty());
    }

    #[test]
    fn event_cap_drops_and_counts() {
        let mut cfg = TraceConfig::new(1);
        cfg.max_events = 2;
        let mut r = TraceRecorder::new(cfg, 0, "s");
        r.on_submit(0, 0.0, 0.0, 1, 1);
        r.transition(0, 1.0, SpanState::Prefill);
        r.transition(0, 2.0, SpanState::Decode);
        r.terminal(0, 3.0, Outcome::Done);
        let doc = r.finish();
        assert_eq!(doc.events.len(), 2);
        assert_eq!(doc.dropped, 2);
        // Capped docs still lint (contiguity checks relax).
        lint(&doc.to_chrome_string()).expect("lint capped doc");
    }

    #[test]
    fn merge_shifts_pids_and_sums_aggregates() {
        let mut a = mini_doc();
        let mut b = mini_doc();
        b.shift_pids(10_000);
        a.merge(b);
        assert_eq!(a.outcomes, [2, 2, 0, 0]);
        assert_eq!(a.skips["orca+max"][SkipReason::BatchFull as usize], 2);
        let rep = lint(&a.to_chrome_string()).expect("merged lint");
        assert_eq!(rep.request_tracks, 2);
    }

    #[test]
    fn jsonl_mirrors_chrome_events() {
        let doc = mini_doc();
        let jsonl = doc.to_jsonl();
        let mut lines = jsonl.lines();
        let head = Json::parse(lines.next().unwrap()).expect("meta line");
        assert!(head.get("meta").is_some());
        let n = lines.clone().count();
        assert_eq!(n, doc.events.len());
        for line in lines {
            Json::parse(line).expect("event line");
        }
    }

    #[test]
    fn report_attributes_time() {
        let text = mini_doc().to_chrome_string();
        let rendered = report(&text).expect("report");
        assert!(rendered.contains("stalled_kvc"), "{rendered}");
        assert!(rendered.contains("orca+max"), "{rendered}");
        assert!(rendered.contains("done=1"), "{rendered}");
    }

    #[test]
    fn prom_counter_reads_canonical_text() {
        let text = "# TYPE econoserve_requests_total counter\n\
                    econoserve_requests_total{outcome=\"done\"} 42\n\
                    econoserve_preemptions_total 7\n";
        assert_eq!(
            prom_counter(text, "econoserve_requests_total", "{outcome=\"done\"}"),
            Some(42)
        );
        assert_eq!(prom_counter(text, "econoserve_preemptions_total", ""), Some(7));
        assert_eq!(prom_counter(text, "econoserve_nope_total", ""), None);
    }

    #[test]
    fn reconcile_matches_and_mismatches() {
        let rep = LintReport { meta_outcomes: [42, 3, 1, 5], ..LintReport::default() };
        let ok = "econoserve_requests_total{outcome=\"cancelled\"} 1\n\
                  econoserve_requests_total{outcome=\"done\"} 42\n\
                  econoserve_requests_total{outcome=\"rejected\"} 3\n";
        reconcile(&rep, ok).expect("reconciles");
        let bad = ok.replace(" 42", " 41");
        assert!(reconcile(&rep, &bad).unwrap_err().contains("done"));
    }
}
