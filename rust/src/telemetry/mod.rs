//! Unified telemetry: one metric vocabulary for the simulator and the
//! real HTTP server.
//!
//! The registry is std-only and `Send + Sync`: registration (interning a
//! `(family, label-set)` pair to a dense series id) takes a mutex once,
//! and returns a cheap `Clone`-able handle ([`Counter`], [`Gauge`],
//! [`Histogram`]) whose hot path is a couple of atomic ops — no locks,
//! no allocation, no hashing. That keeps instrumentation safe inside
//! `World::apply_plan` (millions of iterations) and inside the HTTP
//! server's per-connection threads alike.
//!
//! Exposition is Prometheus text format ([`Registry::render`]), rendered
//! in a canonical order (families by name, series by sorted label set,
//! histogram buckets by bound) so that equal metric states produce
//! byte-identical text — the fleet equivalence tests pin exactly this.
//! [`text::Snapshot`] parses the format back, merges snapshots (the
//! fleet sums its replicas' registries in replica-id order; sweeps merge
//! cells in cell order), and re-renders canonically.
//!
//! Sub-modules:
//!  * [`text`] — Prometheus text encode/parse/merge ([`Snapshot`]).
//!  * [`reqlog`] — bounded structured per-request event log.
//!  * [`vocab`] — the pre-registered metric families shared by the sim
//!    ([`SimMetrics`]) and the server ([`ServerMetrics`]); see
//!    `docs/metrics-dictionary.md` for the full dictionary.
//!  * [`span`] / [`trace`] — request-lifecycle span tracing with
//!    scheduler decision provenance (Chrome trace-event / JSONL
//!    export); see the "Tracing" section of `docs/API.md`.
//!
//! Determinism contract: sim-side metric values are pure functions of
//! (config, seed). Each replica `World` owns its own registry and
//! updates it single-threaded; the fleet merges the rendered snapshots
//! in replica-id order at finalize, so the merged text is bit-identical
//! at any worker-thread count.

pub mod reqlog;
pub mod span;
pub mod text;
pub mod trace;
pub mod vocab;

pub use reqlog::{RequestEvent, RequestLog};
pub use span::{Outcome, SkipReason, SpanState};
pub use text::Snapshot;
pub use trace::{TraceConfig, TraceDoc, TraceRecorder};
pub use vocab::{FleetMetrics, ServerMetrics, SimMetrics};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Metric family kind (Prometheus `# TYPE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    pub fn as_str(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "counter" => Some(MetricKind::Counter),
            "gauge" => Some(MetricKind::Gauge),
            "histogram" => Some(MetricKind::Histogram),
            _ => None,
        }
    }
}

/// A sorted, owned label set. Sorting makes the set canonical: the same
/// labels in any order intern to the same series, and render order is
/// deterministic.
#[derive(Debug, Clone, Default, PartialEq, Eq, PartialOrd, Ord)]
pub struct LabelSet(Vec<(String, String)>);

impl LabelSet {
    pub fn empty() -> Self {
        LabelSet(Vec::new())
    }

    pub fn from_pairs(pairs: &[(&str, &str)]) -> Self {
        let mut v: Vec<(String, String)> =
            pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        v.sort();
        LabelSet(v)
    }

    pub fn from_owned(mut pairs: Vec<(String, String)>) -> Self {
        pairs.sort();
        LabelSet(pairs)
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn pairs(&self) -> &[(String, String)] {
        &self.0
    }

    /// This set plus one more label (used for histogram `le`).
    fn with(&self, key: &str, value: String) -> LabelSet {
        let mut v = self.0.clone();
        v.push((key.to_string(), value));
        v.sort();
        LabelSet(v)
    }

    /// Render as `{k1="v1",k2="v2"}`, or the empty string for no labels.
    pub fn render(&self) -> String {
        if self.0.is_empty() {
            return String::new();
        }
        let inner: Vec<String> = self
            .0
            .iter()
            .map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v)))
            .collect();
        format!("{{{}}}", inner.join(","))
    }
}

/// Escape a label value for the Prometheus text format.
pub(crate) fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Escape HELP text (backslash and newline only, per the exposition spec).
pub(crate) fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Format a sample value the way both the registry and [`Snapshot`]
/// render it, so a parse→render round trip is byte-identical. Rust's
/// shortest-roundtrip `Display` for f64 is deterministic.
pub(crate) fn fmt_value(v: f64) -> String {
    if v.is_infinite() {
        if v > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() }
    } else if v.is_nan() {
        "NaN".to_string()
    } else {
        format!("{v}")
    }
}

/// Fixed histogram bucket bounds (upper edges, excluding `+Inf`).
#[derive(Debug, Clone)]
pub struct Buckets(Arc<[f64]>);

impl Buckets {
    /// `count` exponential bucket bounds: start, start*factor, ...
    /// Panics on a non-positive start or a factor <= 1 — bucket layouts
    /// are compile-time decisions, not data.
    pub fn exponential(start: f64, factor: f64, count: usize) -> Self {
        assert!(start > 0.0 && factor > 1.0 && count > 0, "bad exponential buckets");
        let mut v = Vec::with_capacity(count);
        let mut b = start;
        for _ in 0..count {
            v.push(b);
            b *= factor;
        }
        Buckets(v.into())
    }

    /// `count` linear bucket bounds: start, start+width, ...
    pub fn linear(start: f64, width: f64, count: usize) -> Self {
        assert!(width > 0.0 && count > 0, "bad linear buckets");
        let v: Vec<f64> = (0..count).map(|i| start + width * i as f64).collect();
        Buckets(v.into())
    }

    pub fn bounds(&self) -> &[f64] {
        &self.0
    }
}

// ---------------------------------------------------------------------------
// Cells: the shared atomic state behind each handle.

#[derive(Debug, Default)]
struct CounterCell(AtomicU64);

#[derive(Debug, Default)]
struct GaugeCell(AtomicU64); // f64 bits

impl GaugeCell {
    fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    fn add(&self, d: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + d).to_bits();
            match self.0.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }
}

#[derive(Debug)]
struct HistogramCell {
    bounds: Arc<[f64]>,
    /// Non-cumulative per-bucket counts; last slot is the +Inf overflow.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: GaugeCell, // CAS-add f64
}

impl HistogramCell {
    fn new(bounds: Arc<[f64]>) -> Self {
        let n = bounds.len() + 1;
        HistogramCell {
            bounds,
            buckets: (0..n).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: GaugeCell::default(),
        }
    }

    fn observe(&self, v: f64) {
        let idx = self.bounds.partition_point(|b| v > *b);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.add(v);
    }
}

// ---------------------------------------------------------------------------
// Handles: pre-registered, Clone, lock-free hot path.

/// Monotone integer counter.
#[derive(Debug, Clone)]
pub struct Counter {
    cell: Arc<CounterCell>,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.cell.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.cell.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous value.
#[derive(Debug, Clone)]
pub struct Gauge {
    cell: Arc<GaugeCell>,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.cell.set(v);
    }

    pub fn add(&self, d: f64) {
        self.cell.add(d);
    }

    pub fn get(&self) -> f64 {
        self.cell.get()
    }
}

/// Fixed-bucket histogram.
#[derive(Debug, Clone)]
pub struct Histogram {
    cell: Arc<HistogramCell>,
}

impl Histogram {
    pub fn observe(&self, v: f64) {
        self.cell.observe(v);
    }

    pub fn count(&self) -> u64 {
        self.cell.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        self.cell.sum.get()
    }

    /// Mean of all observations (0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 { 0.0 } else { self.sum() / n as f64 }
    }

    /// Approximate quantile by linear interpolation inside the bucket
    /// holding the q-th observation. Clamped to the last finite bound for
    /// overflow observations; 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, b) in self.cell.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            if cum + n >= target {
                let lower = if i == 0 { 0.0 } else { self.cell.bounds[i - 1] };
                let upper = match self.cell.bounds.get(i) {
                    Some(u) => *u,
                    None => return self.cell.bounds.last().copied().unwrap_or(0.0),
                };
                let frac = (target - cum) as f64 / n as f64;
                return lower + (upper - lower) * frac;
            }
            cum += n;
        }
        self.cell.bounds.last().copied().unwrap_or(0.0)
    }
}

// ---------------------------------------------------------------------------
// Registry.

#[derive(Debug)]
enum CellRef {
    Counter(Arc<CounterCell>),
    Gauge(Arc<GaugeCell>),
    Histogram(Arc<HistogramCell>),
}

#[derive(Debug)]
struct Series {
    /// Dense id in registration order (diagnostics / log correlation).
    #[allow(dead_code)]
    id: usize,
    cell: CellRef,
}

#[derive(Debug)]
struct Family {
    kind: MetricKind,
    help: String,
    bounds: Option<Arc<[f64]>>,
    series: BTreeMap<LabelSet, Series>,
}

#[derive(Debug, Default)]
struct Inner {
    families: BTreeMap<String, Family>,
    next_id: usize,
}

/// The metric registry: interns `(family, labels)` pairs and renders the
/// whole state as canonical Prometheus text.
///
/// Registering the same family+labels twice returns a handle to the same
/// underlying series, so independent components can share a series by
/// name. Registering a name with a different kind panics — metric names
/// are a compile-time vocabulary (`telemetry::vocab`), not data.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Registry {
    pub fn new() -> Arc<Registry> {
        Arc::new(Registry::default())
    }

    fn family<'a>(
        inner: &'a mut Inner,
        name: &str,
        help: &str,
        kind: MetricKind,
        bounds: Option<Arc<[f64]>>,
    ) -> &'a mut Family {
        let fam = inner.families.entry(name.to_string()).or_insert_with(|| Family {
            kind,
            help: help.to_string(),
            bounds: bounds.clone(),
            series: BTreeMap::new(),
        });
        assert!(
            fam.kind == kind,
            "metric family '{name}' re-registered as {} (was {})",
            kind.as_str(),
            fam.kind.as_str()
        );
        fam
    }

    /// Register (or look up) a counter series.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        let ls = LabelSet::from_pairs(labels);
        let mut inner = crate::util::sync::lock(&self.inner);
        let id = inner.next_id;
        let fam = Self::family(&mut inner, name, help, MetricKind::Counter, None);
        let series = fam.series.entry(ls).or_insert_with(|| Series {
            id,
            cell: CellRef::Counter(Arc::new(CounterCell::default())),
        });
        let cell = match &series.cell {
            CellRef::Counter(c) => c.clone(),
            _ => unreachable!("kind checked above"),
        };
        if series.id == id {
            inner.next_id += 1;
        }
        Counter { cell }
    }

    /// Register (or look up) a gauge series.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        let ls = LabelSet::from_pairs(labels);
        let mut inner = crate::util::sync::lock(&self.inner);
        let id = inner.next_id;
        let fam = Self::family(&mut inner, name, help, MetricKind::Gauge, None);
        let series = fam.series.entry(ls).or_insert_with(|| Series {
            id,
            cell: CellRef::Gauge(Arc::new(GaugeCell::default())),
        });
        let cell = match &series.cell {
            CellRef::Gauge(c) => c.clone(),
            _ => unreachable!("kind checked above"),
        };
        if series.id == id {
            inner.next_id += 1;
        }
        Gauge { cell }
    }

    /// Register (or look up) a histogram series. The bucket layout is
    /// fixed at first registration; later registrations reuse it.
    pub fn histogram(
        &self,
        name: &str,
        help: &str,
        buckets: Buckets,
        labels: &[(&str, &str)],
    ) -> Histogram {
        let ls = LabelSet::from_pairs(labels);
        let mut inner = crate::util::sync::lock(&self.inner);
        let id = inner.next_id;
        let fam =
            Self::family(&mut inner, name, help, MetricKind::Histogram, Some(buckets.0.clone()));
        let bounds = fam.bounds.clone().expect("histogram family has bounds");
        let series = fam.series.entry(ls).or_insert_with(|| Series {
            id,
            cell: CellRef::Histogram(Arc::new(HistogramCell::new(bounds))),
        });
        let cell = match &series.cell {
            CellRef::Histogram(c) => c.clone(),
            _ => unreachable!("kind checked above"),
        };
        if series.id == id {
            inner.next_id += 1;
        }
        Histogram { cell }
    }

    /// Number of interned series (dense-id high-water mark).
    pub fn series_count(&self) -> usize {
        crate::util::sync::lock(&self.inner).next_id
    }

    /// Render the whole registry as canonical Prometheus text: families
    /// in name order, series in label-set order, histogram buckets
    /// cumulative and bound-ordered with `le` in its sorted label slot.
    /// Equal metric states render to byte-identical text.
    pub fn render(&self) -> String {
        let inner = crate::util::sync::lock(&self.inner);
        let mut out = String::new();
        for (name, fam) in &inner.families {
            out.push_str(&format!("# HELP {name} {}\n", escape_help(&fam.help)));
            out.push_str(&format!("# TYPE {name} {}\n", fam.kind.as_str()));
            for (ls, series) in &fam.series {
                match &series.cell {
                    CellRef::Counter(c) => {
                        let v = c.0.load(Ordering::Relaxed);
                        out.push_str(&format!("{name}{} {}\n", ls.render(), fmt_value(v as f64)));
                    }
                    CellRef::Gauge(g) => {
                        out.push_str(&format!("{name}{} {}\n", ls.render(), fmt_value(g.get())));
                    }
                    CellRef::Histogram(h) => {
                        let mut cum = 0u64;
                        for (i, b) in h.buckets.iter().enumerate() {
                            cum += b.load(Ordering::Relaxed);
                            let le = match h.bounds.get(i) {
                                Some(bound) => fmt_value(*bound),
                                None => "+Inf".to_string(),
                            };
                            let bls = ls.with("le", le);
                            out.push_str(&format!(
                                "{name}_bucket{} {}\n",
                                bls.render(),
                                fmt_value(cum as f64)
                            ));
                        }
                        out.push_str(&format!(
                            "{name}_sum{} {}\n",
                            ls.render(),
                            fmt_value(h.sum.get())
                        ));
                        let n = h.count.load(Ordering::Relaxed);
                        out.push_str(&format!(
                            "{name}_count{} {}\n",
                            ls.render(),
                            fmt_value(n as f64)
                        ));
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_histogram_basics() {
        let reg = Registry::new();
        let c = reg.counter("econoserve_test_total", "test counter", &[("k", "a")]);
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Same name+labels intern to the same series.
        let c2 = reg.counter("econoserve_test_total", "test counter", &[("k", "a")]);
        c2.inc();
        assert_eq!(c.get(), 6);

        let g = reg.gauge("econoserve_test_gauge", "test gauge", &[]);
        g.set(2.5);
        g.add(-0.5);
        assert!((g.get() - 2.0).abs() < 1e-12);

        let h = reg.histogram(
            "econoserve_test_seconds",
            "test histogram",
            Buckets::exponential(0.1, 2.0, 3), // 0.1, 0.2, 0.4
            &[],
        );
        h.observe(0.05); // bucket 0
        h.observe(0.2); // exact bound -> le="0.2" (bucket 1)
        h.observe(9.0); // +Inf overflow
        assert_eq!(h.count(), 3);
        assert!((h.sum() - 9.25).abs() < 1e-12);
        assert!((h.mean() - 9.25 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_exact_bound_is_inclusive() {
        let reg = Registry::new();
        let h = reg.histogram("h", "x", Buckets::linear(1.0, 1.0, 3), &[]);
        h.observe(2.0); // le="2" must include it
        let text = reg.render();
        assert!(text.contains("h_bucket{le=\"1\"} 0"), "{text}");
        assert!(text.contains("h_bucket{le=\"2\"} 1"), "{text}");
        assert!(text.contains("h_bucket{le=\"+Inf\"} 1"), "{text}");
    }

    #[test]
    fn render_is_canonical_and_label_order_independent() {
        let mk = |swap: bool| {
            let reg = Registry::new();
            let labels: &[(&str, &str)] =
                if swap { &[("b", "2"), ("a", "1")] } else { &[("a", "1"), ("b", "2")] };
            reg.counter("z_total", "last", labels).add(3);
            reg.gauge("a_gauge", "first", &[]).set(1.5);
            reg.render()
        };
        let t1 = mk(false);
        let t2 = mk(true);
        assert_eq!(t1, t2);
        // Families sorted by name: a_gauge before z_total.
        let a = t1.find("a_gauge").unwrap();
        let z = t1.find("z_total").unwrap();
        assert!(a < z);
        assert!(t1.contains("z_total{a=\"1\",b=\"2\"} 3"), "{t1}");
    }

    #[test]
    fn label_values_are_escaped() {
        let reg = Registry::new();
        reg.counter("c_total", "c", &[("k", "a\"b\\c\nd")]).inc();
        let text = reg.render();
        assert!(text.contains("c_total{k=\"a\\\"b\\\\c\\nd\"} 1"), "{text}");
    }

    #[test]
    fn quantile_interpolates_and_handles_edges() {
        let reg = Registry::new();
        let h = reg.histogram("q", "x", Buckets::linear(1.0, 1.0, 4), &[]);
        assert_eq!(h.quantile(0.95), 0.0, "empty histogram");
        for _ in 0..100 {
            h.observe(0.5); // all in bucket [0, 1]
        }
        let q50 = h.quantile(0.5);
        assert!(q50 > 0.0 && q50 <= 1.0, "q50={q50}");
        h.observe(100.0); // overflow clamps to last finite bound
        assert_eq!(h.quantile(1.0), 4.0);
    }

    #[test]
    fn registry_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Registry>();
        assert_send_sync::<Counter>();
        assert_send_sync::<Gauge>();
        assert_send_sync::<Histogram>();
    }

    #[test]
    fn dense_ids_count_series_not_lookups() {
        let reg = Registry::new();
        reg.counter("a_total", "a", &[("k", "1")]);
        reg.counter("a_total", "a", &[("k", "2")]);
        reg.counter("a_total", "a", &[("k", "1")]); // lookup, not new
        reg.gauge("g", "g", &[]);
        assert_eq!(reg.series_count(), 3);
    }
}
