//! The span model behind [`super::trace`]: typed lifecycle states,
//! terminal outcomes, scheduler skip reasons, and the single event
//! record both export formats (Chrome trace-event JSON and JSONL)
//! serialize.
//!
//! Times are recorded as **integer microseconds** (`ts_us`/`dur_us`).
//! Rounding happens once, at the moment a segment boundary is recorded,
//! so two segments sharing an f64 boundary share the same integer
//! microsecond — adjacent spans are *exactly* contiguous and
//! `econoserve tracelint` can check the partition property with `==`,
//! not an epsilon. Integer times also make the rendered bytes
//! platform-independent, which the 1-vs-N-thread bit-identical trace
//! test pins.

/// What a traced request is doing during one span. These five states
/// partition every traced request's `[submit, finish]` window on the
/// simulated clock (the span-conservation property in `tests/trace.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanState {
    /// Waiting for a batch slot (inbox or scheduler-internal queue).
    Queued,
    /// Member of an executed iteration's prefill set.
    Prefill,
    /// Member of an executed iteration's decode set.
    Decode,
    /// Waiting while the KV cache is the binding constraint: the
    /// scheduler skipped it with reason `kvc_exhausted` and it has not
    /// been scheduled since.
    StalledKvc,
    /// Preempted out of the running batch (swap or drop-recompute),
    /// lease released, waiting to be restored.
    Preempted,
}

impl SpanState {
    pub fn as_str(self) -> &'static str {
        match self {
            SpanState::Queued => "queued",
            SpanState::Prefill => "prefill",
            SpanState::Decode => "decode",
            SpanState::StalledKvc => "stalled_kvc",
            SpanState::Preempted => "preempted",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "queued" => Some(SpanState::Queued),
            "prefill" => Some(SpanState::Prefill),
            "decode" => Some(SpanState::Decode),
            "stalled_kvc" => Some(SpanState::StalledKvc),
            "preempted" => Some(SpanState::Preempted),
            _ => None,
        }
    }

    pub const ALL: [SpanState; 5] = [
        SpanState::Queued,
        SpanState::Prefill,
        SpanState::Decode,
        SpanState::StalledKvc,
        SpanState::Preempted,
    ];
}

/// Terminal outcome of a traced request. `Done`/`Rejected`/`Cancelled`
/// reconcile 1:1 with `econoserve_requests_total{outcome=...}`; `Lost`
/// is trace-only (crash victims increment no sim counter — the fleet
/// accounts for them at the fleet level).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    Done,
    Rejected,
    Cancelled,
    Lost,
}

impl Outcome {
    pub fn as_str(self) -> &'static str {
        match self {
            Outcome::Done => "done",
            Outcome::Rejected => "rejected",
            Outcome::Cancelled => "cancelled",
            Outcome::Lost => "lost",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "done" => Some(Outcome::Done),
            "rejected" => Some(Outcome::Rejected),
            "cancelled" => Some(Outcome::Cancelled),
            "lost" => Some(Outcome::Lost),
            _ => None,
        }
    }

    pub const ALL: [Outcome; 4] =
        [Outcome::Done, Outcome::Rejected, Outcome::Cancelled, Outcome::Lost];
}

/// Why the scheduler skipped a queued request in an executed iteration.
/// Emitted centrally from `IterCtx::finish_into` (every scheduler gets
/// the records through the shared `plan_iteration` plumbing) except
/// `BrownoutShed`, which the fleet front door emits at arrival time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SkipReason {
    /// A KVC allocation failed this iteration: the cache, not the batch,
    /// is the binding constraint.
    KvcExhausted,
    /// The batch ran without it and no later-arrived request bypassed
    /// it: capacity, not ordering, held it back.
    BatchFull,
    /// A later-arrived request was scheduled ahead of it (priority /
    /// SJF / slack ordering), or the scheduler formed no batch at all
    /// while holding it (e.g. a synchronous group boundary).
    Ordering,
    /// Shed by the brownout admission gate before routing.
    BrownoutShed,
    /// Held in a non-runnable wait state: prefill finished and the
    /// request waits for its decode group, or it is preempted awaiting
    /// restore.
    WaitingHeld,
}

impl SkipReason {
    pub fn as_str(self) -> &'static str {
        match self {
            SkipReason::KvcExhausted => "kvc_exhausted",
            SkipReason::BatchFull => "batch_full",
            SkipReason::Ordering => "ordering",
            SkipReason::BrownoutShed => "brownout_shed",
            SkipReason::WaitingHeld => "waiting_held",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "kvc_exhausted" => Some(SkipReason::KvcExhausted),
            "batch_full" => Some(SkipReason::BatchFull),
            "ordering" => Some(SkipReason::Ordering),
            "brownout_shed" => Some(SkipReason::BrownoutShed),
            "waiting_held" => Some(SkipReason::WaitingHeld),
            _ => None,
        }
    }

    pub const ALL: [SkipReason; 5] = [
        SkipReason::KvcExhausted,
        SkipReason::BatchFull,
        SkipReason::Ordering,
        SkipReason::BrownoutShed,
        SkipReason::WaitingHeld,
    ];
}

/// Chrome trace-event phase. `X` = complete span (ts + dur), `I` =
/// instant, `M` = metadata (process/thread naming).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventPhase {
    Complete,
    Instant,
    Meta,
}

impl EventPhase {
    pub fn as_str(self) -> &'static str {
        match self {
            EventPhase::Complete => "X",
            EventPhase::Instant => "i",
            EventPhase::Meta => "M",
        }
    }
}

/// One event argument value (kept typed so numbers render as numbers).
#[derive(Debug, Clone, PartialEq)]
pub enum ArgValue {
    U64(u64),
    Str(String),
}

impl ArgValue {
    fn render(&self, out: &mut String) {
        match self {
            ArgValue::U64(n) => out.push_str(&n.to_string()),
            ArgValue::Str(s) => {
                out.push('"');
                out.push_str(&escape_json(s));
                out.push('"');
            }
        }
    }
}

/// The thread id of the per-replica scheduler decision track (one past
/// the `u32` request-id space, so it can never collide with a request).
pub const SCHED_TID: u64 = 1 << 32;
/// The thread id of the fleet control track (routing/boot/crash/drain).
pub const FLEET_TID: u64 = (1 << 32) + 1;

/// One trace event: the unit both export formats serialize. Requests
/// map to `tid = request id` within `pid = replica`; the scheduler and
/// fleet control tracks use the reserved tids above.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub name: &'static str,
    pub ph: EventPhase,
    /// Microseconds (sim clock for sim traces, wall clock for the HTTP
    /// server's) — integer so contiguity checks are exact.
    pub ts_us: u64,
    /// Only meaningful for `EventPhase::Complete`.
    pub dur_us: u64,
    pub pid: u32,
    pub tid: u64,
    pub args: Vec<(&'static str, ArgValue)>,
}

/// Round a time in seconds to integer microseconds (the single rounding
/// point of the tracing layer).
pub fn to_us(t_s: f64) -> u64 {
    (t_s * 1e6).round().max(0.0) as u64
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl TraceEvent {
    pub fn span(
        name: &'static str,
        t0_us: u64,
        t1_us: u64,
        pid: u32,
        tid: u64,
    ) -> TraceEvent {
        TraceEvent {
            name,
            ph: EventPhase::Complete,
            ts_us: t0_us,
            dur_us: t1_us.saturating_sub(t0_us),
            pid,
            tid,
            args: Vec::new(),
        }
    }

    pub fn instant(name: &'static str, ts_us: u64, pid: u32, tid: u64) -> TraceEvent {
        TraceEvent { name, ph: EventPhase::Instant, ts_us, dur_us: 0, pid, tid, args: Vec::new() }
    }

    pub fn meta(name: &'static str, pid: u32, tid: u64, value: &str) -> TraceEvent {
        TraceEvent {
            name,
            ph: EventPhase::Meta,
            ts_us: 0,
            dur_us: 0,
            pid,
            tid,
            args: vec![("name", ArgValue::Str(value.to_string()))],
        }
    }

    pub fn with_arg(mut self, key: &'static str, value: ArgValue) -> TraceEvent {
        self.args.push((key, value));
        self
    }

    /// Render as one Chrome trace-event JSON object (stable key order,
    /// integer times — byte-deterministic).
    pub fn render(&self, out: &mut String) {
        out.push_str("{\"name\":\"");
        out.push_str(self.name);
        out.push_str("\",\"ph\":\"");
        out.push_str(self.ph.as_str());
        out.push_str("\",\"ts\":");
        out.push_str(&self.ts_us.to_string());
        if self.ph == EventPhase::Complete {
            out.push_str(",\"dur\":");
            out.push_str(&self.dur_us.to_string());
        }
        out.push_str(",\"pid\":");
        out.push_str(&self.pid.to_string());
        out.push_str(",\"tid\":");
        out.push_str(&self.tid.to_string());
        if !self.args.is_empty() {
            out.push_str(",\"args\":{");
            for (i, (k, v)) in self.args.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('"');
                out.push_str(k);
                out.push_str("\":");
                v.render(out);
            }
            out.push('}');
        }
        out.push('}');
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_round_trips() {
        for s in SpanState::ALL {
            assert_eq!(SpanState::parse(s.as_str()), Some(s));
        }
        for o in Outcome::ALL {
            assert_eq!(Outcome::parse(o.as_str()), Some(o));
        }
        for r in SkipReason::ALL {
            assert_eq!(SkipReason::parse(r.as_str()), Some(r));
        }
        assert_eq!(SpanState::parse("nope"), None);
    }

    #[test]
    fn rounding_is_single_point_and_contiguous() {
        // Two segments sharing an f64 boundary share the integer
        // microsecond, so spans built from the same boundary are exactly
        // contiguous.
        let t = 1.2345678;
        let a = TraceEvent::span("queued", to_us(0.5), to_us(t), 0, 7);
        let b = TraceEvent::span("decode", to_us(t), to_us(2.0), 0, 7);
        assert_eq!(a.ts_us + a.dur_us, b.ts_us);
    }

    #[test]
    fn event_renders_stable_json() {
        let mut s = String::new();
        TraceEvent::span("decode", 10, 25, 1, 42)
            .with_arg("n", ArgValue::U64(3))
            .with_arg("why", ArgValue::Str("a\"b".into()))
            .render(&mut s);
        assert_eq!(
            s,
            "{\"name\":\"decode\",\"ph\":\"X\",\"ts\":10,\"dur\":15,\"pid\":1,\"tid\":42,\
             \"args\":{\"n\":3,\"why\":\"a\\\"b\"}}"
        );
        let mut i = String::new();
        TraceEvent::instant("crash", 5, 2, FLEET_TID).render(&mut i);
        assert!(!i.contains("dur"), "{i}");
    }

    #[test]
    fn reserved_tids_clear_request_space() {
        assert!(SCHED_TID > u32::MAX as u64);
        assert!(FLEET_TID > u32::MAX as u64);
        assert_ne!(SCHED_TID, FLEET_TID);
    }
}
