//! Prometheus text-format parsing, merging, and canonical re-rendering.
//!
//! [`Registry::render`](super::Registry::render) produces the canonical
//! text; [`Snapshot::parse`] reads it back into a value form that can be
//! merged (summing samples — this is how the fleet combines its
//! replicas' registries in replica-id order, and how a sweep combines
//! its cells in cell order) and re-rendered byte-identically. The
//! parse→render round trip doubles as the `promlint` validity check in
//! `scripts/check.sh`.
//!
//! Merge semantics are uniform addition: counters and histogram
//! `_bucket`/`_sum`/`_count` samples sum exactly (cumulative bucket
//! counts stay cumulative under addition), and gauges sum too — the one
//! gauge in the shared vocabulary (`econoserve_queue_depth`) reads as a
//! fleet-wide total when summed across replicas.

use std::collections::BTreeMap;

use super::{escape_help, fmt_value, LabelSet, MetricKind};

#[derive(Debug, Clone)]
struct Meta {
    kind: MetricKind,
    help: String,
}

/// A parsed metric exposition: family metadata plus flat samples.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    metas: BTreeMap<String, Meta>,
    samples: BTreeMap<(String, LabelSet), f64>,
}

impl Snapshot {
    /// Parse Prometheus text. Strict: every sample must belong to a
    /// family announced by a `# TYPE` line (histogram samples may use
    /// the `_bucket`/`_sum`/`_count` suffixes of a histogram family).
    pub fn parse(text: &str) -> Result<Snapshot, String> {
        let mut snap = Snapshot::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim_end();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| format!("line {}: {msg}: {line}", lineno + 1);
            if let Some(rest) = line.strip_prefix("# HELP ") {
                let (name, help) = rest.split_once(' ').unwrap_or((rest, ""));
                let entry = snap.metas.entry(name.to_string()).or_insert(Meta {
                    kind: MetricKind::Gauge,
                    help: String::new(),
                });
                entry.help = unescape_help(help);
                continue;
            }
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let (name, kind_s) =
                    rest.split_once(' ').ok_or_else(|| err("malformed TYPE line"))?;
                let kind =
                    MetricKind::parse(kind_s.trim()).ok_or_else(|| err("unknown metric type"))?;
                snap.metas.entry(name.to_string()).or_insert(Meta {
                    kind,
                    help: String::new(),
                }).kind = kind;
                continue;
            }
            if line.starts_with('#') {
                continue; // other comments
            }
            let (name, labels, value) = parse_sample(line).map_err(|m| err(&m))?;
            if snap.family_of(&name).is_none() {
                return Err(err(&format!("sample '{name}' has no # TYPE family")));
            }
            *snap.samples.entry((name, labels)).or_insert(0.0) += value;
        }
        Ok(snap)
    }

    /// The family a sample name belongs to, honoring histogram suffixes.
    fn family_of(&self, sample: &str) -> Option<&str> {
        if let Some((name, meta)) = self.metas.get_key_value(sample) {
            // A histogram family's own name is not a valid sample name.
            if meta.kind != MetricKind::Histogram {
                return Some(name);
            }
        }
        for suffix in ["_bucket", "_sum", "_count"] {
            if let Some(base) = sample.strip_suffix(suffix) {
                if let Some((name, meta)) = self.metas.get_key_value(base) {
                    if meta.kind == MetricKind::Histogram {
                        return Some(name);
                    }
                }
            }
        }
        None
    }

    /// Add every sample of `other` into this snapshot. Family kinds must
    /// agree, histogram families present on both sides must agree on
    /// their per-series bucket layout (summed cumulative buckets are
    /// only meaningful over identical `le` bounds), and families unique
    /// to either side are unioned.
    pub fn merge(&mut self, other: &Snapshot) -> Result<(), String> {
        // Bucket-layout consistency first, before any mutation: for each
        // histogram family both sides carry, every series (base label
        // set) present in both must expose the same `le` bounds.
        for (name, meta) in &other.metas {
            if meta.kind != MetricKind::Histogram {
                continue;
            }
            match self.metas.get(name) {
                Some(mine) if mine.kind == MetricKind::Histogram => {}
                _ => continue, // absent, or a kind mismatch reported below
            }
            let a = self.bucket_layout(name);
            let b = other.bucket_layout(name);
            for (base, bounds) in &b {
                if let Some(have) = a.get(base) {
                    if have != bounds {
                        let series = if base.render().is_empty() {
                            "{}".to_string()
                        } else {
                            base.render()
                        };
                        return Err(format!(
                            "family '{name}' bucket layout mismatch (series {series}): \
                             le bounds [{}] vs [{}]",
                            have.join(","),
                            bounds.join(","),
                        ));
                    }
                }
            }
        }
        for (name, meta) in &other.metas {
            match self.metas.get(name) {
                Some(mine) if mine.kind != meta.kind => {
                    return Err(format!(
                        "family '{name}' kind mismatch: {} vs {}",
                        mine.kind.as_str(),
                        meta.kind.as_str()
                    ));
                }
                Some(_) => {}
                None => {
                    self.metas.insert(name.clone(), meta.clone());
                }
            }
        }
        for ((name, ls), v) in &other.samples {
            *self.samples.entry((name.clone(), ls.clone())).or_insert(0.0) += v;
        }
        Ok(())
    }

    /// The `le` bounds of a histogram family's `_bucket` samples,
    /// grouped by base label set (labels minus `le`) and sorted by
    /// numeric bound.
    fn bucket_layout(&self, family: &str) -> BTreeMap<LabelSet, Vec<String>> {
        let sample = format!("{family}_bucket");
        let mut out: BTreeMap<LabelSet, Vec<String>> = BTreeMap::new();
        for ((_, ls), _) in self
            .samples
            .range((sample.clone(), LabelSet::empty())..=(sample, max_label_set()))
        {
            let mut base = Vec::new();
            let mut le = String::new();
            for (k, v) in ls.pairs() {
                if k == "le" {
                    le = v.clone();
                } else {
                    base.push((k.clone(), v.clone()));
                }
            }
            out.entry(LabelSet::from_owned(base)).or_default().push(le);
        }
        for bounds in out.values_mut() {
            bounds.sort_by(|a, b| {
                parse_value(a)
                    .unwrap_or(f64::INFINITY)
                    .total_cmp(&parse_value(b).unwrap_or(f64::INFINITY))
            });
        }
        out
    }

    /// Look up one sample value (for reconciliation tests). For
    /// histograms pass the suffixed sample name (`..._count`).
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        self.samples.get(&(name.to_string(), LabelSet::from_pairs(labels))).copied()
    }

    pub fn sample_count(&self) -> usize {
        self.samples.len()
    }

    pub fn family_names(&self) -> Vec<&str> {
        self.metas.keys().map(|s| s.as_str()).collect()
    }

    /// Render canonically — the same ordering rules as
    /// [`Registry::render`](super::Registry::render), so that
    /// `Snapshot::parse(reg.render()).render() == reg.render()`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, meta) in &self.metas {
            out.push_str(&format!("# HELP {name} {}\n", escape_help(&meta.help)));
            out.push_str(&format!("# TYPE {name} {}\n", meta.kind.as_str()));
            match meta.kind {
                MetricKind::Counter | MetricKind::Gauge => {
                    for ((sname, ls), v) in self.samples.range(
                        (name.clone(), LabelSet::empty())..=(name.clone(), max_label_set()),
                    ) {
                        debug_assert_eq!(sname, name);
                        out.push_str(&format!("{name}{} {}\n", ls.render(), fmt_value(*v)));
                    }
                }
                MetricKind::Histogram => self.render_histogram(name, &mut out),
            }
        }
        out
    }

    fn render_histogram(&self, name: &str, out: &mut String) {
        // Group bucket/sum/count samples by their base label set (the
        // set minus `le`), then emit per base set: buckets by bound,
        // sum, count — matching the registry's per-series order.
        #[derive(Default)]
        struct SeriesAcc {
            buckets: Vec<(f64, LabelSet, f64)>, // (bound, full labels, value)
            sum: Option<f64>,
            count: Option<f64>,
        }
        let mut by_base: BTreeMap<LabelSet, SeriesAcc> = BTreeMap::new();
        let collect = |snap: &Snapshot, sample: String| -> Vec<(LabelSet, f64)> {
            snap.samples
                .range((sample.clone(), LabelSet::empty())..=(sample, max_label_set()))
                .map(|((_, ls), v)| (ls.clone(), *v))
                .collect()
        };
        for (ls, v) in collect(self, format!("{name}_bucket")) {
            let mut base = Vec::new();
            let mut bound = f64::INFINITY;
            for (k, val) in ls.pairs() {
                if k == "le" {
                    bound = parse_value(val).unwrap_or(f64::INFINITY);
                } else {
                    base.push((k.clone(), val.clone()));
                }
            }
            by_base
                .entry(LabelSet::from_owned(base))
                .or_default()
                .buckets
                .push((bound, ls, v));
        }
        for (ls, v) in collect(self, format!("{name}_sum")) {
            by_base.entry(ls).or_default().sum = Some(v);
        }
        for (ls, v) in collect(self, format!("{name}_count")) {
            by_base.entry(ls).or_default().count = Some(v);
        }
        for (base, mut acc) in by_base {
            acc.buckets.sort_by(|a, b| a.0.total_cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
            for (_, ls, v) in &acc.buckets {
                out.push_str(&format!("{name}_bucket{} {}\n", ls.render(), fmt_value(*v)));
            }
            if let Some(v) = acc.sum {
                out.push_str(&format!("{name}_sum{} {}\n", base.render(), fmt_value(v)));
            }
            if let Some(v) = acc.count {
                out.push_str(&format!("{name}_count{} {}\n", base.render(), fmt_value(v)));
            }
        }
    }
}

/// An upper bound for `BTreeMap::range` over label sets of one sample
/// name: no real label set sorts above a single `\u{10FFFF}` key.
fn max_label_set() -> LabelSet {
    LabelSet::from_owned(vec![("\u{10FFFF}".to_string(), String::new())])
}

fn unescape_help(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

fn parse_value(s: &str) -> Result<f64, String> {
    match s {
        "+Inf" | "Inf" => Ok(f64::INFINITY),
        "-Inf" => Ok(f64::NEG_INFINITY),
        "NaN" => Ok(f64::NAN),
        _ => s.parse::<f64>().map_err(|_| format!("bad value '{s}'")),
    }
}

/// Parse one sample line: `name{k="v",...} value` or `name value`.
fn parse_sample(line: &str) -> Result<(String, LabelSet, f64), String> {
    let (head, value_s) = match line.find('{') {
        Some(brace) => {
            let close = line.rfind('}').ok_or("unterminated label set")?;
            if close < brace {
                return Err("unterminated label set".to_string());
            }
            let name = &line[..brace];
            let labels = &line[brace + 1..close];
            let rest = line[close + 1..].trim();
            return Ok((
                name.to_string(),
                parse_labels(labels)?,
                parse_value(rest)?,
            ));
        }
        None => {
            let (name, v) = line
                .split_once(char::is_whitespace)
                .ok_or("sample line without value")?;
            (name, v.trim())
        }
    };
    Ok((head.to_string(), LabelSet::empty(), parse_value(value_s)?))
}

fn parse_labels(s: &str) -> Result<LabelSet, String> {
    let mut pairs = Vec::new();
    let mut chars = s.chars().peekable();
    loop {
        // Skip separators / trailing comma.
        while matches!(chars.peek(), Some(',') | Some(' ')) {
            chars.next();
        }
        if chars.peek().is_none() {
            break;
        }
        let mut key = String::new();
        for c in chars.by_ref() {
            if c == '=' {
                break;
            }
            key.push(c);
        }
        if chars.next() != Some('"') {
            return Err(format!("label '{key}' value not quoted"));
        }
        let mut value = String::new();
        let mut closed = false;
        while let Some(c) = chars.next() {
            match c {
                '\\' => match chars.next() {
                    Some('n') => value.push('\n'),
                    Some('\\') => value.push('\\'),
                    Some('"') => value.push('"'),
                    Some(other) => {
                        value.push('\\');
                        value.push(other);
                    }
                    None => return Err("dangling escape in label value".to_string()),
                },
                '"' => {
                    closed = true;
                    break;
                }
                c => value.push(c),
            }
        }
        if !closed {
            return Err(format!("label '{key}' value not terminated"));
        }
        pairs.push((key.trim().to_string(), value));
    }
    Ok(LabelSet::from_owned(pairs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{Buckets, Registry};

    fn sample_registry() -> std::sync::Arc<Registry> {
        let reg = Registry::new();
        reg.counter("econoserve_requests_total", "requests", &[("outcome", "done")]).add(7);
        reg.counter("econoserve_requests_total", "requests", &[("outcome", "rejected")]).add(2);
        reg.gauge("econoserve_queue_depth", "queued requests", &[]).set(3.0);
        let h = reg.histogram(
            "econoserve_request_latency_seconds",
            "latency",
            Buckets::exponential(0.5, 2.0, 3),
            &[],
        );
        h.observe(0.4);
        h.observe(1.7);
        h.observe(64.0);
        reg
    }

    #[test]
    fn parse_render_round_trips_registry_text() {
        let text = sample_registry().render();
        let snap = Snapshot::parse(&text).expect("valid exposition");
        assert_eq!(snap.render(), text);
    }

    #[test]
    fn value_lookup_and_family_names() {
        let snap = Snapshot::parse(&sample_registry().render()).unwrap();
        assert_eq!(snap.value("econoserve_requests_total", &[("outcome", "done")]), Some(7.0));
        assert_eq!(snap.value("econoserve_request_latency_seconds_count", &[]), Some(3.0));
        assert_eq!(snap.value("econoserve_queue_depth", &[]), Some(3.0));
        assert_eq!(snap.value("econoserve_requests_total", &[("outcome", "nope")]), None);
        assert_eq!(
            snap.family_names(),
            vec![
                "econoserve_queue_depth",
                "econoserve_request_latency_seconds",
                "econoserve_requests_total"
            ]
        );
    }

    #[test]
    fn merge_sums_counters_histograms_and_gauges() {
        let a_text = sample_registry().render();
        let mut a = Snapshot::parse(&a_text).unwrap();
        let b = Snapshot::parse(&a_text).unwrap();
        a.merge(&b).unwrap();
        assert_eq!(a.value("econoserve_requests_total", &[("outcome", "done")]), Some(14.0));
        assert_eq!(a.value("econoserve_request_latency_seconds_count", &[]), Some(6.0));
        assert_eq!(a.value("econoserve_queue_depth", &[]), Some(6.0));
        // Cumulative buckets stay cumulative under addition: the +Inf
        // bucket equals the merged count.
        assert_eq!(
            a.value("econoserve_request_latency_seconds_bucket", &[("le", "+Inf")]),
            Some(6.0)
        );
        // Merged text still round-trips.
        let round = Snapshot::parse(&a.render()).unwrap().render();
        assert_eq!(round, a.render());
    }

    #[test]
    fn merge_order_is_deterministic() {
        let reg_a = Registry::new();
        reg_a.counter("x_total", "x", &[]).add(1);
        let reg_b = Registry::new();
        reg_b.counter("x_total", "x", &[]).add(41);
        let parse = |r: &Registry| Snapshot::parse(&r.render()).unwrap();
        let mut ab = parse(&reg_a);
        ab.merge(&parse(&reg_b)).unwrap();
        let mut ba = parse(&reg_b);
        ba.merge(&parse(&reg_a)).unwrap();
        assert_eq!(ab.render(), ba.render());
        assert_eq!(ab.value("x_total", &[]), Some(42.0));
    }

    #[test]
    fn merge_rejects_bucket_layout_mismatch() {
        let mk = |start: f64| {
            let reg = Registry::new();
            let h = reg.histogram("h_seconds", "h", Buckets::exponential(start, 2.0, 3), &[]);
            h.observe(1.0);
            Snapshot::parse(&reg.render()).unwrap()
        };
        let mut a = mk(0.5);
        let err = a.merge(&mk(0.25)).unwrap_err();
        assert!(err.contains("h_seconds"), "error must name the family: {err}");
        assert!(err.contains("bucket layout"), "{err}");
        // Identical layouts still merge, and sum.
        let mut a = mk(0.5);
        a.merge(&mk(0.5)).unwrap();
        assert_eq!(a.value("h_seconds_count", &[]), Some(2.0));
        // A histogram family unique to one side is unioned untouched.
        let mut a = mk(0.5);
        let reg = Registry::new();
        reg.counter("c_total", "c", &[]).inc();
        a.merge(&Snapshot::parse(&reg.render()).unwrap()).unwrap();
        assert_eq!(a.value("c_total", &[]), Some(1.0));
    }

    #[test]
    fn strict_parse_rejects_orphans_and_bad_lines() {
        assert!(Snapshot::parse("no_type_metric 1\n").is_err());
        assert!(Snapshot::parse("# TYPE x counter\nx{k=\"v} 1\n").is_err());
        assert!(Snapshot::parse("# TYPE x counter\nx notanumber\n").is_err());
        assert!(Snapshot::parse("# TYPE x zigzag\n").is_err());
        // A histogram family's own bare name is not a sample name.
        assert!(Snapshot::parse("# TYPE h histogram\nh 1\n").is_err());
    }

    #[test]
    fn escaped_labels_round_trip() {
        let reg = Registry::new();
        reg.counter("c_total", "c", &[("k", "a\"b\\c\nd")]).inc();
        let text = reg.render();
        let snap = Snapshot::parse(&text).unwrap();
        assert_eq!(snap.value("c_total", &[("k", "a\"b\\c\nd")]), Some(1.0));
        assert_eq!(snap.render(), text);
    }
}
