//! The parallel experiment engine: deterministic fan-out of independent
//! simulation cells over OS threads.
//!
//! Every headline result in the paper is a *grid* — Figs 9–15 sweep
//! rate × scheduler × seed, Fig 12 searches fleet sizes, and the hot-path
//! bench sweeps the sched × alloc combo grid. Each cell is an independent
//! simulation, so the harness itself is a parallel program (the way
//! DistServe's placement search and vLLM's benchmark suites treat
//! theirs). This module is the one engine behind all of them:
//!
//!  * [`map_indexed`] — deterministic parallel map: cells are claimed
//!    from an atomic cursor (work-stealing, so heterogeneous cell costs
//!    balance) but results land in **input order**, and each cell's
//!    output is a pure function of its description — never of thread
//!    count or completion order. Output is therefore bit-identical to
//!    the sequential path at any `--threads`.
//!  * [`for_each_mut`] — in-place parallel for-each over disjoint
//!    `&mut` items (the fleet layer advances all live replicas to the
//!    next event horizon with it).
//!  * [`grid`] — the sweep surface: a [`GridSpec`] (systems × models ×
//!    traces × rates × seeds, optionally × routers × autoscalers) fanned
//!    out cell-per-task, backing the figure drivers, the
//!    `econoserve sweep` CLI subcommand, and the capacity search.
//!
//! Like the rest of `util/`, this is std-only by necessity: the offline
//! crate registry has no rayon, so the engine is scoped threads + an
//! atomic cursor — which is also exactly enough, because cells are
//! seconds-long simulations and per-cell overhead is noise.
//!
//! Thread-count resolution (everywhere in the crate): an explicit
//! request wins; `0` defers to the `ECONOSERVE_THREADS` environment
//! variable, then to the machine's available parallelism. Moving whole
//! simulations across threads is what the `Send` bounds on
//! [`crate::sched::Scheduler`], [`crate::kvc::Allocator`],
//! [`crate::predictor::Predictor`], [`crate::fleet::Router`] and
//! [`crate::fleet::Autoscaler`] exist for — see "Parallel execution" in
//! `docs/API.md` for the implementor contract.

pub mod grid;

pub use grid::{run_grid, Cell, GridSpec, SweepResult};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Environment variable overriding the default worker-thread count.
pub const THREADS_ENV: &str = "ECONOSERVE_THREADS";

/// The machine's available parallelism (1 if it cannot be queried).
pub fn available_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a requested worker count: `n > 0` is taken as-is; `0` defers
/// to `ECONOSERVE_THREADS`, then to [`available_parallelism`].
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Ok(v) = std::env::var(THREADS_ENV) {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    available_parallelism()
}

/// Deterministic parallel map: apply `f(index, &item)` to every item and
/// collect the results **in input order**.
///
/// Workers claim indices from a shared atomic cursor, so heterogeneous
/// cell costs load-balance; each result is written to its own slot, so
/// the returned `Vec` is identical to the sequential
/// `items.iter().enumerate().map(f).collect()` at any thread count —
/// provided `f` is a pure function of `(index, item)` (derive any
/// randomness from the item's own seed via
/// [`crate::util::rng::derive_seed`], never from global state).
///
/// `threads` follows [`resolve_threads`] (`0` = env/auto) and is capped
/// at the item count. A panic in any cell propagates to the caller after
/// the scope joins.
pub fn map_indexed<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    let threads = resolve_threads(threads).min(n);
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                *slots[i].lock().expect("result slot poisoned") = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("result slot poisoned")
                .expect("every claimed cell stores a result")
        })
        .collect()
}

/// In-place parallel for-each over disjoint mutable items (contiguous
/// chunk per worker). The items must be independent — `f` sees exactly
/// one of them at a time and items never observe each other, so the
/// post-state is identical at any thread count.
///
/// This is the fleet layer's stepping primitive: replicas are
/// independent between routing events, so advancing all of them to the
/// next event horizon is a parallel loop.
pub fn for_each_mut<T, F>(items: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(&mut T) + Sync,
{
    let threads = resolve_threads(threads).min(items.len());
    if threads <= 1 {
        for it in items.iter_mut() {
            f(it);
        }
        return;
    }
    let chunk = items.len().div_ceil(threads);
    std::thread::scope(|s| {
        for part in items.chunks_mut(chunk) {
            let f = &f;
            s.spawn(move || {
                for it in part {
                    f(it);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{derive_seed, Rng};

    #[test]
    fn map_indexed_preserves_input_order() {
        let items: Vec<u64> = (0..257).collect();
        let reference: Vec<u64> = items.iter().map(|&x| derive_seed(x, 3)).collect();
        for threads in [1, 2, 4, 16] {
            let got = map_indexed(&items, threads, |i, &x| {
                assert_eq!(i as u64, x);
                // Uneven per-cell cost so completion order scrambles.
                let mut r = Rng::new(x);
                let spins = r.range_u64(0, 2000);
                let mut acc = 0u64;
                for _ in 0..spins {
                    acc = acc.wrapping_add(r.next_u64());
                }
                std::hint::black_box(acc);
                derive_seed(x, 3)
            });
            assert_eq!(got, reference, "threads={threads}");
        }
    }

    #[test]
    fn map_indexed_handles_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(map_indexed(&empty, 8, |_, &x| x).is_empty());
        assert_eq!(map_indexed(&[7u32], 8, |_, &x| x * 2), vec![14]);
    }

    #[test]
    fn for_each_mut_touches_every_item_once() {
        for threads in [1, 3, 8] {
            let mut items: Vec<u64> = (0..100).collect();
            for_each_mut(&mut items, threads, |x| *x = derive_seed(*x, 1));
            let want: Vec<u64> = (0..100).map(|x| derive_seed(x, 1)).collect();
            assert_eq!(items, want, "threads={threads}");
        }
    }

    #[test]
    fn resolve_threads_explicit_wins() {
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }
}
