//! Grid sweeps: the experiment surface of the parallel engine.
//!
//! A [`GridSpec`] names the axes — systems (the `sched::by_name`
//! `"<sched>+<alloc>"` grammar) × models × traces × rates × seeds, and
//! optionally routers × autoscalers × fault profiles for fleet cells —
//! and [`run_grid`]
//! fans the cross-product out over [`super::map_indexed`], one
//! simulation per cell, collecting one flat JSON row per cell in grid
//! order. This backs the `econoserve sweep` CLI subcommand (JSON grid
//! in → JSON results out) and the 1-vs-N-thread equivalence tests.
//!
//! Determinism contract: a cell's RNG seed is derived from its
//! **coordinates** (seed, model, trace, rate indices) via
//! [`derive_seed`], never from grid position or execution order; every
//! system at the same (model, trace, rate, seed) point sees the same
//! workload and prediction-error stream (a fair comparison), and sweep
//! cells always run with `sched_time_scale = 0` (measured scheduler
//! wall-clock is never charged into the simulated clock), so
//! [`run_grid`] output is bit-identical at any thread count — including
//! 1.

use crate::coordinator::{harness, RunLimits};
use crate::fleet::{self, FleetConfig};
use crate::figures::common;
use crate::telemetry::{TraceConfig, TraceDoc};
use crate::util::json::{obj, Json};
use crate::util::rng::{derive_seed, stream};

/// The axes of one sweep. Cells are the cross-product, enumerated
/// model-major: model × trace × rate × seed × system (× router ×
/// autoscaler when the fleet axes are non-empty).
#[derive(Debug, Clone)]
pub struct GridSpec {
    /// Systems in the `sched::by_name` registry grammar.
    pub systems: Vec<String>,
    pub models: Vec<String>,
    pub traces: Vec<String>,
    /// Explicit arrival rates (req/s). Empty ⇒ a `rate_points`-long
    /// capacity-scaled grid per (model, trace), like the figure drivers.
    pub rates: Vec<f64>,
    pub rate_points: usize,
    /// Workload/prediction replication seeds.
    pub seeds: Vec<u64>,
    /// Fleet axes: when BOTH are non-empty every cell runs a fleet of
    /// up to `replicas` replicas instead of a single world.
    pub routers: Vec<String>,
    pub autoscalers: Vec<String>,
    /// Fault-injection axis for fleet cells (`fleet::all_profiles`
    /// names). Empty ⇒ `["none"]`; requires the fleet axes.
    pub faults: Vec<String>,
    /// Reliability-guardrail axis for fleet cells
    /// (`reliability::GuardrailConfig::parse` grammar, e.g. `"off"`,
    /// `"retry+hedge"`, `"full"`). Empty ⇒ `["off"]`; requires the
    /// fleet axes.
    pub guardrails: Vec<String>,
    /// Predictor fault-profile axis (`predictor::faults::by_name`
    /// names). A `SystemConfig` knob, so it works for single AND fleet
    /// cells. Empty ⇒ `["none"]`.
    pub predictor_faults: Vec<String>,
    /// KVC padding-mode axis (`reliability::headroom` grammar:
    /// `"static"` | `"adaptive"`). A `SystemConfig` knob, so it works
    /// for single AND fleet cells. Empty ⇒ `["static"]`.
    pub headroom: Vec<String>,
    /// Fleet size bound for fleet cells (`static-k` fixes the fleet at
    /// this size; scaling policies move within `[1, replicas]`).
    pub replicas: usize,
    /// Workload duration (simulated seconds of arrivals).
    pub duration: f64,
    /// Hard simulated-time cap (drain allowance).
    pub max_time: f64,
    pub oracle: bool,
    /// Worker threads (0 = `ECONOSERVE_THREADS` / available parallelism).
    pub threads: usize,
    /// Record span traces: every cell runs with a recorder (seeded from
    /// its own cell seed via `stream::TRACE`) and [`SweepResult::trace`]
    /// carries the merged document, cells in grid order with disjoint
    /// pid bands (`econoserve sweep --trace-out`).
    pub trace: bool,
}

impl Default for GridSpec {
    fn default() -> Self {
        GridSpec {
            systems: vec!["econoserve".to_string()],
            models: vec!["opt-13b".to_string()],
            traces: vec!["sharegpt".to_string()],
            rates: Vec::new(),
            rate_points: 4,
            seeds: vec![42],
            routers: Vec::new(),
            autoscalers: Vec::new(),
            faults: Vec::new(),
            guardrails: Vec::new(),
            predictor_faults: Vec::new(),
            headroom: Vec::new(),
            replicas: 2,
            duration: common::DURATION,
            max_time: common::MAX_TIME,
            oracle: false,
            threads: 0,
            trace: false,
        }
    }
}

/// One grid point, fully describing an independent simulation.
#[derive(Debug, Clone)]
pub struct Cell {
    pub system: String,
    pub model: String,
    pub trace: String,
    pub rate: f64,
    pub seed: u64,
    /// `Some` only for fleet cells.
    pub router: Option<String>,
    pub autoscaler: Option<String>,
    /// Fault profile (`Some` only for fleet cells; `"none"` by default).
    pub faults: Option<String>,
    /// Guardrail mode (`Some` only for fleet cells; `"off"` by default).
    pub guardrails: Option<String>,
    /// Predictor fault profile (every cell kind; `"none"` = faultless).
    pub predictor_faults: String,
    /// KVC padding mode (every cell kind; `"static"` = sweet spot).
    pub headroom: String,
    /// Per-cell RNG stream: a pure function of (seed, model/trace/rate
    /// coordinates) — shared by every system at this point, independent
    /// of grid order and thread count.
    pub cell_seed: u64,
}

impl GridSpec {
    /// Parse the `econoserve sweep` input document. Every field is
    /// optional; omitted ones keep the [`Default`] value. Unknown keys
    /// are rejected up front — a typoed axis name (`"seed"` for
    /// `"seeds"`) must fail immediately, not silently sweep defaults.
    pub fn from_json(doc: &Json) -> Result<GridSpec, String> {
        const KNOWN: [&str; 18] = [
            "systems",
            "models",
            "traces",
            "rates",
            "rate_points",
            "seeds",
            "routers",
            "autoscalers",
            "faults",
            "guardrails",
            "predictor_faults",
            "headroom",
            "replicas",
            "duration",
            "max_time",
            "oracle",
            "threads",
            "trace",
        ];
        match doc {
            Json::Obj(m) => {
                for key in m.keys() {
                    if !KNOWN.contains(&key.as_str()) {
                        return Err(format!(
                            "unknown key '{key}' (expected one of {KNOWN:?})"
                        ));
                    }
                }
            }
            _ => return Err("grid spec must be a JSON object".to_string()),
        }
        let mut spec = GridSpec::default();
        let strings = |key: &str, into: &mut Vec<String>| -> Result<(), String> {
            if let Some(v) = doc.get(key) {
                let arr = v.as_arr().ok_or_else(|| format!("'{key}' must be an array"))?;
                *into = arr
                    .iter()
                    .map(|x| {
                        x.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| format!("'{key}' entries must be strings"))
                    })
                    .collect::<Result<_, _>>()?;
            }
            Ok(())
        };
        strings("systems", &mut spec.systems)?;
        strings("models", &mut spec.models)?;
        strings("traces", &mut spec.traces)?;
        strings("routers", &mut spec.routers)?;
        strings("autoscalers", &mut spec.autoscalers)?;
        strings("faults", &mut spec.faults)?;
        strings("guardrails", &mut spec.guardrails)?;
        strings("predictor_faults", &mut spec.predictor_faults)?;
        strings("headroom", &mut spec.headroom)?;
        if let Some(v) = doc.get("rates") {
            let arr = v.as_arr().ok_or("'rates' must be an array")?;
            spec.rates = arr
                .iter()
                .map(|x| x.as_f64().ok_or("'rates' entries must be numbers".to_string()))
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = doc.get("seeds") {
            let arr = v.as_arr().ok_or("'seeds' must be an array")?;
            spec.seeds = arr
                .iter()
                .map(|x| {
                    x.as_i64()
                        .map(|n| n as u64)
                        .ok_or("'seeds' entries must be integers".to_string())
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = doc.get("rate_points") {
            spec.rate_points = v.as_usize().ok_or("'rate_points' must be an integer")?;
        }
        if let Some(v) = doc.get("replicas") {
            spec.replicas = v.as_usize().ok_or("'replicas' must be an integer")?;
        }
        if let Some(v) = doc.get("duration") {
            spec.duration = v.as_f64().ok_or("'duration' must be a number")?;
        }
        if let Some(v) = doc.get("max_time") {
            spec.max_time = v.as_f64().ok_or("'max_time' must be a number")?;
        }
        if let Some(v) = doc.get("oracle") {
            spec.oracle = v.as_bool().ok_or("'oracle' must be a boolean")?;
        }
        if let Some(v) = doc.get("threads") {
            spec.threads = v.as_usize().ok_or("'threads' must be an integer")?;
        }
        if let Some(v) = doc.get("trace") {
            spec.trace = v.as_bool().ok_or("'trace' must be a boolean")?;
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Reject unknown registry names and empty axes up front (cells
    /// would otherwise panic mid-sweep inside a worker).
    pub fn validate(&self) -> Result<(), String> {
        for s in &self.systems {
            if crate::sched::by_name(s).is_none() {
                return Err(format!("unknown system '{s}'"));
            }
        }
        for m in &self.models {
            if crate::config::ModelProfile::by_name(m).is_none() {
                return Err(format!("unknown model '{m}'"));
            }
        }
        for t in &self.traces {
            if crate::trace::TraceSpec::by_name(t).is_none() {
                return Err(format!("unknown trace '{t}'"));
            }
        }
        for r in &self.routers {
            if !fleet::all_routers().contains(&r.as_str()) {
                return Err(format!("unknown router '{r}'"));
            }
        }
        for a in &self.autoscalers {
            if !fleet::all_autoscalers().contains(&a.as_str()) {
                return Err(format!("unknown autoscaler '{a}'"));
            }
        }
        for f in &self.faults {
            if fleet::faults::by_name(f).is_none() {
                return Err(format!("unknown fault profile '{f}'"));
            }
        }
        for g in &self.guardrails {
            if crate::reliability::GuardrailConfig::parse(g).is_none() {
                return Err(format!("unknown guardrail mode '{g}'"));
            }
        }
        for p in &self.predictor_faults {
            if crate::predictor::faults::by_name(p).is_none() {
                return Err(format!("unknown predictor fault profile '{p}'"));
            }
        }
        for h in &self.headroom {
            if crate::reliability::headroom::HeadroomConfig::parse(h).is_none() {
                return Err(format!("unknown headroom mode '{h}'"));
            }
        }
        if self.routers.is_empty() != self.autoscalers.is_empty() {
            return Err("'routers' and 'autoscalers' must be set together".to_string());
        }
        if !self.faults.is_empty() && self.routers.is_empty() {
            return Err("'faults' requires the fleet axes ('routers'/'autoscalers')".to_string());
        }
        if !self.guardrails.is_empty() && self.routers.is_empty() {
            return Err(
                "'guardrails' requires the fleet axes ('routers'/'autoscalers')".to_string()
            );
        }
        if self.systems.is_empty() || self.models.is_empty() || self.traces.is_empty() {
            return Err("systems/models/traces must be non-empty".to_string());
        }
        if self.seeds.is_empty() {
            return Err("seeds must be non-empty".to_string());
        }
        if self.rates.is_empty() && self.rate_points == 0 {
            return Err("either 'rates' or 'rate_points' must be set".to_string());
        }
        Ok(())
    }

    #[allow(clippy::type_complexity)]
    fn fleet_axis(
        &self,
    ) -> Vec<(Option<String>, Option<String>, Option<String>, Option<String>)> {
        if self.routers.is_empty() {
            return vec![(None, None, None, None)];
        }
        let faults: Vec<String> = if self.faults.is_empty() {
            vec!["none".to_string()]
        } else {
            self.faults.clone()
        };
        let guardrails: Vec<String> = if self.guardrails.is_empty() {
            vec!["off".to_string()]
        } else {
            self.guardrails.clone()
        };
        let mut axis = Vec::new();
        for r in &self.routers {
            for a in &self.autoscalers {
                for f in &faults {
                    for g in &guardrails {
                        axis.push((
                            Some(r.clone()),
                            Some(a.clone()),
                            Some(f.clone()),
                            Some(g.clone()),
                        ));
                    }
                }
            }
        }
        axis
    }

    /// Enumerate the cross-product in deterministic grid order.
    pub fn cells(&self) -> Vec<Cell> {
        let axis = self.fleet_axis();
        // Config-level axes (work for single and fleet cells alike);
        // innermost, so the default (one-point) axes leave the grid order
        // of pre-existing specs untouched.
        let pfaults: Vec<String> = if self.predictor_faults.is_empty() {
            vec!["none".to_string()]
        } else {
            self.predictor_faults.clone()
        };
        let headrooms: Vec<String> = if self.headroom.is_empty() {
            vec!["static".to_string()]
        } else {
            self.headroom.clone()
        };
        let mut cells = Vec::new();
        for (mi, model) in self.models.iter().enumerate() {
            for (ti, trace) in self.traces.iter().enumerate() {
                let rates = if self.rates.is_empty() {
                    let cfg = common::cfg(model, trace);
                    common::rate_grid(&cfg, trace, self.rate_points)
                } else {
                    self.rates.clone()
                };
                for (ri, &rate) in rates.iter().enumerate() {
                    for &seed in &self.seeds {
                        // Coordinate-indexed stream (system excluded:
                        // rivals at one point share the workload).
                        let cell_seed = derive_seed(seed, stream::grid_cell(mi, ti, ri));
                        for system in &self.systems {
                            for (router, autoscaler, faults, guardrails) in &axis {
                                for pf in &pfaults {
                                    for hr in &headrooms {
                                        cells.push(Cell {
                                            system: system.clone(),
                                            model: model.clone(),
                                            trace: trace.clone(),
                                            rate,
                                            seed,
                                            router: router.clone(),
                                            autoscaler: autoscaler.clone(),
                                            faults: faults.clone(),
                                            guardrails: guardrails.clone(),
                                            predictor_faults: pf.clone(),
                                            headroom: hr.clone(),
                                            cell_seed,
                                        });
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        cells
    }
}

/// Outcome of [`run_grid`]: one JSON row per cell, in grid order.
#[derive(Debug)]
pub struct SweepResult {
    pub rows: Vec<Json>,
    /// Worker threads actually used.
    pub threads: usize,
    /// Host wall-clock seconds for the whole sweep.
    pub wall_s: f64,
    /// Canonical Prometheus text: every cell's telemetry registry merged
    /// in grid order (`econoserve sweep --metrics-out`). Cells are
    /// simulated quantities only, so — like `rows` — this string is
    /// bit-identical at any thread count.
    pub metrics: String,
    /// Merged span trace (`GridSpec::trace` enabled): cell documents in
    /// grid order, each cell's pids shifted into its own band so replica
    /// tracks never collide across cells. Simulated time only, so the
    /// rendered bytes are bit-identical at any thread count
    /// (`econoserve sweep --trace-out`).
    pub trace: Option<TraceDoc>,
}

impl SweepResult {
    /// The `econoserve sweep` output document.
    pub fn to_json(&self) -> Json {
        obj([
            ("sweep", "econoserve".into()),
            ("threads", self.threads.into()),
            ("wall_s", self.wall_s.into()),
            ("rows", Json::Arr(self.rows.clone())),
        ])
    }
}

/// Run every cell of `spec` (in parallel, respecting `spec.threads`) and
/// collect one flat row per cell in grid order. Rows contain only
/// simulated quantities — no wall-clock — so two sweeps of the same spec
/// are bit-identical at any thread count.
///
/// Panics on an invalid spec (see [`GridSpec::validate`]) — a bad axis
/// must fail loudly up front, not panic mid-sweep inside a worker or
/// silently produce zero cells.
pub fn run_grid(spec: &GridSpec) -> SweepResult {
    if let Err(e) = spec.validate() {
        panic!("invalid GridSpec: {e}");
    }
    let cells = spec.cells();
    let threads = super::resolve_threads(spec.threads).min(cells.len().max(1));
    let t0 = std::time::Instant::now();
    let outs = super::map_indexed(&cells, threads, |i, cell| run_cell(i, cell, spec));
    // Merge per-cell registries (and trace documents) in grid order
    // (map_indexed collects in input order, so the merge sequence — and
    // thus the rendered text — is independent of thread count).
    let mut rows = Vec::with_capacity(outs.len());
    let mut merged: Option<crate::telemetry::Snapshot> = None;
    let mut trace: Option<TraceDoc> = None;
    for (row, metrics, doc) in outs {
        rows.push(row);
        let snap = crate::telemetry::Snapshot::parse(&metrics)
            .expect("cell registry render is valid exposition text");
        match &mut merged {
            None => merged = Some(snap),
            Some(m) => m.merge(&snap).expect("cells share one metric vocabulary"),
        }
        if let Some(d) = doc {
            match &mut trace {
                None => trace = Some(d),
                Some(t) => t.merge(d),
            }
        }
    }
    let metrics = merged.map(|m| m.render()).unwrap_or_default();
    SweepResult { rows, threads, wall_s: t0.elapsed().as_secs_f64(), metrics, trace }
}

/// Disjoint pid band per cell: replica ids stay far below this, so cell
/// `i`'s tracks land in `[i * PID_BAND, (i + 1) * PID_BAND)`.
const PID_BAND: u32 = 10_000;

fn run_cell(cell_idx: usize, cell: &Cell, spec: &GridSpec) -> (Json, String, Option<TraceDoc>) {
    let mut cfg = common::cfg(&cell.model, &cell.trace);
    cfg.seed = cell.cell_seed;
    // Never charge measured scheduler wall-clock into the simulated
    // clock in sweep cells: rows must be a pure function of the spec.
    cfg.sched_time_scale = 0.0;
    // Config-level robustness axes flow to every replica's predictor and
    // headroom controller through the cfg clone.
    cfg.predictor_faults = cell.predictor_faults.clone();
    cfg.headroom = cell.headroom.clone();
    // Cell-seeded sampling stream: the same cell samples the same
    // requests whatever the grid shape or thread count.
    let tracing =
        spec.trace.then(|| TraceConfig::new(derive_seed(cfg.seed, stream::TRACE)));
    let items = common::workload(&cfg, &cell.trace, cell.rate, spec.duration, cfg.seed);
    let mut row = vec![
        ("system", Json::from(cell.system.as_str())),
        ("model", Json::from(cell.model.as_str())),
        ("trace", Json::from(cell.trace.as_str())),
        ("rate", Json::from(cell.rate)),
        ("seed", Json::from(cell.seed as usize)),
        ("n", Json::from(items.len())),
        ("predictor_faults", Json::from(cell.predictor_faults.as_str())),
        ("headroom", Json::from(cell.headroom.as_str())),
    ];
    match (&cell.router, &cell.autoscaler) {
        (Some(router), Some(autoscaler)) => {
            let mut fc = FleetConfig::new(cfg, &cell.system, &cell.trace);
            fc.oracle = spec.oracle;
            fc.router = router.clone();
            fc.autoscaler = autoscaler.clone();
            fc.max_replicas = spec.replicas.max(1);
            if autoscaler == "static-k" {
                fc.init_replicas = fc.max_replicas;
                fc.min_replicas = fc.max_replicas;
            } else {
                fc.init_replicas = 1;
                fc.min_replicas = 1;
            }
            fc.max_sim_time = spec.max_time;
            if let Some(f) = &cell.faults {
                fc.faults = f.clone();
            }
            if let Some(g) = &cell.guardrails {
                fc.guardrails = g.clone();
            }
            // Cell-level fan-out owns the cores; replicas step serially.
            fc.threads = 1;
            fc.tracing = tracing;
            let res = fleet::run(&fc, &items);
            let metrics = res.metrics;
            let trace = res.trace_doc.map(|mut d| {
                d.shift_pids(cell_idx as u32 * PID_BAND);
                d
            });
            let s = res.summary;
            row.extend([
                ("router", Json::from(router.as_str())),
                ("autoscaler", Json::from(autoscaler.as_str())),
                ("faults", Json::from(cell.faults.as_deref().unwrap_or("none"))),
                ("guardrails", Json::from(cell.guardrails.as_deref().unwrap_or("off"))),
                ("n_done", Json::from(s.n_done)),
                ("goodput_rps", Json::from(s.goodput_rps)),
                ("throughput_rps", Json::from(s.throughput_rps)),
                ("ssr", Json::from(s.ssr)),
                ("mean_jct", Json::from(s.mean_jct)),
                ("p95_jct", Json::from(s.p95_jct)),
                ("gpu_hours", Json::from(s.gpu_hours)),
                ("goodput_per_gpu_hour", Json::from(s.goodput_per_gpu_hour)),
                ("peak_replicas", Json::from(s.peak_replicas)),
                ("mean_replicas", Json::from(s.mean_replicas)),
                ("crashes", Json::from(s.faults.crashes)),
                ("boot_failures", Json::from(s.faults.boot_failures)),
                ("rerouted", Json::from(s.faults.rerouted)),
                ("lost", Json::from(s.faults.lost)),
                ("retried", Json::from(s.faults.retried)),
                ("recovered", Json::from(s.faults.recovered)),
                ("hedges_won", Json::from(s.faults.hedges_won)),
                ("aborted", Json::from(s.faults.aborted)),
            ]);
            (obj(row), metrics, trace)
        }
        _ => {
            let res = harness::simulate_traced(
                &cfg,
                &cell.system,
                &cell.trace,
                &items,
                spec.oracle,
                RunLimits::for_time(spec.max_time),
                tracing,
            );
            let metrics = res.metrics;
            let trace = res.trace.map(|mut d| {
                d.name_process(0, &cell.system);
                d.shift_pids(cell_idx as u32 * PID_BAND);
                d
            });
            let s = res.summary;
            row.extend([
                ("n_done", Json::from(s.n_done)),
                ("throughput_rps", Json::from(s.throughput_rps)),
                ("ssr", Json::from(s.ssr)),
                ("mean_jct", Json::from(s.mean_jct)),
                ("p95_jct", Json::from(s.p95_jct)),
                ("norm_latency", Json::from(s.norm_latency)),
                ("kvc_util", Json::from(s.kvc_util)),
                ("gpu_util", Json::from(s.gpu_util)),
                ("preemptions", Json::from(s.preemptions as usize)),
            ]);
            (obj(row), metrics, trace)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> GridSpec {
        GridSpec {
            systems: vec!["orca".to_string()],
            models: vec!["opt-13b".to_string()],
            traces: vec!["alpaca".to_string()],
            rates: vec![2.0],
            seeds: vec![7],
            duration: 3.0,
            max_time: 60.0,
            oracle: true,
            threads: 1,
            ..GridSpec::default()
        }
    }

    #[test]
    fn cell_enumeration_is_grid_ordered_and_seed_stable() {
        let mut spec = tiny_spec();
        spec.systems = vec!["orca".to_string(), "vllm".to_string()];
        spec.rates = vec![1.0, 2.0];
        let cells = spec.cells();
        assert_eq!(cells.len(), 4);
        // rate-major, system-minor.
        assert_eq!((cells[0].rate, cells[0].system.as_str()), (1.0, "orca"));
        assert_eq!((cells[1].rate, cells[1].system.as_str()), (1.0, "vllm"));
        assert_eq!((cells[2].rate, cells[2].system.as_str()), (2.0, "orca"));
        // Rival systems at one grid point share the workload stream.
        assert_eq!(cells[0].cell_seed, cells[1].cell_seed);
        assert_ne!(cells[0].cell_seed, cells[2].cell_seed);
    }

    #[test]
    fn from_json_roundtrip_and_validation() {
        let doc = Json::parse(
            r#"{"systems": ["vllm+exact"], "rates": [1.5, 3.0], "seeds": [1, 2],
                "duration": 10, "oracle": true, "threads": 2}"#,
        )
        .unwrap();
        let spec = GridSpec::from_json(&doc).unwrap();
        assert_eq!(spec.systems, vec!["vllm+exact"]);
        assert_eq!(spec.rates, vec![1.5, 3.0]);
        assert_eq!(spec.seeds, vec![1, 2]);
        assert!(spec.oracle);
        assert_eq!(spec.threads, 2);
        // Unknown system is rejected up front, not at cell time.
        let bad = Json::parse(r#"{"systems": ["nope"]}"#).unwrap();
        assert!(GridSpec::from_json(&bad).is_err());
        let half_fleet = Json::parse(r#"{"routers": ["round-robin"]}"#).unwrap();
        assert!(GridSpec::from_json(&half_fleet).is_err());
        // Fault profiles are validated and require the fleet axes.
        let bad_fault = Json::parse(
            r#"{"routers": ["round-robin"], "autoscalers": ["static-k"],
                "faults": ["meteor-strike"]}"#,
        )
        .unwrap();
        assert!(GridSpec::from_json(&bad_fault).unwrap_err().contains("fault profile"));
        let orphan_fault = Json::parse(r#"{"faults": ["crashes"]}"#).unwrap();
        assert!(GridSpec::from_json(&orphan_fault).is_err());
        // Guardrail modes are validated and require the fleet axes too.
        let bad_guard = Json::parse(
            r#"{"routers": ["round-robin"], "autoscalers": ["static-k"],
                "guardrails": ["retry+yolo"]}"#,
        )
        .unwrap();
        assert!(GridSpec::from_json(&bad_guard).unwrap_err().contains("guardrail mode"));
        let orphan_guard = Json::parse(r#"{"guardrails": ["retry"]}"#).unwrap();
        assert!(GridSpec::from_json(&orphan_guard).is_err());
        // Predictor-fault and headroom axes are validated but do NOT
        // require the fleet axes (they are SystemConfig knobs).
        let bad_pf = Json::parse(r#"{"predictor_faults": ["meteor-strike"]}"#).unwrap();
        assert!(GridSpec::from_json(&bad_pf)
            .unwrap_err()
            .contains("predictor fault profile"));
        let bad_hr = Json::parse(r#"{"headroom": ["galactic"]}"#).unwrap();
        assert!(GridSpec::from_json(&bad_hr).unwrap_err().contains("headroom mode"));
        let single_pf = Json::parse(
            r#"{"predictor_faults": ["none", "regime-shift"], "headroom": ["static", "adaptive"]}"#,
        )
        .unwrap();
        let spec = GridSpec::from_json(&single_pf).unwrap();
        assert_eq!(spec.predictor_faults.len(), 2);
        assert_eq!(spec.headroom.len(), 2);
        // Typoed keys fail fast instead of silently sweeping defaults.
        let typo = Json::parse(r#"{"seed": [1, 2]}"#).unwrap();
        assert!(GridSpec::from_json(&typo).unwrap_err().contains("unknown key 'seed'"));
        assert!(GridSpec::from_json(&Json::parse("[1]").unwrap()).is_err());
    }

    #[test]
    fn predictor_axes_multiply_cells_and_share_the_workload_seed() {
        let mut spec = tiny_spec();
        spec.predictor_faults = vec!["none".to_string(), "regime-shift".to_string()];
        spec.headroom = vec!["static".to_string(), "adaptive".to_string()];
        let cells = spec.cells();
        assert_eq!(cells.len(), 4);
        // headroom-minor within predictor-faults.
        assert_eq!(
            (cells[0].predictor_faults.as_str(), cells[0].headroom.as_str()),
            ("none", "static")
        );
        assert_eq!(
            (cells[1].predictor_faults.as_str(), cells[1].headroom.as_str()),
            ("none", "adaptive")
        );
        assert_eq!(cells[3].predictor_faults.as_str(), "regime-shift");
        // Robustness variants at one grid point share the workload
        // stream: the comparison isolates the axis under test.
        assert!(cells.windows(2).all(|w| w[0].cell_seed == w[1].cell_seed));
    }

    #[test]
    fn run_grid_smoke_single_cell() {
        let res = run_grid(&tiny_spec());
        assert_eq!(res.rows.len(), 1);
        let row = &res.rows[0];
        assert_eq!(row.get("system").unwrap().as_str(), Some("orca"));
        assert!(row.get("n_done").unwrap().as_usize().unwrap() > 0);
        assert!(row.get("mean_jct").unwrap().as_f64().unwrap() > 0.0);
    }
}
