//! Minimal property-testing kit (the offline registry has no proptest).
//!
//! `run_prop(name, cases, |rng| ...)` runs the closure `cases` times with
//! independent deterministic RNGs. On failure it retries with the same seed
//! to confirm, then panics with the seed so the case can be replayed:
//!
//! ```text
//! PROP_SEED=0xDEADBEEF cargo test kvc_prop_never_double_allocates
//! ```
//!
//! There is no shrinking; generators should therefore bias toward small
//! sizes (use [`sized`] helpers) so failures are readable directly.

use super::rng::Rng;

/// Run `f` for `cases` random cases. Panics with the replay seed on failure.
pub fn run_prop(name: &str, cases: usize, mut f: impl FnMut(&mut Rng)) {
    // Fixed master seed by default => CI-stable; override via PROP_SEED to
    // replay a specific failing case, or PROP_CASES to crank coverage.
    let (replay, master_seed) = match std::env::var("PROP_SEED") {
        Ok(s) => {
            let s = s.trim_start_matches("0x").to_string();
            (true, u64::from_str_radix(&s, 16).expect("PROP_SEED must be hex"))
        }
        Err(_) => (false, 0x5EED_0000_0000_0000 ^ fxhash(name)),
    };
    let cases = std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);

    if replay {
        let mut rng = Rng::new(master_seed);
        f(&mut rng);
        return;
    }

    let mut meta = Rng::new(master_seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed on case {case}/{cases}: {msg}\n\
                 replay with: PROP_SEED={case_seed:#018x}"
            );
        }
    }
}

/// Stable hash of the property name to decorrelate master seeds.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Generator helper: a size in [1, max] biased toward small values
/// (geometric-ish), so failures stay small without shrinking.
pub fn sized(rng: &mut Rng, max: usize) -> usize {
    let r = rng.f64();
    let x = (r * r * max as f64) as usize; // quadratic bias toward 0
    x.clamp(1, max.max(1))
}

/// Generator helper: a Vec of length in [1, max_len] built by `g`.
pub fn vec_of<T>(rng: &mut Rng, max_len: usize, mut g: impl FnMut(&mut Rng) -> T) -> Vec<T> {
    let n = sized(rng, max_len);
    (0..n).map(|_| g(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_prop("always_true", 50, |_rng| {
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "replay with: PROP_SEED=")]
    fn failing_property_reports_seed() {
        run_prop("always_false", 10, |rng| {
            // Fail on a specific draw so some cases pass first.
            assert!(rng.f64() < 0.5, "draw too large");
        });
    }

    #[test]
    fn sized_within_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let s = sized(&mut rng, 20);
            assert!((1..=20).contains(&s));
        }
    }

    #[test]
    fn vec_of_builds() {
        let mut rng = Rng::new(2);
        let v = vec_of(&mut rng, 10, |r| r.range_u64(0, 5));
        assert!(!v.is_empty() && v.len() <= 10);
    }
}
