//! Deterministic pseudo-random numbers for simulation and property tests.
//!
//! SplitMix64 core with convenience samplers (uniform, exponential for
//! Poisson arrival gaps, log-normal for sequence-length bodies). No
//! external dependency; identical streams across platforms, which keeps
//! every experiment in EXPERIMENTS.md exactly reproducible from its seed.

/// SplitMix64 PRNG (Steele et al., "Fast Splittable Pseudorandom Number
/// Generators", OOPSLA 2014). Passes BigCrush when used as a stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream (for per-component RNGs).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] (inclusive). Requires lo <= hi.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo + 1;
        // Lemire's multiply-shift rejection-free approximation is fine for
        // simulation purposes (span << 2^64 makes bias negligible).
        lo + ((self.next_u64() as u128 * span as u128) >> 64) as u64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean 1/lambda) — Poisson arrival gaps.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given *underlying* mu/sigma.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i);
            xs.swap(i, j);
        }
    }

    /// Pick a random element index weighted by `weights` (must be non-empty,
    /// non-negative, not all zero).
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Deterministically derive an independent seed for a named stream of a
/// base seed — e.g. per-replica RNGs in the fleet layer, where replica
/// `i` must get the same stream regardless of which router placed which
/// request on it. One SplitMix64 step over a stream-salted state; any
/// (base, stream) pair yields a stable, well-mixed seed. The salt uses
/// `stream + 1` so stream 0 still perturbs the base (a zero salt would
/// collapse it onto the base's own stream).
pub fn derive_seed(base: u64, stream: u64) -> u64 {
    Rng::new(base ^ stream.wrapping_add(1).wrapping_mul(0xA076_1D64_78BD_642F)).next_u64()
}

/// The crate-wide registry of `derive_seed` stream IDs. Every component
/// that derives a sub-stream from a user-facing seed takes its stream ID
/// from here, so the namespaces are visibly disjoint in one place
/// instead of as ad-hoc literals at call sites. The collision test below
/// pins the disjointness (fixed IDs against each other, and against the
/// low per-replica band and the high grid-cell band).
pub mod stream {
    /// Per-replica world streams: replica `i` draws `REPLICA_BASE + i`.
    /// Occupies the low band `[1, 1 + max_replicas)`.
    pub const REPLICA_BASE: u64 = 1;

    /// Stream for replica `id` of a fleet (see [`REPLICA_BASE`]).
    pub fn replica(id: usize) -> u64 {
        REPLICA_BASE + id as u64
    }

    /// The fleet router's own stream (power-of-two sampling).
    pub const ROUTER: u64 = 0xF1EE7;

    /// The fault injector's stream (crash/outage/straggler/boot draws).
    pub const FAULTS: u64 = 0xFA017;

    /// The reliability guardrails' stream (retry backoff jitter). Kept
    /// separate from [`FAULTS`] so enabling guardrails never perturbs
    /// the fault timeline, and vice versa.
    pub const GUARDRAILS: u64 = 0x6A4D5;

    /// The span recorder's head-sampling stream
    /// (`telemetry::trace::sample_key`). Dedicated so enabling tracing
    /// never perturbs any simulation draw, and sampling decisions are
    /// identical at every thread count.
    pub const TRACE: u64 = 0x7AACE;

    /// The predictor fault injector's stream (`predictor::faults`):
    /// drift/shift/outage timelines and heavy-tail draws. Dedicated so
    /// enabling predictor chaos never perturbs the workload, router,
    /// replica-fault, or guardrail streams — and vice versa.
    pub const PREDICTOR: u64 = 0x9ED1C7;

    /// Grid cells pack their coordinates into one stream ID. Bit 63
    /// flags the grid namespace so packed coordinates can never collide
    /// with the fixed IDs or the per-replica band above.
    pub const GRID_FLAG: u64 = 1 << 63;

    /// Stream for grid cell (model_idx, trace_idx, rate_idx).
    pub fn grid_cell(mi: usize, ti: usize, ri: usize) -> u64 {
        GRID_FLAG | ((mi as u64) << 40) | ((ti as u64) << 20) | ri as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_seed_is_stable_and_distinct() {
        assert_eq!(derive_seed(42, 0), derive_seed(42, 0));
        assert_ne!(derive_seed(42, 0), derive_seed(42, 1));
        assert_ne!(derive_seed(42, 1), derive_seed(43, 1));
        // Stream 0 must not collapse to the base stream.
        let mut base = Rng::new(42);
        assert_ne!(derive_seed(42, 0), base.next_u64());
    }

    #[test]
    fn stream_namespaces_never_collide() {
        // Every fixed stream ID, a generous per-replica band, and a
        // corner-heavy sample of the grid-cell namespace must be
        // pairwise distinct: a collision would make two "independent"
        // components draw identical randomness from the same base seed.
        let mut ids: Vec<u64> = vec![
            stream::ROUTER,
            stream::FAULTS,
            stream::GUARDRAILS,
            stream::TRACE,
            stream::PREDICTOR,
        ];
        ids.extend((0..4096).map(stream::replica));
        for &mi in &[0usize, 1, 7, 255] {
            for &ti in &[0usize, 1, 15, 1023] {
                for &ri in &[0usize, 1, 31, 0xF_FFFF] {
                    ids.push(stream::grid_cell(mi, ti, ri));
                }
            }
        }
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "stream-ID namespaces overlap");
        // And distinct streams must actually produce distinct seeds.
        let mut seeds: Vec<u64> = ids.iter().map(|&s| derive_seed(42, s)).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), n, "derive_seed collapsed two streams");
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(3);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let x = r.range_u64(5, 8);
            assert!((5..=8).contains(&x));
            saw_lo |= x == 5;
            saw_hi |= x == 8;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_prefers_heavy() {
        let mut r = Rng::new(19);
        let w = [0.0, 1.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[2] > counts[1] * 5);
    }
}
