//! Statistics helpers: summaries, percentiles, CDFs, and fixed-width table
//! printing used by the paper-figure bench drivers.

/// Online accumulator for mean/min/max/count.
#[derive(Debug, Clone, Default)]
pub struct Acc {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Acc {
    pub fn new() -> Self {
        Acc { n: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

/// Sample container with percentile queries (sorts lazily on demand).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    data: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.data.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        self.data.extend(xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().sum::<f64>() / self.data.len() as f64
        }
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.data.sort_by(f64::total_cmp);
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let rank = p / 100.0 * (self.data.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.data[lo] * (1.0 - frac) + self.data[hi] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p5(&mut self) -> f64 {
        self.percentile(5.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// Empirical CDF evaluated at `points.len()` equally spaced quantiles;
    /// returns (value, cumulative_fraction) pairs for figure export.
    pub fn cdf(&mut self, points: usize) -> Vec<(f64, f64)> {
        if self.data.is_empty() {
            return vec![];
        }
        self.ensure_sorted();
        (0..points)
            .map(|i| {
                let frac = (i + 1) as f64 / points as f64;
                let idx = ((frac * self.data.len() as f64).ceil() as usize).min(self.data.len()) - 1;
                (self.data[idx], frac)
            })
            .collect()
    }

    pub fn values(&self) -> &[f64] {
        &self.data
    }
}

/// Fixed-width ASCII table used by the figure drivers to print the paper's
/// rows/series in a uniform format (also mirrored to CSV by benchkit).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, label: &str, values: &[f64]) {
        let mut cells = vec![label.to_string()];
        cells.extend(values.iter().map(|v| format_sig(*v, 4)));
        self.row(&cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format with `sig` significant digits (for table cells).
pub fn format_sig(x: f64, sig: usize) -> String {
    if x == 0.0 || !x.is_finite() {
        return format!("{x}");
    }
    let mag = x.abs().log10().floor() as i32;
    let decimals = (sig as i32 - 1 - mag).max(0) as usize;
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acc_basics() {
        let mut a = Acc::new();
        for x in [1.0, 2.0, 3.0] {
            a.add(x);
        }
        assert_eq!(a.mean(), 2.0);
        assert_eq!(a.min, 1.0);
        assert_eq!(a.max, 3.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        s.extend((1..=100).map(|x| x as f64));
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.p95() - 95.05).abs() < 0.01);
    }

    #[test]
    fn percentile_single_sample() {
        let mut s = Samples::new();
        s.push(7.0);
        assert_eq!(s.p50(), 7.0);
        assert_eq!(s.p99(), 7.0);
    }

    #[test]
    fn cdf_monotone() {
        let mut s = Samples::new();
        s.extend([5.0, 1.0, 3.0, 2.0, 4.0]);
        let cdf = s.cdf(5);
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(cdf.last().unwrap().0, 5.0);
    }

    #[test]
    fn table_render_and_csv() {
        let mut t = Table::new(&["sys", "jct", "tp"]);
        t.rowf("vllm", &[1.2345678, 100.0]);
        let s = t.render();
        assert!(s.contains("vllm"));
        assert!(t.to_csv().starts_with("sys,jct,tp\n"));
    }

    #[test]
    fn sig_format() {
        assert_eq!(format_sig(1234.5678, 4), "1235");
        assert_eq!(format_sig(0.0012345, 4), "0.001234");
        assert_eq!(format_sig(0.0, 4), "0");
    }
}
