//! Poison-tolerant synchronization helpers.
//!
//! `Mutex::lock().unwrap()` turns one panicking holder into a cascade:
//! every later locker panics on the poison error even though the
//! protected data (counters, ring buffers, token buckets) is still
//! structurally valid — none of our critical sections leave partial
//! states behind on unwind. [`lock`] recovers the guard from a poisoned
//! mutex instead, so a single wrecked request handler cannot take down
//! the metrics endpoint or the whole serving surface with it.
//!
//! `scripts/check.sh` greps non-test sources for `lock().unwrap()` to
//! keep new poison-panicking sites from creeping back in.

use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Acquire `m`, recovering the guard if a previous holder panicked.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// `Condvar::wait_timeout` with the same poison recovery as [`lock`].
pub fn wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(guard, dur) {
        Ok((g, timeout)) => (g, timeout.timed_out()),
        Err(poisoned) => {
            let (g, timeout) = poisoned.into_inner();
            (g, timeout.timed_out())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn lock_recovers_from_poison() {
        let m = Mutex::new(5u32);
        // Poison the mutex by panicking while holding the guard.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison it");
        }));
        assert!(m.is_poisoned());
        let mut g = lock(&m);
        *g += 1;
        assert_eq!(*g, 6);
    }

    #[test]
    fn wait_timeout_times_out() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock(&m);
        let (_g, timed_out) = wait_timeout(&cv, g, Duration::from_millis(1));
        assert!(timed_out);
    }
}
