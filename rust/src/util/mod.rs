//! Self-contained substrate utilities.
//!
//! The offline crate registry carries only the `xla` crate's dependency
//! closure, so the usual ecosystem crates (clap, serde, criterion, proptest,
//! rand, tokio) are unavailable. Everything the system needs from them is
//! implemented here, scoped to exactly what this project uses.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
