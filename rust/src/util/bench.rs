//! In-tree benchmarking kit (the offline registry has no criterion).
//!
//! Two pieces:
//!  * [`time_fn`] / [`Bencher`] — warmup + timed iterations with mean /
//!    p50 / p95 reporting, used by the micro-benches (scheduler hot path).
//!  * [`BenchOut`] — uniform result sink for the paper-figure drivers:
//!    prints the table to stdout AND writes `bench_out/<name>.csv` +
//!    `.json` so EXPERIMENTS.md entries can be regenerated mechanically.

use std::time::{Duration, Instant};

use super::stats::{Samples, Table};

/// Time `f` for at least `min_iters` iterations / `min_time`, after warmup.
pub fn time_fn(mut f: impl FnMut(), min_iters: usize, min_time: Duration) -> BenchResult {
    // Warmup: 10% of min_iters, at least 3.
    for _ in 0..(min_iters / 10).max(3) {
        f();
    }
    let mut samples = Samples::new();
    let start = Instant::now();
    let mut iters = 0usize;
    while iters < min_iters || start.elapsed() < min_time {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        iters += 1;
        if iters > 10_000_000 {
            break;
        }
    }
    BenchResult { samples }
}

pub struct BenchResult {
    pub samples: Samples,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.samples.mean() * 1e9
    }

    pub fn report(&mut self, name: &str) -> String {
        format!(
            "{name}: n={} mean={} p50={} p95={}",
            self.samples.len(),
            fmt_ns(self.samples.mean() * 1e9),
            fmt_ns(self.samples.p50() * 1e9),
            fmt_ns(self.samples.p95() * 1e9),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Prevent the optimizer from deleting a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Result sink for figure drivers: stdout table + bench_out/ CSV artifacts.
pub struct BenchOut {
    name: String,
    sections: Vec<(String, Table)>,
}

impl BenchOut {
    pub fn new(name: &str) -> Self {
        BenchOut { name: name.to_string(), sections: vec![] }
    }

    pub fn section(&mut self, title: &str, table: Table) {
        println!("\n== {} :: {} ==", self.name, title);
        print!("{}", table.render());
        self.sections.push((title.to_string(), table));
    }

    /// Write all sections to bench_out/<name>__<section>.csv.
    pub fn finish(self) {
        let dir = std::path::Path::new("bench_out");
        if std::fs::create_dir_all(dir).is_err() {
            return;
        }
        for (title, table) in &self.sections {
            let slug: String = title
                .chars()
                .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
                .collect();
            let path = dir.join(format!("{}__{}.csv", self.name, slug));
            let _ = std::fs::write(path, table.to_csv());
        }
        println!("\n[{}] wrote {} csv file(s) to bench_out/", self.name, self.sections.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures() {
        let mut acc = 0u64;
        let mut res = time_fn(
            || {
                acc = black_box(acc.wrapping_add(1));
            },
            100,
            Duration::from_millis(1),
        );
        assert!(res.samples.len() >= 100);
        assert!(res.mean_ns() >= 0.0);
        let rep = res.report("noop");
        assert!(rep.contains("mean="));
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(2500.0), "2.50µs");
        assert_eq!(fmt_ns(3.5e6), "3.50ms");
        assert_eq!(fmt_ns(1.2e9), "1.20s");
    }
}
