//! Minimal JSON reader/writer (the offline registry has no serde).
//!
//! Covers the full JSON grammar we produce and consume: the AOT
//! `manifest.json` / `golden.json` artifacts and the bench-result exports.
//! Numbers are parsed as f64 (JSON's own number model); integer accessors
//! check exactness.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------------
    // Accessors
    // ------------------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][...]` chain with a useful error message.
    pub fn at(&self, path: &[&str]) -> Result<&Json, String> {
        let mut cur = self;
        for (i, key) in path.iter().enumerate() {
            cur = cur
                .get(key)
                .ok_or_else(|| format!("missing key '{}' at /{}", key, path[..i].join("/")))?;
        }
        Ok(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && x.abs() < 9e15 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ------------------------------------------------------------------
    // Parsing
    // ------------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Json, String> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| format!("read {}: {e}", path.as_ref().display()))?;
        Json::parse(&text)
    }

    // ------------------------------------------------------------------
    // Writing
    // ------------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a `Json::Obj` tersely: `obj([("a", 1.0.into()), ...])`.
pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes (fast path, keeps UTF-8 intact).
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] got {other:?} at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} got {other:?} at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": null, "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("42").unwrap().as_i64(), Some(42));
        assert_eq!(Json::parse("-7").unwrap().as_i64(), Some(-7));
        assert_eq!(Json::parse("2.5").unwrap().as_f64(), Some(2.5));
        assert_eq!(Json::parse("2.5").unwrap().as_i64(), None);
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn nested_path_access() {
        let v = Json::parse(r#"{"a":{"b":{"c": 9}}}"#).unwrap();
        assert_eq!(v.at(&["a", "b", "c"]).unwrap().as_i64(), Some(9));
        assert!(v.at(&["a", "x"]).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"config": {"vocab": 512}, "params": [{"name": "embed", "shape": [512, 128], "offset": 0, "elems": 65536}]}"#;
        let v = Json::parse(src).unwrap();
        let p = &v.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("name").unwrap().as_str(), Some("embed"));
        assert_eq!(p.get("elems").unwrap().as_usize(), Some(65536));
    }
}
