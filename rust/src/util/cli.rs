//! Tiny CLI argument parser (the offline registry has no clap).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, repeated keys,
//! and positional arguments, with auto-generated `--help` text.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, Vec<String>>,
    positional: Vec<String>,
}

pub struct Cli {
    program: &'static str,
    about: &'static str,
    specs: Vec<ArgSpec>,
}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Cli { program, about, specs: vec![] }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.program, self.about);
        for spec in &self.specs {
            let kind = if spec.is_flag { "" } else { " <value>" };
            let def = match spec.default {
                Some(d) => format!(" [default: {d}]"),
                None if spec.is_flag => String::new(),
                None => " (required)".to_string(),
            };
            s.push_str(&format!("  --{}{kind}\n      {}{def}\n", spec.name, spec.help));
        }
        s
    }

    /// Parse `argv` (without the program name). Exits with usage on --help.
    pub fn parse(&self, argv: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                print!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n{}", self.usage()))?;
                let val = if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("--{key} is a flag, no value allowed"));
                    }
                    "true".to_string()
                } else {
                    match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{key} requires a value"))?,
                    }
                };
                args.values.entry(key).or_default().push(val);
            } else {
                args.positional.push(tok);
            }
        }
        // Fill defaults, check required.
        for spec in &self.specs {
            if !args.values.contains_key(spec.name) {
                match (spec.default, spec.is_flag) {
                    (Some(d), _) => {
                        args.values.insert(spec.name.to_string(), vec![d.to_string()]);
                    }
                    (None, true) => {
                        args.values.insert(spec.name.to_string(), vec!["false".to_string()]);
                    }
                    (None, false) => {
                        return Err(format!("missing required --{}\n{}", spec.name, self.usage()))
                    }
                }
            }
        }
        Ok(args)
    }

    pub fn parse_env(&self) -> Result<Args, String> {
        self.parse(std::env::args().skip(1))
    }
}

impl Args {
    pub fn get(&self, key: &str) -> &str {
        self.values
            .get(key)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
            .unwrap_or_else(|| panic!("option --{key} was not declared"))
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.values.get(key).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    pub fn f64(&self, key: &str) -> f64 {
        self.get(key).parse().unwrap_or_else(|_| panic!("--{key} must be a number"))
    }

    pub fn usize(&self, key: &str) -> usize {
        self.get(key).parse().unwrap_or_else(|_| panic!("--{key} must be an integer"))
    }

    pub fn u64(&self, key: &str) -> u64 {
        self.get(key).parse().unwrap_or_else(|_| panic!("--{key} must be an integer"))
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), "true" | "1" | "yes")
    }

    /// Comma-separated list of f64.
    pub fn f64_list(&self, key: &str) -> Vec<f64> {
        self.get(key)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{key}: bad number '{s}'")))
            .collect()
    }

    pub fn str_list(&self, key: &str) -> Vec<String> {
        self.get(key)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().to_string())
            .collect()
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("rate", "1.5", "arrival rate")
            .req("trace", "trace name")
            .flag("verbose", "chatty")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_forms() {
        let a = cli().parse(sv(&["--trace", "alpaca", "--rate=3", "--verbose", "pos1"])).unwrap();
        assert_eq!(a.get("trace"), "alpaca");
        assert_eq!(a.f64("rate"), 3.0);
        assert!(a.bool("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse(sv(&["--trace", "x"])).unwrap();
        assert_eq!(a.f64("rate"), 1.5);
        assert!(!a.bool("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse(sv(&["--rate", "2"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse(sv(&["--trace", "x", "--nope", "1"])).is_err());
    }

    #[test]
    fn lists() {
        let a = cli().parse(sv(&["--trace", "a,b", "--rate=1,2.5,3"])).unwrap();
        assert_eq!(a.f64_list("rate"), vec![1.0, 2.5, 3.0]);
        assert_eq!(a.str_list("trace"), vec!["a", "b"]);
    }
}
