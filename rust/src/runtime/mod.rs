//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and serve the real (small) transformer from
//! rust. Python never runs on this path.
//!
//! Artifacts (see aot.py's module docs for the exact layouts):
//!  * `manifest.json` — model config + parameter table + state sizes.
//!  * `weights.bin`   — little-endian f32 parameters, manifest order.
//!  * `prefill.hlo.txt` / `decode.hlo.txt` / `insert.hlo.txt` /
//!    `logits_1.hlo.txt` / `logits_b.hlo.txt` — packed-state programs
//!    (single flat f32 output each; see model.py).
//!  * `golden.json`   — deterministic transcript for integration tests.
//!
//! Weights are uploaded to device buffers ONCE and reused via
//! `execute_b`. The serving state (KV caches + logits, packed into one
//! flat array per program) chains on-device between steps; only the
//! logits block is read back per iteration (EXPERIMENTS.md §Perf).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Model dimensions parsed from manifest.json.
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub max_prompt: usize,
    pub decode_slots: usize,
    pub head_dim: usize,
    pub param_count: usize,
    /// Packed-state lengths (f32 elements) for B=1 and B=decode_slots.
    pub state_elems_1: usize,
    pub state_elems_b: usize,
}

/// Golden transcript for end-to-end validation.
#[derive(Debug, Clone)]
pub struct Golden {
    pub prompt: Vec<i32>,
    pub prompt_len: usize,
    pub steps: usize,
    pub generated: Vec<i32>,
    pub prefill_logits_l2: f64,
}

fn jerr(e: String) -> anyhow::Error {
    anyhow!(e)
}

fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

pub fn load_manifest(dir: &Path) -> Result<(ModelDims, Vec<(String, Vec<usize>)>)> {
    let m = Json::parse_file(dir.join("manifest.json")).map_err(jerr)?;
    let c = m.at(&["config"]).map_err(jerr)?;
    let get = |k: &str| -> Result<usize> {
        c.get(k)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("manifest config missing '{k}'"))
    };
    let dims = ModelDims {
        vocab: get("vocab")?,
        d_model: get("d_model")?,
        n_heads: get("n_heads")?,
        n_layers: get("n_layers")?,
        max_seq: get("max_seq")?,
        max_prompt: get("max_prompt")?,
        decode_slots: get("decode_slots")?,
        head_dim: get("head_dim")?,
        param_count: get("param_count")?,
        state_elems_1: m
            .at(&["artifacts", "state_elems_1"])
            .map_err(jerr)?
            .as_usize()
            .ok_or_else(|| anyhow!("bad state_elems_1"))?,
        state_elems_b: m
            .at(&["artifacts", "state_elems_b"])
            .map_err(jerr)?
            .as_usize()
            .ok_or_else(|| anyhow!("bad state_elems_b"))?,
    };
    let mut params = Vec::new();
    for p in m.at(&["params"]).map_err(jerr)?.as_arr().unwrap_or(&[]) {
        let name = p
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("param missing name"))?
            .to_string();
        let shape: Vec<usize> = p
            .get("shape")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("param missing shape"))?
            .iter()
            .map(|x| x.as_usize().unwrap_or(0))
            .collect();
        params.push((name, shape));
    }
    Ok((dims, params))
}

pub fn load_golden(dir: &Path) -> Result<Golden> {
    let g = Json::parse_file(dir.join("golden.json")).map_err(jerr)?;
    let ints = |k: &str| -> Result<Vec<i32>> {
        Ok(g.get(k)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("golden missing '{k}'"))?
            .iter()
            .map(|x| x.as_i64().unwrap_or(0) as i32)
            .collect())
    };
    Ok(Golden {
        prompt: ints("prompt")?,
        prompt_len: g.get("prompt_len").and_then(|v| v.as_usize()).unwrap_or(0),
        steps: g.get("steps").and_then(|v| v.as_usize()).unwrap_or(0),
        generated: ints("generated")?,
        prefill_logits_l2: g
            .get("prefill_logits_l2")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0),
    })
}

/// The loaded model: compiled executables + device-resident weights and
/// packed serving state.
///
/// Every AOT program has a SINGLE flat f32 output (see model.py's
/// packed-state docs): PJRT hands back one plain buffer per step, so the
/// KV state chains on-device across prefill -> insert -> decode and only
/// the logits block (a few KB) is read to the host per iteration.
pub struct PjrtModel {
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
    insert_exe: xla::PjRtLoadedExecutable,
    logits_1_exe: xla::PjRtLoadedExecutable,
    logits_b_exe: xla::PjRtLoadedExecutable,
    weights: Vec<xla::PjRtBuffer>,
    pub dims: ModelDims,
    /// Packed decode-batch state [2*L*B*H*T*hd kv | B*V logits], on device.
    state_b: xla::PjRtBuffer,
    pub dir: PathBuf,
}

impl PjrtModel {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let (dims, params) = load_manifest(&dir)?;

        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(xerr)
            .with_context(|| format!("parsing {name}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(xerr).with_context(|| format!("compiling {name}"))
        };
        let prefill_exe = compile("prefill.hlo.txt")?;
        let decode_exe = compile("decode.hlo.txt")?;
        let insert_exe = compile("insert.hlo.txt")?;
        let logits_1_exe = compile("logits_1.hlo.txt")?;
        let logits_b_exe = compile("logits_b.hlo.txt")?;

        // Upload weights once.
        let bytes = std::fs::read(dir.join("weights.bin"))
            .with_context(|| format!("reading {}/weights.bin", dir.display()))?;
        if bytes.len() % 4 != 0 {
            bail!("weights.bin not a multiple of 4 bytes");
        }
        let mut floats = vec![0f32; bytes.len() / 4];
        for (i, ch) in bytes.chunks_exact(4).enumerate() {
            floats[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
        }
        let mut weights = Vec::with_capacity(params.len());
        let mut off = 0usize;
        for (name, shape) in &params {
            let n: usize = shape.iter().product();
            let slice = floats
                .get(off..off + n)
                .ok_or_else(|| anyhow!("weights.bin too short at {name}"))?;
            let buf = client
                .buffer_from_host_buffer::<f32>(slice, shape, None)
                .map_err(xerr)
                .with_context(|| format!("uploading {name}"))?;
            weights.push(buf);
            off += n;
        }
        if off != floats.len() {
            bail!("weights.bin has {} extra floats", floats.len() - off);
        }

        // Zeroed packed batch state on device.
        let zeros = vec![0f32; dims.state_elems_b];
        let state_b = client
            .buffer_from_host_buffer::<f32>(&zeros, &[dims.state_elems_b], None)
            .map_err(xerr)?;

        Ok(PjrtModel {
            client,
            prefill_exe,
            decode_exe,
            insert_exe,
            logits_1_exe,
            logits_b_exe,
            weights,
            dims,
            state_b,
            dir,
        })
    }

    /// Execute `exe` with the resident weight buffers followed by `tmp`
    /// extra inputs; returns the single output buffer on device.
    fn exec_with_weights(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        tmp: &[&xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        let mut refs: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        refs.extend(tmp.iter().copied());
        let mut out = exe.execute_b(&refs).map_err(xerr)?;
        Ok(out.remove(0).remove(0))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer::<i32>(data, dims, None).map_err(xerr)
    }

    /// Read the logits block of a packed state to the host.
    fn read_logits(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        state: &xla::PjRtBuffer,
    ) -> Result<Vec<f32>> {
        let mut out = exe.execute_b(&[state]).map_err(xerr)?;
        let buf = out.remove(0).remove(0);
        buf.to_literal_sync().map_err(xerr)?.to_vec::<f32>().map_err(xerr)
    }

    /// Run prefill on ONE prompt. Returns (logits[vocab], state_1 buffer)
    /// — the packed B=1 state stays on device, ready for `insert`.
    pub fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, xla::PjRtBuffer)> {
        let p = self.dims.max_prompt;
        if prompt.is_empty() || prompt.len() > p {
            bail!("prompt length {} out of range 1..={p}", prompt.len());
        }
        let mut padded = vec![0i32; p];
        padded[..prompt.len()].copy_from_slice(prompt);
        let tokens = self.upload_i32(&padded, &[1, p])?;
        let lens = self.upload_i32(&[prompt.len() as i32], &[1])?;
        let state_1 = self.exec_with_weights(&self.prefill_exe, &[&tokens, &lens])?;
        let logits = self.read_logits(&self.logits_1_exe, &state_1)?;
        Ok((logits, state_1))
    }

    /// Splice a prefilled B=1 state into decode slot `slot`. Pure
    /// device-to-device: no KV bytes touch the host.
    pub fn insert(&mut self, state_1: &xla::PjRtBuffer, slot: usize) -> Result<()> {
        // NOTE: R0 scalars must go through buffer_from_host_buffer with
        // empty dims — buffer_from_host_literal on an R0 literal crashes
        // xla_extension 0.5.1 ("Unhandled primitive type").
        let slot_buf = self.upload_i32(&[slot as i32], &[])?;
        let args: Vec<&xla::PjRtBuffer> = vec![&self.state_b, state_1, &slot_buf];
        let mut out = self.insert_exe.execute_b(&args).map_err(xerr)?;
        self.state_b = out.remove(0).remove(0);
        Ok(())
    }

    /// One decode iteration over the slot batch. `lens[i] == 0` marks a
    /// dead slot. Returns per-slot logits (garbage rows for dead slots).
    pub fn decode_step(&mut self, lens: &[i32], tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        let b = self.dims.decode_slots;
        if lens.len() != b || tokens.len() != b {
            bail!("lens/tokens must have {b} entries");
        }
        let lens_buf = self.upload_i32(lens, &[b])?;
        let toks_buf = self.upload_i32(tokens, &[b])?;
        self.state_b =
            self.exec_with_weights(&self.decode_exe, &[&self.state_b, &lens_buf, &toks_buf])?;
        let flat = self.read_logits(&self.logits_b_exe, &self.state_b)?;
        let vocab = self.dims.vocab;
        Ok(flat.chunks(vocab).map(|c| c.to_vec()).collect())
    }

    /// Greedy argmax over a logits row.
    pub fn argmax(logits: &[f32]) -> i32 {
        let mut best = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[best] {
                best = i;
            }
        }
        best as i32
    }
}

// NOTE: correctness of this runtime against the python stack is pinned by
// tests/pjrt_golden.rs (integration test: replays golden.json through the
// artifacts and compares greedy tokens).
