//! Serving front-end over the real PJRT model: continuous slot-based
//! batching with decoupled PT/GT handling, driven either synchronously
//! (open-loop replay, used by examples/serve_real_model.rs) or as a
//! background worker thread with request/response channels.
//!
//! This is the "real" counterpart of the simulation coordinator: requests
//! queue as PTs, are prefilled one at a time (B=1 prefill artifact),
//! spliced into a free decode slot (`insert` artifact — KV never leaves
//! the device layout), and then advance one token per decode iteration
//! together with every other live slot (continuous batching). Slots are
//! the real engine's KVC granularity; the EconoServe ordering policy
//! picks which queued PT gets a freed slot.

pub mod http;

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::PjrtModel;
use crate::util::stats::Samples;

/// One serving request (token ids in; the demo model has no tokenizer —
/// callers supply ids in [1, vocab)).
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// Stop after this many generated tokens (the trace's true RL).
    pub max_new_tokens: usize,
    /// Predicted RL (for ordering); 0 = unknown.
    pub predicted_rl: u32,
    /// Deadline in seconds from submission (SLO); inf = none.
    pub slo_budget: f64,
}

/// Completed response with timing.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Time to first token (s).
    pub ttft: f64,
    /// End-to-end latency (s).
    pub latency: f64,
    /// Mean time between tokens (s).
    pub mean_tbt: f64,
    pub met_slo: bool,
}

struct Slot {
    req: ServeRequest,
    submitted: Instant,
    first_token_at: Option<Instant>,
    last_token_at: Instant,
    tbt: Samples,
    tokens: Vec<i32>,
    /// Context length inside the slot (prompt + generated).
    len: usize,
    /// Hard cap on `len` (max_seq guard).
    len_cap: usize,
}

/// Aggregate serving stats.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub completed: usize,
    pub throughput_rps: f64,
    pub throughput_tps: f64,
    pub mean_latency: f64,
    pub p95_latency: f64,
    pub mean_ttft: f64,
    pub mean_tbt: f64,
    pub ssr: f64,
    pub decode_iterations: u64,
    pub mean_batch_occupancy: f64,
}

pub struct RealServer {
    model: PjrtModel,
    waiting: VecDeque<(Instant, ServeRequest)>,
    slots: Vec<Option<Slot>>,
    responses: Vec<ServeResponse>,
    decode_iters: u64,
    occupancy_sum: u64,
    started: Instant,
}

impl RealServer {
    pub fn new(model: PjrtModel) -> Self {
        let n = model.dims.decode_slots;
        RealServer {
            model,
            waiting: VecDeque::new(),
            slots: (0..n).map(|_| None).collect(),
            responses: Vec::new(),
            decode_iters: 0,
            occupancy_sum: 0,
            started: Instant::now(),
        }
    }

    pub fn submit(&mut self, req: ServeRequest) {
        self.waiting.push_back((Instant::now(), req));
    }

    fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Admit queued PTs into free slots (prefill + insert). The queue is
    /// ordered EconoServe-style: longer prompts first within the same
    /// deadline bucket (slots are uniform so the occupied-KVC factor is
    /// constant here).
    fn admit(&mut self) -> Result<()> {
        while let Some(slot_idx) = self.free_slot() {
            if self.waiting.is_empty() {
                break;
            }
            // Ordering: ascending deadline bucket, then longest prompt.
            let now = Instant::now();
            let best = (0..self.waiting.len())
                .min_by_key(|&i| {
                    let (t0, r) = &self.waiting[i];
                    let slack = r.slo_budget - now.duration_since(*t0).as_secs_f64();
                    let bucket = crate::ordering::deadline_bucket(slack);
                    (bucket, usize::MAX - r.prompt.len())
                })
                .unwrap();
            let (t0, req) = self.waiting.remove(best).unwrap();
            let prompt: Vec<i32> =
                req.prompt.iter().copied().take(self.model.dims.max_prompt).collect();
            let (logits, state_1) = self.model.prefill(&prompt)?;
            self.model.insert(&state_1, slot_idx)?;
            let first = PjrtModel::argmax(&logits);
            let now = Instant::now();
            let len = prompt.len();
            let len_cap = (self.model.dims.max_seq - 1).min(len + req.max_new_tokens);
            self.slots[slot_idx] = Some(Slot {
                len,
                len_cap,
                req,
                submitted: t0,
                first_token_at: Some(now),
                last_token_at: now,
                tbt: Samples::new(),
                tokens: vec![first],
            });
        }
        Ok(())
    }

    /// One decode iteration across all live slots. Returns completions.
    fn decode_once(&mut self) -> Result<usize> {
        let b = self.model.dims.decode_slots;
        let mut lens = vec![0i32; b];
        let mut toks = vec![0i32; b];
        let mut any = false;
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(slot) = s {
                lens[i] = slot.len as i32;
                toks[i] = *slot.tokens.last().unwrap();
                any = true;
            }
        }
        if !any {
            return Ok(0);
        }
        let logits = self.model.decode_step(&lens, &toks)?;
        self.decode_iters += 1;
        self.occupancy_sum += self.slots.iter().filter(|s| s.is_some()).count() as u64;
        let now = Instant::now();
        let mut done = 0usize;
        for i in 0..b {
            let Some(slot) = self.slots[i].as_mut() else { continue };
            let tok = PjrtModel::argmax(&logits[i]);
            slot.tokens.push(tok);
            slot.len += 1;
            slot.tbt.push(now.duration_since(slot.last_token_at).as_secs_f64());
            slot.last_token_at = now;
            let finished =
                slot.tokens.len() >= slot.req.max_new_tokens || slot.len + 1 >= slot.len_cap.max(2);
            if finished {
                let slot = self.slots[i].take().unwrap();
                let latency = now.duration_since(slot.submitted).as_secs_f64();
                self.responses.push(ServeResponse {
                    id: slot.req.id,
                    ttft: slot
                        .first_token_at
                        .map(|t| t.duration_since(slot.submitted).as_secs_f64())
                        .unwrap_or(0.0),
                    latency,
                    mean_tbt: slot.tbt.mean(),
                    met_slo: latency <= slot.req.slo_budget,
                    tokens: slot.tokens,
                });
                done += 1;
            }
        }
        Ok(done)
    }

    /// True when no request is queued or in flight.
    pub fn idle(&self) -> bool {
        self.slots.iter().all(|s| s.is_none()) && self.waiting.is_empty()
    }

    /// One engine tick: admit queued PTs, then one decode iteration.
    /// Returns the number of requests completed this tick.
    pub fn tick(&mut self) -> Result<usize> {
        self.admit()?;
        self.decode_once()
    }

    /// Run until the queue and all slots drain. Returns responses.
    pub fn run_to_completion(&mut self) -> Result<&[ServeResponse]> {
        self.started = Instant::now();
        loop {
            self.admit()?;
            if self.slots.iter().all(|s| s.is_none()) && self.waiting.is_empty() {
                break;
            }
            self.decode_once()?;
        }
        Ok(&self.responses)
    }

    pub fn stats(&self) -> ServeStats {
        let span = self.started.elapsed().as_secs_f64().max(1e-9);
        let mut lat = Samples::new();
        let mut ttft = Samples::new();
        let mut tbt = Samples::new();
        let mut tokens = 0usize;
        let mut ok = 0usize;
        for r in &self.responses {
            lat.push(r.latency);
            ttft.push(r.ttft);
            tbt.push(r.mean_tbt);
            tokens += r.tokens.len();
            ok += r.met_slo as usize;
        }
        ServeStats {
            completed: self.responses.len(),
            throughput_rps: self.responses.len() as f64 / span,
            throughput_tps: tokens as f64 / span,
            mean_latency: lat.mean(),
            p95_latency: lat.p95(),
            mean_ttft: ttft.mean(),
            mean_tbt: tbt.mean(),
            ssr: if self.responses.is_empty() { 0.0 } else { ok as f64 / self.responses.len() as f64 },
            decode_iterations: self.decode_iters,
            mean_batch_occupancy: if self.decode_iters > 0 {
                self.occupancy_sum as f64 / self.decode_iters as f64
            } else {
                0.0
            },
        }
    }

    pub fn responses(&self) -> &[ServeResponse] {
        &self.responses
    }
}

/// Commands for the threaded front-end.
enum Cmd {
    Submit(ServeRequest),
    Drain,
}

/// Handle to a server running on a background thread (Python-free request
/// path: the thread owns the PJRT model).
pub struct ServerHandle {
    tx: mpsc::Sender<Cmd>,
    rx_done: mpsc::Receiver<(Vec<ServeResponse>, ServeStats)>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Spawn a worker thread that loads the model from `artifacts_dir`.
    pub fn spawn(artifacts_dir: String) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Cmd>();
        let (tx_done, rx_done) = mpsc::channel();
        let join = std::thread::spawn(move || {
            let model = match PjrtModel::load(&artifacts_dir) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("server: failed to load model: {e:#}");
                    return;
                }
            };
            let mut server = RealServer::new(model);
            loop {
                // Drain pending commands without blocking, then do work.
                let mut drain_requested = false;
                loop {
                    match rx.try_recv() {
                        Ok(Cmd::Submit(r)) => server.submit(r),
                        Ok(Cmd::Drain) => {
                            drain_requested = true;
                            break;
                        }
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => return,
                    }
                }
                let _ = server.admit();
                let idle = server.slots.iter().all(|s| s.is_none());
                if !idle {
                    let _ = server.decode_once();
                } else if drain_requested {
                    let _ = tx_done.send((server.responses.clone(), server.stats()));
                    return;
                } else {
                    // Nothing to do: block for the next command.
                    match rx.recv() {
                        Ok(Cmd::Submit(r)) => server.submit(r),
                        Ok(Cmd::Drain) => {
                            let _ = tx_done.send((server.responses.clone(), server.stats()));
                            return;
                        }
                        Err(_) => return,
                    }
                }
                if drain_requested {
                    // Finish remaining work, then report.
                    while !(server.slots.iter().all(|s| s.is_none())
                        && server.waiting.is_empty())
                    {
                        let _ = server.admit();
                        let _ = server.decode_once();
                    }
                    let _ = tx_done.send((server.responses.clone(), server.stats()));
                    return;
                }
            }
        });
        Ok(ServerHandle { tx, rx_done, join: Some(join) })
    }

    pub fn submit(&self, req: ServeRequest) {
        let _ = self.tx.send(Cmd::Submit(req));
    }

    /// Finish all outstanding work and return (responses, stats).
    pub fn drain(mut self) -> Result<(Vec<ServeResponse>, ServeStats)> {
        let _ = self.tx.send(Cmd::Drain);
        let out = self
            .rx_done
            .recv()
            .map_err(|_| anyhow::anyhow!("server thread terminated unexpectedly"))?;
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        Ok(out)
    }
}
