//! Serving front-end over the real PJRT model: continuous slot-based
//! batching with decoupled PT/GT handling, driven either synchronously
//! (open-loop replay, used by examples/serve_real_model.rs) or as a
//! background worker thread, or over HTTP ([`http`]).
//!
//! This is the "real" counterpart of the simulation coordinator, speaking
//! the shared request-lifecycle API of [`crate::api`]: requests enter
//! through [`RealServer::submit`] (admission-controlled, returning a
//! streaming [`RequestHandle`]), queue as PTs, are prefilled one at a
//! time (B=1 prefill artifact), spliced into a free decode slot (`insert`
//! artifact — KV never leaves the device layout), and then advance one
//! token per decode iteration together with every other live slot
//! (continuous batching). Every generated token is pushed to the
//! request's handle as it is produced; cancellation (explicit, or a
//! dropped handle/connection) frees the slot at the next iteration
//! boundary.
//!
//! Slots are the real engine's KVC granularity; which queued PT gets a
//! freed slot is decided by the same [`crate::ordering::QueuePolicy`]
//! the simulation scheduler uses — one EconoServe ordering
//! implementation, two engines. Slot capacity itself is accounted
//! through the same [`crate::kvc::Allocator`] API as the simulator: a
//! decode slot is one max-allocation lease (`max_seq` tokens — the
//! real engine's static KV layout IS max-allocation), granted at slot
//! admission and released when the request retires.

pub mod http;

use std::collections::VecDeque;
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::Result;

use crate::api::{
    channel, AdmissionConfig, AdmissionController, Completion, EventSink, FinishReason,
    RateLimitConfig, RequestHandle, ServeError, SubmitOptions,
};
use crate::kvc::{Allocator, Demand, MaxAlloc, ReserveClass};
use crate::ordering::{QueuePolicy, QueuedTask};
use crate::runtime::PjrtModel;
use crate::telemetry::{RequestLog, ServerMetrics};
use crate::util::stats::Samples;

/// Front-door configuration for the real serving path.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Queue-ordering policy for slot admission (`QueuePolicy::by_name`).
    pub ordering: QueuePolicy,
    pub admission: AdmissionConfig,
    /// Per-key token-bucket rate limiting at the HTTP front door
    /// (default: off). Enforced by [`http::HttpServer`], not by
    /// [`RealServer::submit`] — direct embedders own their own limits.
    pub rate_limit: RateLimitConfig,
    /// Brownout overload shedding at the HTTP front door (default: off).
    /// Like `rate_limit`, enforced only by [`http::HttpServer`]: tiered
    /// refusal of generation requests (batch-class bodies first, then
    /// everything) as 503 + `Retry-After` once in-flight load crosses
    /// the configured thresholds.
    pub brownout: crate::reliability::HttpBrownout,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            ordering: QueuePolicy::EconoServe,
            admission: AdmissionConfig::default(),
            rate_limit: RateLimitConfig::default(),
            brownout: crate::reliability::HttpBrownout::default(),
        }
    }
}

/// A submitted request waiting for a decode slot.
struct Pending {
    id: u64,
    submitted: Instant,
    opts: SubmitOptions,
    sink: EventSink,
}

/// A request occupying a decode slot.
struct Slot {
    id: u64,
    opts: SubmitOptions,
    sink: EventSink,
    submitted: Instant,
    first_token_at: Instant,
    last_token_at: Instant,
    tbt: Samples,
    tokens: Vec<i32>,
    /// Context length inside the slot (prompt + generated).
    len: usize,
    /// Hard cap on `len` (max_seq guard).
    len_cap: usize,
}

/// Aggregate serving stats.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Successful terminals only (`Complete` | `LengthCap`).
    pub completed: usize,
    /// Requests cancelled mid-flight (explicitly or by client departure).
    pub cancelled: usize,
    /// Requests shed by the admission controller.
    pub rejected: usize,
    pub throughput_rps: f64,
    pub throughput_tps: f64,
    pub mean_latency: f64,
    pub p95_latency: f64,
    pub mean_ttft: f64,
    pub mean_tbt: f64,
    pub ssr: f64,
    pub decode_iterations: u64,
    pub mean_batch_occupancy: f64,
}

pub struct RealServer {
    model: PjrtModel,
    cfg: ServerConfig,
    admission: AdmissionController,
    waiting: VecDeque<Pending>,
    slots: Vec<Option<Slot>>,
    /// Slot-capacity ledger: one max-allocation lease (`max_seq` tokens)
    /// per occupied decode slot, speaking the same `kvc::Allocator` API
    /// as the simulation path.
    slot_leases: MaxAlloc,
    finished: Vec<Completion>,
    /// Throughput time base: anchored at the FIRST submit (not at
    /// construction, not at `run_to_completion`), so stats are correct
    /// for tick-/thread-driven use too.
    first_submit: Option<Instant>,
    next_id: u64,
    /// Shared metric registry (the HTTP layer scrapes it via
    /// `GET /metrics`); also the single source of truth for [`stats`]
    /// (`Self::stats`) — the legacy side-car counters are gone.
    tel: ServerMetrics,
    /// Structured per-request event log (submit/first_token/finish),
    /// timestamped against `origin`.
    log: Arc<RequestLog>,
    /// Epoch for request-log timestamps and the rate-limiter clock.
    origin: Instant,
}

impl RealServer {
    pub fn new(model: PjrtModel) -> Self {
        Self::with_config(model, ServerConfig::default())
    }

    pub fn with_config(model: PjrtModel, cfg: ServerConfig) -> Self {
        Self::with_telemetry(model, cfg, ServerMetrics::new(), Arc::new(RequestLog::default()))
    }

    /// Construct over an externally owned registry/log — how the HTTP
    /// front-end shares one telemetry surface between the engine thread
    /// (which records) and connection threads (which scrape/serve it).
    pub fn with_telemetry(
        model: PjrtModel,
        cfg: ServerConfig,
        tel: ServerMetrics,
        log: Arc<RequestLog>,
    ) -> Self {
        let n = model.dims.decode_slots;
        // The engine's prefill window is the authoritative prompt cap: a
        // looser configured cap would let prompts through that
        // PjrtModel::prefill rejects.
        let mut adm = cfg.admission;
        adm.max_prompt = if adm.max_prompt == 0 {
            model.dims.max_prompt
        } else {
            adm.max_prompt.min(model.dims.max_prompt)
        };
        let max_seq = model.dims.max_seq as u32;
        RealServer {
            admission: AdmissionController::new(adm),
            slot_leases: MaxAlloc::new(n as u32 * max_seq, max_seq, 0),
            model,
            cfg,
            waiting: VecDeque::new(),
            slots: (0..n).map(|_| None).collect(),
            finished: Vec::new(),
            first_submit: None,
            next_id: 1,
            tel,
            log,
            origin: Instant::now(),
        }
    }

    /// Seconds since server construction (request-log time base).
    fn t_s(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }

    /// Requests waiting for a slot.
    pub fn queue_len(&self) -> usize {
        self.waiting.len()
    }

    /// Occupied decode slots.
    pub fn live_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Requests in flight (waiting + executing) — the admission bound.
    pub fn inflight(&self) -> usize {
        self.queue_len() + self.live_slots()
    }

    /// Submit one request through admission control. On acceptance the
    /// returned handle streams a `StreamEvent::Token` per generated token
    /// and ends with `StreamEvent::Finished`; on rejection the request
    /// never enters the queue.
    pub fn submit(&mut self, opts: SubmitOptions) -> Result<RequestHandle, ServeError> {
        if let Err(e) = self.admission.check(self.inflight(), &opts) {
            self.tel.core.requests_rejected.inc();
            self.log.log(0, self.t_s(), "reject", e.kind().to_string());
            return Err(e);
        }
        self.first_submit.get_or_insert_with(Instant::now);
        let id = self.next_id;
        self.next_id += 1;
        let (sink, handle) = channel(id);
        self.log.log(
            id,
            self.t_s(),
            "submit",
            format!("prompt_len={} max_new={}", opts.prompt.len(), opts.max_new_tokens),
        );
        self.waiting.push_back(Pending { id, submitted: Instant::now(), opts, sink });
        Ok(handle)
    }

    fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Record a terminal outcome in the telemetry registry and the
    /// structured request log. Successful finishes feed the latency
    /// histograms; the same families the simulator records (see
    /// `docs/metrics-dictionary.md`).
    fn observe_finish(&self, c: &Completion) {
        match c.finish {
            FinishReason::Complete | FinishReason::LengthCap => {
                self.tel.core.requests_done.inc();
                if c.met_slo {
                    self.tel.core.slo_hit.inc();
                } else {
                    self.tel.core.slo_miss.inc();
                }
                self.tel.core.request_latency.observe(c.latency_s);
                self.tel.core.ttft.observe(c.ttft_s);
                self.tel.core.tbt.observe(c.mean_tbt_s);
            }
            FinishReason::Cancelled => self.tel.core.requests_cancelled.inc(),
            FinishReason::Rejected | FinishReason::Error => {}
        }
        self.log.log(c.id, self.t_s(), "finish", c.finish.as_str().to_string());
    }

    /// Retire a request that never reached a slot.
    fn finish_pending(&mut self, p: Pending, finish: FinishReason) {
        let c = Completion {
            id: p.id,
            finish,
            tokens: Vec::new(),
            ttft_s: 0.0,
            latency_s: p.submitted.elapsed().as_secs_f64(),
            mean_tbt_s: 0.0,
            met_slo: false,
        };
        self.observe_finish(&c);
        p.sink.finish(c.clone());
        self.finished.push(c);
    }

    /// Retire a slot-holding request, freeing the slot and its lease.
    fn finish_slot(&mut self, idx: usize, finish: FinishReason, now: Instant) {
        let slot = self.slots[idx].take().expect("finish_slot on empty slot");
        self.slot_leases.release(slot.id as usize);
        let Slot { id, opts, sink, submitted, first_token_at, tbt, tokens, .. } = slot;
        let latency_s = now.duration_since(submitted).as_secs_f64();
        let c = Completion {
            id,
            finish,
            ttft_s: first_token_at.duration_since(submitted).as_secs_f64(),
            latency_s,
            mean_tbt_s: tbt.mean(),
            met_slo: finish.is_success() && latency_s <= opts.slo_budget,
            tokens,
        };
        self.observe_finish(&c);
        sink.finish(c.clone());
        self.finished.push(c);
    }

    /// Retire cancelled requests: waiting entries are dropped without
    /// spending a prefill, and cancelled slots are freed so admission can
    /// hand them out in the SAME tick.
    fn sweep_cancelled(&mut self) {
        let mut i = 0;
        while i < self.waiting.len() {
            if self.waiting[i].sink.cancelled() {
                let p = self.waiting.remove(i).unwrap();
                self.finish_pending(p, FinishReason::Cancelled);
            } else {
                i += 1;
            }
        }
        let now = Instant::now();
        for idx in 0..self.slots.len() {
            if self.slots[idx].as_ref().is_some_and(|s| s.sink.cancelled()) {
                self.finish_slot(idx, FinishReason::Cancelled, now);
            }
        }
    }

    /// Admit queued PTs into free slots (prefill + insert). Which PT gets
    /// the slot is the configured `ordering` policy's choice — EconoServe
    /// by default: ascending deadline bucket, then longest prompt (the
    /// occupied-KVC factor is constant here because slots are uniform).
    fn admit(&mut self) -> Result<()> {
        self.sweep_cancelled();
        // Snapshot the queue view once per admission pass (slack drift
        // within one pass is microseconds); the snapshot and `waiting`
        // are kept index-aligned as entries are removed.
        let now = Instant::now();
        let mut queue: Vec<QueuedTask> = self
            .waiting
            .iter()
            .map(|p| QueuedTask {
                seq: p.id,
                priority: p.opts.priority,
                slack: p.opts.slo_budget - now.duration_since(p.submitted).as_secs_f64(),
                occupied_kvc: 0,
                len: p.opts.prompt.len() as u32,
            })
            .collect();
        while let Some(slot_idx) = self.free_slot() {
            let Some(best) = self.cfg.ordering.select(&queue) else { break };
            queue.remove(best);
            let p = self.waiting.remove(best).unwrap();
            if p.sink.cancelled() {
                self.finish_pending(p, FinishReason::Cancelled);
                continue;
            }
            let (logits, state_1) = self.model.prefill(&p.opts.prompt)?;
            self.model.insert(&state_1, slot_idx)?;
            // Take the slot's KVC lease (max-allocation: the real engine's
            // static per-slot KV layout) only once the engine calls have
            // succeeded, so an engine error cannot leak slot capacity.
            // The free-slot gate makes the grant infallible (one lease
            // per slot); finish_slot releases it.
            let demand = Demand {
                immediate: p.opts.prompt.len() as u32,
                predicted: p.opts.max_new_tokens as u32,
                max_total: self.model.dims.max_seq as u32,
            };
            let granted = self.slot_leases.admit(p.id as usize, demand, ReserveClass::Normal);
            debug_assert!(granted.ok(), "free slot without lease capacity");
            self.tel.core.alloc_granted.inc();
            self.tel.core.tokens_prefill.add(p.opts.prompt.len() as u64);
            self.log.log(p.id, self.t_s(), "first_token", String::new());
            let first = PjrtModel::argmax(&logits);
            let now = Instant::now();
            let len = p.opts.prompt.len();
            let len_cap = (self.model.dims.max_seq - 1).min(len + p.opts.max_new_tokens);
            let slot = Slot {
                id: p.id,
                submitted: p.submitted,
                sink: p.sink,
                opts: p.opts,
                first_token_at: now,
                last_token_at: now,
                tbt: Samples::new(),
                tokens: vec![first],
                len,
                len_cap,
            };
            let delivered = slot.sink.send_token(0, first);
            // The prefill itself emits the first response token, so a
            // 1-token budget (or an exhausted context) finishes here
            // without spending a decode iteration.
            let finish = if !delivered {
                // Client left while queued: free the slot right away.
                Some(FinishReason::Cancelled)
            } else if slot.tokens.len() >= slot.opts.max_new_tokens {
                Some(FinishReason::Complete)
            } else if slot.len + 1 >= slot.len_cap.max(2) {
                Some(FinishReason::LengthCap)
            } else {
                None
            };
            self.slots[slot_idx] = Some(slot);
            if let Some(reason) = finish {
                self.finish_slot(slot_idx, reason, now);
            }
        }
        self.tel.core.queue_depth.set(self.waiting.len() as f64);
        Ok(())
    }

    /// One decode iteration across all live slots. Returns the number of
    /// SUCCESSFUL completions this iteration.
    fn decode_once(&mut self) -> Result<usize> {
        // Cancellation sweep first: a cancelled slot is freed at this
        // iteration boundary instead of consuming another model step
        // (admit() sweeps too, so tick() reuses freed slots immediately;
        // this covers direct decode_once drivers).
        self.sweep_cancelled();

        let b = self.model.dims.decode_slots;
        let mut lens = vec![0i32; b];
        let mut toks = vec![0i32; b];
        let mut any = false;
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(slot) = s {
                lens[i] = slot.len as i32;
                toks[i] = *slot.tokens.last().unwrap();
                any = true;
            }
        }
        if !any {
            return Ok(0);
        }
        let logits = self.model.decode_step(&lens, &toks)?;
        let live = self.slots.iter().filter(|s| s.is_some()).count();
        self.tel.core.iterations.inc();
        self.tel.core.tokens_decode.add(live as u64);
        self.tel.core.batch_occupancy.observe(live as f64);
        // The real engine's KVC is its static slot layout: utilization is
        // the occupied-slot fraction (the sim records the written-block
        // fraction under the same family).
        self.tel.core.kvc_utilization.observe(live as f64 / b.max(1) as f64);
        self.tel.core.queue_depth.set(self.waiting.len() as f64);
        let now = Instant::now();
        let mut done = 0usize;
        for i in 0..b {
            let finish = {
                let Some(slot) = self.slots[i].as_mut() else { continue };
                let tok = PjrtModel::argmax(&logits[i]);
                slot.tokens.push(tok);
                slot.len += 1;
                slot.tbt.push(now.duration_since(slot.last_token_at).as_secs_f64());
                slot.last_token_at = now;
                let delivered = slot.sink.send_token(slot.tokens.len() as u32 - 1, tok);
                if !delivered || slot.sink.cancelled() {
                    Some(FinishReason::Cancelled)
                } else if slot.tokens.len() >= slot.opts.max_new_tokens {
                    Some(FinishReason::Complete)
                } else if slot.len + 1 >= slot.len_cap.max(2) {
                    Some(FinishReason::LengthCap)
                } else {
                    None
                }
            };
            if let Some(reason) = finish {
                if reason.is_success() {
                    done += 1;
                }
                self.finish_slot(i, reason, now);
            }
        }
        Ok(done)
    }

    /// True when no request is queued or in flight.
    pub fn idle(&self) -> bool {
        self.slots.iter().all(|s| s.is_none()) && self.waiting.is_empty()
    }

    /// One engine tick: admit queued PTs, then one decode iteration.
    /// Returns the number of requests completed this tick.
    pub fn tick(&mut self) -> Result<usize> {
        self.admit()?;
        self.decode_once()
    }

    /// Run until the queue and all slots drain. Returns all terminal
    /// records (including cancellations).
    pub fn run_to_completion(&mut self) -> Result<&[Completion]> {
        loop {
            self.admit()?;
            if self.idle() {
                break;
            }
            self.decode_once()?;
        }
        Ok(&self.finished)
    }

    /// Aggregate stats, read back from the shared telemetry registry —
    /// the same cells `GET /metrics` exposes, so `/v1/stats` can never
    /// drift from the Prometheus view. The JSON shape is unchanged;
    /// counters are exact, means come from histogram sum/count, and the
    /// p95 is the histogram's bucket-interpolated quantile (previously
    /// an exact order statistic). `throughput_tps` counts every emitted
    /// token — one per slot admission (the prefill's first token) plus
    /// one per slot per decode iteration — cancelled streams included.
    pub fn stats(&self) -> ServeStats {
        let span = self
            .first_submit
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0)
            .max(1e-9);
        let m = &self.tel.core;
        let completed = m.requests_done.get() as usize;
        let ok = m.slo_hit.get();
        let emitted = m.alloc_granted.get() + m.tokens_decode.get();
        ServeStats {
            completed,
            cancelled: m.requests_cancelled.get() as usize,
            rejected: m.requests_rejected.get() as usize,
            throughput_rps: completed as f64 / span,
            throughput_tps: emitted as f64 / span,
            mean_latency: m.request_latency.mean(),
            p95_latency: m.request_latency.quantile(0.95),
            mean_ttft: m.ttft.mean(),
            mean_tbt: m.tbt.mean(),
            ssr: if completed == 0 { 0.0 } else { ok as f64 / completed as f64 },
            decode_iterations: m.iterations.get(),
            mean_batch_occupancy: m.batch_occupancy.mean(),
        }
    }

    /// The shared telemetry bundle (HTTP layer: scrape + rate-limit
    /// counters).
    pub fn telemetry(&self) -> &ServerMetrics {
        &self.tel
    }

    /// Canonical Prometheus text of the server's registry.
    pub fn metrics_text(&self) -> String {
        self.tel.registry().render()
    }

    /// The structured per-request event log.
    pub fn request_log(&self) -> &Arc<RequestLog> {
        &self.log
    }

    /// Terminate every in-flight request with `FinishReason::Error` (the
    /// engine hit an unrecoverable fault): clients blocked on their
    /// handles observe a terminal event instead of hanging forever.
    pub fn fail_all(&mut self) {
        while let Some(p) = self.waiting.pop_front() {
            self.finish_pending(p, FinishReason::Error);
        }
        let now = Instant::now();
        for idx in 0..self.slots.len() {
            if self.slots[idx].is_some() {
                self.finish_slot(idx, FinishReason::Error, now);
            }
        }
    }

    /// All terminal records so far (successes and cancellations).
    pub fn finished(&self) -> &[Completion] {
        &self.finished
    }

    /// Model dimensions (for clients sizing prompts against the window).
    pub fn dims(&self) -> &crate::runtime::ModelDims {
        &self.model.dims
    }
}

/// Commands for the threaded front-end.
enum Cmd {
    Submit(SubmitOptions, mpsc::Sender<Result<RequestHandle, ServeError>>),
    Drain,
}

/// Handle to a server running on a background thread (Python-free request
/// path: the thread owns the PJRT model).
pub struct ServerHandle {
    tx: mpsc::Sender<Cmd>,
    rx_done: mpsc::Receiver<(Vec<Completion>, ServeStats)>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Spawn a worker thread that loads the model from `artifacts_dir`.
    pub fn spawn(artifacts_dir: String) -> Result<Self> {
        Self::spawn_with(artifacts_dir, ServerConfig::default())
    }

    pub fn spawn_with(artifacts_dir: String, cfg: ServerConfig) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Cmd>();
        let (tx_done, rx_done) = mpsc::channel();
        let join = std::thread::spawn(move || {
            let model = match PjrtModel::load(&artifacts_dir) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("server: failed to load model: {e:#}");
                    return;
                }
            };
            let mut server = RealServer::with_config(model, cfg);
            loop {
                // Drain pending commands without blocking, then do work.
                let mut drain_requested = false;
                loop {
                    match rx.try_recv() {
                        Ok(Cmd::Submit(opts, reply)) => {
                            let _ = reply.send(server.submit(opts));
                        }
                        Ok(Cmd::Drain) => {
                            drain_requested = true;
                            break;
                        }
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => return,
                    }
                }
                let fail = |server: &mut RealServer, e: anyhow::Error| {
                    eprintln!("server: fatal engine error: {e:#}");
                    server.fail_all();
                };
                if let Err(e) = server.admit() {
                    fail(&mut server, e);
                    let _ = tx_done.send((server.finished.clone(), server.stats()));
                    return;
                }
                let idle = server.slots.iter().all(|s| s.is_none());
                if !idle {
                    if let Err(e) = server.decode_once() {
                        fail(&mut server, e);
                        let _ = tx_done.send((server.finished.clone(), server.stats()));
                        return;
                    }
                } else if drain_requested {
                    let _ = tx_done.send((server.finished.clone(), server.stats()));
                    return;
                } else {
                    // Nothing to do: block for the next command.
                    match rx.recv() {
                        Ok(Cmd::Submit(opts, reply)) => {
                            let _ = reply.send(server.submit(opts));
                        }
                        Ok(Cmd::Drain) => {
                            let _ = tx_done.send((server.finished.clone(), server.stats()));
                            return;
                        }
                        Err(_) => return,
                    }
                }
                if drain_requested {
                    // Finish remaining work, then report.
                    while !server.idle() {
                        if server.admit().and_then(|_| server.decode_once()).is_err() {
                            server.fail_all();
                            break;
                        }
                    }
                    let _ = tx_done.send((server.finished.clone(), server.stats()));
                    return;
                }
            }
        });
        Ok(ServerHandle { tx, rx_done, join: Some(join) })
    }

    /// Submit through the worker's admission controller; the returned
    /// handle streams tokens as the worker generates them.
    pub fn submit(&self, opts: SubmitOptions) -> Result<RequestHandle, ServeError> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Cmd::Submit(opts, rtx)).map_err(|_| ServeError::EngineDown)?;
        rrx.recv().map_err(|_| ServeError::EngineDown)?
    }

    /// Finish all outstanding work and return (completions, stats).
    pub fn drain(mut self) -> Result<(Vec<Completion>, ServeStats)> {
        let _ = self.tx.send(Cmd::Drain);
        let out = self
            .rx_done
            .recv()
            .map_err(|_| anyhow::anyhow!("server thread terminated unexpectedly"))?;
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        Ok(out)
    }
}
