//! Minimal HTTP/1.1 front-end for the real-model server (std-only: the
//! offline registry has no hyper/axum/tokio), speaking the unified
//! request-lifecycle API of [`crate::api`].
//!
//! Endpoints:
//!   POST /v1/generate   {"prompt": [ids], "max_new_tokens": n,
//!                        "slo_budget_s": s?, "priority": p?}
//!                       -> {"id", "tokens", "finish", "met_slo",
//!                           "ttft_s", "latency_s", "tbt_s"}
//!   POST /v1/stream     same body; chunked NDJSON response: one
//!                       {"index", "token"} object per generated token,
//!                       then a terminal {"done": true, "finish", ...}.
//!                       Dropping the connection cancels the request and
//!                       frees its decode slot.
//!   GET  /v1/stats      -> aggregate ServeStats snapshot
//!   GET  /v1/info       -> model dims (decode_slots, max_prompt, ...)
//!   GET  /health        -> 200 "ok"
//!
//! Errors are structured: {"error": msg, "kind": stable_kind} with the
//! [`ServeError`] status mapping (400 bad request, 404 unknown route,
//! 429 queue full, 503 SLO-infeasible/engine down).
//!
//! Architecture: one acceptor thread per connection (serving concurrency
//! is bounded by the model's decode slots anyway), all requests funneled
//! to the single engine thread that owns the PJRT model. The engine
//! replies to a submission immediately with a `RequestHandle` (or a
//! rejection); the connection thread then consumes the handle's event
//! stream while the engine keeps batching.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;

use anyhow::{anyhow, Context, Result};

use super::{RealServer, ServeStats, ServerConfig};
use crate::api::{RequestHandle, ServeError, StreamEvent, SubmitOptions};
use crate::runtime::{ModelDims, PjrtModel};
use crate::util::json::{obj, Json};

enum EngineCmd {
    Submit(SubmitOptions, mpsc::Sender<Result<RequestHandle, ServeError>>),
    Stats(mpsc::Sender<ServeStats>),
    Info(mpsc::Sender<ModelDims>),
    Shutdown,
}

/// Handle to a running HTTP server (engine thread + acceptor thread).
pub struct HttpServer {
    pub addr: std::net::SocketAddr,
    tx: mpsc::Sender<EngineCmd>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    engine_handle: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and serve
    /// the model from `artifacts_dir` with the default front door.
    pub fn start(addr: &str, artifacts_dir: &str) -> Result<Self> {
        Self::start_with(addr, artifacts_dir, ServerConfig::default())
    }

    /// As [`start`](Self::start), with an explicit ordering policy and
    /// admission configuration.
    pub fn start_with(addr: &str, artifacts_dir: &str, cfg: ServerConfig) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;

        let (tx, rx) = mpsc::channel::<EngineCmd>();

        // Engine thread: owns the model (PjRtModel is !Send — the PJRT
        // client handle is thread-affine in the xla crate — so it is
        // LOADED on the engine thread), runs the slot-batch loop.
        let dir = artifacts_dir.to_string();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let engine_handle = std::thread::spawn(move || {
            let model = match PjrtModel::load(&dir) {
                Ok(m) => {
                    let _ = ready_tx.send(Ok(()));
                    m
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            engine_loop(RealServer::with_config(model, cfg), rx)
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during load"))?
            .with_context(|| format!("loading artifacts from {artifacts_dir}"))?;

        // Acceptor thread: parses HTTP, forwards to the engine.
        let tx_accept = tx.clone();
        let accept_handle = std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                let tx = tx_accept.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, tx);
                });
            }
        });

        Ok(HttpServer {
            addr: local,
            tx,
            accept_handle: Some(accept_handle),
            engine_handle: Some(engine_handle),
        })
    }

    /// Stop the engine (the acceptor thread dies with the process; tests
    /// only need the engine drained).
    pub fn shutdown(mut self) {
        let _ = self.tx.send(EngineCmd::Shutdown);
        if let Some(h) = self.engine_handle.take() {
            let _ = h.join();
        }
        drop(self.accept_handle.take());
    }
}

/// Engine loop: interleave admission of submitted requests with decode
/// iterations. Token delivery runs over each request's own handle
/// channel, so this loop never blocks on a slow client.
fn engine_loop(mut server: RealServer, rx: mpsc::Receiver<EngineCmd>) {
    loop {
        // Drain pending commands without blocking; block only when idle.
        let idle = server.idle();
        loop {
            let cmd = if idle {
                match rx.recv() {
                    Ok(c) => c,
                    Err(_) => return,
                }
            } else {
                match rx.try_recv() {
                    Ok(c) => c,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => return,
                }
            };
            match cmd {
                EngineCmd::Submit(opts, reply) => {
                    let _ = reply.send(server.submit(opts));
                }
                EngineCmd::Stats(reply) => {
                    let _ = reply.send(server.stats());
                }
                EngineCmd::Info(reply) => {
                    let _ = reply.send(server.dims().clone());
                }
                EngineCmd::Shutdown => return,
            }
            if !server.idle() {
                break;
            }
        }
        if let Err(e) = server.tick() {
            // Unrecoverable engine fault: terminate every in-flight
            // stream (clients see FinishReason::Error, not a hang) and
            // exit; subsequent submissions get EngineDown from the
            // dropped command channel.
            eprintln!("engine: fatal tick error: {e:#}");
            server.fail_all();
            return;
        }
    }
}

/// Parse a generate/stream request body into [`SubmitOptions`].
fn parse_submit(body: &[u8]) -> Result<SubmitOptions, ServeError> {
    let text = std::str::from_utf8(body)
        .map_err(|_| ServeError::InvalidRequest("body is not utf-8".into()))?;
    let j = Json::parse(text).map_err(|e| ServeError::InvalidRequest(format!("bad json: {e}")))?;
    let prompt: Vec<i32> = j
        .get("prompt")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| ServeError::InvalidRequest("missing 'prompt' (array of token ids)".into()))?
        .iter()
        .map(|x| x.as_i64().unwrap_or(0) as i32)
        .collect();
    let max_new = j.get("max_new_tokens").and_then(|v| v.as_usize()).unwrap_or(32);
    let slo = j.get("slo_budget_s").and_then(|v| v.as_f64()).unwrap_or(f64::INFINITY);
    let priority = j.get("priority").and_then(|v| v.as_usize()).unwrap_or(0).min(255) as u8;
    let predicted =
        j.get("predicted_rl").and_then(|v| v.as_usize()).unwrap_or(max_new) as u32;
    Ok(SubmitOptions {
        prompt,
        max_new_tokens: max_new,
        predicted_rl: predicted,
        slo_budget: slo,
        priority,
    })
}

fn submit_to_engine(
    tx: &mpsc::Sender<EngineCmd>,
    body: &[u8],
) -> Result<RequestHandle, ServeError> {
    let opts = parse_submit(body)?;
    let (rtx, rrx) = mpsc::channel();
    tx.send(EngineCmd::Submit(opts, rtx)).map_err(|_| ServeError::EngineDown)?;
    rrx.recv().map_err(|_| ServeError::EngineDown)?
}

fn error_json(e: &ServeError) -> Json {
    obj([("error", Json::from(e.to_string())), ("kind", Json::from(e.kind()))])
}

fn completion_json(c: &crate::api::Completion) -> Json {
    obj([
        ("id", Json::from(c.id as usize)),
        ("finish", Json::from(c.finish.as_str())),
        ("tokens", Json::Arr(c.tokens.iter().map(|t| Json::from(*t as usize)).collect())),
        ("met_slo", Json::Bool(c.met_slo)),
        ("ttft_s", Json::from(c.ttft_s)),
        ("latency_s", Json::from(c.latency_s)),
        ("tbt_s", Json::from(c.mean_tbt_s)),
    ])
}

fn handle_conn(stream: TcpStream, tx: mpsc::Sender<EngineCmd>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    // Headers (we only need Content-Length).
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }

    // Streaming endpoint: the response is written incrementally, so it
    // cannot go through the buffered route/respond pair below.
    if method == "POST" && path == "/v1/stream" {
        return match submit_to_engine(&tx, &body) {
            Ok(handle) => stream_response(stream, handle),
            Err(e) => respond(stream, e.http_status(), &error_json(&e).to_string()),
        };
    }

    let (status, payload) = route(&method, &path, &body, &tx).unwrap_or_else(|e| {
        let err = ServeError::Internal(format!("{e:#}"));
        (err.http_status(), error_json(&err))
    });
    respond(stream, status, &payload.to_string())
}

/// Write one chunked-transfer NDJSON event stream: a chunk per token,
/// then a terminal completion chunk. A failed write means the client is
/// gone — cancel the request so the engine frees its slot.
fn stream_response(mut stream: TcpStream, handle: RequestHandle) -> Result<()> {
    write!(
        stream,
        "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    )?;
    stream.flush()?;
    let write_chunk = |stream: &mut TcpStream, data: &str| -> std::io::Result<()> {
        write!(stream, "{:x}\r\n{data}\r\n", data.len())?;
        stream.flush()
    };
    let cancel = handle.cancel_token();
    for event in handle {
        let (line, last) = match &event {
            StreamEvent::Token(t) => (
                obj([
                    ("index", Json::from(t.index as usize)),
                    ("token", Json::from(t.token as usize)),
                ])
                .to_string(),
                false,
            ),
            StreamEvent::Finished(c) => {
                let mut o = completion_json(c);
                if let Json::Obj(m) = &mut o {
                    m.insert("done".into(), Json::Bool(true));
                }
                (o.to_string(), true)
            }
        };
        if write_chunk(&mut stream, &(line + "\n")).is_err() {
            // Client disconnected mid-stream: cancel so the engine frees
            // the decode slot at the next iteration boundary.
            cancel.cancel();
            return Ok(());
        }
        if last {
            break;
        }
    }
    let _ = write!(stream, "0\r\n\r\n");
    let _ = stream.flush();
    Ok(())
}

fn route(
    method: &str,
    path: &str,
    body: &[u8],
    tx: &mpsc::Sender<EngineCmd>,
) -> Result<(u16, Json)> {
    match (method, path) {
        ("GET", "/health") => Ok((200, Json::from("ok"))),
        ("GET", "/v1/stats") => {
            let (rtx, rrx) = mpsc::channel();
            tx.send(EngineCmd::Stats(rtx)).map_err(|_| anyhow!("engine down"))?;
            let s = rrx.recv().map_err(|_| anyhow!("engine down"))?;
            Ok((
                200,
                obj([
                    ("completed", Json::from(s.completed)),
                    ("cancelled", Json::from(s.cancelled)),
                    ("rejected", Json::from(s.rejected)),
                    ("throughput_rps", Json::from(s.throughput_rps)),
                    ("throughput_tps", Json::from(s.throughput_tps)),
                    ("mean_latency_s", Json::from(s.mean_latency)),
                    ("p95_latency_s", Json::from(s.p95_latency)),
                    ("mean_ttft_s", Json::from(s.mean_ttft)),
                    ("mean_tbt_s", Json::from(s.mean_tbt)),
                    ("ssr", Json::from(s.ssr)),
                    ("decode_iterations", Json::from(s.decode_iterations as usize)),
                    ("mean_batch_occupancy", Json::from(s.mean_batch_occupancy)),
                ]),
            ))
        }
        ("GET", "/v1/info") => {
            let (rtx, rrx) = mpsc::channel();
            tx.send(EngineCmd::Info(rtx)).map_err(|_| anyhow!("engine down"))?;
            let d = rrx.recv().map_err(|_| anyhow!("engine down"))?;
            Ok((
                200,
                obj([
                    ("vocab", Json::from(d.vocab)),
                    ("decode_slots", Json::from(d.decode_slots)),
                    ("max_prompt", Json::from(d.max_prompt)),
                    ("max_seq", Json::from(d.max_seq)),
                    ("n_layers", Json::from(d.n_layers)),
                    ("param_count", Json::from(d.param_count)),
                ]),
            ))
        }
        ("POST", "/v1/generate") => match submit_to_engine(tx, body) {
            Ok(handle) => match handle.wait() {
                Ok(c) if c.finish == crate::api::FinishReason::Error => {
                    let e = ServeError::Internal("engine failed mid-generation".into());
                    Ok((e.http_status(), error_json(&e)))
                }
                Ok(c) => Ok((200, completion_json(&c))),
                Err(e) => Ok((e.http_status(), error_json(&e))),
            },
            Err(e) => Ok((e.http_status(), error_json(&e))),
        },
        _ => Ok((
            404,
            obj([
                ("error", Json::from(format!("no route {method} {path}"))),
                ("kind", Json::from("not_found")),
            ]),
        )),
    }
}

fn respond(mut stream: TcpStream, status: u16, body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        499 => "Client Closed Request",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    Ok(())
}

/// Tiny blocking HTTP client for tests/examples (same std-only rationale).
pub fn http_request(
    addr: &std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    let mut buf = String::new();
    BufReader::new(stream).read_to_string(&mut buf)?;
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("bad response: {buf}"))?;
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    Ok((status, body))
}

/// Incremental chunked-response reader for the `/v1/stream` endpoint.
/// Dropping it mid-stream closes the connection, which the server treats
/// as a cancellation.
pub struct ChunkStream {
    reader: BufReader<TcpStream>,
    pub status: u16,
}

impl ChunkStream {
    /// Open a streaming request and parse the response head. The body
    /// chunks are then pulled one at a time via [`next_chunk`].
    ///
    /// [`next_chunk`]: Self::next_chunk
    pub fn open(addr: &std::net::SocketAddr, path: &str, body: &str) -> Result<Self> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        write!(
            stream,
            "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )?;
        stream.flush()?;
        let mut reader = BufReader::new(stream);
        let mut status_line = String::new();
        reader.read_line(&mut status_line)?;
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad status line: {status_line}"))?;
        let mut chunked = false;
        loop {
            let mut line = String::new();
            reader.read_line(&mut line)?;
            let line = line.trim().to_ascii_lowercase();
            if line.is_empty() {
                break;
            }
            if line.starts_with("transfer-encoding:") && line.contains("chunked") {
                chunked = true;
            }
        }
        if status == 200 && !chunked {
            return Err(anyhow!("expected a chunked response"));
        }
        Ok(ChunkStream { reader, status })
    }

    /// Next body chunk as a string; `None` on the terminating 0-chunk or
    /// a closed connection.
    pub fn next_chunk(&mut self) -> Option<String> {
        let mut size_line = String::new();
        self.reader.read_line(&mut size_line).ok()?;
        let size = usize::from_str_radix(size_line.trim(), 16).ok()?;
        if size == 0 {
            return None;
        }
        let mut data = vec![0u8; size + 2]; // chunk + trailing CRLF
        self.reader.read_exact(&mut data).ok()?;
        data.truncate(size);
        String::from_utf8(data).ok()
    }

    /// Drain the rest of the stream, returning all remaining chunks.
    pub fn collect_remaining(mut self) -> Vec<String> {
        let mut out = Vec::new();
        while let Some(c) = self.next_chunk() {
            out.push(c);
        }
        out
    }
}
