//! Minimal HTTP/1.1 front-end for the real-model server (std-only: the
//! offline registry has no hyper/axum/tokio), speaking the unified
//! request-lifecycle API of [`crate::api`].
//!
//! Endpoints:
//!   POST /v1/generate     {"prompt": [ids], "max_new_tokens": n,
//!                          "slo_budget_s": s?, "priority": p?}
//!                         -> {"id", "tokens", "finish", "met_slo",
//!                             "ttft_s", "latency_s", "tbt_s"}
//!   POST /v1/stream       same body; chunked NDJSON response: one
//!                         {"index", "token"} object per generated token,
//!                         then a terminal {"done": true, "finish", ...}.
//!                         Dropping the connection cancels the request and
//!                         frees its decode slot.
//!   POST /v1/completions  OpenAI-compatible facade: {"prompt": "text"
//!                         or [ids], "max_tokens": n?, "stream": bool?}.
//!                         A string prompt uses a bytes-as-token-ids
//!                         stand-in tokenizer (the demo model has no BPE
//!                         vocabulary); `"stream": true` answers with
//!                         `text/event-stream` SSE frames ending in
//!                         `data: [DONE]`.
//!   GET  /v1/models       OpenAI-compatible model listing.
//!   GET  /v1/stats        -> aggregate ServeStats snapshot (read from the
//!                         telemetry registry — same cells as /metrics)
//!   GET  /v1/info         -> model dims (decode_slots, max_prompt, ...)
//!   GET  /metrics         -> Prometheus text exposition of the shared
//!                         registry (same family names as the simulator's
//!                         `--metrics-out`; see docs/metrics-dictionary.md)
//!   GET  /trace           -> Chrome trace-event JSON of the wall-clock
//!                         request-lifecycle spans recorded so far (same
//!                         span schema as the simulator's `--trace-out`;
//!                         timestamps are seconds since server start;
//!                         see docs/API.md "Tracing")
//!   GET  /health          -> 200 "ok"
//!
//! Errors are structured: {"error": msg, "kind": stable_kind} with the
//! [`ServeError`] status mapping (400 bad request, 404 unknown route,
//! 429 queue full / rate limited, 503 SLO-infeasible/draining/engine
//! down).
//!
//! Hardening: an optional per-key token-bucket rate limiter guards the
//! generation endpoints (key = `x-api-key` header, `"anon"` otherwise;
//! `ServerConfig::rate_limit`), an optional brownout controller sheds
//! generation load under overload (batch-class bodies first, then all
//! generates; 503 `brownout` with a `Retry-After` header;
//! `ServerConfig::brownout`), and shutdown is graceful — a [`DrainGate`]
//! lets in-flight connections (token streams included) finish while new
//! ones get 503 `shutting_down`, then the engine is stopped
//! ([`HttpServer::shutdown`]).
//!
//! Architecture: one acceptor thread per connection (serving concurrency
//! is bounded by the model's decode slots anyway), all requests funneled
//! to the single engine thread that owns the PJRT model. The engine
//! replies to a submission immediately with a `RequestHandle` (or a
//! rejection); the connection thread then consumes the handle's event
//! stream while the engine keeps batching. Engine and connection threads
//! share one telemetry registry and request log.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::{RealServer, ServeStats, ServerConfig};
use crate::api::{
    DrainGate, RequestHandle, ServeError, StreamEvent, SubmitOptions, TokenBucketLimiter,
};
use crate::runtime::{ModelDims, PjrtModel};
use crate::telemetry::{
    Outcome, Registry, RequestLog, ServerMetrics, SpanState, TraceConfig, TraceRecorder,
};
use crate::util::json::{obj, Json};

enum EngineCmd {
    Submit(SubmitOptions, mpsc::Sender<Result<RequestHandle, ServeError>>),
    Stats(mpsc::Sender<ServeStats>),
    Info(mpsc::Sender<ModelDims>),
    Shutdown,
}

/// Shared state every connection thread needs: the engine channel plus
/// the telemetry/hardening surface.
struct Ctx {
    tx: mpsc::Sender<EngineCmd>,
    tel: ServerMetrics,
    log: Arc<RequestLog>,
    gate: Arc<DrainGate>,
    limiter: Mutex<TokenBucketLimiter>,
    brownout: crate::reliability::HttpBrownout,
    /// Epoch of the rate-limiter clock.
    origin: Instant,
    /// Wall-clock lifecycle spans (pid 0, tid = request id), scraped at
    /// `GET /trace`. Timestamps are seconds since `origin`, so the spans
    /// share the simulator's schema and tooling (`tracelint`,
    /// `trace-report`).
    tracer: Mutex<TraceRecorder>,
}

/// Handle to a running HTTP server (engine thread + acceptor thread).
pub struct HttpServer {
    pub addr: std::net::SocketAddr,
    tx: mpsc::Sender<EngineCmd>,
    ctx: Arc<Ctx>,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    engine_handle: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and serve
    /// the model from `artifacts_dir` with the default front door.
    pub fn start(addr: &str, artifacts_dir: &str) -> Result<Self> {
        Self::start_with(addr, artifacts_dir, ServerConfig::default())
    }

    /// As [`start`](Self::start), with an explicit ordering policy,
    /// admission configuration, rate limit, and brownout thresholds.
    pub fn start_with(addr: &str, artifacts_dir: &str, cfg: ServerConfig) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;

        let (tx, rx) = mpsc::channel::<EngineCmd>();
        // One registry + request log shared by the engine thread (which
        // records serving metrics) and every connection thread (which
        // records HTTP metrics and serves GET /metrics).
        let registry = Registry::new();
        let tel = ServerMetrics::on(registry);
        let log: Arc<RequestLog> = Arc::new(RequestLog::default());
        let ctx = Arc::new(Ctx {
            tx: tx.clone(),
            tel: tel.clone(),
            log: log.clone(),
            gate: DrainGate::new(),
            limiter: Mutex::new(TokenBucketLimiter::new(cfg.rate_limit)),
            brownout: cfg.brownout,
            origin: Instant::now(),
            tracer: Mutex::new(TraceRecorder::new(TraceConfig::new(0), 0, "http")),
        });

        // Engine thread: owns the model (PjRtModel is !Send — the PJRT
        // client handle is thread-affine in the xla crate — so it is
        // LOADED on the engine thread), runs the slot-batch loop.
        let dir = artifacts_dir.to_string();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let engine_handle = std::thread::spawn(move || {
            let model = match PjrtModel::load(&dir) {
                Ok(m) => {
                    let _ = ready_tx.send(Ok(()));
                    m
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            engine_loop(RealServer::with_telemetry(model, cfg, tel, log), rx)
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during load"))?
            .with_context(|| format!("loading artifacts from {artifacts_dir}"))?;

        // Acceptor thread: parses HTTP, forwards to the engine. Exits
        // when `stop` is set (shutdown self-connects to unblock accept).
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = stop.clone();
        let accept_ctx = ctx.clone();
        let accept_handle = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let ctx = accept_ctx.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, &ctx);
                });
            }
        });

        Ok(HttpServer {
            addr: local,
            tx,
            ctx,
            stop,
            accept_handle: Some(accept_handle),
            engine_handle: Some(engine_handle),
        })
    }

    /// The shared telemetry bundle (scraped at `GET /metrics`).
    pub fn telemetry(&self) -> &ServerMetrics {
        &self.ctx.tel
    }

    /// Canonical Prometheus text of the server's registry.
    pub fn metrics_text(&self) -> String {
        self.ctx.tel.registry().render()
    }

    /// Chrome trace-event JSON of the wall-clock lifecycle spans
    /// recorded so far (the same document `GET /trace` serves).
    pub fn trace_text(&self) -> String {
        crate::util::sync::lock(&self.ctx.tracer).doc().to_chrome_string()
    }

    /// The structured per-request event log.
    pub fn request_log(&self) -> &Arc<RequestLog> {
        &self.ctx.log
    }

    /// Graceful shutdown with a 10 s drain allowance; see
    /// [`shutdown_within`](Self::shutdown_within).
    pub fn shutdown(self) {
        self.shutdown_within(Duration::from_secs(10));
    }

    /// Graceful shutdown: (1) begin draining — the acceptor stays up but
    /// every new connection gets 503 `shutting_down`, (2) wait up to
    /// `grace` for in-flight connections (streams included) to finish —
    /// the engine keeps batching so they CAN finish, (3) stop the
    /// acceptor, (4) stop and join the engine thread.
    pub fn shutdown_within(mut self, grace: Duration) {
        self.ctx.gate.begin_drain();
        if !self.ctx.gate.wait_idle(grace) {
            eprintln!(
                "http: drain timed out with {} connection(s) still open",
                self.ctx.gate.active()
            );
        }
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the acceptor's accept(); it re-checks the stop flag
        // before handling the connection and exits instead.
        let _ = TcpStream::connect(self.addr);
        let _ = self.tx.send(EngineCmd::Shutdown);
        if let Some(h) = self.engine_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// Engine loop: interleave admission of submitted requests with decode
/// iterations. Token delivery runs over each request's own handle
/// channel, so this loop never blocks on a slow client.
fn engine_loop(mut server: RealServer, rx: mpsc::Receiver<EngineCmd>) {
    loop {
        // Drain pending commands without blocking; block only when idle.
        let idle = server.idle();
        loop {
            let cmd = if idle {
                match rx.recv() {
                    Ok(c) => c,
                    Err(_) => return,
                }
            } else {
                match rx.try_recv() {
                    Ok(c) => c,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => return,
                }
            };
            match cmd {
                EngineCmd::Submit(opts, reply) => {
                    let _ = reply.send(server.submit(opts));
                }
                EngineCmd::Stats(reply) => {
                    let _ = reply.send(server.stats());
                }
                EngineCmd::Info(reply) => {
                    let _ = reply.send(server.dims().clone());
                }
                EngineCmd::Shutdown => return,
            }
            if !server.idle() {
                break;
            }
        }
        if let Err(e) = server.tick() {
            // Unrecoverable engine fault: terminate every in-flight
            // stream (clients see FinishReason::Error, not a hang) and
            // exit; subsequent submissions get EngineDown from the
            // dropped command channel.
            eprintln!("engine: fatal tick error: {e:#}");
            server.fail_all();
            return;
        }
    }
}

/// Parse a generate/stream request body into [`SubmitOptions`].
fn parse_submit(body: &[u8]) -> Result<SubmitOptions, ServeError> {
    let text = std::str::from_utf8(body)
        .map_err(|_| ServeError::InvalidRequest("body is not utf-8".into()))?;
    let j = Json::parse(text).map_err(|e| ServeError::InvalidRequest(format!("bad json: {e}")))?;
    let prompt: Vec<i32> = j
        .get("prompt")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| ServeError::InvalidRequest("missing 'prompt' (array of token ids)".into()))?
        .iter()
        .map(|x| x.as_i64().unwrap_or(0) as i32)
        .collect();
    let max_new = j.get("max_new_tokens").and_then(|v| v.as_usize()).unwrap_or(32);
    let slo = j.get("slo_budget_s").and_then(|v| v.as_f64()).unwrap_or(f64::INFINITY);
    let priority = j.get("priority").and_then(|v| v.as_usize()).unwrap_or(0).min(255) as u8;
    let predicted =
        j.get("predicted_rl").and_then(|v| v.as_usize()).unwrap_or(max_new) as u32;
    Ok(SubmitOptions {
        prompt,
        max_new_tokens: max_new,
        predicted_rl: predicted,
        slo_budget: slo,
        priority,
    })
}

fn submit_to_engine(
    tx: &mpsc::Sender<EngineCmd>,
    opts: SubmitOptions,
) -> Result<RequestHandle, ServeError> {
    let (rtx, rrx) = mpsc::channel();
    tx.send(EngineCmd::Submit(opts, rtx)).map_err(|_| ServeError::EngineDown)?;
    rrx.recv().map_err(|_| ServeError::EngineDown)?
}

fn error_json(e: &ServeError) -> Json {
    obj([("error", Json::from(e.to_string())), ("kind", Json::from(e.kind()))])
}

fn completion_json(c: &crate::api::Completion) -> Json {
    obj([
        ("id", Json::from(c.id as usize)),
        ("finish", Json::from(c.finish.as_str())),
        ("tokens", Json::Arr(c.tokens.iter().map(|t| Json::from(*t as usize)).collect())),
        ("met_slo", Json::Bool(c.met_slo)),
        ("ttft_s", Json::from(c.ttft_s)),
        ("latency_s", Json::from(c.latency_s)),
        ("tbt_s", Json::from(c.mean_tbt_s)),
    ])
}

/// Normalize a request path to a bounded label for
/// `econoserve_http_requests_total{route=...}` — arbitrary client paths
/// must not mint unbounded label cardinality.
fn route_label(path: &str) -> &'static str {
    match path {
        "/health" => "/health",
        "/metrics" => "/metrics",
        "/trace" => "/trace",
        "/v1/stats" => "/v1/stats",
        "/v1/info" => "/v1/info",
        "/v1/models" => "/v1/models",
        "/v1/generate" => "/v1/generate",
        "/v1/stream" => "/v1/stream",
        "/v1/completions" => "/v1/completions",
        _ => "other",
    }
}

/// RAII increment of `econoserve_http_connections_active`.
struct ActiveConn(crate::telemetry::Gauge);

impl ActiveConn {
    fn new(tel: &ServerMetrics) -> Self {
        tel.connections_active.add(1.0);
        ActiveConn(tel.connections_active.clone())
    }
}

impl Drop for ActiveConn {
    fn drop(&mut self) {
        self.0.add(-1.0);
    }
}

fn handle_conn(stream: TcpStream, ctx: &Ctx) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    // Headers (Content-Length for the body, x-api-key for the limiter).
    let mut content_length = 0usize;
    let mut api_key = "anon".to_string();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim();
        if line.is_empty() {
            break;
        }
        let lower = line.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        } else if let Some(v) = lower.strip_prefix("x-api-key:") {
            let v = v.trim();
            if !v.is_empty() {
                api_key = v.to_string();
            }
        }
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    let label = route_label(&path);
    // Arrival time on the server's wall clock — the submit edge of this
    // request's lifecycle spans.
    let t0 = ctx.origin.elapsed().as_secs_f64();

    // Drain gate: during shutdown, in-flight connections finish while
    // new ones are refused here. The guard is held for the whole
    // exchange — streaming responses included — so `wait_idle` covers
    // them.
    let Some(_conn_guard) = ctx.gate.try_enter() else {
        let e = ServeError::ShuttingDown;
        ctx.tel.http_observe(label, e.http_status());
        return respond(stream, e.http_status(), &error_json(&e).to_string());
    };
    let _active = ActiveConn::new(&ctx.tel);

    // Token-bucket rate limit on the generation endpoints (reads and
    // health stay unthrottled: scrapers and probes are not clients).
    let generates = method == "POST"
        && matches!(path.as_str(), "/v1/generate" | "/v1/stream" | "/v1/completions");
    if generates {
        let now_s = ctx.origin.elapsed().as_secs_f64();
        let verdict = crate::util::sync::lock(&ctx.limiter).check(&api_key, now_s);
        if let Err(retry_after_s) = verdict {
            ctx.tel.rate_limited.inc();
            let e = ServeError::RateLimited { retry_after_s };
            ctx.tel.http_observe(label, e.http_status());
            return respond_retry_after(stream, e.http_status(), retry_after_s, &error_json(&e).to_string());
        }
        // Brownout overload shedding: the in-flight count (this request
        // included — the gate was entered above) proxies pressure, the
        // body size proxies the batch class. Refusals carry Retry-After
        // so well-behaved clients back off instead of hammering.
        if ctx.brownout.refuses(ctx.gate.active(), content_length) {
            let e = ServeError::Brownout { retry_after_s: ctx.brownout.retry_after_s };
            ctx.tel.http_observe(label, e.http_status());
            return respond_retry_after(
                stream,
                e.http_status(),
                ctx.brownout.retry_after_s,
                &error_json(&e).to_string(),
            );
        }
    }

    // Streaming endpoints write their responses incrementally, so they
    // cannot go through the buffered route/respond pair below.
    if method == "POST" && path == "/v1/stream" {
        return match parse_submit(&body).and_then(|o| submit_to_engine(&ctx.tx, o)) {
            Ok(handle) => {
                ctx.tel.http_observe(label, 200);
                stream_response(stream, handle, ctx, t0)
            }
            Err(e) => {
                ctx.tel.http_observe(label, e.http_status());
                respond(stream, e.http_status(), &error_json(&e).to_string())
            }
        };
    }
    if method == "POST" && path == "/v1/completions" {
        return handle_completions(stream, &body, ctx, label, t0);
    }
    if method == "GET" && path == "/metrics" {
        // Surface request-log ring evictions as a counter: the log's own
        // drop count is authoritative, so top the counter up to it here
        // (monotonic — evictions only grow).
        let dropped = ctx.log.dropped();
        let seen = ctx.tel.reqlog_dropped.get();
        if dropped > seen {
            ctx.tel.reqlog_dropped.add(dropped - seen);
        }
        let text = ctx.tel.registry().render();
        ctx.tel.http_observe(label, 200);
        return respond_typed(stream, 200, "text/plain; version=0.0.4", &text);
    }
    if method == "GET" && path == "/trace" {
        let text = crate::util::sync::lock(&ctx.tracer).doc().to_chrome_string();
        ctx.tel.http_observe(label, 200);
        return respond_typed(stream, 200, "application/json", &text);
    }

    let (status, payload) = route(&method, &path, &body, ctx, t0).unwrap_or_else(|e| {
        let err = ServeError::Internal(format!("{e:#}"));
        (err.http_status(), error_json(&err))
    });
    ctx.tel.http_observe(label, status);
    respond(stream, status, &payload.to_string())
}

/// Record a finished request's wall-clock lifecycle on the server's
/// trace: queued from arrival to first token, decode from first token to
/// completion, then the terminal outcome — the same span schema the
/// simulator emits (docs/API.md "Tracing"), with timestamps in seconds
/// since server start.
fn trace_completion(ctx: &Ctx, t0: f64, c: &crate::api::Completion) {
    let id = c.id as usize;
    let mut tr = crate::util::sync::lock(&ctx.tracer);
    tr.on_submit_sampled(id, t0, true);
    if c.ttft_s > 0.0 && c.latency_s > c.ttft_s {
        tr.transition(id, t0 + c.ttft_s, SpanState::Decode);
    }
    let outcome = match c.finish {
        crate::api::FinishReason::Error => Outcome::Lost,
        crate::api::FinishReason::Cancelled => Outcome::Cancelled,
        crate::api::FinishReason::Rejected => Outcome::Rejected,
        _ => Outcome::Done,
    };
    tr.terminal(id, t0 + c.latency_s.max(c.ttft_s).max(0.0), outcome);
}

/// As [`trace_completion`] for a request that died in `wait()` without a
/// completion record (engine fault or mid-flight cancellation).
fn trace_wait_error(ctx: &Ctx, t0: f64, id: u64, e: &ServeError) {
    let now = ctx.origin.elapsed().as_secs_f64();
    let mut tr = crate::util::sync::lock(&ctx.tracer);
    tr.on_submit_sampled(id as usize, t0, true);
    let outcome = match e {
        ServeError::Cancelled => Outcome::Cancelled,
        _ => Outcome::Lost,
    };
    tr.terminal(id as usize, now.max(t0), outcome);
}

/// Write one chunked-transfer NDJSON event stream: a chunk per token,
/// then a terminal completion chunk. A failed write means the client is
/// gone — cancel the request so the engine frees its slot.
fn stream_response(mut stream: TcpStream, handle: RequestHandle, ctx: &Ctx, t0: f64) -> Result<()> {
    write!(
        stream,
        "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    )?;
    stream.flush()?;
    let cancel = handle.cancel_token();
    for event in handle {
        let (line, last) = match &event {
            StreamEvent::Token(t) => (
                obj([
                    ("index", Json::from(t.index as usize)),
                    ("token", Json::from(t.token as usize)),
                ])
                .to_string(),
                false,
            ),
            StreamEvent::Finished(c) => {
                trace_completion(ctx, t0, c);
                let mut o = completion_json(c);
                if let Json::Obj(m) = &mut o {
                    m.insert("done".into(), Json::Bool(true));
                }
                (o.to_string(), true)
            }
        };
        if write_chunk(&mut stream, &(line + "\n")).is_err() {
            // Client disconnected mid-stream: cancel so the engine frees
            // the decode slot at the next iteration boundary.
            cancel.cancel();
            return Ok(());
        }
        if last {
            break;
        }
    }
    let _ = write!(stream, "0\r\n\r\n");
    let _ = stream.flush();
    Ok(())
}

fn write_chunk(stream: &mut TcpStream, data: &str) -> std::io::Result<()> {
    write!(stream, "{:x}\r\n{data}\r\n", data.len())?;
    stream.flush()
}

/// The OpenAI-compatible `/v1/completions` facade.
///
/// The demo model has no text tokenizer, so a string `prompt` uses a
/// bytes-as-token-ids stand-in: each UTF-8 byte becomes one token id
/// (mod the model vocabulary), and response ids in `0..256` decode back
/// to bytes. A JSON-array prompt is passed through as raw token ids,
/// matching the native endpoints.
fn handle_completions(
    stream: TcpStream,
    body: &[u8],
    ctx: &Ctx,
    label: &'static str,
    t0: f64,
) -> Result<()> {
    let reply = |stream: TcpStream, e: ServeError, ctx: &Ctx| {
        ctx.tel.http_observe(label, e.http_status());
        respond(stream, e.http_status(), &error_json(&e).to_string())
    };
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return reply(stream, ServeError::InvalidRequest("body is not utf-8".into()), ctx),
    };
    let j = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => {
            return reply(stream, ServeError::InvalidRequest(format!("bad json: {e}")), ctx)
        }
    };
    // Vocab size bounds the stand-in token ids.
    let vocab = {
        let (rtx, rrx) = mpsc::channel();
        if ctx.tx.send(EngineCmd::Info(rtx)).is_err() {
            return reply(stream, ServeError::EngineDown, ctx);
        }
        match rrx.recv() {
            Ok(d) => d.vocab.max(1),
            Err(_) => return reply(stream, ServeError::EngineDown, ctx),
        }
    };
    let prompt: Vec<i32> = match j.get("prompt") {
        Some(Json::Str(s)) => s.bytes().map(|b| (b as usize % vocab) as i32).collect(),
        Some(v) => match v.as_arr() {
            Some(arr) => arr.iter().map(|x| x.as_i64().unwrap_or(0) as i32).collect(),
            None => {
                return reply(
                    stream,
                    ServeError::InvalidRequest("'prompt' must be a string or an array".into()),
                    ctx,
                )
            }
        },
        None => {
            return reply(stream, ServeError::InvalidRequest("missing 'prompt'".into()), ctx)
        }
    };
    let max_tokens = j.get("max_tokens").and_then(|v| v.as_usize()).unwrap_or(16);
    let model_name =
        j.get("model").and_then(|v| v.as_str()).unwrap_or("econoserve-pjrt").to_string();
    let want_stream = j.get("stream").and_then(|v| v.as_bool()).unwrap_or(false);
    let n_prompt = prompt.len();
    let opts = SubmitOptions::new(prompt, max_tokens.max(1));
    let handle = match submit_to_engine(&ctx.tx, opts) {
        Ok(h) => h,
        Err(e) => return reply(stream, e, ctx),
    };
    ctx.tel.http_observe(label, 200);
    if want_stream {
        completions_sse(stream, handle, &model_name, ctx, t0)
    } else {
        completions_blocking(stream, handle, &model_name, n_prompt, ctx, t0)
    }
}

/// Decode response token ids back to text under the bytes-as-token-ids
/// stand-in (ids outside the byte range render as U+FFFD).
fn detokenize(tokens: &[i32]) -> String {
    let bytes: Vec<u8> =
        tokens.iter().map(|&t| u8::try_from(t).unwrap_or(b'\xEF')).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

fn openai_finish(finish: crate::api::FinishReason) -> &'static str {
    match finish {
        crate::api::FinishReason::Complete => "stop",
        crate::api::FinishReason::LengthCap => "length",
        _ => "stop",
    }
}

fn completions_blocking(
    stream: TcpStream,
    handle: RequestHandle,
    model: &str,
    n_prompt: usize,
    ctx: &Ctx,
    t0: f64,
) -> Result<()> {
    let rid = handle.id();
    match handle.wait() {
        Ok(c) if c.finish == crate::api::FinishReason::Error => {
            trace_completion(ctx, t0, &c);
            let e = ServeError::Internal("engine failed mid-generation".into());
            respond(stream, e.http_status(), &error_json(&e).to_string())
        }
        Ok(c) => {
            trace_completion(ctx, t0, &c);
            let n_out = c.tokens.len();
            let doc = obj([
                ("id", Json::from(format!("cmpl-{}", c.id))),
                ("object", Json::from("text_completion")),
                ("model", Json::from(model)),
                (
                    "choices",
                    Json::Arr(vec![obj([
                        ("index", Json::from(0usize)),
                        ("text", Json::from(detokenize(&c.tokens))),
                        ("finish_reason", Json::from(openai_finish(c.finish))),
                    ])]),
                ),
                (
                    "usage",
                    obj([
                        ("prompt_tokens", Json::from(n_prompt)),
                        ("completion_tokens", Json::from(n_out)),
                        ("total_tokens", Json::from(n_prompt + n_out)),
                    ]),
                ),
            ]);
            respond(stream, 200, &doc.to_string())
        }
        Err(e) => {
            trace_wait_error(ctx, t0, rid, &e);
            respond(stream, e.http_status(), &error_json(&e).to_string())
        }
    }
}

/// Server-sent events variant: one `data: {...}` frame per token, then a
/// final frame carrying the finish_reason, then `data: [DONE]`.
fn completions_sse(
    mut stream: TcpStream,
    handle: RequestHandle,
    model: &str,
    ctx: &Ctx,
    t0: f64,
) -> Result<()> {
    write!(
        stream,
        "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    )?;
    stream.flush()?;
    let frame = |id: u64, text: Json, finish: Option<&str>| {
        obj([
            ("id", Json::from(format!("cmpl-{id}"))),
            ("object", Json::from("text_completion")),
            ("model", Json::from(model)),
            (
                "choices",
                Json::Arr(vec![obj([
                    ("index", Json::from(0usize)),
                    ("text", text),
                    (
                        "finish_reason",
                        finish.map(Json::from).unwrap_or(Json::Null),
                    ),
                ])]),
            ),
        ])
        .to_string()
    };
    let cancel = handle.cancel_token();
    let id = handle.id();
    for event in handle {
        let (data, last) = match &event {
            StreamEvent::Token(t) => {
                (frame(id, Json::from(detokenize(&[t.token])), None), false)
            }
            StreamEvent::Finished(c) => {
                trace_completion(ctx, t0, c);
                (frame(id, Json::from(""), Some(openai_finish(c.finish))), true)
            }
        };
        if write_chunk(&mut stream, &format!("data: {data}\n\n")).is_err() {
            cancel.cancel();
            return Ok(());
        }
        if last {
            break;
        }
    }
    let _ = write_chunk(&mut stream, "data: [DONE]\n\n");
    let _ = write!(stream, "0\r\n\r\n");
    let _ = stream.flush();
    Ok(())
}

fn route(method: &str, path: &str, body: &[u8], ctx: &Ctx, t0: f64) -> Result<(u16, Json)> {
    let tx = &ctx.tx;
    match (method, path) {
        ("GET", "/health") => Ok((200, Json::from("ok"))),
        ("GET", "/v1/stats") => {
            let (rtx, rrx) = mpsc::channel();
            tx.send(EngineCmd::Stats(rtx)).map_err(|_| anyhow!("engine down"))?;
            let s = rrx.recv().map_err(|_| anyhow!("engine down"))?;
            Ok((
                200,
                obj([
                    ("completed", Json::from(s.completed)),
                    ("cancelled", Json::from(s.cancelled)),
                    ("rejected", Json::from(s.rejected)),
                    ("throughput_rps", Json::from(s.throughput_rps)),
                    ("throughput_tps", Json::from(s.throughput_tps)),
                    ("mean_latency_s", Json::from(s.mean_latency)),
                    ("p95_latency_s", Json::from(s.p95_latency)),
                    ("mean_ttft_s", Json::from(s.mean_ttft)),
                    ("mean_tbt_s", Json::from(s.mean_tbt)),
                    ("ssr", Json::from(s.ssr)),
                    ("decode_iterations", Json::from(s.decode_iterations as usize)),
                    ("mean_batch_occupancy", Json::from(s.mean_batch_occupancy)),
                ]),
            ))
        }
        ("GET", "/v1/info") => {
            let (rtx, rrx) = mpsc::channel();
            tx.send(EngineCmd::Info(rtx)).map_err(|_| anyhow!("engine down"))?;
            let d = rrx.recv().map_err(|_| anyhow!("engine down"))?;
            Ok((
                200,
                obj([
                    ("vocab", Json::from(d.vocab)),
                    ("decode_slots", Json::from(d.decode_slots)),
                    ("max_prompt", Json::from(d.max_prompt)),
                    ("max_seq", Json::from(d.max_seq)),
                    ("n_layers", Json::from(d.n_layers)),
                    ("param_count", Json::from(d.param_count)),
                ]),
            ))
        }
        ("GET", "/v1/models") => Ok((
            200,
            obj([
                ("object", Json::from("list")),
                (
                    "data",
                    Json::Arr(vec![obj([
                        ("id", Json::from("econoserve-pjrt")),
                        ("object", Json::from("model")),
                        ("owned_by", Json::from("econoserve")),
                    ])]),
                ),
            ]),
        )),
        ("POST", "/v1/generate") => {
            match parse_submit(body).and_then(|o| submit_to_engine(tx, o)) {
                Ok(handle) => {
                    let rid = handle.id();
                    match handle.wait() {
                        Ok(c) if c.finish == crate::api::FinishReason::Error => {
                            trace_completion(ctx, t0, &c);
                            let e = ServeError::Internal("engine failed mid-generation".into());
                            Ok((e.http_status(), error_json(&e)))
                        }
                        Ok(c) => {
                            trace_completion(ctx, t0, &c);
                            Ok((200, completion_json(&c)))
                        }
                        Err(e) => {
                            trace_wait_error(ctx, t0, rid, &e);
                            Ok((e.http_status(), error_json(&e)))
                        }
                    }
                }
                Err(e) => Ok((e.http_status(), error_json(&e))),
            }
        }
        _ => Ok((
            404,
            obj([
                ("error", Json::from(format!("no route {method} {path}"))),
                ("kind", Json::from("not_found")),
            ]),
        )),
    }
}

fn respond(stream: TcpStream, status: u16, body: &str) -> Result<()> {
    respond_typed(stream, status, "application/json", body)
}

fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        499 => "Client Closed Request",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Error",
    }
}

fn respond_typed(mut stream: TcpStream, status: u16, ctype: &str, body: &str) -> Result<()> {
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len(),
        reason = status_reason(status)
    )?;
    stream.flush()?;
    Ok(())
}

/// As [`respond`], with a `Retry-After` header. The header is
/// integer-valued (RFC 9110 delay-seconds), so the hint is rounded up to
/// at least one second; the precise float stays in the JSON body.
fn respond_retry_after(
    mut stream: TcpStream,
    status: u16,
    retry_after_s: f64,
    body: &str,
) -> Result<()> {
    let secs = retry_after_s.ceil().max(1.0) as u64;
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nRetry-After: {secs}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len(),
        reason = status_reason(status)
    )?;
    stream.flush()?;
    Ok(())
}

/// Tiny blocking HTTP client for tests/examples (same std-only rationale).
pub fn http_request(
    addr: &std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> Result<(u16, String)> {
    http_request_with_key(addr, method, path, body, None)
}

/// As [`http_request`], with an `x-api-key` header (rate-limiter tests).
pub fn http_request_with_key(
    addr: &std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
    api_key: Option<&str>,
) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let key_header =
        api_key.map(|k| format!("x-api-key: {k}\r\n")).unwrap_or_default();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\n{key_header}Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    let mut buf = String::new();
    BufReader::new(stream).read_to_string(&mut buf)?;
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("bad response: {buf}"))?;
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    Ok((status, body))
}

/// Incremental chunked-response reader for the `/v1/stream` endpoint.
/// Dropping it mid-stream closes the connection, which the server treats
/// as a cancellation.
pub struct ChunkStream {
    reader: BufReader<TcpStream>,
    pub status: u16,
}

impl ChunkStream {
    /// Open a streaming request and parse the response head. The body
    /// chunks are then pulled one at a time via [`next_chunk`].
    ///
    /// [`next_chunk`]: Self::next_chunk
    pub fn open(addr: &std::net::SocketAddr, path: &str, body: &str) -> Result<Self> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        write!(
            stream,
            "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )?;
        stream.flush()?;
        let mut reader = BufReader::new(stream);
        let mut status_line = String::new();
        reader.read_line(&mut status_line)?;
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad status line: {status_line}"))?;
        let mut chunked = false;
        loop {
            let mut line = String::new();
            reader.read_line(&mut line)?;
            let line = line.trim().to_ascii_lowercase();
            if line.is_empty() {
                break;
            }
            if line.starts_with("transfer-encoding:") && line.contains("chunked") {
                chunked = true;
            }
        }
        if status == 200 && !chunked {
            return Err(anyhow!("expected a chunked response"));
        }
        Ok(ChunkStream { reader, status })
    }

    /// Next body chunk as a string; `None` on the terminating 0-chunk or
    /// a closed connection.
    pub fn next_chunk(&mut self) -> Option<String> {
        let mut size_line = String::new();
        self.reader.read_line(&mut size_line).ok()?;
        let size = usize::from_str_radix(size_line.trim(), 16).ok()?;
        if size == 0 {
            return None;
        }
        let mut data = vec![0u8; size + 2]; // chunk + trailing CRLF
        self.reader.read_exact(&mut data).ok()?;
        data.truncate(size);
        String::from_utf8(data).ok()
    }

    /// Drain the rest of the stream, returning all remaining chunks.
    pub fn collect_remaining(mut self) -> Vec<String> {
        let mut out = Vec::new();
        while let Some(c) = self.next_chunk() {
            out.push(c);
        }
        out
    }
}
