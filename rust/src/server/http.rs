//! Minimal HTTP/1.1 front-end for the real-model server (std-only: the
//! offline registry has no hyper/axum/tokio).
//!
//! Endpoints:
//!   POST /v1/generate   {"prompt": [int token ids], "max_new_tokens": n}
//!                       -> {"id", "tokens", "ttft_s", "latency_s", "tbt_s"}
//!   GET  /v1/stats      -> aggregate ServeStats snapshot
//!   GET  /health        -> 200 "ok"
//!
//! Architecture: one acceptor thread per connection (serving concurrency
//! is bounded by the model's decode slots anyway), all requests funneled
//! to the single engine thread that owns the PJRT model — the same
//! decoupled PT-queue / slot-batch structure as `RealServer`, with
//! per-request oneshot response channels.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use super::{RealServer, ServeRequest, ServeResponse, ServeStats};
use crate::runtime::PjrtModel;
use crate::util::json::{obj, Json};

enum EngineCmd {
    Generate(ServeRequest, mpsc::Sender<ServeResponse>),
    Stats(mpsc::Sender<ServeStats>),
    Shutdown,
}

/// Handle to a running HTTP server (engine thread + acceptor thread).
pub struct HttpServer {
    pub addr: std::net::SocketAddr,
    tx: mpsc::Sender<EngineCmd>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    engine_handle: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and serve
    /// the model from `artifacts_dir`.
    pub fn start(addr: &str, artifacts_dir: &str) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;

        let (tx, rx) = mpsc::channel::<EngineCmd>();

        // Engine thread: owns the model (PjRtModel is !Send — the PJRT
        // client handle is thread-affine in the xla crate — so it is
        // LOADED on the engine thread), runs the slot-batch loop.
        let dir = artifacts_dir.to_string();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let engine_handle = std::thread::spawn(move || {
            let model = match PjrtModel::load(&dir) {
                Ok(m) => {
                    let _ = ready_tx.send(Ok(()));
                    m
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            engine_loop(model, rx)
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during load"))?
            .with_context(|| format!("loading artifacts from {artifacts_dir}"))?;

        // Acceptor thread: parses HTTP, forwards to the engine.
        let tx_accept = tx.clone();
        let accept_handle = std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                let tx = tx_accept.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, tx);
                });
            }
        });

        Ok(HttpServer { addr: local, tx, accept_handle: Some(accept_handle), engine_handle: Some(engine_handle) })
    }

    /// Stop the engine (the acceptor thread dies with the process; tests
    /// only need the engine drained).
    pub fn shutdown(mut self) {
        let _ = self.tx.send(EngineCmd::Shutdown);
        if let Some(h) = self.engine_handle.take() {
            let _ = h.join();
        }
        drop(self.accept_handle.take());
    }
}

/// Engine loop: interleave admission of queued generate commands with
/// decode iterations; reply on each request's channel as it completes.
fn engine_loop(model: PjrtModel, rx: mpsc::Receiver<EngineCmd>) {
    let mut server = RealServer::new(model);
    let mut waiters: Vec<(u64, mpsc::Sender<ServeResponse>)> = Vec::new();
    let next_id = AtomicU64::new(1);
    let mut replied = 0usize;

    loop {
        // Drain pending commands without blocking; block only when idle.
        let idle = server.idle();
        loop {
            let cmd = if idle && waiters.is_empty() {
                match rx.recv() {
                    Ok(c) => c,
                    Err(_) => return,
                }
            } else {
                match rx.try_recv() {
                    Ok(c) => c,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => return,
                }
            };
            match cmd {
                EngineCmd::Generate(mut req, reply) => {
                    req.id = next_id.fetch_add(1, Ordering::Relaxed);
                    waiters.push((req.id, reply));
                    server.submit(req);
                }
                EngineCmd::Stats(reply) => {
                    let _ = reply.send(server.stats());
                }
                EngineCmd::Shutdown => return,
            }
            if !(idle && waiters.is_empty()) {
                break;
            }
        }

        let _ = server.tick();

        // Deliver any newly completed responses.
        let responses = server.responses();
        while replied < responses.len() {
            let r = responses[replied].clone();
            if let Some(pos) = waiters.iter().position(|(id, _)| *id == r.id) {
                let (_, ch) = waiters.swap_remove(pos);
                let _ = ch.send(r);
            }
            replied += 1;
        }
    }
}

fn handle_conn(stream: TcpStream, tx: mpsc::Sender<EngineCmd>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    // Headers (we only need Content-Length).
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }

    let (status, payload) = route(&method, &path, &body, &tx)
        .unwrap_or_else(|e| (400, obj([("error", Json::from(format!("{e:#}")))])));
    respond(stream, status, &payload.to_string())
}

fn route(
    method: &str,
    path: &str,
    body: &[u8],
    tx: &mpsc::Sender<EngineCmd>,
) -> Result<(u16, Json)> {
    match (method, path) {
        ("GET", "/health") => Ok((200, Json::from("ok"))),
        ("GET", "/v1/stats") => {
            let (rtx, rrx) = mpsc::channel();
            tx.send(EngineCmd::Stats(rtx)).map_err(|_| anyhow!("engine down"))?;
            let s = rrx.recv().map_err(|_| anyhow!("engine down"))?;
            Ok((
                200,
                obj([
                    ("completed", Json::from(s.completed)),
                    ("throughput_rps", Json::from(s.throughput_rps)),
                    ("throughput_tps", Json::from(s.throughput_tps)),
                    ("mean_latency_s", Json::from(s.mean_latency)),
                    ("p95_latency_s", Json::from(s.p95_latency)),
                    ("mean_ttft_s", Json::from(s.mean_ttft)),
                    ("mean_tbt_s", Json::from(s.mean_tbt)),
                    ("decode_iterations", Json::from(s.decode_iterations as usize)),
                    ("mean_batch_occupancy", Json::from(s.mean_batch_occupancy)),
                ]),
            ))
        }
        ("POST", "/v1/generate") => {
            let text = std::str::from_utf8(body).context("body not utf-8")?;
            let j = Json::parse(text).map_err(|e| anyhow!("bad json: {e}"))?;
            let prompt: Vec<i32> = j
                .get("prompt")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("missing 'prompt' (array of token ids)"))?
                .iter()
                .map(|x| x.as_i64().unwrap_or(0) as i32)
                .collect();
            if prompt.is_empty() {
                return Err(anyhow!("'prompt' must be non-empty"));
            }
            let max_new =
                j.get("max_new_tokens").and_then(|v| v.as_usize()).unwrap_or(32).max(1);
            let slo = j.get("slo_budget_s").and_then(|v| v.as_f64()).unwrap_or(f64::INFINITY);
            let (rtx, rrx) = mpsc::channel();
            tx.send(EngineCmd::Generate(
                ServeRequest {
                    id: 0, // assigned by the engine
                    prompt,
                    max_new_tokens: max_new,
                    predicted_rl: max_new as u32,
                    slo_budget: slo,
                },
                rtx,
            ))
            .map_err(|_| anyhow!("engine down"))?;
            let r = rrx.recv().map_err(|_| anyhow!("engine down"))?;
            Ok((
                200,
                obj([
                    ("id", Json::from(r.id as usize)),
                    ("tokens", Json::Arr(r.tokens.iter().map(|t| Json::from(*t as usize)).collect())),
                    ("ttft_s", Json::from(r.ttft)),
                    ("latency_s", Json::from(r.latency)),
                    ("tbt_s", Json::from(r.mean_tbt)),
                ]),
            ))
        }
        _ => Ok((404, obj([("error", Json::from("not found"))]))),
    }
}

fn respond(mut stream: TcpStream, status: u16, body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    Ok(())
}

/// Tiny blocking HTTP client for tests/examples (same std-only rationale).
pub fn http_request(addr: &std::net::SocketAddr, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    let mut buf = String::new();
    BufReader::new(stream).read_to_string(&mut buf)?;
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("bad response: {buf}"))?;
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    Ok((status, body))
}

/// Shared server handle for concurrent client tests.
pub type SharedServer = Arc<Mutex<HttpServer>>;
