//! O(1) request-set indexing for scheduler hot paths.
//!
//! Schedulers keep per-state lists (running GTs, in-flight prefills, …)
//! that previously paid an `iter().position()` scan for every membership
//! test and removal. [`IndexedList`] pairs an order-preserving list with
//! a dense `id → position` slot map so that
//!
//!  * `contains` / `remove` are O(1) (amortized),
//!  * iteration order is the push order (FIFO semantics preserved —
//!    removal tombstones the slot and compacts lazily),
//!  * `push` is O(1) amortized; `push_front` is O(live) and reserved for
//!    the rare priority-insert paths (recompute resumption).
//!
//! Positions handed out by [`IndexedList::raw_len`] / `get_raw` stay
//! stable across `push` (appends only) but NOT across `remove`,
//! `push_front` or `retain` — index-based loops must not remove.

use super::ReqId;

/// Absent marker in the position slot map.
const NONE: usize = usize::MAX;
/// Tombstone marker inside the item list.
const HOLE: ReqId = usize::MAX;

/// Order-preserving list of request ids with O(1) membership and removal.
#[derive(Debug, Clone, Default)]
pub struct IndexedList {
    items: Vec<ReqId>,
    /// id -> index into `items` (NONE = absent).
    pos: Vec<usize>,
    /// Tombstoned slots awaiting compaction.
    holes: usize,
}

impl IndexedList {
    pub fn new() -> Self {
        Self::default()
    }

    /// Live element count.
    pub fn len(&self) -> usize {
        self.items.len() - self.holes
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, id: ReqId) -> bool {
        self.pos.get(id).copied().unwrap_or(NONE) != NONE
    }

    fn ensure_pos(&mut self, id: ReqId) {
        if id >= self.pos.len() {
            self.pos.resize(id + 1, NONE);
        }
    }

    /// Append `id` (must not already be present).
    pub fn push(&mut self, id: ReqId) {
        self.ensure_pos(id);
        debug_assert!(self.pos[id] == NONE, "IndexedList: duplicate push of {id}");
        self.pos[id] = self.items.len();
        self.items.push(id);
    }

    /// Insert `id` at the FRONT (O(live); rare priority path).
    pub fn push_front(&mut self, id: ReqId) {
        self.compact();
        self.ensure_pos(id);
        debug_assert!(self.pos[id] == NONE, "IndexedList: duplicate push_front of {id}");
        self.items.insert(0, id);
        for (i, &it) in self.items.iter().enumerate() {
            self.pos[it] = i;
        }
    }

    /// Remove `id` if present; returns whether it was. O(1) amortized
    /// (tombstone + occasional compaction).
    pub fn remove(&mut self, id: ReqId) -> bool {
        let p = match self.pos.get(id).copied() {
            Some(p) if p != NONE => p,
            _ => return false,
        };
        self.pos[id] = NONE;
        self.items[p] = HOLE;
        self.holes += 1;
        if self.holes * 2 > self.items.len() {
            self.compact();
        }
        true
    }

    /// Drop tombstones, preserving order and refreshing positions.
    fn compact(&mut self) {
        if self.holes == 0 {
            return;
        }
        self.items.retain(|&id| id != HOLE);
        self.holes = 0;
        for (i, &id) in self.items.iter().enumerate() {
            self.pos[id] = i;
        }
    }

    /// Keep only elements for which `f` returns true (order preserved).
    pub fn retain(&mut self, mut f: impl FnMut(ReqId) -> bool) {
        self.compact();
        let pos = &mut self.pos;
        self.items.retain(|&id| {
            if f(id) {
                true
            } else {
                pos[id] = NONE;
                false
            }
        });
        for (i, &id) in self.items.iter().enumerate() {
            self.pos[id] = i;
        }
    }

    /// Live elements in push order.
    pub fn iter(&self) -> impl Iterator<Item = ReqId> + '_ {
        self.items.iter().copied().filter(|&id| id != HOLE)
    }

    /// Raw slot count for index-based loops that must tolerate concurrent
    /// `push` (appends keep earlier slots stable). Pair with
    /// [`IndexedList::get_raw`].
    pub fn raw_len(&self) -> usize {
        self.items.len()
    }

    /// The id in raw slot `i`, or `None` for a tombstone.
    pub fn get_raw(&self, i: usize) -> Option<ReqId> {
        match self.items.get(i) {
            Some(&id) if id != HOLE => Some(id),
            _ => None,
        }
    }

    /// First live element (front of the FIFO order).
    pub fn front(&self) -> Option<ReqId> {
        self.iter().next()
    }

    /// Remove and return the LAST live element (back of the FIFO order).
    pub fn pop_back(&mut self) -> Option<ReqId> {
        loop {
            let id = self.items.pop()?;
            if id != HOLE {
                self.pos[id] = NONE;
                return Some(id);
            }
            self.holes -= 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_remove_contains() {
        let mut l = IndexedList::new();
        for id in [3usize, 7, 1, 9] {
            l.push(id);
        }
        assert_eq!(l.len(), 4);
        assert!(l.contains(7));
        assert!(!l.contains(2));
        assert!(l.remove(7));
        assert!(!l.remove(7));
        assert!(!l.contains(7));
        assert_eq!(l.iter().collect::<Vec<_>>(), vec![3, 1, 9]);
        assert_eq!(l.len(), 3);
    }

    #[test]
    fn order_preserved_across_heavy_removal() {
        let mut l = IndexedList::new();
        for id in 0..100 {
            l.push(id);
        }
        for id in (0..100).step_by(2) {
            assert!(l.remove(id));
        }
        let got: Vec<_> = l.iter().collect();
        let want: Vec<_> = (1..100).step_by(2).collect();
        assert_eq!(got, want);
        // Re-push after removal works and appends.
        l.push(0);
        assert_eq!(l.iter().last(), Some(0));
    }

    #[test]
    fn push_front_prioritizes() {
        let mut l = IndexedList::new();
        l.push(1);
        l.push(2);
        l.push_front(5);
        assert_eq!(l.iter().collect::<Vec<_>>(), vec![5, 1, 2]);
        assert!(l.contains(5));
        assert!(l.remove(1));
        assert_eq!(l.iter().collect::<Vec<_>>(), vec![5, 2]);
    }

    #[test]
    fn retain_filters_and_reindexes() {
        let mut l = IndexedList::new();
        for id in 0..10 {
            l.push(id);
        }
        l.remove(4);
        l.retain(|id| id % 3 != 0);
        assert_eq!(l.iter().collect::<Vec<_>>(), vec![1, 2, 5, 7, 8]);
        for id in [1, 2, 5, 7, 8] {
            assert!(l.contains(id));
        }
        assert!(!l.contains(4));
        assert!(!l.contains(9));
        assert_eq!(l.len(), 5);
    }

    #[test]
    fn raw_access_skips_holes() {
        let mut l = IndexedList::new();
        l.push(10);
        l.push(11);
        l.push(12);
        l.remove(11);
        let live: Vec<_> = (0..l.raw_len()).filter_map(|i| l.get_raw(i)).collect();
        assert_eq!(live, vec![10, 12]);
        assert_eq!(l.front(), Some(10));
        assert_eq!(l.pop_back(), Some(12));
        assert_eq!(l.pop_back(), Some(10));
        assert_eq!(l.pop_back(), None);
        assert!(l.is_empty());
    }
}
