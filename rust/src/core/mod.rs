//! Core domain types: requests, task phases, SLOs, and the simulation clock.
//!
//! Terminology follows the paper:
//!  * **PT** — prompt-processing task (prefill). Compute-intensive.
//!  * **GT** — (token-)generation task (decode). Memory(KVC)-intensive.
//!  * **RL** — response length, in tokens. The *true* RL comes from the
//!    trace; schedulers only see the predictor's (padded) estimate.
//!  * **KVC** — key-value cache, measured in tokens here (block-granular
//!    allocation lives in [`crate::kvc`]).

pub mod index;
pub mod world;

pub use index::IndexedList;

/// Simulation time in seconds.
pub type Time = f64;

/// Request identifier == index into `World::reqs`.
pub type ReqId = usize;

/// A user request as it enters the system.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: ReqId,
    /// Absolute arrival time (seconds since experiment start).
    pub arrival: Time,
    /// Prompt length in tokens.
    pub prompt_len: u32,
    /// Ground-truth response length in tokens (>= 1; the first response
    /// token is produced by the PT itself, per ORCA-style iteration flow).
    pub true_rl: u32,
    /// Absolute JCT deadline: `arrival + slo_scale * (t_p + t_g * true_rl)`
    /// following the paper's SLO definition (§4, after [36]).
    pub deadline: Time,
}

impl Request {
    pub fn total_len(&self) -> u32 {
        self.prompt_len + self.true_rl
    }
}

/// Lifecycle phase of a request. Transitions:
///
/// ```text
/// PtQueued -> Prefilling -> GtQueued -> Decoding -> Done
///                  ^            ^           |
///                  |            +-- Preempted (offload-free or swapped)
///                  +-- (chunked prefill re-enters Prefilling)
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Waiting in the PT queue (prompt not fully processed).
    PtQueued,
    /// At least one prompt chunk is in flight or processed, not all.
    Prefilling,
    /// Prompt fully processed; waiting in the GT queue for decode service.
    /// (In decoupled schedulers this is a real queue; in coupled ones the
    /// request usually passes through instantly.)
    GtQueued,
    /// In the running batch, generating tokens.
    Decoding,
    /// Paused: KVC allocation failed (vLLM-style swap) or a time-synced
    /// group returned with this member unfinished (offload-free).
    Preempted,
    /// Completed; response returned to the user.
    Done,
}

/// Mutable per-request simulation record.
#[derive(Debug, Clone)]
pub struct ReqRec {
    pub req: Request,
    pub phase: Phase,
    /// Prompt tokens already processed (chunked prefill may take several
    /// iterations to reach `prompt_len`).
    pub prompt_done: u32,
    /// Response tokens generated so far.
    pub generated: u32,
    /// Current (padded) RL prediction visible to schedulers. Re-prediction
    /// after an under-provision updates this (see §3.3.2 Misprediction).
    pub predicted_rl: u32,
    /// `generated` value at the time of the last (re)prediction; the
    /// *remaining* predicted tokens are `predicted_rl - (generated - base)`.
    pub predicted_base: u32,
    /// Most recent *raw* (pre-padding) prediction — what the predictor
    /// said before headroom was applied. Feeds the misprediction tracker
    /// (`reliability::headroom`) with the unpadded signed error.
    pub predicted_raw: u32,
    /// The first padded prediction made at admission. Under/over
    /// provisioning verdicts compare this (not re-predictions) against
    /// the truth, matching the paper's Fig 5a accounting.
    pub predicted_initial: u32,
    /// KVC tokens this request currently HOLDS (its own allocation;
    /// excludes space borrowed from a host via KVC pipelining).
    pub kvc_held: u32,
    /// Timestamping for metrics.
    pub first_token_at: Option<Time>,
    pub exec_start_at: Option<Time>,
    pub done_at: Option<Time>,
    pub last_emit_at: Option<Time>,
    /// Accumulated time spent preempted.
    pub preempt_total: f64,
    pub preempted_since: Option<Time>,
    /// Number of preemptions suffered.
    pub preempt_count: u32,
    /// Sum of inter-token gaps and gap count (for mean TBT).
    pub tbt_sum: f64,
    pub tbt_n: u32,
    /// Tokens offloaded to CPU memory while preempted (0 for offload-free).
    pub swapped_tokens: u32,
    /// KV tokens dropped by an offload-free preemption that must be
    /// recomputed (as prefill work) before decoding can resume.
    pub lost_kv: u32,
    /// `generated` value when the current GT span was scheduled; the
    /// host's write head within its span is `generated - gt_span_base`.
    pub gt_span_base: u32,
    /// Length (tokens) of the currently allocated GT span (exact-alloc).
    pub gt_span_len: u32,
}

impl ReqRec {
    pub fn new(req: Request) -> Self {
        ReqRec {
            req,
            phase: Phase::PtQueued,
            prompt_done: 0,
            generated: 0,
            predicted_rl: 0,
            predicted_base: 0,
            predicted_raw: 0,
            predicted_initial: 0,
            kvc_held: 0,
            first_token_at: None,
            exec_start_at: None,
            done_at: None,
            last_emit_at: None,
            preempt_total: 0.0,
            preempted_since: None,
            preempt_count: 0,
            tbt_sum: 0.0,
            tbt_n: 0,
            swapped_tokens: 0,
            lost_kv: 0,
            gt_span_base: 0,
            gt_span_len: 0,
        }
    }

    /// Tokens of context this request has in the KVC *right now* (prompt
    /// processed so far + tokens generated). This is what attention reads.
    pub fn context_tokens(&self) -> u32 {
        self.prompt_done + self.generated
    }

    /// Remaining predicted response tokens under the current prediction.
    pub fn predicted_remaining(&self) -> u32 {
        let gen_since = self.generated.saturating_sub(self.predicted_base);
        self.predicted_rl.saturating_sub(gen_since)
    }

    /// True remaining tokens (oracle view; used by the engine to decide
    /// actual completion, never exposed to schedulers except Oracle mode).
    pub fn true_remaining(&self) -> u32 {
        self.req.true_rl.saturating_sub(self.generated)
    }

    pub fn is_done(&self) -> bool {
        self.phase == Phase::Done
    }

    pub fn jct(&self) -> Option<f64> {
        self.done_at.map(|d| d - self.req.arrival)
    }

    /// Mean time-between-tokens over the emitted response.
    pub fn mean_tbt(&self) -> Option<f64> {
        if self.tbt_n == 0 {
            None
        } else {
            Some(self.tbt_sum / self.tbt_n as f64)
        }
    }

    pub fn met_slo(&self) -> bool {
        match self.done_at {
            Some(d) => d <= self.req.deadline,
            None => false,
        }
    }
}

/// One unit of work inside an iteration batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchTask {
    /// Process `chunk` prompt tokens of request `id` (chunked prefill:
    /// Sarathi/FastGen split long prompts; others use chunk == prompt_len).
    Prefill { id: ReqId, chunk: u32 },
    /// Generate one token for request `id`.
    Decode { id: ReqId },
}

impl BatchTask {
    pub fn id(&self) -> ReqId {
        match self {
            BatchTask::Prefill { id, .. } | BatchTask::Decode { id } => *id,
        }
    }

    /// Contribution to the forward size (token count) of the iteration.
    pub fn forward_tokens(&self) -> u32 {
        match self {
            BatchTask::Prefill { chunk, .. } => *chunk,
            BatchTask::Decode { .. } => 1,
        }
    }
}

/// How a preemption treats the victim's KV data (config::PreemptMode is the
/// *policy*; this is the mechanism chosen for one specific preemption).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptKind {
    /// Swap KV to CPU memory (vLLM): swap-in cost charged on resume.
    Swap,
    /// Drop KV; recompute later as prefill work.
    DropRecompute,
}

/// The typed plan a scheduler returns for one iteration: the tasks to
/// execute plus a record of the preemptions and guest evictions it
/// decided through `IterCtx`. Allocation intents are tallied by the
/// allocator itself and folded into metrics by `World::apply_plan` — the
/// only code that executes a plan against the KVC.
#[derive(Debug, Clone, Default)]
pub struct BatchPlan {
    pub tasks: Vec<BatchTask>,
    /// Extra time charged to this iteration beyond the compute cost
    /// (KV swap-in from CPU memory, KV transfer, ...).
    pub extra_time: f64,
    /// Requests this plan preempted (hard: lease released), with the
    /// mechanism used per victim.
    pub preempted: Vec<(ReqId, PreemptKind)>,
    /// Pipelined guests whose borrowed space this plan revoked.
    pub evicted: Vec<ReqId>,
}

impl BatchPlan {
    /// Plan containing just `tasks` (test / driver convenience).
    pub fn of(tasks: Vec<BatchTask>) -> Self {
        BatchPlan { tasks, ..Default::default() }
    }

    /// Empty the plan while keeping buffer capacity (the zero-allocation
    /// reuse path: `IterCtx::take_plan` / `World::recycle_plan`).
    pub fn clear(&mut self) {
        self.tasks.clear();
        self.preempted.clear();
        self.evicted.clear();
        self.extra_time = 0.0;
    }

    pub fn forward_size(&self) -> u32 {
        self.tasks.iter().map(|t| t.forward_tokens()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn decode_count(&self) -> usize {
        self.tasks.iter().filter(|t| matches!(t, BatchTask::Decode { .. })).count()
    }

    pub fn prefill_tokens(&self) -> u32 {
        self.tasks
            .iter()
            .map(|t| match t {
                BatchTask::Prefill { chunk, .. } => *chunk,
                _ => 0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request { id: 0, arrival: 1.0, prompt_len: 10, true_rl: 5, deadline: 9.0 }
    }

    #[test]
    fn rec_context_and_remaining() {
        let mut r = ReqRec::new(req());
        r.predicted_rl = 8;
        r.prompt_done = 10;
        r.generated = 3;
        assert_eq!(r.context_tokens(), 13);
        assert_eq!(r.predicted_remaining(), 5);
        assert_eq!(r.true_remaining(), 2);
    }

    #[test]
    fn repredicted_remaining_uses_base() {
        let mut r = ReqRec::new(req());
        r.generated = 6;
        r.predicted_base = 6; // re-predicted after 6 tokens
        r.predicted_rl = 4; // new prediction: 4 more
        assert_eq!(r.predicted_remaining(), 4);
        r.generated = 9;
        assert_eq!(r.predicted_remaining(), 1);
    }

    #[test]
    fn batch_plan_forward_size() {
        let b = BatchPlan::of(vec![
            BatchTask::Prefill { id: 0, chunk: 128 },
            BatchTask::Decode { id: 1 },
            BatchTask::Decode { id: 2 },
        ]);
        assert_eq!(b.forward_size(), 130);
        assert_eq!(b.decode_count(), 2);
        assert_eq!(b.prefill_tokens(), 128);
        assert!(b.preempted.is_empty() && b.evicted.is_empty());
    }

    #[test]
    fn slo_met_only_when_done_before_deadline() {
        let mut r = ReqRec::new(req());
        assert!(!r.met_slo());
        r.done_at = Some(8.0);
        assert!(r.met_slo());
        r.done_at = Some(9.5);
        assert!(!r.met_slo());
    }
}
