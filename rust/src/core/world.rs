//! The simulation world: request records, arrival feed, the KVC
//! allocator, metrics, and the shared iteration-execution semantics every
//! scheduler drives.
//!
//! Division of labour (the policy/mechanism split):
//!  * **Schedulers** decide *what* runs. They see the world through an
//!    [`IterCtx`]: read-only state views, the previous iteration's
//!    [`Events`], typed request-state mutators, and an
//!    `&mut dyn Allocator` — the only path to KVC capacity. They return a
//!    [`BatchPlan`].
//!  * **[`World::apply_plan`]** executes the plan's physics: token
//!    writes, completions, TBT/JCT timestamps, KVC-pipelining overrun
//!    eviction, guest transfer when a host finishes early, and the
//!    per-iteration [`crate::kvc::AllocTally`] fold into metrics. It is
//!    the only code that executes a plan against the pool; schedulers
//!    never touch block accounting directly.

use std::cell::Cell;
use std::collections::VecDeque;

use super::{BatchPlan, BatchTask, Phase, PreemptKind, ReqId, ReqRec, Request, Time};
use crate::config::SystemConfig;
use crate::kvc::Allocator;
use crate::metrics::Collector;
use crate::predictor::Predictor;
use crate::reliability::headroom::{Headroom, HeadroomConfig};
use crate::telemetry::reqlog::RequestLog;
use crate::telemetry::span::{Outcome, SkipReason, SpanState};
use crate::telemetry::trace::{TraceConfig, TraceDoc, TraceRecorder};
use crate::telemetry::SimMetrics;
use crate::trace::TraceItem;

/// Events produced by the last executed iteration, consumed by the
/// scheduler at the next planning step (delivered in [`IterCtx::events`]).
#[derive(Debug, Default, Clone)]
pub struct Events {
    /// PTs whose prompt finished this iteration (they emitted their first
    /// token and are now GTs awaiting decode service).
    pub finished_prefill: Vec<ReqId>,
    /// Requests that truly completed (KVC already released).
    pub completed: Vec<ReqId>,
    /// GTs that reached their predicted RL but are NOT done —
    /// under-provisioned; the scheduler must rescue or preempt them.
    pub reached_prediction: Vec<ReqId>,
    /// Guests force-evicted because their host's write head caught up
    /// (already preempted offload-free by the world).
    pub evicted_guests: Vec<ReqId>,
    /// Requests whose recompute (lost KV) finished this iteration and can
    /// decode again.
    pub recompute_done: Vec<ReqId>,
}

impl Events {
    fn clear(&mut self) {
        self.finished_prefill.clear();
        self.completed.clear();
        self.reached_prediction.clear();
        self.evicted_guests.clear();
        self.recompute_done.clear();
    }
}

/// Build one request record (clamped lengths, SLO deadline from the cfg
/// formula, padded RL prediction) plus its prediction-ready time. The
/// single construction path for both seeded (`World::new`) and
/// dynamically injected (`World::push_item`) requests — the two
/// populations must never diverge.
fn build_rec(
    cfg: &SystemConfig,
    predictor: &mut dyn Predictor,
    pad_ratio: f64,
    id: ReqId,
    it: &TraceItem,
) -> (ReqRec, Time) {
    let true_rl = it.true_rl.max(1);
    let deadline = it.arrival + cfg.slo_budget(true_rl);
    let req = Request {
        id,
        arrival: it.arrival,
        prompt_len: it.prompt_len.max(1),
        true_rl,
        deadline,
    };
    let mut rec = ReqRec::new(req);
    predictor.observe_request(it.arrival, rec.req.prompt_len);
    let raw = predictor.predict_raw(id, true_rl);
    rec.predicted_raw = raw;
    rec.predicted_rl = SystemConfig::pad_with(raw, pad_ratio);
    rec.predicted_initial = rec.predicted_rl;
    (rec, it.arrival + predictor.latency())
}

pub struct World {
    pub cfg: SystemConfig,
    pub clock: Time,
    pub recs: Vec<ReqRec>,
    /// The KVC allocation mechanism (policy chosen via `set_allocator` /
    /// the `sched::by_name` registry). Private: schedulers reach it only
    /// through [`IterCtx::alloc`].
    kvc: Box<dyn Allocator>,
    pub col: Collector,
    /// Arrived requests not yet picked up by the scheduler.
    pub inbox: VecDeque<ReqId>,
    /// Future arrivals, next at the BACK (sorted descending by arrival).
    future: Vec<ReqId>,
    pub events: Events,
    /// Time each request's RL prediction becomes available.
    pub pred_ready: Vec<Time>,
    /// The RL predictor (kept for re-prediction after under-provision,
    /// §3.3.2: the predictor "undergoes continual retraining" and is
    /// re-consulted when a request outruns its prediction).
    predictor: Box<dyn Predictor>,
    /// O(1) request-state index: ids that have arrived and are not Done,
    /// with `active_pos[id]` giving each id's slot in `active`
    /// (usize::MAX = absent). Maintained by `drain_arrivals` /
    /// `complete` / `reject`; lets `apply_plan`'s diagnostics sweep,
    /// `all_done` and admission control skip whole-`recs` scans.
    active: Vec<ReqId>,
    active_pos: Vec<usize>,
    /// Completed (or shed) request count — `all_done`/`n_done` in O(1).
    done_count: usize,
    /// Recycled iteration buffers (steady-state zero-allocation planning):
    /// `spare_events` ping-pongs with `events` through `begin_iter` /
    /// `IterCtx::finish_into`; `spare_plan` is handed out by
    /// `IterCtx::take_plan` and returned via `recycle_plan`.
    spare_events: Events,
    spare_plan: BatchPlan,
    /// Telemetry registry for this world (the shared sim/server metric
    /// vocabulary). Each world owns its own registry and updates it
    /// single-threaded, so every metric value is a pure function of
    /// (config, seed); the fleet merges rendered snapshots in replica-id
    /// order at finalize.
    tel: SimMetrics,
    /// Optional request-lifecycle span recorder (`--trace-out`). Owned
    /// per world and updated single-threaded like `tel`, so the trace
    /// bytes stay a pure function of (config, seed); the fleet merges
    /// finished [`TraceDoc`]s in replica-id order. `None` costs one
    /// branch per hook.
    tracer: Option<Box<TraceRecorder>>,
    /// Optional bounded request log (`--log-out`): the same structured
    /// event ring the HTTP server keeps, fed from the sim lifecycle
    /// hooks.
    reqlog: Option<RequestLog>,
    /// Adaptive headroom controller (`cfg.headroom == "adaptive"`): the
    /// online misprediction tracker steering the padding ratio and the
    /// per-iteration eviction budget. `None` (the `"static"` default)
    /// keeps the sweet-spot constant and an unbounded budget — runs are
    /// then bit-identical to pre-headroom builds.
    headroom: Option<Headroom>,
    /// Predictor-accuracy counts already exported to `tel` —
    /// `(close, off)` — so the monotone `predictions_total` top-up can
    /// run from `&self` (metrics render) and `&mut self` (apply_plan)
    /// without double counting.
    acc_exported: Cell<(u64, u64)>,
}

impl World {
    /// Build a world from trace items; predictions (padded) are assigned
    /// via `predictor` and deadlines via the cfg SLO formula. The default
    /// allocator is `exact`; install the scheduler's pairing with
    /// [`World::set_allocator`] (the harness does this from the registry).
    pub fn new(cfg: SystemConfig, items: &[TraceItem], mut predictor: Box<dyn Predictor>) -> Self {
        let hcfg = HeadroomConfig::parse(&cfg.headroom)
            .unwrap_or_else(|| panic!("unknown headroom mode '{}'", cfg.headroom));
        let headroom =
            hcfg.is_active().then(|| Headroom::new(hcfg, cfg.padding_ratio));
        let pad0 = headroom.as_ref().map_or(cfg.padding_ratio, |h| h.pad());
        let mut recs = Vec::with_capacity(items.len());
        let mut pred_ready = Vec::with_capacity(items.len());
        for (id, it) in items.iter().enumerate() {
            let (rec, ready) = build_rec(&cfg, predictor.as_mut(), pad0, id, it);
            recs.push(rec);
            pred_ready.push(ready);
        }
        let mut future: Vec<ReqId> = (0..recs.len()).collect();
        // NaN-safe total order (arrivals are finite in practice, but a
        // poisoned trace must not panic the sort).
        future.sort_by(|a, b| recs[*b].req.arrival.total_cmp(&recs[*a].req.arrival));
        let kvc = crate::kvc::by_name(
            "exact",
            cfg.kvc_tokens(),
            cfg.block_size,
            cfg.reserve_tokens(),
        )
        .expect("default allocator");
        let n = recs.len();
        World {
            cfg,
            clock: 0.0,
            recs,
            kvc,
            col: Collector::new(),
            inbox: VecDeque::new(),
            future,
            events: Events::default(),
            pred_ready,
            predictor,
            active: Vec::with_capacity(n.min(4096)),
            active_pos: vec![usize::MAX; n],
            done_count: 0,
            spare_events: Events::default(),
            spare_plan: BatchPlan::default(),
            tel: SimMetrics::new(),
            tracer: None,
            reqlog: None,
            headroom,
            acc_exported: Cell::new((0, 0)),
        }
    }

    /// This world's telemetry bundle (pre-registered metric handles).
    pub fn telemetry(&self) -> &SimMetrics {
        &self.tel
    }

    /// Canonical Prometheus text for this world's registry. Syncs the
    /// predictor-accuracy counters first so `predictions_total` is
    /// current at any scrape point, not just after an iteration.
    pub fn metrics_text(&self) -> String {
        self.sync_prediction_counters();
        self.tel.render()
    }

    /// The padding ratio in force right now: the adaptive controller's
    /// steered value, or the configured static sweet spot.
    pub fn current_pad(&self) -> f64 {
        self.headroom.as_ref().map_or(self.cfg.padding_ratio, |h| h.pad())
    }

    /// The adaptive headroom controller, if enabled.
    pub fn headroom(&self) -> Option<&Headroom> {
        self.headroom.as_ref()
    }

    /// The predictor's lifetime accuracy accounting `(n_pred, n_close)`
    /// — includes re-predictions and (when a fault wrapper is installed)
    /// outage fallbacks.
    pub fn predictor_accuracy(&self) -> (u64, u64) {
        self.predictor.accuracy()
    }

    /// Top up `econoserve_predictions_total{verdict}` from the
    /// predictor's own monotone accounting. Counters have interior
    /// mutability, so this works from `&self`; the cursor cell prevents
    /// double export.
    fn sync_prediction_counters(&self) {
        let (n_pred, n_close) = self.predictor.accuracy();
        let n_off = n_pred - n_close;
        let (close_seen, off_seen) = self.acc_exported.get();
        self.tel.pred_close.add(n_close - close_seen);
        self.tel.pred_off.add(n_off - off_seen);
        self.acc_exported.set((n_close, n_off));
    }

    /// Turn on request-lifecycle span tracing for this world. `pid` tags
    /// every event (fleet: the replica id; single worlds: 0); `system`
    /// keys the skip-reason aggregates (`sched+alloc`). Already-seeded
    /// requests are registered at their arrival time, so enabling right
    /// after `World::new` traces the whole population.
    pub fn enable_tracing(&mut self, cfg: TraceConfig, pid: u32, system: &str) {
        let mut tr = Box::new(TraceRecorder::new(cfg, pid, system));
        for rec in &self.recs {
            if !rec.is_done() {
                let r = &rec.req;
                tr.on_submit(r.id, r.arrival, r.arrival, r.prompt_len as u64, r.true_rl as u64);
            }
        }
        self.tracer = Some(tr);
    }

    /// The active span recorder, if tracing is enabled.
    pub fn tracer(&self) -> Option<&TraceRecorder> {
        self.tracer.as_deref()
    }

    /// Detach the recorder and finish it into its mergeable document.
    pub fn take_trace(&mut self) -> Option<TraceDoc> {
        self.tracer.take().map(|tr| tr.finish())
    }

    /// Turn on the sim-side bounded request log (`cap` = ring capacity,
    /// 0 = count-only), fed from the same lifecycle hooks as tracing.
    pub fn enable_reqlog(&mut self, cap: usize) {
        let log = RequestLog::with_capacity(cap);
        for rec in &self.recs {
            if !rec.is_done() {
                let r = &rec.req;
                log.log(
                    r.id as u64,
                    r.arrival,
                    "submit",
                    format!("prompt={} true_rl={}", r.prompt_len, r.true_rl),
                );
            }
        }
        self.reqlog = Some(log);
    }

    /// The sim-side request log, if enabled.
    pub fn reqlog(&self) -> Option<&RequestLog> {
        self.reqlog.as_ref()
    }

    // ------------------------------------------------------------------
    // Tracing hooks (each is one branch when tracing is off)
    // ------------------------------------------------------------------

    fn trace_transition(&mut self, id: ReqId, t: Time, next: SpanState) {
        if let Some(tr) = self.tracer.as_mut() {
            tr.transition(id, t, next);
        }
    }

    fn trace_terminal(&mut self, id: ReqId, t: Time, outcome: Outcome) {
        if let Some(tr) = self.tracer.as_mut() {
            tr.terminal(id, t, outcome);
        }
        if let Some(log) = self.reqlog.as_ref() {
            log.log(id as u64, t, outcome.as_str(), String::new());
        }
    }

    pub(crate) fn trace_skip(&mut self, id: ReqId, t: Time, reason: SkipReason) {
        if let Some(tr) = self.tracer.as_mut() {
            tr.skip(id, t, reason);
        }
    }

    fn trace_lease(&mut self, id: ReqId, t: Time, name: &'static str) {
        if let Some(tr) = self.tracer.as_mut() {
            tr.lease_event(id, t, name);
        }
    }

    /// Is span tracing enabled (drives the skip-classification pass)?
    pub fn tracing_enabled(&self) -> bool {
        self.tracer.is_some()
    }

    /// Add an arrived request to the active index (idempotent).
    fn index_activate(&mut self, id: ReqId) {
        if self.active_pos[id] == usize::MAX {
            self.active_pos[id] = self.active.len();
            self.active.push(id);
        }
    }

    /// Remove a finished request from the active index (idempotent).
    fn index_deactivate(&mut self, id: ReqId) {
        let pos = self.active_pos[id];
        if pos == usize::MAX {
            return;
        }
        self.active_pos[id] = usize::MAX;
        let last = self.active.pop().expect("active list empty with live pos");
        if pos < self.active.len() {
            self.active[pos] = last;
            self.active_pos[last] = pos;
        }
    }

    /// Arrived-and-unfinished request count (O(1)); the same in-flight
    /// definition admission control uses.
    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// Arrived-and-unfinished request ids, in no particular order.
    pub fn active_ids(&self) -> &[ReqId] {
        &self.active
    }

    /// Swap in the KVC allocation policy by registry name (`max`, `block`,
    /// `exact`, `pipelined-*`). Must happen before any allocation.
    pub fn set_allocator(&mut self, name: &str) {
        assert_eq!(
            self.kvc.total_allocated(),
            0,
            "allocator swap after allocations were made"
        );
        self.kvc = crate::kvc::by_name(
            name,
            self.cfg.kvc_tokens(),
            self.cfg.block_size,
            self.cfg.reserve_tokens(),
        )
        .unwrap_or_else(|| panic!("unknown allocator '{name}'"));
    }

    /// Read-only view of the KVC allocator (metrics, figures, tests).
    pub fn kvc(&self) -> &dyn Allocator {
        self.kvc.as_ref()
    }

    /// Mutable allocator access for drivers and tests. Schedulers never
    /// see a `&mut World`, so this does not leak mechanism to policy.
    pub fn kvc_mut(&mut self) -> &mut dyn Allocator {
        self.kvc.as_mut()
    }

    /// Open the planning context for one iteration: consumes the previous
    /// iteration's events and exposes the typed scheduler contract.
    /// Usually called through `sched::plan_iteration`.
    ///
    /// The events buffer handed to the context is swapped against a spare
    /// so that, once [`IterCtx::finish_into`] returns it, iteration N+1
    /// reuses iteration N's vector capacity (zero-allocation steady
    /// state).
    pub fn begin_iter(&mut self) -> IterCtx<'_> {
        let spare = std::mem::take(&mut self.spare_events);
        let events = std::mem::replace(&mut self.events, spare);
        let failures_at = self.kvc.stats().failures;
        IterCtx {
            w: self,
            events,
            preempted: Vec::new(),
            evicted: Vec::new(),
            failures_at,
            noted_skips: Vec::new(),
        }
    }

    /// Return an executed plan's buffers for reuse by the next
    /// [`IterCtx::take_plan`]. Optional: drivers that drop plans instead
    /// just allocate fresh ones.
    pub fn recycle_plan(&mut self, plan: BatchPlan) {
        self.spare_plan = plan;
    }

    /// Re-predict the REMAINING response length of an under-provisioned
    /// request (padded + quantized like the initial prediction). Updates
    /// the record and returns the new remaining prediction.
    ///
    /// A re-prediction only happens because the previous prediction was
    /// outrun, so this is also a misprediction-tracker feed point: the
    /// previous raw prediction's realized (so far) signed log error goes
    /// into the headroom ring with an under-provision mark. Together with
    /// the completion-time feed this double-weights sustained
    /// misprediction — deliberate, so the tiered fallback escalates
    /// faster than the completion rate alone would allow.
    pub fn re_predict(&mut self, id: ReqId) -> u32 {
        let rec = &self.recs[id];
        let true_remaining = rec.true_remaining().max(1);
        if let Some(h) = self.headroom.as_mut() {
            // Tokens the previous raw prediction actually had to cover:
            // what was generated since its base plus what is still left.
            let actual = (rec.req.true_rl.saturating_sub(rec.predicted_base)).max(1);
            let err = (actual as f64 / rec.predicted_raw.max(1) as f64).ln();
            h.observe(err, true);
        }
        let pad = self.current_pad();
        let rec = &self.recs[id];
        self.predictor.observe_request(self.clock, rec.req.prompt_len);
        let raw = self.predictor.predict_raw(id, true_remaining);
        let padded = SystemConfig::pad_with(raw, pad);
        let rec = &mut self.recs[id];
        rec.predicted_base = rec.generated;
        rec.predicted_raw = raw;
        rec.predicted_rl = padded;
        padded
    }

    /// Append a request that arrives *dynamically* — the fleet layer's
    /// front door routes each arrival to a replica at its arrival time,
    /// so replica worlds grow during the run instead of being seeded with
    /// a pre-sharded trace. Assigns the next `ReqId`, runs the world's
    /// predictor, derives the SLO deadline from the config, and files the
    /// request into the inbox (already due) or the future-arrivals feed.
    pub fn push_item(&mut self, it: &TraceItem) -> ReqId {
        let id = self.recs.len();
        let pad = self.current_pad();
        let (rec, ready) = build_rec(&self.cfg, self.predictor.as_mut(), pad, id, it);
        self.recs.push(rec);
        self.pred_ready.push(ready);
        self.active_pos.push(usize::MAX);
        if let Some(tr) = self.tracer.as_mut() {
            // Register at the ORIGINAL arrival: retry/hedge copies keep
            // their logical request's content triple, so the sampling
            // decision follows the request across replicas.
            let r = &self.recs[id].req;
            tr.on_submit(id, r.arrival, r.arrival, r.prompt_len as u64, r.true_rl as u64);
        }
        if let Some(log) = self.reqlog.as_ref() {
            let r = &self.recs[id].req;
            log.log(
                id as u64,
                r.arrival,
                "submit",
                format!("prompt={} true_rl={}", r.prompt_len, r.true_rl),
            );
        }
        if it.arrival <= self.clock {
            self.inbox.push_back(id);
            self.index_activate(id);
        } else {
            // Keep `future` sorted descending by arrival (next at the
            // back); equal arrivals stay FIFO.
            let recs = &self.recs;
            let pos = self
                .future
                .partition_point(|&x| recs[x].req.arrival.total_cmp(&it.arrival).is_gt());
            self.future.insert(pos, id);
        }
        id
    }

    /// Move arrivals with `arrival <= clock` into the inbox. Returns how
    /// many arrived.
    pub fn drain_arrivals(&mut self) -> usize {
        let mut n = 0;
        while let Some(&id) = self.future.last() {
            if self.recs[id].req.arrival <= self.clock {
                self.future.pop();
                self.inbox.push_back(id);
                self.index_activate(id);
                n += 1;
            } else {
                break;
            }
        }
        n
    }

    /// Next future arrival time (for idle fast-forward).
    pub fn next_arrival(&self) -> Option<Time> {
        self.future.last().map(|id| self.recs[*id].req.arrival)
    }

    /// Load-shed a queued request before it receives any service (the
    /// admission-control front door of `coordinator::run_admitted`). The
    /// request leaves the system immediately; `done_at` stays `None`, so
    /// it is excluded from latency stats and counts as an SLO miss.
    pub fn reject(&mut self, id: ReqId) {
        let rec = &mut self.recs[id];
        debug_assert!(
            matches!(rec.phase, Phase::PtQueued),
            "reject() is only valid before any service"
        );
        rec.phase = Phase::Done;
        self.done_count += 1;
        self.index_deactivate(id);
        self.tel.requests_rejected.inc();
        let now = self.clock;
        self.trace_terminal(id, now, Outcome::Rejected);
    }

    /// Kill this world (fleet-layer replica crash): every request that
    /// has not completed — queued, running, or not yet arrived — is
    /// marked lost-to-crash (phase `Done`, `done_at` stays `None` so it
    /// counts as an SLO miss unless re-routed) and returned as a fresh
    /// `TraceItem` carrying its ORIGINAL arrival time. Re-routing the
    /// item through [`World::push_item`] on a surviving replica
    /// re-derives the same SLO deadline from that arrival, so a
    /// re-route is idempotent with respect to the request's SLO. After
    /// this call `all_done()` is true; the caller must never advance or
    /// inject into this world again.
    pub fn crash_all(&mut self) -> Vec<TraceItem> {
        let mut victims: Vec<ReqId> = self.active.to_vec();
        victims.extend(self.future.drain(..));
        // Id order is injection order — the fleet routes arrivals in
        // timestamp order, so the re-route feed stays deterministic.
        victims.sort_unstable();
        let mut items = Vec::with_capacity(victims.len());
        let now = self.clock;
        for id in victims {
            self.kvc.release(id);
            let rec = &mut self.recs[id];
            rec.phase = Phase::Done;
            rec.kvc_held = 0;
            self.done_count += 1;
            self.index_deactivate(id);
            // Not-yet-arrived victims close at their (future) arrival:
            // an empty lifecycle, not a negative span.
            self.trace_terminal(id, now, Outcome::Lost);
            let req = &self.recs[id].req;
            items.push(TraceItem {
                arrival: req.arrival,
                prompt_len: req.prompt_len,
                true_rl: req.true_rl,
            });
        }
        self.inbox.clear();
        debug_assert!(self.all_done());
        items
    }

    /// Deadline-aware abort sweep (the `reliability` guardrail): cancel
    /// every decode-phase request whose minimum remaining service time —
    /// one calibrated `t_g` iteration per remaining token, the engine's
    /// floor — overshoots its SLO deadline by more than `slack` seconds.
    /// Such a request converts KVC into a certain SLO miss with every
    /// further iteration; releasing the cache to queued work is the
    /// paper's timely-release insight applied to hopeless work. With
    /// `oracle` the bound uses the true remaining length (provable);
    /// otherwise the current prediction (best-effort — `slack` absorbs
    /// prediction error).
    ///
    /// Scope: only `Phase::Decoding` requests, and never one with a
    /// pending `recompute_done` event — every scheduler sweeps `Done`
    /// ids out of its running set at the top of `plan()`, so an abort
    /// between iterations is exactly as safe as an exogenous
    /// `push_item`, but a queued-phase abort could leave a stale id in
    /// scheduler-internal queues. Victims are processed in id order;
    /// like [`World::crash_all`], aborted requests keep `done_at = None`
    /// (SLO miss unless retried elsewhere) and come back as re-routable
    /// `TraceItem`s with their ORIGINAL arrival.
    pub fn abort_hopeless(&mut self, oracle: bool, slack: f64) -> Vec<TraceItem> {
        let mut victims: Vec<ReqId> = Vec::new();
        for &id in &self.active {
            let rec = &self.recs[id];
            if rec.phase != Phase::Decoding {
                continue;
            }
            let remaining =
                if oracle { rec.true_remaining() } else { rec.predicted_remaining() };
            if self.clock + remaining as f64 * self.cfg.t_g > rec.req.deadline + slack
                && !self.events.recompute_done.contains(&id)
            {
                victims.push(id);
            }
        }
        victims.sort_unstable();
        let mut items = Vec::with_capacity(victims.len());
        for id in victims {
            items.push(self.abort_one(id));
        }
        items
    }

    /// Cancel one in-service request: the guest/host unwinding of
    /// `complete` (re-home or evict live guests, release the lease) with
    /// a cancellation terminal instead of a completion — `done_at` stays
    /// `None` and the telemetry counts it under
    /// `requests_total{outcome="cancelled"}`.
    fn abort_one(&mut self, id: ReqId) -> TraceItem {
        let guests = self.kvc.detach_host(id);
        for g in guests {
            if self.recs[g].is_done() {
                continue;
            }
            let need = self.kvc.guest_written(g) + self.recs[g].predicted_remaining() + 1;
            if !self.kvc.adopt(g, need).ok() {
                self.evict_guest(g);
            }
        }
        self.kvc.release(id);
        let rec = &mut self.recs[id];
        rec.phase = Phase::Done;
        rec.kvc_held = 0;
        self.done_count += 1;
        self.index_deactivate(id);
        self.tel.requests_cancelled.inc();
        let now = self.clock;
        self.trace_lease(id, now, "kvc_release");
        self.trace_terminal(id, now, Outcome::Cancelled);
        let req = &self.recs[id].req;
        TraceItem { arrival: req.arrival, prompt_len: req.prompt_len, true_rl: req.true_rl }
    }

    /// Void a recorded completion: the request stays terminal (`Done`)
    /// but loses its completion time, so summaries no longer count it as
    /// done or SLO-satisfying. The fleet's hedging guardrail needs this
    /// for the race where BOTH copies of a hedged request finish within
    /// one advance window — the deterministic winner keeps its record,
    /// the loser is voided. Telemetry counters already incremented for
    /// the voided completion are monotonic history; such races are
    /// exported as `econoserve_hedges_total{outcome="duplicate"}` and
    /// the reconciliation tests account for them exactly.
    pub fn void_completion(&mut self, id: ReqId) {
        debug_assert!(
            self.recs[id].done_at.is_some(),
            "void_completion requires a recorded completion"
        );
        self.recs[id].done_at = None;
    }

    /// Best-effort cancellation of a single request (hedging's
    /// loser-copy teardown). Succeeds only in the two phases where an
    /// exogenous cancel provably cannot leave a stale id inside a
    /// scheduler's internal queues:
    ///
    /// - still queued in the inbox (`PtQueued`, never admitted): the id
    ///   is removed from the inbox and terminated without touching the
    ///   KVC (nothing was ever allocated);
    /// - decoding with no pending `recompute_done` event: the same
    ///   between-iterations teardown as [`World::abort_hopeless`].
    ///
    /// Returns `false` in any other phase (prefilling, GT-queued,
    /// preempted, already done); the caller retries on a later
    /// iteration, when the request has moved to a safe phase or
    /// completed on its own.
    pub fn cancel_if_safe(&mut self, id: ReqId) -> bool {
        if self.recs[id].is_done() {
            return false;
        }
        match self.recs[id].phase {
            Phase::PtQueued => {
                let Some(pos) = self.inbox.iter().position(|&x| x == id) else {
                    // Admitted this iteration but the phase flip lands
                    // with the plan's effects; not safe yet.
                    return false;
                };
                self.inbox.remove(pos);
                let rec = &mut self.recs[id];
                rec.phase = Phase::Done;
                self.done_count += 1;
                self.index_deactivate(id);
                self.tel.requests_cancelled.inc();
                let now = self.clock;
                self.trace_terminal(id, now, Outcome::Cancelled);
                true
            }
            Phase::Decoding if !self.events.recompute_done.contains(&id) => {
                self.abort_one(id);
                true
            }
            _ => false,
        }
    }

    /// O(1): every request has arrived and completed (or was shed).
    pub fn all_done(&self) -> bool {
        self.done_count == self.recs.len()
    }

    /// O(1) count of completed (or shed) requests.
    pub fn n_done(&self) -> usize {
        self.done_count
    }

    // ------------------------------------------------------------------
    // Request-state mechanism (reached through IterCtx during planning)
    // ------------------------------------------------------------------

    /// Mark the start of service (first time any chunk of the request is
    /// put in a batch).
    pub fn mark_exec_start(&mut self, id: ReqId) {
        let now = self.clock;
        let rec = &mut self.recs[id];
        if rec.exec_start_at.is_none() {
            rec.exec_start_at = Some(now);
        }
        if let Some(since) = rec.preempted_since.take() {
            rec.preempt_total += now - since;
        }
    }

    /// Preempt a running/queued GT. Swap releases its lease and records
    /// swapped bytes; DropRecompute releases and queues recompute work.
    /// Guests orphaned by the release are evicted offload-free and
    /// returned so the caller (IterCtx records them into the plan) can
    /// pull them out of its running set.
    pub fn preempt(&mut self, id: ReqId, kind: PreemptKind) -> Vec<ReqId> {
        let now = self.clock;
        let rel = self.kvc.release(id);
        let mut orphans = Vec::new();
        for g in rel.orphans {
            if !self.recs[g].is_done() {
                self.orphan_evict(g);
                orphans.push(g);
            }
        }
        let lost = rel.written + rel.guest_written;
        let rec = &mut self.recs[id];
        rec.phase = Phase::Preempted;
        rec.preempted_since.get_or_insert(now);
        rec.preempt_count += 1;
        rec.kvc_held = 0;
        match kind {
            PreemptKind::Swap => {
                rec.swapped_tokens = lost;
                self.col.swap_preemptions += 1;
            }
            PreemptKind::DropRecompute => {
                rec.lost_kv = lost;
            }
        }
        self.col.preemptions += 1;
        self.tel.preemptions.inc();
        self.trace_lease(id, now, "kvc_release");
        self.trace_transition(id, now, SpanState::Preempted);
        orphans
    }

    /// A guest whose host vanished mid-plan: same mechanics as
    /// [`World::evict_guest`] but no event fires (apply_plan clears
    /// events before execution); the caller is responsible for surfacing
    /// the eviction — `IterCtx::preempt` records it into the plan's
    /// eviction list.
    fn orphan_evict(&mut self, g: ReqId) {
        self.evict_guest_core(g);
    }

    /// Swap-in cost (seconds) for a swapped-out request (vLLM restore).
    pub fn swap_in_cost(&self, id: ReqId) -> f64 {
        let bytes =
            self.recs[id].swapped_tokens as f64 * self.cfg.profile.kv_bytes_per_token() as f64;
        bytes / self.cfg.pcie_bw
    }

    /// KVC tokens a *queued* task currently occupies (Fig 6 / the Ordering
    /// method's second factor): processed prompt chunks + generated tokens
    /// still resident (not lost/swapped).
    pub fn occupied_kvc(&self, id: ReqId) -> u32 {
        self.kvc.occupied(id)
    }

    // ------------------------------------------------------------------
    // Plan execution (shared physics)
    // ------------------------------------------------------------------

    /// Execute `plan` as one iteration lasting `dur` seconds with the
    /// given engine-computed GPU utilization. Applies token writes and
    /// completions, sweeps pipelining overruns, folds the allocator's
    /// per-iteration outcome tally into metrics, and populates
    /// `self.events` for the next planning step.
    pub fn apply_plan(&mut self, plan: &BatchPlan, dur: f64, gpu_util: f64) {
        self.events.clear();
        let t0 = self.clock;
        let end = self.clock + dur;
        let mut prefill_tokens = 0u64;
        let mut decode_tokens = 0u64;
        let mut prefill_n = 0u64;
        let mut decode_n = 0u64;

        // Batch membership spans: every task's request enters its
        // prefill/decode segment at iteration start (closed at `end` by
        // the requeue pass below, or by its terminal hook).
        if self.tracer.is_some() {
            for task in &plan.tasks {
                let state = match *task {
                    BatchTask::Prefill { .. } => SpanState::Prefill,
                    BatchTask::Decode { .. } => SpanState::Decode,
                };
                self.trace_transition(task.id(), t0, state);
            }
        }

        for task in &plan.tasks {
            match *task {
                BatchTask::Prefill { id, chunk } => {
                    debug_assert!(chunk > 0);
                    prefill_tokens += chunk as u64;
                    prefill_n += 1;
                    if self.recs[id].lost_kv > 0 {
                        // Recompute pass for offload-free-preempted KV.
                        let applied = chunk.min(self.recs[id].lost_kv);
                        self.recs[id].lost_kv -= applied;
                        self.write_kv(id, applied);
                        if self.recs[id].lost_kv == 0 {
                            self.events.recompute_done.push(id);
                            self.recs[id].phase = Phase::Decoding;
                        }
                        continue;
                    }
                    let applied = {
                        let rec = &mut self.recs[id];
                        rec.phase = Phase::Prefilling;
                        let applied = chunk.min(rec.req.prompt_len - rec.prompt_done);
                        debug_assert_eq!(applied, chunk, "prefill chunk beyond prompt");
                        rec.prompt_done += applied;
                        applied
                    };
                    self.write_kv(id, applied);
                    let finished = {
                        let rec = &mut self.recs[id];
                        if rec.prompt_done == rec.req.prompt_len {
                            // PT emits the first response token (ORCA flow).
                            rec.generated = 1;
                            rec.first_token_at = Some(end);
                            rec.last_emit_at = Some(end);
                            true
                        } else {
                            false
                        }
                    };
                    if finished {
                        if self.recs[id].generated >= self.recs[id].req.true_rl {
                            self.complete(id, end);
                        } else {
                            self.recs[id].phase = Phase::GtQueued;
                            self.events.finished_prefill.push(id);
                        }
                    }
                }
                BatchTask::Decode { id } => {
                    // Write the KV of the previously generated token, then
                    // produce the next one.
                    decode_tokens += 1;
                    decode_n += 1;
                    self.write_kv(id, 1);
                    let done = {
                        let rec = &mut self.recs[id];
                        rec.phase = Phase::Decoding;
                        rec.generated += 1;
                        if let Some(last) = rec.last_emit_at {
                            rec.tbt_sum += end - last;
                            rec.tbt_n += 1;
                        }
                        rec.last_emit_at = Some(end);
                        if rec.first_token_at.is_none() {
                            rec.first_token_at = Some(end);
                        }
                        rec.generated >= rec.req.true_rl
                    };
                    if done {
                        self.complete(id, end);
                    } else if self.recs[id].predicted_remaining() == 0 {
                        self.events.reached_prediction.push(id);
                    }
                }
            }
        }

        // Host write-head vs guest overrun sweep. Runs after all tasks so
        // an eviction decision cannot be clobbered by the guest's own
        // decode task later in the same batch.
        //
        // Eviction-storm containment: with adaptive headroom enabled the
        // sweep evicts at most `eviction_budget()` guests per iteration.
        // `overrun_guests` is a pure query, so a deferred guest simply
        // reappears in the next iteration's sweep (one decode step later;
        // the host writes into already-reserved span space meanwhile), and
        // by then the re-predictions triggered by this iteration's
        // evictions have usually relieved the pressure — backpressure
        // instead of a requeue avalanche.
        let evict_budget =
            self.headroom.as_ref().map_or(u32::MAX, |h| h.eviction_budget());
        let mut evicted_now = 0u32;
        let mut deferred = false;
        for task in &plan.tasks {
            if let BatchTask::Decode { id } = *task {
                if self.recs[id].is_done() {
                    continue;
                }
                let head = self.recs[id].generated - self.recs[id].gt_span_base;
                let over = self.kvc.overrun_guests(id, head);
                for g in over {
                    if evicted_now >= evict_budget {
                        deferred = true;
                        self.trace_lease(g, t0, "kvc_evict_deferred");
                        continue;
                    }
                    evicted_now += 1;
                    self.evict_guest(g);
                }
            }
        }
        self.col.max_iter_evictions = self.col.max_iter_evictions.max(evicted_now as u64);
        if deferred {
            self.col.eviction_storms += 1;
            self.tel.eviction_storms.inc();
        }

        // Close batch membership: survivors leave the batch at `end` and
        // wait (`queued`) until their next iteration; completed requests
        // were closed by their terminal hook and evicted guests by the
        // preemption hook.
        if self.tracer.is_some() {
            for task in &plan.tasks {
                let id = task.id();
                let rec = &self.recs[id];
                if rec.is_done() || rec.phase == Phase::Preempted {
                    continue;
                }
                self.trace_transition(id, end, SpanState::Queued);
            }
        }

        let completed_count = self.events.completed.len();
        self.clock = end;
        // Sparse allocation-breakdown sampling (diagnostics for the KVC
        // economy; cheap: every 32nd iteration, over the ACTIVE index
        // only — future and completed requests hold no KVC and were
        // always skipped by the phase match).
        if self.col.iterations % 32 == 0 {
            let cap = self.kvc.capacity_tokens() as f64;
            let mut run_w = 0u64;
            let mut run_a = 0u64;
            let mut wait_h = 0u64;
            for &id in &self.active {
                let rec = &self.recs[id];
                let alloc = self.kvc.allocated(rec.req.id) as u64;
                let written = self.kvc.written(rec.req.id) as u64;
                match rec.phase {
                    Phase::Decoding => {
                        run_w += written;
                        run_a += alloc;
                    }
                    Phase::Prefilling => {
                        run_w += written;
                        run_a += alloc;
                        // A partially processed (chunked) prompt occupies
                        // KVC while the rest of the prompt waits (Fig 6).
                        if rec.prompt_done > 0 && rec.prompt_done < rec.req.prompt_len {
                            self.col.occ_chunked_pt.push(written as f64);
                        }
                    }
                    Phase::GtQueued | Phase::Preempted => {
                        wait_h += alloc;
                        if written > 0 {
                            if rec.preempt_count == 0 {
                                self.col.occ_new_gt.push(written as f64);
                            } else {
                                self.col.occ_preempted_gt.push(written as f64);
                            }
                        }
                    }
                    _ => {}
                }
            }
            self.col.brk_running_written.add(self.clock, dur, run_w as f64 / cap);
            self.col
                .brk_running_unwritten
                .add(self.clock, dur, run_a.saturating_sub(run_w) as f64 / cap);
            self.col.brk_waiting_held.add(self.clock, dur, wait_h as f64 / cap);
        }
        let kvc_util = self.kvc.utilization();
        let kvc_alloc = self.kvc.allocation_ratio();
        let tally = self.kvc.take_tally();
        self.col.record_alloc_tally(tally);
        self.col.record_iteration(
            self.clock,
            dur,
            plan.forward_size(),
            gpu_util,
            kvc_util,
            kvc_alloc,
            completed_count,
        );
        // Telemetry mirror of the iteration (same values the collector
        // just folded, exported under the shared metric vocabulary).
        self.tel.iterations.inc();
        self.tel.tokens_prefill.add(prefill_tokens);
        self.tel.tokens_decode.add(decode_tokens);
        self.tel.batch_occupancy.observe(plan.tasks.len() as f64);
        self.tel.kvc_utilization.observe(kvc_util);
        self.tel.alloc_granted.add(tally.granted as u64);
        self.tel.alloc_hosted.add(tally.hosted as u64);
        self.tel.alloc_exhausted.add(tally.exhausted as u64);
        self.tel.padding_ratio.set(self.current_pad());
        self.sync_prediction_counters();
        // Scheduler-track iteration record: batch composition plus this
        // iteration's KVC lease tally (`AllocOutcome` grants/hosted
        // placements/exhaustions).
        if let Some(tr) = self.tracer.as_mut() {
            tr.iteration(
                t0,
                end,
                prefill_n,
                decode_n,
                tally.granted as u64,
                tally.hosted as u64,
                tally.exhausted as u64,
            );
        }
        // Queue depth: arrived-and-unfinished requests that were not in
        // this iteration's batch (one task per request in a plan).
        self.tel
            .queue_depth
            .set(self.active.len().saturating_sub(plan.tasks.len()) as f64);
    }

    /// Route a KV write through the allocator (own lease, or borrowed
    /// space for a hosted guest).
    fn write_kv(&mut self, id: ReqId, n: u32) {
        self.kvc.record_write(id, n);
        self.recs[id].kvc_held = self.kvc.occupied(id);
    }

    fn complete(&mut self, id: ReqId, at: Time) {
        // Live direct guests of this host must be re-homed or evicted
        // before the host's blocks are freed.
        let guests = self.kvc.detach_host(id);
        for g in guests {
            if self.recs[g].is_done() {
                continue;
            }
            let need = self.kvc.guest_written(g) + self.recs[g].predicted_remaining() + 1;
            if !self.kvc.adopt(g, need).ok() {
                self.evict_guest(g);
            }
        }
        self.kvc.release(id);
        let rec = &mut self.recs[id];
        rec.phase = Phase::Done;
        rec.done_at = Some(at);
        rec.kvc_held = 0;
        self.done_count += 1;
        self.index_deactivate(id);
        self.events.completed.push(id);
        // Misprediction accounting at the ground-truth moment. The
        // provisioning verdict compares the INITIAL padded prediction to
        // the truth (Fig 5a); the tracker ingests the signed log error of
        // the most recent raw prediction against what it actually had to
        // cover (tokens generated past its base).
        let rec = &self.recs[id];
        let under = rec.predicted_initial < rec.req.true_rl;
        let actual = (rec.req.true_rl.saturating_sub(rec.predicted_base)).max(1);
        let ratio = actual as f64 / rec.predicted_raw.max(1) as f64;
        if under {
            self.tel.pred_under.inc();
        } else {
            self.tel.pred_over.inc();
        }
        self.tel.prediction_error.observe(ratio);
        if let Some(h) = self.headroom.as_mut() {
            h.observe(ratio.ln(), under);
        }
        let rec = &self.recs[id];
        self.tel.requests_done.inc();
        if rec.met_slo() {
            self.tel.slo_hit.inc();
        } else {
            self.tel.slo_miss.inc();
        }
        if let Some(j) = rec.jct() {
            self.tel.request_latency.observe(j);
        }
        if let Some(ft) = rec.first_token_at {
            self.tel.ttft.observe(ft - rec.req.arrival);
        }
        if let Some(t) = rec.mean_tbt() {
            self.tel.tbt.observe(t);
        }
        self.trace_lease(id, at, "kvc_release");
        self.trace_terminal(id, at, Outcome::Done);
    }

    /// Force-evict a hosted guest whose backing disappeared (host head
    /// overrun or host early completion without transfer capacity).
    /// Offload-free: its generated-token KV is dropped for recompute; its
    /// own (prompt) lease is kept.
    fn evict_guest(&mut self, g: ReqId) {
        self.evict_guest_core(g);
        self.events.evicted_guests.push(g);
    }

    /// Shared guest-eviction bookkeeping (event-firing and planning-time
    /// orphan paths must never diverge).
    fn evict_guest_core(&mut self, g: ReqId) {
        let dropped = self.kvc.drop_guest(g);
        let now = self.clock;
        let rec = &mut self.recs[g];
        rec.lost_kv += dropped;
        rec.phase = Phase::Preempted;
        rec.preempted_since.get_or_insert(now);
        rec.preempt_count += 1;
        self.col.preemptions += 1;
        self.col.pipeline_evictions += 1;
        self.tel.preemptions.inc();
        self.trace_lease(g, now, "kvc_evict");
        self.trace_transition(g, now, SpanState::Preempted);
    }
}

/// The typed planning context handed to [`crate::sched::Scheduler::plan`]
/// each iteration: the policy side's ONLY window into the world.
///
///  * **Reads** go through [`IterCtx::world`] (the world's public state —
///    records, clock, config, queues — with the KVC mechanism sealed off).
///  * **Allocation** goes through [`IterCtx::alloc`], the
///    `&mut dyn Allocator` of the installed policy.
///  * **Request-state changes** go through the typed mutators below;
///    hard preemptions and guest drops are recorded and folded into the
///    returned [`BatchPlan`].
pub struct IterCtx<'w> {
    w: &'w mut World,
    /// The previous iteration's outcomes, consumed at context creation
    /// (an empty plan skips `apply_plan`, so events must not linger).
    pub events: Events,
    preempted: Vec<(ReqId, PreemptKind)>,
    evicted: Vec<ReqId>,
    /// Cumulative allocator-failure count at context open; a delta by
    /// plan time means some allocation failed THIS iteration, which is
    /// what classifies skipped queued work as `kvc_exhausted`.
    failures_at: u64,
    /// Requests the scheduler explicitly explained via
    /// [`IterCtx::note_skip`]; exempt from the central classification.
    noted_skips: Vec<ReqId>,
}

impl IterCtx<'_> {
    /// Read-only view of the whole world state.
    pub fn world(&self) -> &World {
        self.w
    }

    pub fn clock(&self) -> Time {
        self.w.clock
    }

    pub fn cfg(&self) -> &SystemConfig {
        &self.w.cfg
    }

    pub fn rec(&self, id: ReqId) -> &ReqRec {
        &self.w.recs[id]
    }

    /// Mutable access to per-request *scheduling* state (phases, spans,
    /// predictions). KVC state is only reachable through [`IterCtx::alloc`].
    pub fn rec_mut(&mut self, id: ReqId) -> &mut ReqRec {
        &mut self.w.recs[id]
    }

    /// The installed KVC allocation policy.
    pub fn alloc(&mut self) -> &mut dyn Allocator {
        self.w.kvc.as_mut()
    }

    /// Read-only allocator queries.
    pub fn kvc(&self) -> &dyn Allocator {
        self.w.kvc.as_ref()
    }

    pub fn peek_arrival(&self) -> Option<ReqId> {
        self.w.inbox.front().copied()
    }

    pub fn pop_arrival(&mut self) -> Option<ReqId> {
        self.w.inbox.pop_front()
    }

    /// Is the RL prediction for `id` available yet (§3.3.2 predictor
    /// latency)?
    pub fn pred_ready(&self, id: ReqId) -> bool {
        self.w.pred_ready[id] <= self.w.clock
    }

    pub fn mark_exec_start(&mut self, id: ReqId) {
        self.w.mark_exec_start(id);
    }

    pub fn re_predict(&mut self, id: ReqId) -> u32 {
        self.w.re_predict(id)
    }

    /// Hard preemption: release the victim's lease (swap or drop), with
    /// the mechanism recorded into the plan. Guests orphaned by the
    /// release are evicted offload-free, recorded in the plan's eviction
    /// list, and returned so the scheduler can drop them from its running
    /// set (only lending schedulers ever see a non-empty list).
    pub fn preempt(&mut self, id: ReqId, kind: PreemptKind) -> Vec<ReqId> {
        let orphans = self.w.preempt(id, kind);
        self.preempted.push((id, kind));
        self.evicted.extend(orphans.iter().copied());
        orphans
    }

    /// Soft pause (SRTF/MLFQ style): the request keeps its lease but sits
    /// out this iteration.
    pub fn pause(&mut self, id: ReqId) {
        let now = self.w.clock;
        let rec = &mut self.w.recs[id];
        if matches!(rec.phase, Phase::Decoding | Phase::Prefilling) {
            rec.phase = Phase::Preempted;
            rec.preempted_since.get_or_insert(now);
            self.w.trace_transition(id, now, SpanState::Preempted);
        }
    }

    /// Offload-free requeue bookkeeping (§3.3.2): the GT leaves the
    /// running set but keeps its written KV resident.
    pub fn requeue_gt(&mut self, id: ReqId) {
        let now = self.w.clock;
        let rec = &mut self.w.recs[id];
        rec.phase = Phase::GtQueued;
        rec.preempted_since.get_or_insert(now);
        rec.preempt_count += 1;
        self.w.col.preemptions += 1;
        self.w.tel.preemptions.inc();
        // Offload-free requeue keeps the lease: waiting, not preempted.
        self.w.trace_transition(id, now, SpanState::Queued);
    }

    /// Revoke a guest's borrowed space (host trimmed / guest repredicted):
    /// drops its guest-written KV into `lost_kv` and records the eviction.
    pub fn evict_guest(&mut self, g: ReqId) -> u32 {
        let dropped = self.w.kvc.drop_guest(g);
        self.w.recs[g].lost_kv += dropped;
        self.evicted.push(g);
        let now = self.w.clock;
        self.w.trace_lease(g, now, "kvc_evict");
        dropped
    }

    pub fn swap_in_cost(&self, id: ReqId) -> f64 {
        self.w.swap_in_cost(id)
    }

    /// Record that `id` suffered a KVC allocation failure (Fig 1d metric).
    pub fn note_alloc_failed(&mut self, id: ReqId) {
        self.w.col.alloc_failed_reqs.insert(id);
    }

    /// Mutable metrics access for scheduler-owned counters.
    pub fn metrics_mut(&mut self) -> &mut Collector {
        &mut self.w.col
    }

    /// A cleared [`BatchPlan`] recycled from the previous iteration
    /// (capacity preserved). Schedulers should start from this instead of
    /// `BatchPlan::default()` so steady-state planning allocates nothing.
    pub fn take_plan(&mut self) -> BatchPlan {
        let mut plan = std::mem::take(&mut self.w.spare_plan);
        plan.clear();
        plan
    }

    /// Optional trace sink: a scheduler that *knows* why it skipped a
    /// queued request this iteration records the reason here, overriding
    /// the central classification in [`IterCtx::finish_into`] for that
    /// request. No-op when tracing is off; no scheduler is required to
    /// call it — the shared plumbing classifies every skip by default.
    pub fn note_skip(&mut self, id: ReqId, reason: SkipReason) {
        if !self.w.tracing_enabled() {
            return;
        }
        let now = self.w.clock;
        self.w.trace_skip(id, now, reason);
        self.noted_skips.push(id);
    }

    /// Fold the recorded preemptions/evictions into the finished plan and
    /// hand the (now consumed) events buffer back to the world for reuse.
    ///
    /// When tracing is on, this is also where the per-iteration
    /// **scheduler decision records** are emitted: every active request
    /// the (non-empty) plan skipped gets a reason — shared plumbing, so
    /// all schedulers produce decision provenance without per-scheduler
    /// edits. Classification:
    ///  * `waiting_held` — not in a runnable wait (`GtQueued` waiting for
    ///    its decode group, or `Preempted` awaiting restore);
    ///  * `kvc_exhausted` — still queued for prefill while some KVC
    ///    allocation failed this iteration (the cache is the binding
    ///    constraint);
    ///  * `ordering` — a later-arrived request was planned ahead of it
    ///    (priority/SJF/slack bypass);
    ///  * `batch_full` — everything else: the batch ran without it.
    pub fn finish_into(mut self, plan: &mut BatchPlan) {
        if self.w.tracing_enabled() && !plan.tasks.is_empty() {
            let kvc_failed = self.w.kvc.stats().failures > self.failures_at;
            let mut planned: Vec<ReqId> = plan.tasks.iter().map(|t| t.id()).collect();
            planned.sort_unstable();
            let mut max_arr = f64::NEG_INFINITY;
            for &id in &planned {
                max_arr = max_arr.max(self.w.recs[id].req.arrival);
            }
            let mut skipped: Vec<ReqId> = self
                .w
                .active
                .iter()
                .copied()
                .filter(|id| {
                    planned.binary_search(id).is_err() && !self.noted_skips.contains(id)
                })
                .collect();
            skipped.sort_unstable();
            let now = self.w.clock;
            for id in skipped {
                let rec = &self.w.recs[id];
                let reason = match rec.phase {
                    Phase::Done => continue,
                    Phase::GtQueued | Phase::Preempted => SkipReason::WaitingHeld,
                    Phase::PtQueued => {
                        if kvc_failed {
                            SkipReason::KvcExhausted
                        } else if max_arr > rec.req.arrival {
                            SkipReason::Ordering
                        } else {
                            SkipReason::BatchFull
                        }
                    }
                    Phase::Prefilling | Phase::Decoding => SkipReason::BatchFull,
                };
                self.w.trace_skip(id, now, reason);
            }
        }
        plan.preempted.extend(self.preempted.drain(..));
        plan.evicted.extend(self.evicted.drain(..));
        self.events.clear();
        self.w.spare_events = std::mem::take(&mut self.events);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelProfile;
    use crate::kvc::ReserveClass;
    use crate::predictor::OraclePredictor;

    fn mini_cfg() -> SystemConfig {
        let mut profile = ModelProfile::opt_13b();
        profile.kvc_bytes = 819_200 * 2048; // 2048 tokens of KVC
        let mut cfg = SystemConfig::new(profile);
        cfg.block_size = 32;
        cfg.reserve_frac = 0.05;
        cfg
    }

    fn item(arrival: f64, p: u32, r: u32) -> TraceItem {
        TraceItem { arrival, prompt_len: p, true_rl: r }
    }

    fn world(items: &[TraceItem]) -> World {
        let cfg = mini_cfg();
        let pred = Box::new(OraclePredictor::new(1));
        World::new(cfg, items, pred)
    }

    fn extend(w: &mut World, id: ReqId, tokens: u32) {
        assert!(w.kvc_mut().extend(id, tokens, ReserveClass::Normal).ok());
    }

    #[test]
    fn arrivals_flow_into_inbox() {
        let mut w = world(&[item(0.0, 10, 5), item(1.0, 10, 5), item(2.0, 10, 5)]);
        w.clock = 1.5;
        assert_eq!(w.drain_arrivals(), 2);
        assert_eq!(w.inbox.len(), 2);
        assert_eq!(w.next_arrival(), Some(2.0));
    }

    #[test]
    fn push_item_files_past_and_future_arrivals() {
        let mut w = world(&[item(0.0, 10, 5), item(5.0, 10, 5)]);
        w.clock = 1.0;
        w.drain_arrivals();
        assert_eq!(w.inbox.len(), 1);
        // Past arrival goes straight to the inbox and counts as active.
        let a = w.push_item(&item(0.5, 8, 3));
        assert_eq!(a, 2);
        assert_eq!(w.inbox.len(), 2);
        assert_eq!(w.n_active(), 2);
        // Future arrivals interleave with the seeded feed in time order.
        let b = w.push_item(&item(3.0, 8, 3));
        assert_eq!(w.next_arrival(), Some(3.0));
        w.clock = 6.0;
        assert_eq!(w.drain_arrivals(), 2);
        assert_eq!(w.inbox.pop_front(), Some(0));
        assert_eq!(w.inbox.pop_front(), Some(2));
        assert_eq!(w.inbox.pop_front(), Some(b));
        assert_eq!(w.inbox.pop_front(), Some(1));
        assert!(w.recs[a].predicted_rl >= 1);
        assert!(w.recs[a].req.deadline > 0.5);
    }

    #[test]
    fn prefill_then_decode_completes() {
        let mut w = world(&[item(0.0, 8, 3)]);
        w.drain_arrivals();
        extend(&mut w, 0, 8 + 4);
        // Prefill whole prompt.
        let b = BatchPlan::of(vec![BatchTask::Prefill { id: 0, chunk: 8 }]);
        w.apply_plan(&b, 0.01, 0.9);
        assert_eq!(w.events.finished_prefill, vec![0]);
        assert_eq!(w.recs[0].generated, 1);
        assert!(w.recs[0].first_token_at.is_some());
        // Two decode steps complete rl=3.
        let d = BatchPlan::of(vec![BatchTask::Decode { id: 0 }]);
        w.apply_plan(&d, 0.01, 0.5);
        assert!(w.events.completed.is_empty());
        w.apply_plan(&d, 0.01, 0.5);
        assert!(w.recs[0].is_done());
        assert_eq!(w.kvc().allocated(0), 0, "KVC released on completion");
        assert!((w.recs[0].jct().unwrap() - 0.03).abs() < 1e-9);
        assert_eq!(w.recs[0].tbt_n, 2);
    }

    #[test]
    fn chunked_prefill_needs_two_iterations() {
        let mut w = world(&[item(0.0, 100, 2)]);
        w.drain_arrivals();
        extend(&mut w, 0, 101);
        let b1 = BatchPlan::of(vec![BatchTask::Prefill { id: 0, chunk: 60 }]);
        w.apply_plan(&b1, 0.01, 1.0);
        assert!(w.events.finished_prefill.is_empty());
        assert_eq!(w.recs[0].prompt_done, 60);
        let b2 = BatchPlan::of(vec![BatchTask::Prefill { id: 0, chunk: 40 }]);
        w.apply_plan(&b2, 0.01, 1.0);
        assert_eq!(w.events.finished_prefill, vec![0]);
    }

    #[test]
    fn underprediction_raises_event() {
        let mut w = world(&[item(0.0, 4, 10)]);
        // Oracle predicts 10, but force a bad prediction:
        w.recs[0].predicted_rl = 3;
        w.drain_arrivals();
        extend(&mut w, 0, 4 + 4);
        let b = BatchPlan::of(vec![BatchTask::Prefill { id: 0, chunk: 4 }]);
        w.apply_plan(&b, 0.01, 1.0);
        let d = BatchPlan::of(vec![BatchTask::Decode { id: 0 }]);
        w.apply_plan(&d, 0.01, 1.0); // generated=2
        assert!(w.events.reached_prediction.is_empty());
        w.apply_plan(&d, 0.01, 1.0); // generated=3 == predicted
        assert_eq!(w.events.reached_prediction, vec![0]);
    }

    #[test]
    fn swap_preempt_and_cost() {
        let mut w = world(&[item(0.0, 32, 5)]);
        w.drain_arrivals();
        extend(&mut w, 0, 33);
        let b = BatchPlan::of(vec![BatchTask::Prefill { id: 0, chunk: 32 }]);
        w.apply_plan(&b, 0.01, 1.0);
        w.preempt(0, PreemptKind::Swap);
        assert_eq!(w.recs[0].phase, Phase::Preempted);
        assert_eq!(w.recs[0].swapped_tokens, 32);
        assert_eq!(w.kvc().allocated(0), 0);
        assert!(w.swap_in_cost(0) > 0.0);
    }

    #[test]
    fn offload_free_preempt_requires_recompute() {
        let mut w = world(&[item(0.0, 16, 8)]);
        w.drain_arrivals();
        extend(&mut w, 0, 24);
        let b = BatchPlan::of(vec![BatchTask::Prefill { id: 0, chunk: 16 }]);
        w.apply_plan(&b, 0.01, 1.0);
        let d = BatchPlan::of(vec![BatchTask::Decode { id: 0 }]);
        w.apply_plan(&d, 0.01, 1.0); // generated=2, written=17
        w.preempt(0, PreemptKind::DropRecompute);
        assert_eq!(w.recs[0].lost_kv, 17);
        // Resume: re-alloc and recompute in one chunk.
        extend(&mut w, 0, 17 + 7);
        let r = BatchPlan::of(vec![BatchTask::Prefill { id: 0, chunk: 17 }]);
        w.apply_plan(&r, 0.01, 1.0);
        assert_eq!(w.events.recompute_done, vec![0]);
        assert_eq!(w.recs[0].generated, 2, "generation progress preserved");
        // Decoding continues to completion.
        for _ in 0..6 {
            w.apply_plan(&d, 0.01, 1.0);
        }
        assert!(w.recs[0].is_done());
    }

    #[test]
    fn guest_completes_before_host_head() {
        // Host: rl 16 (span 17). Guest: rl 6 placed at offset 8.
        let mut w = world(&[item(0.0, 4, 16), item(0.0, 4, 6)]);
        w.set_allocator("pipelined-exact");
        w.drain_arrivals();
        extend(&mut w, 0, 4 + 17);
        extend(&mut w, 1, 4); // prompt only
        let b = BatchPlan::of(vec![
            BatchTask::Prefill { id: 0, chunk: 4 },
            BatchTask::Prefill { id: 1, chunk: 4 },
        ]);
        w.apply_plan(&b, 0.01, 1.0);
        // Schedule both as GTs; 1 is guest of 0 at offset 8.
        w.recs[0].gt_span_base = 1;
        w.recs[1].gt_span_base = 1;
        w.kvc_mut().host_at(1, 0, 8, 8);
        let d = BatchPlan::of(vec![BatchTask::Decode { id: 0 }, BatchTask::Decode { id: 1 }]);
        for _ in 0..5 {
            w.apply_plan(&d, 0.01, 1.0);
        }
        // Guest done at generated=6 (5 decodes after first token).
        assert!(w.recs[1].is_done());
        assert_eq!(w.col.pipeline_evictions, 0);
        // Host continues alone.
        let d0 = BatchPlan::of(vec![BatchTask::Decode { id: 0 }]);
        for _ in 0..10 {
            w.apply_plan(&d0, 0.01, 1.0);
        }
        assert!(w.recs[0].is_done());
        assert_eq!(w.kvc().guest_count(), 0);
    }

    #[test]
    fn overrunning_guest_gets_evicted() {
        let mut w = world(&[item(0.0, 4, 16), item(0.0, 4, 12)]);
        w.set_allocator("pipelined-exact");
        w.drain_arrivals();
        extend(&mut w, 0, 4 + 17);
        extend(&mut w, 1, 4);
        let b = BatchPlan::of(vec![
            BatchTask::Prefill { id: 0, chunk: 4 },
            BatchTask::Prefill { id: 1, chunk: 4 },
        ]);
        w.apply_plan(&b, 0.01, 1.0);
        w.recs[0].gt_span_base = 1;
        w.recs[1].gt_span_base = 1;
        // Guest rl=12 wrongly placed at offset 4: host head passes 4 soon.
        w.kvc_mut().host_at(1, 0, 4, 8);
        let d = BatchPlan::of(vec![BatchTask::Decode { id: 0 }, BatchTask::Decode { id: 1 }]);
        for _ in 0..5 {
            w.apply_plan(&d, 0.01, 1.0);
            if !w.events.evicted_guests.is_empty() {
                break;
            }
        }
        assert_eq!(w.recs[1].phase, Phase::Preempted);
        assert!(w.recs[1].lost_kv > 0);
        assert!(w.col.pipeline_evictions >= 1);
    }

    #[test]
    fn iter_ctx_records_preemptions_into_plan() {
        let mut w = world(&[item(0.0, 8, 8)]);
        w.drain_arrivals();
        extend(&mut w, 0, 16);
        let b = BatchPlan::of(vec![BatchTask::Prefill { id: 0, chunk: 8 }]);
        w.apply_plan(&b, 0.01, 1.0);
        let mut ctx = w.begin_iter();
        assert_eq!(ctx.events.finished_prefill, vec![0]);
        assert_eq!(ctx.pop_arrival(), None);
        ctx.preempt(0, PreemptKind::DropRecompute);
        let mut plan = BatchPlan::default();
        ctx.finish_into(&mut plan);
        assert_eq!(plan.preempted, vec![(0, PreemptKind::DropRecompute)]);
        assert_eq!(w.recs[0].phase, Phase::Preempted);
        // Events were consumed by the context.
        assert!(w.events.finished_prefill.is_empty());
    }
}
