//! Analytic iteration-cost model (roofline style).
//!
//! One iteration processing forward size `F` (tokens) with aggregate
//! attention context `C` (tokens) costs:
//!
//! ```text
//! compute = (flops_per_token * F + 4 * hidden * C) / peak_flops
//! memory  = (weight_bytes + kv_bytes_per_token * C) / mem_bw
//! dur     = iter_overhead + max(compute, memory) + batch.extra_time
//! ```
//!
//! * Weights stream from HBM once per iteration regardless of batch size —
//!   this is what makes small-batch decoding memory-bound and creates the
//!   GPU-underutilization the paper attacks.
//! * Prefill chunks contribute large `F`, so they are compute-bound; the
//!   target forward size (TFS) in each profile is the knee where compute
//!   time dominates the weight-streaming time by ~8x (FastGen's method).
//! * GPU utilization of the iteration is `compute / dur` — "full GPU
//!   utilization" == compute-bound iteration.
//!
//! Calibration sanity (OPT-13B, one A100): decode iteration of batch 8 at
//! ~500-token contexts ≈ 21 ms (≈ 47 tok/s/seq); 2048-token prefill
//! ≈ 350 ms. Both match published A100 measurements to ~20%, and only the
//! *ratios* matter for the figures (DESIGN.md §Substitutions).

use super::Engine;
use crate::core::world::World;
use crate::core::{BatchPlan, BatchTask};

#[derive(Debug, Clone, Default)]
pub struct SimEngine;

impl SimEngine {
    pub fn new() -> Self {
        SimEngine
    }
}

impl Engine for SimEngine {
    fn iteration_cost(&self, batch: &BatchPlan, world: &World) -> (f64, f64) {
        let p = &world.cfg.profile;
        let fwd = batch.forward_size() as f64;
        if batch.is_empty() {
            return (p.iter_overhead, 0.0);
        }

        // Aggregate attention context (tokens read from KVC this iteration).
        let mut context = 0.0f64;
        for t in &batch.tasks {
            match *t {
                BatchTask::Decode { id } => {
                    context += world.recs[id].context_tokens() as f64;
                }
                BatchTask::Prefill { id, chunk } => {
                    // A chunk attends to everything processed before it plus
                    // (on average) half of itself.
                    let prior = world.recs[id].prompt_done.saturating_sub(chunk) as f64;
                    context += prior + chunk as f64 * 0.5;
                }
            }
        }

        let attn_flops = 4.0 * p.hidden as f64 * context; // QK^T + PV per layer folded
        let compute = (p.flops_per_token() * fwd + attn_flops * p.n_layers as f64) / p.peak_flops;
        let kv_bytes = p.kv_bytes_per_token() as f64 * context;
        let memory = (p.weight_bytes + kv_bytes) / p.mem_bw;
        let dur = p.iter_overhead + compute.max(memory) + batch.extra_time;
        let util = (compute / dur).clamp(0.0, 1.0);
        (dur, util)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::core::BatchTask;
    use crate::predictor::OraclePredictor;
    use crate::trace::TraceItem;

    fn world_with(n: usize, prompt: u32, rl: u32) -> World {
        let cfg = SystemConfig::new(ModelProfile::opt_13b());
        let items: Vec<TraceItem> = (0..n)
            .map(|i| TraceItem { arrival: i as f64 * 0.001, prompt_len: prompt, true_rl: rl })
            .collect();
        let pred = Box::new(OraclePredictor::new(1));
        World::new(cfg, &items, pred)
    }

    #[test]
    fn decode_batch8_latency_in_a100_ballpark() {
        let mut w = world_with(8, 100, 50);
        for id in 0..8 {
            w.recs[id].prompt_done = 100;
            w.recs[id].generated = 50;
        }
        let b = BatchPlan::of((0..8).map(|id| BatchTask::Decode { id }).collect());
        let (dur, util) = SimEngine::new().iteration_cost(&b, &w);
        // Memory-bound: ~20-30 ms, low GPU utilization.
        assert!((0.015..0.040).contains(&dur), "dur={dur}");
        assert!(util < 0.15, "util={util}");
    }

    #[test]
    fn prefill_2048_latency_in_a100_ballpark() {
        let mut w = world_with(1, 2048, 10);
        w.recs[0].prompt_done = 2048; // engine only reads prompt_done
        let b = BatchPlan::of(vec![BatchTask::Prefill { id: 0, chunk: 2048 }]);
        let (dur, util) = SimEngine::new().iteration_cost(&b, &w);
        assert!((0.2..0.6).contains(&dur), "dur={dur}");
        assert!(util > 0.85, "util={util}");
    }

    #[test]
    fn tfs_is_compute_bound_knee() {
        // At TFS forward tokens, compute should dominate memory clearly.
        let mut w = world_with(1, 2048, 10);
        let tfs = w.cfg.profile.tfs;
        w.recs[0].prompt_done = tfs;
        let b = BatchPlan::of(vec![BatchTask::Prefill { id: 0, chunk: tfs }]);
        let (_, util) = SimEngine::new().iteration_cost(&b, &w);
        assert!(util > 0.9, "TFS iteration should be compute-bound, util={util}");
    }

    #[test]
    fn extra_time_added() {
        let w = world_with(1, 10, 10);
        let b = BatchPlan { extra_time: 0.5, ..Default::default() };
        // Empty batch short-circuits; non-empty path:
        let w2 = world_with(1, 10, 10);
        let b2 = BatchPlan { tasks: vec![BatchTask::Prefill { id: 0, chunk: 10 }], extra_time: 0.5, ..Default::default() };
        let (d0, _) = SimEngine::new().iteration_cost(&b, &w);
        let (d2, _) = SimEngine::new().iteration_cost(&b2, &w2);
        assert!(d2 > 0.5 && d2 < 0.6);
        assert!(d0 < 0.01);
    }

    #[test]
    fn longer_context_costs_more() {
        let mut w = world_with(2, 100, 50);
        w.recs[0].prompt_done = 100;
        w.recs[0].generated = 10;
        w.recs[1].prompt_done = 100;
        w.recs[1].generated = 3000;
        let short = BatchPlan::of(vec![BatchTask::Decode { id: 0 }]);
        let long = BatchPlan::of(vec![BatchTask::Decode { id: 1 }]);
        let e = SimEngine::new();
        assert!(e.iteration_cost(&long, &w).0 > e.iteration_cost(&short, &w).0);
    }
}
