//! Execution engines: something that can price (or actually run) one
//! iteration of a batch.
//!
//! * [`SimEngine`] — analytic roofline cost model calibrated from the
//!   profile (A100-class numbers); powers every paper-figure driver.
//! * [`crate::runtime::PjrtEngine`] — the real path: executes the AOT
//!   HLO artifacts on the PJRT CPU client (see `runtime/`).

pub mod sim;

pub use sim::SimEngine;

use crate::core::world::World;
use crate::core::BatchPlan;

/// Anything that can execute/price one iteration.
pub trait Engine {
    /// Returns `(duration_seconds, gpu_compute_utilization)` for running
    /// `plan` given the current world state. Must NOT mutate the world.
    fn iteration_cost(&self, plan: &BatchPlan, world: &World) -> (f64, f64);
}
