//! Workload traces: synthetic generators calibrated to the paper's Table 2
//! plus a CSV loader for external traces.
//!
//! The paper uses Alpaca, ShareGPT and BookCorpus. Those datasets are not
//! available here, so each generator reproduces the published length
//! statistics (avg/min/max input & output) with a clamped log-normal body
//! whose underlying `mu` is calibrated by bisection so the post-clamping
//! mean matches the paper's average. Arrivals are Poisson at the paper's
//! per-trace rates. See DESIGN.md §Substitutions for why this preserves
//! the figures' behaviour.

pub mod arrival;

pub use arrival::{ArrivalProcess, ArrivalSampler};

use crate::core::Time;
use crate::util::rng::Rng;

/// One request as drawn from a trace (deadline assigned later, once the
/// SLO calibration for the target model is known).
#[derive(Debug, Clone, Copy)]
pub struct TraceItem {
    pub arrival: Time,
    pub prompt_len: u32,
    pub true_rl: u32,
}

/// Length statistics of one side (input or output) of a trace.
#[derive(Debug, Clone, Copy)]
pub struct LenSpec {
    pub avg: f64,
    pub min: u32,
    pub max: u32,
    /// Log-normal sigma (shape): larger == heavier tail.
    pub sigma: f64,
}

/// A named synthetic trace (Table 2 row).
#[derive(Debug, Clone, Copy)]
pub struct TraceSpec {
    pub name: &'static str,
    pub input: LenSpec,
    pub output: LenSpec,
    /// Default Poisson arrival rate (req/s) from Table 2.
    pub default_rate: f64,
    /// Paper's request count (informational).
    pub paper_count: u32,
}

impl TraceSpec {
    pub fn alpaca() -> Self {
        TraceSpec {
            name: "alpaca",
            input: LenSpec { avg: 19.31, min: 9, max: 2470, sigma: 0.55 },
            output: LenSpec { avg: 58.41, min: 13, max: 292, sigma: 0.55 },
            default_rate: 36.0,
            paper_count: 52_000,
        }
    }

    pub fn sharegpt() -> Self {
        TraceSpec {
            name: "sharegpt",
            input: LenSpec { avg: 161.31, min: 16, max: 3200, sigma: 1.0 },
            output: LenSpec { avg: 337.99, min: 19, max: 991, sigma: 0.7 },
            default_rate: 28.0,
            paper_count: 90_000,
        }
    }

    /// BookCorpus prompts are pre-chunked to 2048 tokens in the paper
    /// (§2.1), so the effective input distribution is concentrated near
    /// the chunk size.
    pub fn bookcorpus() -> Self {
        TraceSpec {
            name: "bookcorpus",
            input: LenSpec { avg: 1952.11, min: 18, max: 2048, sigma: 0.35 },
            output: LenSpec { avg: 681.2, min: 32, max: 1041, sigma: 0.45 },
            default_rate: 1.2,
            paper_count: 11_000,
        }
    }

    pub fn all() -> [TraceSpec; 3] {
        [Self::alpaca(), Self::sharegpt(), Self::bookcorpus()]
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "alpaca" => Some(Self::alpaca()),
            "sharegpt" => Some(Self::sharegpt()),
            "bookcorpus" => Some(Self::bookcorpus()),
            _ => None,
        }
    }
}

/// Calibrated sampler for one LenSpec.
#[derive(Debug, Clone)]
pub struct LenSampler {
    spec: LenSpec,
    mu: f64,
}

impl LenSampler {
    /// Calibrate `mu` by bisection so that the clamped log-normal mean
    /// matches `spec.avg` (deterministic: fixed probe RNG).
    pub fn calibrate(spec: LenSpec) -> Self {
        let probe = |mu: f64| -> f64 {
            let mut rng = Rng::new(0xCA11B7A7E);
            let n = 20_000;
            let mut sum = 0.0;
            for _ in 0..n {
                let x = rng.log_normal(mu, spec.sigma);
                sum += x.clamp(spec.min as f64, spec.max as f64);
            }
            sum / n as f64
        };
        // Mean of clamped log-normal is increasing in mu; bisect.
        let (mut lo, mut hi) = (-2.0, (spec.max as f64).ln() + 1.0);
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if probe(mid) < spec.avg {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        LenSampler { spec, mu: 0.5 * (lo + hi) }
    }

    pub fn sample(&self, rng: &mut Rng) -> u32 {
        let x = rng.log_normal(self.mu, self.spec.sigma);
        x.clamp(self.spec.min as f64, self.spec.max as f64).round() as u32
    }
}

/// Trace generator: Poisson arrivals + calibrated length samplers.
pub struct TraceGen {
    pub spec: TraceSpec,
    input: LenSampler,
    output: LenSampler,
}

impl TraceGen {
    pub fn new(spec: TraceSpec) -> Self {
        TraceGen {
            spec,
            input: LenSampler::calibrate(spec.input),
            output: LenSampler::calibrate(spec.output),
        }
    }

    /// Sample one request's (prompt, response) lengths, clamped to the
    /// context limit: shorten the prompt first (chunking), then the
    /// response.
    fn sample_lengths(&self, rng: &mut Rng, max_total_len: u32) -> (u32, u32) {
        let mut prompt_len = self.input.sample(rng);
        let mut true_rl = self.output.sample(rng).max(1);
        if prompt_len + true_rl > max_total_len {
            prompt_len = prompt_len.min(max_total_len.saturating_sub(true_rl).max(1));
            true_rl = true_rl.min(max_total_len - prompt_len);
        }
        (prompt_len, true_rl)
    }

    /// Generate `n` requests at `rate` req/s (Poisson). `max_total_len`
    /// clamps prompt+response to the model's context limit (the paper
    /// chunks/filters to fit its models).
    pub fn generate(&self, n: usize, rate: f64, max_total_len: u32, seed: u64) -> Vec<TraceItem> {
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            t += rng.exponential(rate);
            let (prompt_len, true_rl) = self.sample_lengths(&mut rng, max_total_len);
            out.push(TraceItem { arrival: t, prompt_len, true_rl });
        }
        out
    }

    /// Generate requests covering `duration` seconds whose arrival times
    /// are drawn from `process` (Poisson, bursty MMPP, or diurnal — the
    /// fleet layer's non-stationary workloads). Lengths come from the
    /// same calibrated samplers as [`TraceGen::generate`]; the arrival
    /// stream and the length stream are split off the one seed so the
    /// same requests appear under every process at equal mean rate.
    pub fn generate_arrivals(
        &self,
        process: ArrivalProcess,
        duration: Time,
        max_total_len: u32,
        seed: u64,
    ) -> Vec<TraceItem> {
        let mut rng = Rng::new(seed);
        let mut sampler = process.sampler(rng.next_u64());
        let mut out = Vec::new();
        loop {
            let t = sampler.next_arrival();
            if t > duration {
                break;
            }
            let (prompt_len, true_rl) = self.sample_lengths(&mut rng, max_total_len);
            out.push(TraceItem { arrival: t, prompt_len, true_rl });
        }
        out
    }

    /// Generate requests covering `duration` seconds at `rate` req/s.
    pub fn generate_for(
        &self,
        duration: Time,
        rate: f64,
        max_total_len: u32,
        seed: u64,
    ) -> Vec<TraceItem> {
        let n = (duration * rate * 1.1) as usize + 16;
        let mut v = self.generate(n, rate, max_total_len, seed);
        v.retain(|it| it.arrival <= duration);
        v
    }
}

/// Empirical stats of a generated trace (for the Table 2 self-check).
#[derive(Debug, Clone)]
pub struct TraceStats {
    pub n: usize,
    pub in_avg: f64,
    pub in_min: u32,
    pub in_max: u32,
    pub out_avg: f64,
    pub out_min: u32,
    pub out_max: u32,
    pub rate: f64,
}

pub fn stats(items: &[TraceItem]) -> TraceStats {
    let n = items.len().max(1);
    let in_avg = items.iter().map(|i| i.prompt_len as f64).sum::<f64>() / n as f64;
    let out_avg = items.iter().map(|i| i.true_rl as f64).sum::<f64>() / n as f64;
    let span = items.last().map(|i| i.arrival).unwrap_or(1.0).max(1e-9);
    TraceStats {
        n: items.len(),
        in_avg,
        in_min: items.iter().map(|i| i.prompt_len).min().unwrap_or(0),
        in_max: items.iter().map(|i| i.prompt_len).max().unwrap_or(0),
        out_avg,
        out_min: items.iter().map(|i| i.true_rl).min().unwrap_or(0),
        out_max: items.iter().map(|i| i.true_rl).max().unwrap_or(0),
        rate: items.len() as f64 / span,
    }
}

/// Save to CSV ("arrival,prompt_len,true_rl" with header).
pub fn save_csv(items: &[TraceItem], path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
    let mut s = String::from("arrival,prompt_len,true_rl\n");
    for it in items {
        s.push_str(&format!("{:.6},{},{}\n", it.arrival, it.prompt_len, it.true_rl));
    }
    std::fs::write(path, s)
}

/// Load from CSV produced by [`save_csv`] (or hand-written in that format).
pub fn load_csv(path: impl AsRef<std::path::Path>) -> Result<Vec<TraceItem>, String> {
    let text = std::fs::read_to_string(path.as_ref())
        .map_err(|e| format!("read {}: {e}", path.as_ref().display()))?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if i == 0 && line.starts_with("arrival") {
            continue;
        }
        if line.trim().is_empty() {
            continue;
        }
        let mut parts = line.split(',');
        let parse = |p: Option<&str>, what: &str| -> Result<f64, String> {
            p.ok_or_else(|| format!("line {}: missing {what}", i + 1))?
                .trim()
                .parse::<f64>()
                .map_err(|e| format!("line {}: bad {what}: {e}", i + 1))
        };
        let arrival = parse(parts.next(), "arrival")?;
        let prompt_len = parse(parts.next(), "prompt_len")? as u32;
        let true_rl = parse(parts.next(), "true_rl")? as u32;
        out.push(TraceItem { arrival, prompt_len, true_rl: true_rl.max(1) });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_stats_match_table2() {
        // Tolerances: mean within 12% (clamped lognormal + finite sample),
        // min/max within spec bounds, rate within 10%.
        for spec in TraceSpec::all() {
            let g = TraceGen::new(spec);
            let items = g.generate(20_000, spec.default_rate, 4096, 7);
            let s = stats(&items);
            let in_err = (s.in_avg - spec.input.avg).abs() / spec.input.avg;
            let out_err = (s.out_avg - spec.output.avg).abs() / spec.output.avg;
            assert!(in_err < 0.12, "{}: in_avg {} vs {}", spec.name, s.in_avg, spec.input.avg);
            assert!(out_err < 0.12, "{}: out_avg {} vs {}", spec.name, s.out_avg, spec.output.avg);
            assert!(s.in_min >= spec.input.min);
            assert!(s.out_min >= spec.output.min);
            assert!(s.in_max <= spec.input.max);
            assert!(s.out_max <= spec.output.max);
            let rate_err = (s.rate - spec.default_rate).abs() / spec.default_rate;
            assert!(rate_err < 0.1, "{}: rate {}", spec.name, s.rate);
        }
    }

    #[test]
    fn arrivals_monotone() {
        let g = TraceGen::new(TraceSpec::alpaca());
        let items = g.generate(1000, 10.0, 4096, 1);
        for w in items.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn respects_context_limit() {
        let g = TraceGen::new(TraceSpec::bookcorpus());
        let items = g.generate(5000, 1.2, 2560, 3);
        for it in items {
            assert!(it.prompt_len + it.true_rl <= 2560);
            assert!(it.true_rl >= 1);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let g = TraceGen::new(TraceSpec::sharegpt());
        let a = g.generate(100, 5.0, 4096, 9);
        let b = g.generate(100, 5.0, 4096, 9);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt_len, y.prompt_len);
            assert_eq!(x.true_rl, y.true_rl);
        }
        let c = g.generate(100, 5.0, 4096, 10);
        assert!(a.iter().zip(&c).any(|(x, y)| x.prompt_len != y.prompt_len));
    }

    #[test]
    fn csv_roundtrip() {
        let g = TraceGen::new(TraceSpec::alpaca());
        let items = g.generate(50, 10.0, 4096, 5);
        let dir = std::env::temp_dir().join("econoserve_trace_test.csv");
        save_csv(&items, &dir).unwrap();
        let back = load_csv(&dir).unwrap();
        assert_eq!(items.len(), back.len());
        for (a, b) in items.iter().zip(&back) {
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.true_rl, b.true_rl);
            assert!((a.arrival - b.arrival).abs() < 1e-5);
        }
        let _ = std::fs::remove_file(dir);
    }

    #[test]
    fn generate_arrivals_all_processes() {
        let g = TraceGen::new(TraceSpec::sharegpt());
        for name in ArrivalProcess::names() {
            let p = ArrivalProcess::by_name(name, 10.0).unwrap();
            let items = g.generate_arrivals(p, 60.0, 2048, 4);
            assert!(!items.is_empty(), "{name}");
            assert!(items.last().unwrap().arrival <= 60.0);
            for w in items.windows(2) {
                assert!(w[0].arrival <= w[1].arrival, "{name}");
            }
            for it in &items {
                assert!(it.prompt_len + it.true_rl <= 2048, "{name}");
                assert!(it.true_rl >= 1);
            }
        }
    }

    #[test]
    fn generate_for_duration() {
        let g = TraceGen::new(TraceSpec::alpaca());
        let items = g.generate_for(10.0, 20.0, 4096, 2);
        assert!(!items.is_empty());
        assert!(items.last().unwrap().arrival <= 10.0);
        // ~200 expected
        assert!((150..=260).contains(&items.len()), "{}", items.len());
    }
}
