//! Arrival processes: stationary and non-stationary request streams.
//!
//! The paper evaluates under constant-rate Poisson arrivals. Real fleets
//! breathe — SageServe (arXiv 2502.14617) and Aladdin (arXiv 2405.06856)
//! both show the GPU-cost story is set by *time-varying* load — so the
//! fleet layer's autoscalers need workloads with structure to chase:
//!
//!  * [`ArrivalProcess::Poisson`] — the paper's stationary baseline;
//!  * [`ArrivalProcess::Mmpp`] — a 2-state Markov-modulated Poisson
//!    process (bursty on/off traffic, exponential sojourns per state);
//!  * [`ArrivalProcess::Diurnal`] — a sinusoidal day-curve (compressed
//!    to simulation scale), sampled exactly via Lewis–Shedler thinning.
//!
//! All three are calibrated by their *mean* rate so fleets under
//! different processes are comparable at equal offered load, and all are
//! deterministic per seed (SplitMix64 streams).

use crate::core::Time;
use crate::util::rng::Rng;

/// A named arrival process with a given long-run mean rate (req/s).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Constant-rate Poisson (the paper's setup).
    Poisson { rate: f64 },
    /// 2-state MMPP: Poisson at `rate_on` / `rate_off`, with
    /// exponentially distributed sojourns of mean `mean_on` / `mean_off`
    /// seconds. Long-run mean rate is the sojourn-weighted average.
    Mmpp { rate_on: f64, rate_off: f64, mean_on: f64, mean_off: f64 },
    /// Sinusoidal day-curve: instantaneous rate
    /// `mean_rate * (1 + amplitude * sin(2*pi*t/period))`, `amplitude`
    /// in [0, 1). The long-run mean over whole periods is `mean_rate`.
    Diurnal { mean_rate: f64, amplitude: f64, period: f64 },
}

impl ArrivalProcess {
    /// Registry names (the `--workload` axis of the fleet grammar).
    pub fn names() -> [&'static str; 3] {
        ["poisson", "mmpp", "diurnal"]
    }

    /// Resolve a process by name at the given mean rate, with default
    /// shape parameters: MMPP burst factor 9 (on-rate 1.8x mean, off-rate
    /// 0.2x mean, 10 s sojourns), diurnal amplitude 0.6 over a 400 s
    /// compressed "day". The diurnal mean-rate calibration holds over
    /// *whole* periods — run it for a whole-period duration (or adjust
    /// `period` to divide the horizon, as the `fleet` CLI does) to keep
    /// offered load equal across processes.
    pub fn by_name(name: &str, mean_rate: f64) -> Option<Self> {
        assert!(mean_rate > 0.0, "mean_rate must be positive");
        match name {
            "poisson" => Some(ArrivalProcess::Poisson { rate: mean_rate }),
            "mmpp" => Some(ArrivalProcess::Mmpp {
                rate_on: 1.8 * mean_rate,
                rate_off: 0.2 * mean_rate,
                mean_on: 10.0,
                mean_off: 10.0,
            }),
            "diurnal" => Some(ArrivalProcess::Diurnal {
                mean_rate,
                amplitude: 0.6,
                period: 400.0,
            }),
            _ => None,
        }
    }

    /// Long-run mean rate (req/s).
    pub fn mean_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Mmpp { rate_on, rate_off, mean_on, mean_off } => {
                (rate_on * mean_on + rate_off * mean_off) / (mean_on + mean_off)
            }
            ArrivalProcess::Diurnal { mean_rate, .. } => mean_rate,
        }
    }

    /// Peak instantaneous rate (what a statically provisioned fleet must
    /// be sized for).
    pub fn peak_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Mmpp { rate_on, rate_off, .. } => rate_on.max(rate_off),
            ArrivalProcess::Diurnal { mean_rate, amplitude, .. } => {
                mean_rate * (1.0 + amplitude)
            }
        }
    }

    /// Deterministic intensity at time `t`. Exact for Poisson/diurnal;
    /// for MMPP (whose intensity is a random state) this is the ensemble
    /// mean — use it for display/forecast baselines, not sampling.
    pub fn rate_at(&self, t: Time) -> f64 {
        match *self {
            ArrivalProcess::Diurnal { mean_rate, amplitude, period } => {
                mean_rate * (1.0 + amplitude * (2.0 * std::f64::consts::PI * t / period).sin())
            }
            _ => self.mean_rate(),
        }
    }

    /// A deterministic sampler of absolute arrival times for this process.
    pub fn sampler(&self, seed: u64) -> ArrivalSampler {
        let mut rng = Rng::new(seed);
        let (on, phase_end) = match *self {
            ArrivalProcess::Mmpp { mean_on, mean_off, .. } => {
                // Start in the stationary state distribution.
                let on = rng.f64() < mean_on / (mean_on + mean_off);
                let mean = if on { mean_on } else { mean_off };
                (on, rng.exponential(1.0 / mean))
            }
            _ => (true, f64::INFINITY),
        };
        ArrivalSampler { process: *self, rng, t: 0.0, on, phase_end }
    }
}

/// Stateful arrival-time stream for one [`ArrivalProcess`]. Yields
/// strictly increasing absolute times, deterministic per seed.
#[derive(Debug, Clone)]
pub struct ArrivalSampler {
    process: ArrivalProcess,
    rng: Rng,
    t: Time,
    /// MMPP state (unused by the other processes).
    on: bool,
    phase_end: Time,
}

impl ArrivalSampler {
    /// The next absolute arrival time.
    pub fn next_arrival(&mut self) -> Time {
        match self.process {
            ArrivalProcess::Poisson { rate } => {
                self.t += self.rng.exponential(rate);
                self.t
            }
            ArrivalProcess::Mmpp { rate_on, rate_off, mean_on, mean_off } => loop {
                let rate = if self.on { rate_on } else { rate_off };
                // Memorylessness makes resampling after a state switch
                // exact: the discarded candidate carries no information.
                let cand = if rate > 0.0 {
                    self.t + self.rng.exponential(rate)
                } else {
                    f64::INFINITY
                };
                if cand <= self.phase_end {
                    self.t = cand;
                    return cand;
                }
                self.t = self.phase_end;
                self.on = !self.on;
                let mean = if self.on { mean_on } else { mean_off };
                self.phase_end = self.t + self.rng.exponential(1.0 / mean);
            },
            ArrivalProcess::Diurnal { mean_rate, amplitude, .. } => {
                // Lewis–Shedler thinning against the peak rate.
                let peak = mean_rate * (1.0 + amplitude);
                loop {
                    self.t += self.rng.exponential(peak);
                    if self.rng.f64() * peak <= self.process.rate_at(self.t) {
                        return self.t;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical_rate(p: ArrivalProcess, duration: f64, seed: u64) -> f64 {
        let mut s = p.sampler(seed);
        let mut n = 0usize;
        while s.next_arrival() <= duration {
            n += 1;
        }
        n as f64 / duration
    }

    #[test]
    fn mean_rates_match_configuration_within_5pct() {
        // The satellite property: all three processes deliver their
        // configured mean rate. Durations are sized so the sampling
        // error of the deterministic realization is well inside 5%.
        let cases: [(ArrivalProcess, f64); 3] = [
            (ArrivalProcess::by_name("poisson", 20.0).unwrap(), 2_000.0),
            // MMPP rate variance is dominated by sojourn cycling; a long
            // horizon averages over thousands of on/off cycles.
            (ArrivalProcess::by_name("mmpp", 10.0).unwrap(), 40_000.0),
            // Whole number of periods so the sinusoid integrates to the
            // mean exactly.
            (ArrivalProcess::by_name("diurnal", 20.0).unwrap(), 2_000.0),
        ];
        for (p, duration) in cases {
            let rate = empirical_rate(p, duration, 11);
            let err = (rate - p.mean_rate()).abs() / p.mean_rate();
            assert!(err < 0.05, "{p:?}: empirical {rate:.3} vs {:.3}", p.mean_rate());
        }
    }

    #[test]
    fn arrivals_strictly_increase() {
        for name in ArrivalProcess::names() {
            let p = ArrivalProcess::by_name(name, 15.0).unwrap();
            let mut s = p.sampler(3);
            let mut last = 0.0;
            for _ in 0..5_000 {
                let t = s.next_arrival();
                assert!(t > last, "{name}: {t} after {last}");
                last = t;
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let p = ArrivalProcess::by_name("mmpp", 8.0).unwrap();
        let (mut a, mut b) = (p.sampler(9), p.sampler(9));
        for _ in 0..1_000 {
            assert_eq!(a.next_arrival().to_bits(), b.next_arrival().to_bits());
        }
        let mut c = p.sampler(10);
        let mut a2 = p.sampler(9);
        assert!((0..100).any(|_| a2.next_arrival() != c.next_arrival()));
    }

    #[test]
    fn diurnal_peaks_and_troughs() {
        let p = ArrivalProcess::Diurnal { mean_rate: 10.0, amplitude: 0.5, period: 100.0 };
        assert!((p.rate_at(25.0) - 15.0).abs() < 1e-9, "peak at quarter period");
        assert!((p.rate_at(75.0) - 5.0).abs() < 1e-9, "trough at three quarters");
        assert!((p.peak_rate() - 15.0).abs() < 1e-9);
        // A peak-quarter window sees measurably more arrivals than a
        // trough-quarter window.
        let mut s = p.sampler(5);
        let (mut hi, mut lo) = (0usize, 0usize);
        loop {
            let t = s.next_arrival();
            if t > 4_000.0 {
                break;
            }
            let phase = t % 100.0;
            if (0.0..50.0).contains(&phase) {
                hi += 1;
            } else {
                lo += 1;
            }
        }
        assert!(hi as f64 > lo as f64 * 1.5, "hi={hi} lo={lo}");
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        // Index of dispersion of counts over 10 s windows: ~1 for
        // Poisson, substantially above 1 for the on/off process.
        let disp = |p: ArrivalProcess| -> f64 {
            let mut s = p.sampler(21);
            let mut counts = vec![0f64; 400];
            loop {
                let t = s.next_arrival();
                let w = (t / 10.0) as usize;
                if w >= counts.len() {
                    break;
                }
                counts[w] += 1.0;
            }
            let mean = counts.iter().sum::<f64>() / counts.len() as f64;
            let var = counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>()
                / counts.len() as f64;
            var / mean.max(1e-9)
        };
        let poisson = disp(ArrivalProcess::by_name("poisson", 10.0).unwrap());
        let mmpp = disp(ArrivalProcess::by_name("mmpp", 10.0).unwrap());
        assert!(mmpp > poisson * 2.0, "mmpp {mmpp:.2} vs poisson {poisson:.2}");
    }
}
