//! The coordinator: the per-iteration control loop of Algorithm 1, shared
//! by the simulation drivers and (via the same `Scheduler`/`Engine`
//! seams) the real PJRT serving path.
//!
//! Loop per iteration:
//!  1. drain arrivals into the inbox,
//!  2. let the scheduler form a batch (measuring its wall-clock cost and
//!     charging it to the simulation clock scaled by
//!     `cfg.sched_time_scale` — so MultiRes's O(n²) scan really shows up
//!     in Fig 14, from measured code rather than a constant),
//!  3. price the batch with the engine,
//!  4. apply the iteration to the world,
//!  5. repeat until everything completed or limits hit.

use std::time::Instant;

use crate::api::AdmissionController;
use crate::core::world::World;
use crate::engine::Engine;
use crate::metrics::{summarize, Summary};
use crate::sched::{plan_iteration, Scheduler};

/// Stop conditions for a run.
#[derive(Debug, Clone, Copy)]
pub struct RunLimits {
    /// Simulated seconds (requests arriving after this still count as
    /// unfinished for SSR).
    pub max_sim_time: f64,
    pub max_iterations: u64,
}

impl Default for RunLimits {
    fn default() -> Self {
        RunLimits { max_sim_time: f64::INFINITY, max_iterations: 50_000_000 }
    }
}

impl RunLimits {
    pub fn for_time(t: f64) -> Self {
        RunLimits { max_sim_time: t, ..Default::default() }
    }
}

/// Outcome of a full run.
pub struct RunResult {
    pub summary: Summary,
    /// Simulated end time.
    pub end_time: f64,
    /// Wall-clock seconds the run took (host side).
    pub wall_time: f64,
    /// Requests shed by admission control (0 unless `run_admitted` is
    /// used with a controller).
    pub rejected: usize,
    /// Canonical Prometheus text of the world's telemetry registry at
    /// the end of the run (`econoserve sweep --metrics-out` surfaces
    /// this; see `docs/metrics-dictionary.md`).
    pub metrics: String,
    /// The finished span-trace document when tracing was enabled on the
    /// world (`--trace-out`); `None` otherwise.
    pub trace: Option<crate::telemetry::TraceDoc>,
    /// The sim-side request log as JSONL when enabled (`--log-out`).
    pub reqlog: Option<String>,
}

/// Drive `world` with `sched` and `engine` until completion or limits,
/// admitting every arrival (the paper's setup).
pub fn run(
    world: &mut World,
    sched: &mut dyn Scheduler,
    engine: &dyn Engine,
    limits: RunLimits,
) -> RunResult {
    run_admitted(world, sched, engine, limits, None)
}

/// Stall detection: if no batch executes for this much SIMULATED time
/// while runnable work remains, the scheduler is stuck (bug), not
/// waiting.
const STALL_HORIZON: f64 = 120.0;

/// The shared per-iteration core of [`run_admitted`] and
/// [`Stepper::advance_to`]: plan one iteration (measuring the
/// scheduler's wall-clock cost) and, if a batch was formed, charge the
/// scheduling cost to the simulated clock and execute the plan. Returns
/// `true` when a batch executed; on `false` (empty plan) the caller owns
/// the idle-clock policy. `dilation` stretches the executed batch's
/// simulated duration (1.0 = healthy hardware) — the fleet layer's
/// straggler fault sets it above 1 on a degraded replica.
fn plan_and_execute(
    world: &mut World,
    sched: &mut dyn Scheduler,
    engine: &dyn Engine,
    dilation: f64,
) -> bool {
    let t0 = Instant::now();
    let plan = plan_iteration(world, sched);
    let charged = t0.elapsed().as_secs_f64() * world.cfg.sched_time_scale;
    if plan.is_empty() {
        world.recycle_plan(plan);
        return false;
    }
    world.col.record_sched(charged);
    world.clock += charged;
    let (dur, util) = engine.iteration_cost(&plan, world);
    world.apply_plan(&plan, dur * dilation, util);
    // Hand the plan's buffers back for the next iteration (steady-state
    // planning allocates nothing).
    world.recycle_plan(plan);
    true
}

/// As [`run`], but with the same [`AdmissionController`] front door the
/// real serving path uses: each new arrival is admitted or shed before
/// the scheduler ever sees it (queue-depth bound + SLO infeasibility).
/// Shed requests complete immediately as SLO misses and are counted in
/// `RunResult::rejected`.
pub fn run_admitted(
    world: &mut World,
    sched: &mut dyn Scheduler,
    engine: &dyn Engine,
    limits: RunLimits,
    admission: Option<&AdmissionController>,
) -> RunResult {
    let wall_start = Instant::now();
    let mut iters = 0u64;
    let mut rejected = 0usize;
    let mut last_progress = 0.0f64;

    loop {
        if world.all_done() || world.clock >= limits.max_sim_time || iters >= limits.max_iterations
        {
            break;
        }
        let newly = world.drain_arrivals();
        if let Some(adm) = admission {
            rejected += shed_new_arrivals(world, adm, newly);
        }

        let before = world.clock;
        if !plan_and_execute(world, sched, engine, 1.0) {
            // Nothing runnable. Fast-forward: to the next arrival if it is
            // sooner than the idle quantum, else by the idle quantum —
            // schedulers may be waiting on non-arrival wakeups such as
            // prediction readiness (§3.3.2 predictor latency).
            if world.n_active() == 0 {
                // Only future arrivals remain (long gaps are normal
                // under the low-rate/bursty arrival processes): waiting
                // is progress, not a stall.
                last_progress = world.clock;
            }
            assert!(
                world.clock - last_progress < STALL_HORIZON,
                "{}: no batch executed for {STALL_HORIZON}s sim time ({} inbox, {} done/{})",
                sched.name(),
                world.inbox.len(),
                world.n_done(),
                world.recs.len()
            );
            let idle_step = world.clock + 0.05;
            world.clock = match world.next_arrival() {
                Some(t) if t > world.clock => t.min(idle_step),
                _ => idle_step,
            };
            continue;
        }
        last_progress = before;
        iters += 1;
    }

    let end_time = world.clock;
    let mut summary = summarize(&world.recs, &world.col, end_time);
    (summary.n_pred, summary.n_close) = world.predictor_accuracy();
    RunResult {
        summary,
        end_time,
        wall_time: wall_start.elapsed().as_secs_f64(),
        rejected,
        metrics: world.metrics_text(),
        trace: world.take_trace(),
        reqlog: world.reqlog().map(|l| l.render_jsonl()),
    }
}

/// Apply the admission decision to the `newly` arrivals at the tail of
/// the inbox. The in-flight count matches the real path's definition —
/// every admitted request still in the system (queued anywhere, incl.
/// scheduler-internal queues, or executing), not just the coordinator
/// inbox. The SLO budget is the remaining slack to the deadline; the
/// service estimate uses the PREDICTED response length — the controller,
/// like the scheduler, never sees the true RL.
fn shed_new_arrivals(world: &mut World, adm: &AdmissionController, newly: usize) -> usize {
    if newly == 0 {
        return 0;
    }
    // Arrived-and-unfinished requests (the world's O(1) active index),
    // including the new arrivals themselves; subtract the latter to get
    // the load ahead of them.
    let in_system = world.n_active();
    let mut inflight = in_system - newly;
    let mut shed = 0usize;
    let mut i = world.inbox.len() - newly;
    while i < world.inbox.len() {
        let id = world.inbox[i];
        let rec = &world.recs[id];
        let decision = adm.decide(
            inflight,
            rec.req.prompt_len as usize,
            rec.predicted_rl.max(1) as usize,
            rec.req.deadline - world.clock,
        );
        if decision.is_err() {
            world.inbox.remove(i);
            world.reject(id);
            shed += 1;
        } else {
            inflight += 1;
            i += 1;
        }
    }
    shed
}

/// A resumable, step-driven serving harness: one replica's world +
/// scheduler + sim engine that can be advanced to a time horizon and
/// resumed — the building block the fleet layer interleaves N of on a
/// shared clock. Runs the same per-iteration loop as [`run`], with two
/// differences required for interleaving:
///
///  * the clock never free-runs past the caller's horizon while idle
///    (arrivals routed by the fleet front door must not land in the
///    replica's past), and
///  * requests are injected *during* the run via [`Stepper::inject`]
///    (which files them through [`World::push_item`]).
pub struct Stepper {
    pub world: World,
    sched: Box<dyn Scheduler>,
    engine: crate::engine::SimEngine,
    last_progress: f64,
    pub iterations: u64,
    /// Simulated-time dilation applied to every executed batch (1.0 =
    /// healthy). The fleet layer's straggler fault raises it for the
    /// episode, then resets it — see `fleet::faults`.
    slowdown: f64,
}

impl Stepper {
    /// Build a stepper over `items` (may be empty — fleet replicas start
    /// blank and receive routed arrivals). `system` uses the
    /// `sched::by_name` registry grammar.
    pub fn new(
        cfg: crate::config::SystemConfig,
        system: &str,
        trace: &str,
        oracle: bool,
        items: &[crate::trace::TraceItem],
    ) -> Self {
        let pred = harness::predictor_for(&cfg, trace, oracle);
        let mut world = World::new(cfg, items, pred);
        let sys = crate::sched::by_name(system)
            .unwrap_or_else(|| panic!("unknown system '{system}'"));
        world.set_allocator(sys.alloc);
        Stepper {
            world,
            sched: sys.sched,
            engine: crate::engine::SimEngine::new(),
            last_progress: 0.0,
            iterations: 0,
            slowdown: 1.0,
        }
    }

    /// Set the straggler dilation factor for subsequent batches (1.0
    /// restores healthy speed). Takes effect at the next iteration;
    /// batches already executed are not re-timed.
    pub fn set_slowdown(&mut self, factor: f64) {
        debug_assert!(factor >= 1.0, "slowdown below healthy speed: {factor}");
        self.slowdown = factor;
    }

    pub fn sched_name(&self) -> &'static str {
        self.sched.name()
    }

    /// Fast-forward an idle stepper's clock (and its stall-detection
    /// anchor) to the shared fleet clock — used when a replica boots
    /// mid-run, so its world starts at the boot time, not t=0.
    pub fn sync_clock(&mut self, t: f64) {
        self.world.clock = self.world.clock.max(t);
        self.last_progress = self.last_progress.max(t);
    }

    /// Route one request into this replica (fleet front door).
    pub fn inject(&mut self, it: &crate::trace::TraceItem) -> crate::core::ReqId {
        self.world.push_item(it)
    }

    /// Advance the world until `clock >= horizon` or all work completes.
    /// Iterations that start before the horizon may overshoot it (an
    /// executing batch spans the boundary, as on real hardware); an idle
    /// world's clock is clamped *to* the horizon so later injections are
    /// never in its past.
    pub fn advance_to(&mut self, horizon: f64) {
        loop {
            if self.world.clock >= horizon {
                return;
            }
            if self.world.all_done() {
                // Idle replica: follow the shared fleet clock. Waiting
                // with nothing to do is progress — keep the stall
                // detector anchored so work injected after a long idle
                // stretch does not trip it.
                self.world.clock = horizon;
                self.last_progress = horizon;
                return;
            }
            self.world.drain_arrivals();

            let before = self.world.clock;
            if !plan_and_execute(&mut self.world, self.sched.as_mut(), &self.engine, self.slowdown)
            {
                if self.world.n_active() == 0 {
                    // Only future arrivals remain: waiting is progress.
                    self.last_progress = self.world.clock;
                } else {
                    assert!(
                        self.world.clock - self.last_progress < STALL_HORIZON,
                        "{}: no batch executed for {STALL_HORIZON}s sim time \
                         ({} inbox, {} done/{})",
                        self.sched.name(),
                        self.world.inbox.len(),
                        self.world.n_done(),
                        self.world.recs.len()
                    );
                }
                let idle_step = self.world.clock + 0.05;
                let target = match self.world.next_arrival() {
                    Some(t) if t > self.world.clock => t.min(idle_step),
                    _ => idle_step,
                };
                self.world.clock = target.min(horizon);
                continue;
            }
            self.last_progress = before;
            self.iterations += 1;
        }
    }

    /// Per-replica summary over everything this stepper served, with the
    /// fleet-wide span as the time base (so per-replica throughputs are
    /// comparable and sum correctly).
    pub fn summary_at(&self, end_time: f64) -> Summary {
        let mut s = summarize(&self.world.recs, &self.world.col, end_time);
        (s.n_pred, s.n_close) = self.world.predictor_accuracy();
        s
    }

    /// Canonical Prometheus text of this replica's telemetry registry.
    pub fn metrics_text(&self) -> String {
        self.world.metrics_text()
    }
}

/// Convenience: build world + scheduler + sim engine from names and run.
pub mod harness {
    use super::*;
    use crate::config::SystemConfig;
    use crate::engine::SimEngine;
    use crate::predictor::{OraclePredictor, Predictor, SimPredictor};
    use crate::trace::TraceItem;

    /// Predictor selection for experiment drivers: the base predictor
    /// (oracle, or the per-trace calibrated [`SimPredictor`] with
    /// `cfg.predictor_bias` applied), composed with the
    /// [`crate::predictor::faults::FaultyPredictor`] wrapper when
    /// `cfg.predictor_faults` names an active profile. The fault
    /// timeline draws its seed from the dedicated
    /// [`stream::PREDICTOR`](crate::util::rng::stream) namespace, so
    /// enabling predictor chaos never perturbs the workload, router,
    /// replica-fault, or guardrail streams.
    pub fn predictor_for(cfg: &SystemConfig, trace: &str, oracle: bool) -> Box<dyn Predictor> {
        let inner: Box<dyn Predictor> = if oracle {
            Box::new(OraclePredictor::new(cfg.block_size))
        } else {
            Box::new(
                SimPredictor::for_trace(trace, cfg.block_size, cfg.seed)
                    .with_bias(cfg.predictor_bias),
            )
        };
        let profile = crate::predictor::faults::by_name(&cfg.predictor_faults)
            .unwrap_or_else(|| {
                panic!("unknown predictor fault profile '{}'", cfg.predictor_faults)
            });
        if profile.is_active() {
            let seed = crate::util::rng::derive_seed(cfg.seed, crate::util::rng::stream::PREDICTOR);
            Box::new(crate::predictor::faults::FaultyPredictor::new(
                inner,
                profile,
                seed,
                cfg.block_size,
            ))
        } else {
            inner
        }
    }

    /// One full simulated run of `system` over `items`. `system` uses the
    /// registry grammar, so both `"econoserve"` and grid points like
    /// `"vllm+exact"` work — the resolved allocator is installed into the
    /// world before the run.
    pub fn simulate(
        cfg: &SystemConfig,
        system: &str,
        trace: &str,
        items: &[TraceItem],
        oracle: bool,
        limits: RunLimits,
    ) -> RunResult {
        simulate_traced(cfg, system, trace, items, oracle, limits, None)
    }

    /// As [`simulate`], with optional span tracing: when `tracing` is
    /// `Some`, the world records request-lifecycle spans (pid 0) and the
    /// result carries the finished `TraceDoc`.
    pub fn simulate_traced(
        cfg: &SystemConfig,
        system: &str,
        trace: &str,
        items: &[TraceItem],
        oracle: bool,
        limits: RunLimits,
        tracing: Option<crate::telemetry::TraceConfig>,
    ) -> RunResult {
        let pred = predictor_for(cfg, trace, oracle);
        let mut world = World::new(cfg.clone(), items, pred);
        let sys = crate::sched::by_name(system)
            .unwrap_or_else(|| panic!("unknown system '{system}'"));
        world.set_allocator(sys.alloc);
        if let Some(tc) = tracing {
            world.enable_tracing(tc, 0, system);
        }
        let mut sched = sys.sched;
        let engine = SimEngine::new();
        let res = run(&mut world, sched.as_mut(), &engine, limits);
        if std::env::var("ECONO_DEBUG").is_ok() {
            eprintln!(
                "[kvc breakdown] running-written {:.1}% | running-unwritten {:.1}% | waiting-held {:.1}%",
                world.col.brk_running_written.mean() * 100.0,
                world.col.brk_running_unwritten.mean() * 100.0,
                world.col.brk_waiting_held.mean() * 100.0
            );
        }
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::trace::{TraceGen, TraceSpec};

    #[test]
    fn orca_runs_small_alpaca_slice() {
        let cfg = SystemConfig::new(ModelProfile::opt_13b());
        let gen = TraceGen::new(TraceSpec::alpaca());
        let items = gen.generate(100, 20.0, cfg.profile.max_total_len, 1);
        let res = harness::simulate(&cfg, "orca", "alpaca", &items, true, RunLimits::default());
        assert_eq!(res.summary.n_done, 100);
        assert!(res.summary.mean_jct > 0.0);
        assert!(res.summary.throughput_rps > 0.0);
    }

    #[test]
    fn admission_sheds_overload_and_run_completes() {
        use crate::api::{AdmissionConfig, AdmissionController};
        use crate::engine::SimEngine;
        use crate::predictor::OraclePredictor;

        let cfg = SystemConfig::new(ModelProfile::opt_13b());
        let gen = TraceGen::new(TraceSpec::alpaca());
        // A hard burst: everything arrives at t=0, far beyond a depth-8
        // queue bound.
        let mut items = gen.generate(80, 20.0, cfg.profile.max_total_len, 3);
        for it in &mut items {
            it.arrival = 0.0;
        }
        let n = items.len();
        let pred = Box::new(OraclePredictor::new(cfg.block_size));
        let mut world = crate::core::world::World::new(cfg, &items, pred);
        let sys = crate::sched::by_name("orca").unwrap();
        world.set_allocator(sys.alloc);
        let mut sched = sys.sched;
        let adm = AdmissionController::new(AdmissionConfig {
            max_inflight: 8,
            max_prompt: 0,
            est_token_time: 0.0,
        });
        let res = run_admitted(
            &mut world,
            sched.as_mut(),
            &SimEngine::new(),
            RunLimits::default(),
            Some(&adm),
        );
        assert!(res.rejected > 0, "burst must overflow the depth-8 bound");
        assert_eq!(
            res.summary.n_done + res.rejected,
            n,
            "every request either completes or is shed"
        );
        // Shed requests count against SSR (they are SLO misses).
        assert!(res.summary.ssr <= res.summary.n_done as f64 / n as f64 + 1e-9);
    }

    #[test]
    fn stepper_interleaved_matches_single_run() {
        // Advancing a Stepper in 1 s horizons must execute the same
        // iteration sequence as one uninterrupted `run`: idle clocks are
        // clamped to each horizon but batches only ever start at arrival
        // or idle-quantum points both paths hit exactly. Zero
        // sched-time charging keeps the comparison bit-deterministic.
        let mut cfg = SystemConfig::new(ModelProfile::opt_13b());
        cfg.sched_time_scale = 0.0;
        let gen = TraceGen::new(TraceSpec::alpaca());
        let items = gen.generate(120, 20.0, cfg.profile.max_total_len, 5);
        let full = harness::simulate(&cfg, "orca", "alpaca", &items, true, RunLimits::default());
        let mut st = Stepper::new(cfg, "orca", "alpaca", true, &items);
        assert_eq!(st.sched_name(), "orca");
        let mut horizon = 0.0;
        while !st.world.all_done() {
            horizon += 1.0;
            st.advance_to(horizon);
        }
        let s = st.summary_at(st.world.clock);
        assert_eq!(s.n_done, full.summary.n_done);
        assert!(
            (s.mean_jct - full.summary.mean_jct).abs() < 1e-9,
            "stepper {} vs run {}",
            s.mean_jct,
            full.summary.mean_jct
        );
        assert_eq!(st.iterations, full.summary.iterations);
    }

    #[test]
    fn stepper_injects_mid_run() {
        // The fleet front door routes arrivals while the replica runs:
        // inject after some progress and confirm completion.
        let mut cfg = SystemConfig::new(ModelProfile::opt_13b());
        cfg.sched_time_scale = 0.0;
        let gen = TraceGen::new(TraceSpec::alpaca());
        let items = gen.generate(20, 10.0, cfg.profile.max_total_len, 8);
        let mut st = Stepper::new(cfg, "orca", "alpaca", true, &[]);
        let mut fed = 0usize;
        let mut horizon = 0.0;
        while fed < items.len() || !st.world.all_done() {
            while fed < items.len() && items[fed].arrival <= horizon {
                st.inject(&items[fed]);
                fed += 1;
            }
            horizon += 0.5;
            st.advance_to(horizon);
        }
        assert_eq!(st.world.n_done(), items.len());
        let s = st.summary_at(st.world.clock);
        assert_eq!(s.n_done, items.len());
        assert!(s.mean_jct > 0.0);
    }

    #[test]
    fn time_limit_respected() {
        let cfg = SystemConfig::new(ModelProfile::opt_13b());
        let gen = TraceGen::new(TraceSpec::alpaca());
        let items = gen.generate(5000, 50.0, cfg.profile.max_total_len, 2);
        let res =
            harness::simulate(&cfg, "orca", "alpaca", &items, true, RunLimits::for_time(5.0));
        assert!(res.end_time <= 6.0, "end={}", res.end_time);
        assert!(res.summary.n_done < 5000);
    }
}
