//! The coordinator: the per-iteration control loop of Algorithm 1, shared
//! by the simulation drivers and (via the same `Scheduler`/`Engine`
//! seams) the real PJRT serving path.
//!
//! Loop per iteration:
//!  1. drain arrivals into the inbox,
//!  2. let the scheduler form a batch (measuring its wall-clock cost and
//!     charging it to the simulation clock scaled by
//!     `cfg.sched_time_scale` — so MultiRes's O(n²) scan really shows up
//!     in Fig 14, from measured code rather than a constant),
//!  3. price the batch with the engine,
//!  4. apply the iteration to the world,
//!  5. repeat until everything completed or limits hit.

use std::time::Instant;

use crate::api::AdmissionController;
use crate::core::world::World;
use crate::engine::Engine;
use crate::metrics::{summarize, Summary};
use crate::sched::{plan_iteration, Scheduler};

/// Stop conditions for a run.
#[derive(Debug, Clone, Copy)]
pub struct RunLimits {
    /// Simulated seconds (requests arriving after this still count as
    /// unfinished for SSR).
    pub max_sim_time: f64,
    pub max_iterations: u64,
}

impl Default for RunLimits {
    fn default() -> Self {
        RunLimits { max_sim_time: f64::INFINITY, max_iterations: 50_000_000 }
    }
}

impl RunLimits {
    pub fn for_time(t: f64) -> Self {
        RunLimits { max_sim_time: t, ..Default::default() }
    }
}

/// Outcome of a full run.
pub struct RunResult {
    pub summary: Summary,
    /// Simulated end time.
    pub end_time: f64,
    /// Wall-clock seconds the run took (host side).
    pub wall_time: f64,
    /// Requests shed by admission control (0 unless `run_admitted` is
    /// used with a controller).
    pub rejected: usize,
}

/// Drive `world` with `sched` and `engine` until completion or limits,
/// admitting every arrival (the paper's setup).
pub fn run(
    world: &mut World,
    sched: &mut dyn Scheduler,
    engine: &dyn Engine,
    limits: RunLimits,
) -> RunResult {
    run_admitted(world, sched, engine, limits, None)
}

/// As [`run`], but with the same [`AdmissionController`] front door the
/// real serving path uses: each new arrival is admitted or shed before
/// the scheduler ever sees it (queue-depth bound + SLO infeasibility).
/// Shed requests complete immediately as SLO misses and are counted in
/// `RunResult::rejected`.
pub fn run_admitted(
    world: &mut World,
    sched: &mut dyn Scheduler,
    engine: &dyn Engine,
    limits: RunLimits,
    admission: Option<&AdmissionController>,
) -> RunResult {
    let wall_start = Instant::now();
    let mut iters = 0u64;
    let mut rejected = 0usize;
    // Stall detection: if no batch executes for this much SIMULATED time
    // while work remains, the scheduler is stuck (bug), not waiting.
    const STALL_HORIZON: f64 = 120.0;
    let mut last_progress = 0.0f64;

    loop {
        if world.all_done() || world.clock >= limits.max_sim_time || iters >= limits.max_iterations
        {
            break;
        }
        let newly = world.drain_arrivals();
        if let Some(adm) = admission {
            rejected += shed_new_arrivals(world, adm, newly);
        }

        let t0 = Instant::now();
        let plan = plan_iteration(world, sched);
        let sched_wall = t0.elapsed().as_secs_f64();
        let charged = sched_wall * world.cfg.sched_time_scale;

        if plan.is_empty() {
            // Nothing runnable. Fast-forward: to the next arrival if it is
            // sooner than the idle quantum, else by the idle quantum —
            // schedulers may be waiting on non-arrival wakeups such as
            // prediction readiness (§3.3.2 predictor latency).
            assert!(
                world.clock - last_progress < STALL_HORIZON,
                "{}: no batch executed for {STALL_HORIZON}s sim time ({} inbox, {} done/{})",
                sched.name(),
                world.inbox.len(),
                world.n_done(),
                world.recs.len()
            );
            let idle_step = world.clock + 0.05;
            world.clock = match world.next_arrival() {
                Some(t) if t > world.clock => t.min(idle_step),
                _ => idle_step,
            };
            world.recycle_plan(plan);
            continue;
        }
        last_progress = world.clock;

        world.col.record_sched(charged);
        world.clock += charged;

        let (dur, util) = engine.iteration_cost(&plan, world);
        world.apply_plan(&plan, dur, util);
        // Hand the plan's buffers back for the next iteration
        // (steady-state planning allocates nothing).
        world.recycle_plan(plan);
        iters += 1;
    }

    let end_time = world.clock;
    RunResult {
        summary: summarize(&world.recs, &world.col, end_time),
        end_time,
        wall_time: wall_start.elapsed().as_secs_f64(),
        rejected,
    }
}

/// Apply the admission decision to the `newly` arrivals at the tail of
/// the inbox. The in-flight count matches the real path's definition —
/// every admitted request still in the system (queued anywhere, incl.
/// scheduler-internal queues, or executing), not just the coordinator
/// inbox. The SLO budget is the remaining slack to the deadline; the
/// service estimate uses the PREDICTED response length — the controller,
/// like the scheduler, never sees the true RL.
fn shed_new_arrivals(world: &mut World, adm: &AdmissionController, newly: usize) -> usize {
    if newly == 0 {
        return 0;
    }
    // Arrived-and-unfinished requests (the world's O(1) active index),
    // including the new arrivals themselves; subtract the latter to get
    // the load ahead of them.
    let in_system = world.n_active();
    let mut inflight = in_system - newly;
    let mut shed = 0usize;
    let mut i = world.inbox.len() - newly;
    while i < world.inbox.len() {
        let id = world.inbox[i];
        let rec = &world.recs[id];
        let decision = adm.decide(
            inflight,
            rec.req.prompt_len as usize,
            rec.predicted_rl.max(1) as usize,
            rec.req.deadline - world.clock,
        );
        if decision.is_err() {
            world.inbox.remove(i);
            world.reject(id);
            shed += 1;
        } else {
            inflight += 1;
            i += 1;
        }
    }
    shed
}

/// Convenience: build world + scheduler + sim engine from names and run.
pub mod harness {
    use super::*;
    use crate::config::SystemConfig;
    use crate::engine::SimEngine;
    use crate::predictor::{OraclePredictor, Predictor, SimPredictor};
    use crate::trace::TraceItem;

    /// Predictor selection for experiment drivers.
    pub fn predictor_for(cfg: &SystemConfig, trace: &str, oracle: bool) -> Box<dyn Predictor> {
        if oracle {
            Box::new(OraclePredictor::new(cfg.block_size))
        } else {
            Box::new(SimPredictor::for_trace(trace, cfg.block_size, cfg.seed))
        }
    }

    /// One full simulated run of `system` over `items`. `system` uses the
    /// registry grammar, so both `"econoserve"` and grid points like
    /// `"vllm+exact"` work — the resolved allocator is installed into the
    /// world before the run.
    pub fn simulate(
        cfg: &SystemConfig,
        system: &str,
        trace: &str,
        items: &[TraceItem],
        oracle: bool,
        limits: RunLimits,
    ) -> RunResult {
        let pred = predictor_for(cfg, trace, oracle);
        let mut world = World::new(cfg.clone(), items, pred);
        let sys = crate::sched::by_name(system)
            .unwrap_or_else(|| panic!("unknown system '{system}'"));
        world.set_allocator(sys.alloc);
        let mut sched = sys.sched;
        let engine = SimEngine::new();
        let res = run(&mut world, sched.as_mut(), &engine, limits);
        if std::env::var("ECONO_DEBUG").is_ok() {
            eprintln!(
                "[kvc breakdown] running-written {:.1}% | running-unwritten {:.1}% | waiting-held {:.1}%",
                world.col.brk_running_written.mean() * 100.0,
                world.col.brk_running_unwritten.mean() * 100.0,
                world.col.brk_waiting_held.mean() * 100.0
            );
        }
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::trace::{TraceGen, TraceSpec};

    #[test]
    fn orca_runs_small_alpaca_slice() {
        let cfg = SystemConfig::new(ModelProfile::opt_13b());
        let gen = TraceGen::new(TraceSpec::alpaca());
        let items = gen.generate(100, 20.0, cfg.profile.max_total_len, 1);
        let res = harness::simulate(&cfg, "orca", "alpaca", &items, true, RunLimits::default());
        assert_eq!(res.summary.n_done, 100);
        assert!(res.summary.mean_jct > 0.0);
        assert!(res.summary.throughput_rps > 0.0);
    }

    #[test]
    fn admission_sheds_overload_and_run_completes() {
        use crate::api::{AdmissionConfig, AdmissionController};
        use crate::engine::SimEngine;
        use crate::predictor::OraclePredictor;

        let cfg = SystemConfig::new(ModelProfile::opt_13b());
        let gen = TraceGen::new(TraceSpec::alpaca());
        // A hard burst: everything arrives at t=0, far beyond a depth-8
        // queue bound.
        let mut items = gen.generate(80, 20.0, cfg.profile.max_total_len, 3);
        for it in &mut items {
            it.arrival = 0.0;
        }
        let n = items.len();
        let pred = Box::new(OraclePredictor::new(cfg.block_size));
        let mut world = crate::core::world::World::new(cfg, &items, pred);
        let sys = crate::sched::by_name("orca").unwrap();
        world.set_allocator(sys.alloc);
        let mut sched = sys.sched;
        let adm = AdmissionController::new(AdmissionConfig {
            max_inflight: 8,
            max_prompt: 0,
            est_token_time: 0.0,
        });
        let res = run_admitted(
            &mut world,
            sched.as_mut(),
            &SimEngine::new(),
            RunLimits::default(),
            Some(&adm),
        );
        assert!(res.rejected > 0, "burst must overflow the depth-8 bound");
        assert_eq!(
            res.summary.n_done + res.rejected,
            n,
            "every request either completes or is shed"
        );
        // Shed requests count against SSR (they are SLO misses).
        assert!(res.summary.ssr <= res.summary.n_done as f64 / n as f64 + 1e-9);
    }

    #[test]
    fn time_limit_respected() {
        let cfg = SystemConfig::new(ModelProfile::opt_13b());
        let gen = TraceGen::new(TraceSpec::alpaca());
        let items = gen.generate(5000, 50.0, cfg.profile.max_total_len, 2);
        let res =
            harness::simulate(&cfg, "orca", "alpaca", &items, true, RunLimits::for_time(5.0));
        assert!(res.end_time <= 6.0, "end={}", res.end_time);
        assert!(res.summary.n_done < 5000);
    }
}
