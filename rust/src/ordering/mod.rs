//! Prompt and Generation Task Ordering (§3.4).
//!
//! Tasks are ordered by three bucketed factors, in priority order:
//!  1. **SLO deadline slack** (ascending): ranges 0–0.5 s, 0.5–2 s, > 2 s
//!     (the paper's example ranges);
//!  2. **occupied KVC** (descending, bucketed): run big KVC holders first
//!     so their space frees earlier (Observation 5);
//!  3. **length** (descending): predicted RL for GTs / prompt length for
//!     PTs, so tasks that fill the remaining resource gap are found fast.
//!
//! [`best_fit_leq`] is the paper's "binary search to find a task with the
//! predicted RL or prompt length close to the required length".
//!
//! The policy is engine-agnostic: [`QueuePolicy`] computes the same
//! composite key from a [`QueuedTask`] view, so the simulation scheduler
//! (via [`order_key`]/[`BucketQueue`]) and the real PJRT serving path
//! ([`crate::server`]) share ONE EconoServe ordering implementation. The
//! real path selects a policy by name (`QueuePolicy::by_name`),
//! mirroring `crate::sched::by_name`.
//!
//! Because every factor of the key is **bucketed** (priority class ×
//! deadline bucket × occupied-KVC bucket) with only the length factor
//! dense, the queue does not need a per-iteration re-sort:
//! [`BucketQueue`] keeps tasks in an incremental bucket structure with
//! O(log n) push/pop/remove and re-buckets a task only when one of its
//! key inputs actually changes — deadline-bucket transitions fire from a
//! time calendar (slack only ever shrinks), occupancy/length changes are
//! reported by the scheduler when its events change them.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

use crate::core::world::World;
use crate::core::{ReqId, Time};

/// Composite sort key: smaller = higher priority. Descending factors use
/// [`Reverse`] so the intent is visible in the type rather than hidden in
/// negation arithmetic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct OrderKey {
    /// Explicit client priority class (0 = most urgent; simulation
    /// requests all use 0, so it is inert there).
    pub priority: u8,
    pub deadline_bucket: u8,
    /// Bucketed occupied-KVC, larger occupancy first (Observation 5).
    pub kvc_bucket: Reverse<u32>,
    /// Length, longer first.
    pub len: Reverse<u32>,
    /// Tie-break for determinism (request id / submission order).
    pub tie: u64,
}

/// Engine-agnostic view of one queued task, the input both serving paths
/// feed to a [`QueuePolicy`].
#[derive(Debug, Clone, Copy)]
pub struct QueuedTask {
    /// Submission order: the FCFS key and the deterministic tie-break.
    pub seq: u64,
    /// Explicit priority class (0 = most urgent).
    pub priority: u8,
    /// Seconds until the task's deadline (negative = overdue).
    pub slack: f64,
    /// KVC tokens the task already occupies.
    pub occupied_kvc: u32,
    /// Prompt length (PT) or predicted remaining RL (GT).
    pub len: u32,
}

/// Queue-ordering policy for a serving front-end. `Fcfs` is the baseline;
/// `EconoServe` is the paper's §3.4 three-factor ordering.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueuePolicy {
    Fcfs,
    EconoServe,
}

impl QueuePolicy {
    /// Policy registry by name (the real-path analogue of
    /// `sched::by_name`).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "fcfs" => Some(QueuePolicy::Fcfs),
            "econoserve" => Some(QueuePolicy::EconoServe),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QueuePolicy::Fcfs => "fcfs",
            QueuePolicy::EconoServe => "econoserve",
        }
    }

    pub fn names() -> &'static [&'static str] {
        &["fcfs", "econoserve"]
    }

    /// The composite key for one queued task (smaller = run sooner).
    pub fn key(self, t: &QueuedTask) -> OrderKey {
        match self {
            QueuePolicy::Fcfs => OrderKey {
                priority: t.priority,
                deadline_bucket: 0,
                kvc_bucket: Reverse(0),
                len: Reverse(0),
                tie: t.seq,
            },
            QueuePolicy::EconoServe => OrderKey {
                priority: t.priority,
                deadline_bucket: deadline_bucket(t.slack),
                kvc_bucket: Reverse(t.occupied_kvc / KVC_BUCKET),
                len: Reverse(t.len),
                tie: t.seq,
            },
        }
    }

    /// Index of the task to run next, `None` on an empty queue.
    pub fn select(self, queue: &[QueuedTask]) -> Option<usize> {
        (0..queue.len()).min_by_key(|&i| self.key(&queue[i]))
    }
}

/// Deadline slack buckets (seconds until the JCT deadline).
pub fn deadline_bucket(slack: f64) -> u8 {
    if slack < 0.5 {
        0
    } else if slack < 2.0 {
        1
    } else {
        2
    }
}

/// Occupied-KVC bucket width in tokens (two vLLM blocks of 32 by default;
/// buckets keep factor 2 from overriding factor 1 on noise).
pub const KVC_BUCKET: u32 = 256;

/// Key for a simulated task; `len` is predicted RL (GT) or prompt length
/// (PT). Routes through [`QueuePolicy::EconoServe`] so both engines rank
/// with the identical key function.
pub fn order_key(world: &World, id: ReqId, len: u32) -> OrderKey {
    let rec = &world.recs[id];
    QueuePolicy::EconoServe.key(&QueuedTask {
        seq: id as u64,
        priority: 0,
        slack: rec.req.deadline - world.clock,
        occupied_kvc: world.occupied_kvc(id),
        len,
    })
}

/// Binary search over a **descending-length-sorted** slice of (len, idx)
/// pairs: the first entry with `len <= cap` (i.e. the largest that fits).
/// Returns the position in `pairs`, or None if nothing fits.
pub fn best_fit_leq(pairs: &[(u32, usize)], cap: u32) -> Option<usize> {
    if pairs.is_empty() {
        return None;
    }
    // pairs sorted descending by len: find first index with len <= cap.
    let (mut lo, mut hi) = (0usize, pairs.len());
    while lo < hi {
        let mid = (lo + hi) / 2;
        if pairs[mid].0 > cap {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    if lo < pairs.len() {
        Some(lo)
    } else {
        None
    }
}

// ---------------------------------------------------------------------
// Incremental bucket queue
// ---------------------------------------------------------------------

/// Next representable f64 strictly greater than `x` (finite `x`).
fn bump(x: f64) -> f64 {
    if x.is_nan() {
        return x;
    }
    if x == 0.0 {
        return f64::from_bits(1); // smallest positive subnormal
    }
    let bits = x.to_bits();
    if x > 0.0 {
        f64::from_bits(bits + 1)
    } else {
        f64::from_bits(bits - 1)
    }
}

/// Calendar entry: re-examine `id`'s deadline bucket at time `at`.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Trigger {
    at: Time,
    id: ReqId,
}

impl Eq for Trigger {}

impl PartialOrd for Trigger {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Trigger {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at.total_cmp(&other.at).then(self.id.cmp(&other.id))
    }
}

#[derive(Debug, Clone, Copy)]
struct Entry {
    key: OrderKey,
    deadline: Time,
    priority: u8,
    occupied_kvc: u32,
    len: u32,
}

/// Incremental priority queue over the §3.4 bucketed [`OrderKey`].
///
/// Invariant: after `refresh(clock)` every queued task sits under its
/// *canonical* key at `clock` — the exact key a linear scan with
/// [`order_key`] would compute. Between refreshes only the
/// deadline-bucket factor can go stale, and only toward laxer-than-true;
/// `refresh` migrates those tasks from a time calendar (a task's slack
/// only shrinks, so it crosses each bucket edge once). All mutators that
/// read order (`pop_first`, `peek_first`, `best_fit_leq`) refresh first.
///
/// Complexity: `push`/`remove`/`update` O(log n); `pop_first` O(log n);
/// `best_fit_leq` O(buckets · log n) worst case; calendar migrations are
/// amortized ≤ 2 per task lifetime. No per-iteration re-sort anywhere.
#[derive(Debug, Clone, Default)]
pub struct BucketQueue {
    policy: Option<QueuePolicy>,
    /// Flat bucket structure: the composite key IS the bucket path
    /// (priority → deadline bucket → occupied-KVC bucket → length →
    /// deterministic tie), so a BTreeMap range scan walks buckets in
    /// priority order and serves best-fit length queries per bucket.
    queue: BTreeMap<OrderKey, ReqId>,
    entries: Vec<Option<Entry>>,
    /// Deadline-bucket transition calendar (min-heap on time).
    calendar: BinaryHeap<Reverse<Trigger>>,
    count: usize,
}

impl BucketQueue {
    pub fn new(policy: QueuePolicy) -> Self {
        BucketQueue { policy: Some(policy), ..Default::default() }
    }

    fn policy(&self) -> QueuePolicy {
        self.policy.unwrap_or(QueuePolicy::EconoServe)
    }

    fn canonical_key(&self, e: &Entry, clock: Time) -> OrderKey {
        self.policy().key(&QueuedTask {
            seq: e.key.tie,
            priority: e.priority,
            slack: e.deadline - clock,
            occupied_kvc: e.occupied_kvc,
            len: e.len,
        })
    }

    /// Arm the calendar for `id`'s next deadline-bucket edge (slack
    /// thresholds 2.0 s and 0.5 s), if any remain.
    fn arm(&mut self, id: ReqId, deadline: Time, db: u8) {
        if self.policy() != QueuePolicy::EconoServe {
            return; // FCFS keys have no time-varying factor
        }
        let threshold = match db {
            2 => deadline - 2.0,
            1 => deadline - 0.5,
            _ => return,
        };
        self.calendar.push(Reverse(Trigger { at: threshold, id }));
    }

    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn contains(&self, id: ReqId) -> bool {
        self.entries.get(id).map(|e| e.is_some()).unwrap_or(false)
    }

    /// Current key of a queued task (exact after a refresh at the same
    /// clock).
    pub fn key_of(&self, id: ReqId) -> Option<OrderKey> {
        self.entries.get(id).and_then(|e| e.as_ref()).map(|e| e.key)
    }

    /// Enqueue `id`. `deadline` is the absolute JCT deadline; the tie
    /// factor is the id itself, matching [`order_key`]'s deterministic
    /// tie-break. Must not already be queued.
    pub fn push(
        &mut self,
        id: ReqId,
        priority: u8,
        deadline: Time,
        occupied_kvc: u32,
        len: u32,
        clock: Time,
    ) {
        if id >= self.entries.len() {
            self.entries.resize(id + 1, None);
        }
        assert!(self.entries[id].is_none(), "BucketQueue: duplicate push of {id}");
        let mut e = Entry {
            key: OrderKey {
                priority,
                deadline_bucket: 0,
                kvc_bucket: Reverse(0),
                len: Reverse(0),
                tie: id as u64,
            },
            deadline,
            priority,
            occupied_kvc,
            len,
        };
        e.key = self.canonical_key(&e, clock);
        let prev = self.queue.insert(e.key, id);
        debug_assert!(prev.is_none(), "BucketQueue: key collision");
        let db = e.key.deadline_bucket;
        self.entries[id] = Some(e);
        self.count += 1;
        self.arm(id, deadline, db);
    }

    /// Dequeue `id` if queued; returns whether it was. Stale calendar
    /// triggers are skipped lazily.
    pub fn remove(&mut self, id: ReqId) -> bool {
        match self.entries.get_mut(id).and_then(|e| e.take()) {
            Some(e) => {
                let removed = self.queue.remove(&e.key);
                debug_assert_eq!(removed, Some(id), "BucketQueue: map out of sync");
                self.count -= 1;
                true
            }
            None => false,
        }
    }

    /// Re-key `id` after its occupied-KVC or length input changed (the
    /// event-driven re-bucketing path).
    pub fn update(&mut self, id: ReqId, occupied_kvc: u32, len: u32, clock: Time) {
        let Some(slot) = self.entries.get_mut(id) else { return };
        let Some(e) = slot.as_mut() else { return };
        let old_key = e.key;
        e.occupied_kvc = occupied_kvc;
        e.len = len;
        let new = *e;
        let new_key = self.canonical_key(&new, clock);
        if new_key != old_key {
            self.queue.remove(&old_key);
            self.queue.insert(new_key, id);
            let (deadline, db) = {
                let e = self.entries[id].as_mut().expect("entry just seen");
                e.key = new_key;
                (e.deadline, new_key.deadline_bucket)
            };
            if db > old_key.deadline_bucket {
                // Clock regression (tests only): existing triggers lapsed.
                self.arm(id, deadline, db);
            }
        }
    }

    /// Migrate every task whose deadline bucket has tightened by `clock`.
    /// After this, stored keys are canonical at `clock`.
    pub fn refresh(&mut self, clock: Time) {
        while let Some(&Reverse(t)) = self.calendar.peek() {
            if t.at > clock {
                break;
            }
            self.calendar.pop();
            let Some(e) = self.entries.get(t.id).copied().flatten() else {
                continue; // stale: task left the queue
            };
            let canonical = self.canonical_key(&e, clock);
            if canonical == e.key {
                // Stale or ulp-early trigger: re-arm at the entry's real
                // next edge if it is still ahead, else one float past
                // `clock` (the flip is provably later than `clock`).
                let next = match e.key.deadline_bucket {
                    2 => e.deadline - 2.0,
                    1 => e.deadline - 0.5,
                    _ => continue,
                };
                let at = if next > clock { next } else { bump(clock.max(t.at)) };
                self.calendar.push(Reverse(Trigger { at, id: t.id }));
                continue;
            }
            self.queue.remove(&e.key);
            self.queue.insert(canonical, t.id);
            let slot = self.entries[t.id].as_mut().expect("entry just seen");
            slot.key = canonical;
            self.arm(t.id, e.deadline, canonical.deadline_bucket);
        }
    }

    /// Highest-priority task (smallest canonical key at `clock`), without
    /// removing it.
    pub fn peek_first(&mut self, clock: Time) -> Option<ReqId> {
        self.refresh(clock);
        self.queue.first_key_value().map(|(_, &id)| id)
    }

    /// Pop the highest-priority task.
    pub fn pop_first(&mut self, clock: Time) -> Option<ReqId> {
        self.refresh(clock);
        let (key, id) = self.queue.pop_first()?;
        let e = self.entries[id].take().expect("queue/entries out of sync");
        debug_assert_eq!(e.key, key);
        self.count -= 1;
        Some(id)
    }

    /// Best-fit pop source (§3.4 gap filling): within the most urgent
    /// non-empty (priority, deadline, KVC) bucket, the LONGEST task with
    /// `len <= cap`; falls through to later buckets when nothing fits.
    /// Returns the id without removing it.
    ///
    /// Equivalent to the minimum canonical key over all queued tasks with
    /// `len <= cap` — O(buckets · log n) under EconoServe (the key's
    /// length factor is the true length, so range queries serve it);
    /// O(n) under FCFS, whose keys zero the length factor.
    pub fn best_fit_leq(&mut self, cap: u32, clock: Time) -> Option<ReqId> {
        self.refresh(clock);
        if self.policy() != QueuePolicy::EconoServe {
            // FCFS keys carry no length factor: first task in key
            // (submission) order whose TRUE length fits.
            return self
                .queue
                .values()
                .copied()
                .find(|&id| self.entries[id].map(|e| e.len).unwrap_or(0) <= cap);
        }
        let mut probe = *self.queue.first_key_value()?.0;
        loop {
            let start = OrderKey {
                priority: probe.priority,
                deadline_bucket: probe.deadline_bucket,
                kvc_bucket: probe.kvc_bucket,
                len: Reverse(cap),
                tie: 0,
            };
            let (k, &id) = self.queue.range(start..).next()?;
            if (k.priority, k.deadline_bucket, k.kvc_bucket)
                == (probe.priority, probe.deadline_bucket, probe.kvc_bucket)
            {
                return Some(id);
            }
            probe = *k; // jumped into a later bucket; retry there
        }
    }

    /// Queued ids in current key order (diagnostics/tests).
    pub fn iter_ids(&self) -> impl Iterator<Item = ReqId> + '_ {
        self.queue.values().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::kvc::Allocator;
    use crate::predictor::OraclePredictor;
    use crate::trace::TraceItem;

    fn world(items: &[TraceItem]) -> World {
        let cfg = SystemConfig::new(ModelProfile::opt_13b());
        let p = Box::new(OraclePredictor::new(1));
        World::new(cfg, items, p)
    }

    #[test]
    fn deadline_buckets() {
        assert_eq!(deadline_bucket(0.1), 0);
        assert_eq!(deadline_bucket(1.0), 1);
        assert_eq!(deadline_bucket(10.0), 2);
        assert_eq!(deadline_bucket(-3.0), 0); // overdue = most urgent
    }

    /// Push every id into an EconoServe [`BucketQueue`] with its current
    /// world-state inputs, then drain it — the incremental replacement
    /// for the old `sort_pts` full sort.
    fn drain_order(w: &World, ids: &[usize]) -> Vec<usize> {
        let mut q = BucketQueue::new(QueuePolicy::EconoServe);
        for &id in ids {
            let rec = &w.recs[id];
            let len = rec.req.prompt_len - rec.prompt_done;
            q.push(id, 0, rec.req.deadline, w.occupied_kvc(id), len, w.clock);
        }
        let mut out = Vec::new();
        while let Some(id) = q.pop_first(w.clock) {
            out.push(id);
        }
        out
    }

    #[test]
    fn urgent_tasks_first_then_big_kvc_then_long() {
        let mut w = world(&[
            TraceItem { arrival: 0.0, prompt_len: 100, true_rl: 10 }, // long, lax
            TraceItem { arrival: 0.0, prompt_len: 10, true_rl: 10 },  // short, lax
            TraceItem { arrival: 0.0, prompt_len: 50, true_rl: 10 },  // urgent
        ]);
        // Force deadlines: id 2 nearly due, others far out.
        w.recs[0].req.deadline = w.clock + 100.0;
        w.recs[1].req.deadline = w.clock + 100.0;
        w.recs[2].req.deadline = w.clock + 0.1;
        let ids = drain_order(&w, &[0, 1, 2]);
        assert_eq!(ids[0], 2, "urgent first");
        assert_eq!(ids[1], 0, "then longest prompt");
        assert_eq!(ids[2], 1);
    }

    #[test]
    fn occupied_kvc_beats_length() {
        let mut w = world(&[
            TraceItem { arrival: 0.0, prompt_len: 500, true_rl: 10 },
            TraceItem { arrival: 0.0, prompt_len: 10, true_rl: 10 },
        ]);
        // Give id 1 a big resident KVC footprint (e.g. preempted GT).
        assert!(w
            .kvc_mut()
            .extend(1, 600, crate::kvc::ReserveClass::Reserved)
            .ok());
        w.kvc_mut().record_write(1, 600);
        w.recs[0].req.deadline = w.clock + 100.0;
        w.recs[1].req.deadline = w.clock + 100.0;
        let ids = drain_order(&w, &[0, 1]);
        assert_eq!(ids[0], 1, "bigger KVC holder first despite shorter prompt");
    }

    #[test]
    fn bucket_queue_migrates_across_deadline_edges() {
        // Task 0 enters lax (slack 10 -> bucket 2) behind mid-bucket
        // task 1 (slack 1.5 -> bucket 1). As the clock erodes both into
        // bucket 0, the longer task 0 must take the lead — purely through
        // calendar migration, with no re-push from the caller.
        let mut q = BucketQueue::new(QueuePolicy::EconoServe);
        q.push(0, 0, 10.0, 0, 50, 0.0);
        q.push(1, 0, 1.5, 0, 10, 0.0);
        assert_eq!(q.peek_first(0.0), Some(1), "tighter deadline bucket leads");
        assert_eq!(q.peek_first(9.7), Some(0), "same bucket now: longer task leads");
        assert_eq!(q.pop_first(9.7), Some(0));
        assert_eq!(q.pop_first(9.7), Some(1));
        assert!(q.is_empty());
    }

    #[test]
    fn bucket_queue_matches_linear_scan_as_time_passes() {
        // Canonical-key equivalence: at every probe clock the queue head
        // equals a linear min-scan over the same QueuedTask inputs.
        let deadlines = [0.4, 0.9, 1.6, 2.4, 3.0, 5.0, 9.5];
        let mut q = BucketQueue::new(QueuePolicy::EconoServe);
        for (id, &d) in deadlines.iter().enumerate() {
            q.push(id, 0, d, (id as u32 % 3) * 300, 10 + id as u32, 0.0);
        }
        let mut clock = 0.0;
        while clock < 10.0 {
            let want = (0..deadlines.len())
                .min_by_key(|&id| {
                    QueuePolicy::EconoServe.key(&QueuedTask {
                        seq: id as u64,
                        priority: 0,
                        slack: deadlines[id] - clock,
                        occupied_kvc: (id as u32 % 3) * 300,
                        len: 10 + id as u32,
                    })
                })
                .unwrap();
            assert_eq!(q.peek_first(clock), Some(want), "clock={clock}");
            clock += 0.173;
        }
    }

    #[test]
    fn bucket_queue_best_fit_serves_longest_fitting() {
        let mut q = BucketQueue::new(QueuePolicy::EconoServe);
        // Same bucket (equal deadline class, no KVC): lens 512/256/64.
        for (id, len) in [(0usize, 512u32), (1, 256), (2, 64)].iter().copied() {
            q.push(id, 0, 100.0, 0, len, 0.0);
        }
        assert_eq!(q.best_fit_leq(1024, 0.0), Some(0));
        assert_eq!(q.best_fit_leq(300, 0.0), Some(1));
        assert_eq!(q.best_fit_leq(70, 0.0), Some(2));
        assert_eq!(q.best_fit_leq(10, 0.0), None);
        // remove + update re-bucketing.
        assert!(q.remove(0));
        q.update(1, 600, 256, 0.0); // big occupancy: now leads outright
        assert_eq!(q.peek_first(0.0), Some(1));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn bucket_queue_fcfs_pops_in_id_order() {
        let mut q = BucketQueue::new(QueuePolicy::Fcfs);
        for id in [5usize, 2, 9, 4] {
            q.push(id, 0, 1.0, 0, 10, 0.0);
        }
        let mut got = Vec::new();
        while let Some(id) = q.pop_first(50.0) {
            got.push(id);
        }
        assert_eq!(got, vec![2, 4, 5, 9], "FCFS = tie order, immune to deadlines");
    }

    fn task(seq: u64, slack: f64, len: u32) -> QueuedTask {
        QueuedTask { seq, priority: 0, slack, occupied_kvc: 0, len }
    }

    #[test]
    fn policy_registry_by_name() {
        assert_eq!(QueuePolicy::by_name("fcfs"), Some(QueuePolicy::Fcfs));
        assert_eq!(QueuePolicy::by_name("econoserve"), Some(QueuePolicy::EconoServe));
        assert_eq!(QueuePolicy::by_name("nope"), None);
        for name in QueuePolicy::names() {
            assert_eq!(QueuePolicy::by_name(name).unwrap().name(), *name);
        }
    }

    #[test]
    fn fcfs_selects_in_submission_order() {
        let q = [task(5, 0.1, 9), task(2, 100.0, 1), task(7, 0.0, 50)];
        assert_eq!(QueuePolicy::Fcfs.select(&q), Some(1));
        assert_eq!(QueuePolicy::Fcfs.select(&[]), None);
    }

    #[test]
    fn econoserve_selects_urgent_then_longest() {
        // Same lax deadline bucket: the longer prompt wins (this is the
        // Reverse(len) factor, previously an usize::MAX subtraction hack
        // on the real path).
        let q = [task(0, 100.0, 10), task(1, 100.0, 80), task(2, 100.0, 40)];
        assert_eq!(QueuePolicy::EconoServe.select(&q), Some(1));
        // An urgent task beats a longer lax one.
        let q = [task(0, 100.0, 80), task(1, 0.1, 4)];
        assert_eq!(QueuePolicy::EconoServe.select(&q), Some(1));
    }

    #[test]
    fn explicit_priority_ranks_above_deadline() {
        let urgent_low_pri = QueuedTask { seq: 0, priority: 1, slack: 0.1, occupied_kvc: 0, len: 4 };
        let lax_high_pri = QueuedTask { seq: 1, priority: 0, slack: 100.0, occupied_kvc: 0, len: 4 };
        assert_eq!(QueuePolicy::EconoServe.select(&[urgent_low_pri, lax_high_pri]), Some(1));
    }

    #[test]
    fn policy_key_matches_sim_order_key() {
        // The simulated path's order_key and the real path's policy key
        // are the same function: identical inputs -> identical key.
        let w = world(&[TraceItem { arrival: 0.0, prompt_len: 50, true_rl: 10 }]);
        let rec = &w.recs[0];
        let via_world = order_key(&w, 0, 50);
        let via_policy = QueuePolicy::EconoServe.key(&QueuedTask {
            seq: 0,
            priority: 0,
            slack: rec.req.deadline - w.clock,
            occupied_kvc: w.occupied_kvc(0),
            len: 50,
        });
        assert_eq!(via_world, via_policy);
    }

    #[test]
    fn best_fit_binary_search() {
        // Descending lengths.
        let pairs = vec![(512u32, 0usize), (256, 1), (128, 2), (64, 3), (16, 4)];
        assert_eq!(best_fit_leq(&pairs, 1024), Some(0));
        assert_eq!(best_fit_leq(&pairs, 300), Some(1));
        assert_eq!(best_fit_leq(&pairs, 128), Some(2));
        assert_eq!(best_fit_leq(&pairs, 100), Some(3));
        assert_eq!(best_fit_leq(&pairs, 10), None);
        assert_eq!(best_fit_leq(&[], 10), None);
    }
}
