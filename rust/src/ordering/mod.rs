//! Prompt and Generation Task Ordering (§3.4).
//!
//! Tasks are ordered by three bucketed factors, in priority order:
//!  1. **SLO deadline slack** (ascending): ranges 0–0.5 s, 0.5–2 s, > 2 s
//!     (the paper's example ranges);
//!  2. **occupied KVC** (descending, bucketed): run big KVC holders first
//!     so their space frees earlier (Observation 5);
//!  3. **length** (descending): predicted RL for GTs / prompt length for
//!     PTs, so tasks that fill the remaining resource gap are found fast.
//!
//! [`best_fit_leq`] is the paper's "binary search to find a task with the
//! predicted RL or prompt length close to the required length".
//!
//! The policy is engine-agnostic: [`QueuePolicy`] computes the same
//! composite key from a [`QueuedTask`] view, so the simulation scheduler
//! (via [`order_key`]/[`sort_pts`]/[`sort_gts`]) and the real PJRT
//! serving path ([`crate::server`]) share ONE EconoServe ordering
//! implementation. The real path selects a policy by name
//! (`QueuePolicy::by_name`), mirroring `crate::sched::by_name`.

use std::cmp::Reverse;

use crate::core::world::World;
use crate::core::ReqId;

/// Composite sort key: smaller = higher priority. Descending factors use
/// [`Reverse`] so the intent is visible in the type rather than hidden in
/// negation arithmetic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct OrderKey {
    /// Explicit client priority class (0 = most urgent; simulation
    /// requests all use 0, so it is inert there).
    pub priority: u8,
    pub deadline_bucket: u8,
    /// Bucketed occupied-KVC, larger occupancy first (Observation 5).
    pub kvc_bucket: Reverse<u32>,
    /// Length, longer first.
    pub len: Reverse<u32>,
    /// Tie-break for determinism (request id / submission order).
    pub tie: u64,
}

/// Engine-agnostic view of one queued task, the input both serving paths
/// feed to a [`QueuePolicy`].
#[derive(Debug, Clone, Copy)]
pub struct QueuedTask {
    /// Submission order: the FCFS key and the deterministic tie-break.
    pub seq: u64,
    /// Explicit priority class (0 = most urgent).
    pub priority: u8,
    /// Seconds until the task's deadline (negative = overdue).
    pub slack: f64,
    /// KVC tokens the task already occupies.
    pub occupied_kvc: u32,
    /// Prompt length (PT) or predicted remaining RL (GT).
    pub len: u32,
}

/// Queue-ordering policy for a serving front-end. `Fcfs` is the baseline;
/// `EconoServe` is the paper's §3.4 three-factor ordering.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueuePolicy {
    Fcfs,
    EconoServe,
}

impl QueuePolicy {
    /// Policy registry by name (the real-path analogue of
    /// `sched::by_name`).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "fcfs" => Some(QueuePolicy::Fcfs),
            "econoserve" => Some(QueuePolicy::EconoServe),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QueuePolicy::Fcfs => "fcfs",
            QueuePolicy::EconoServe => "econoserve",
        }
    }

    pub fn names() -> &'static [&'static str] {
        &["fcfs", "econoserve"]
    }

    /// The composite key for one queued task (smaller = run sooner).
    pub fn key(self, t: &QueuedTask) -> OrderKey {
        match self {
            QueuePolicy::Fcfs => OrderKey {
                priority: t.priority,
                deadline_bucket: 0,
                kvc_bucket: Reverse(0),
                len: Reverse(0),
                tie: t.seq,
            },
            QueuePolicy::EconoServe => OrderKey {
                priority: t.priority,
                deadline_bucket: deadline_bucket(t.slack),
                kvc_bucket: Reverse(t.occupied_kvc / KVC_BUCKET),
                len: Reverse(t.len),
                tie: t.seq,
            },
        }
    }

    /// Index of the task to run next, `None` on an empty queue.
    pub fn select(self, queue: &[QueuedTask]) -> Option<usize> {
        (0..queue.len()).min_by_key(|&i| self.key(&queue[i]))
    }
}

/// Deadline slack buckets (seconds until the JCT deadline).
pub fn deadline_bucket(slack: f64) -> u8 {
    if slack < 0.5 {
        0
    } else if slack < 2.0 {
        1
    } else {
        2
    }
}

/// Occupied-KVC bucket width in tokens (two vLLM blocks of 32 by default;
/// buckets keep factor 2 from overriding factor 1 on noise).
pub const KVC_BUCKET: u32 = 256;

/// Key for a simulated task; `len` is predicted RL (GT) or prompt length
/// (PT). Routes through [`QueuePolicy::EconoServe`] so both engines rank
/// with the identical key function.
pub fn order_key(world: &World, id: ReqId, len: u32) -> OrderKey {
    let rec = &world.recs[id];
    QueuePolicy::EconoServe.key(&QueuedTask {
        seq: id as u64,
        priority: 0,
        slack: rec.req.deadline - world.clock,
        occupied_kvc: world.occupied_kvc(id),
        len,
    })
}

/// Sort `ids` in scheduling-priority order (stable, deterministic).
pub fn sort_pts(world: &World, ids: &mut [ReqId]) {
    ids.sort_by_key(|&id| {
        let len = world.recs[id].req.prompt_len - world.recs[id].prompt_done;
        order_key(world, id, len)
    });
}

pub fn sort_gts(world: &World, ids: &mut [ReqId]) {
    ids.sort_by_key(|&id| order_key(world, id, world.recs[id].predicted_remaining()));
}

/// Binary search over a **descending-length-sorted** slice of (len, idx)
/// pairs: the first entry with `len <= cap` (i.e. the largest that fits).
/// Returns the position in `pairs`, or None if nothing fits.
pub fn best_fit_leq(pairs: &[(u32, usize)], cap: u32) -> Option<usize> {
    if pairs.is_empty() {
        return None;
    }
    // pairs sorted descending by len: find first index with len <= cap.
    let (mut lo, mut hi) = (0usize, pairs.len());
    while lo < hi {
        let mid = (lo + hi) / 2;
        if pairs[mid].0 > cap {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    if lo < pairs.len() {
        Some(lo)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::kvc::Allocator;
    use crate::predictor::OraclePredictor;
    use crate::trace::TraceItem;

    fn world(items: &[TraceItem]) -> World {
        let cfg = SystemConfig::new(ModelProfile::opt_13b());
        let p = Box::new(OraclePredictor::new(1));
        World::new(cfg, items, p)
    }

    #[test]
    fn deadline_buckets() {
        assert_eq!(deadline_bucket(0.1), 0);
        assert_eq!(deadline_bucket(1.0), 1);
        assert_eq!(deadline_bucket(10.0), 2);
        assert_eq!(deadline_bucket(-3.0), 0); // overdue = most urgent
    }

    #[test]
    fn urgent_tasks_first_then_big_kvc_then_long() {
        let mut w = world(&[
            TraceItem { arrival: 0.0, prompt_len: 100, true_rl: 10 }, // long, lax
            TraceItem { arrival: 0.0, prompt_len: 10, true_rl: 10 },  // short, lax
            TraceItem { arrival: 0.0, prompt_len: 50, true_rl: 10 },  // urgent
        ]);
        // Force deadlines: id 2 nearly due, others far out.
        w.recs[0].req.deadline = w.clock + 100.0;
        w.recs[1].req.deadline = w.clock + 100.0;
        w.recs[2].req.deadline = w.clock + 0.1;
        let mut ids = vec![0, 1, 2];
        sort_pts(&w, &mut ids);
        assert_eq!(ids[0], 2, "urgent first");
        assert_eq!(ids[1], 0, "then longest prompt");
        assert_eq!(ids[2], 1);
    }

    #[test]
    fn occupied_kvc_beats_length() {
        let mut w = world(&[
            TraceItem { arrival: 0.0, prompt_len: 500, true_rl: 10 },
            TraceItem { arrival: 0.0, prompt_len: 10, true_rl: 10 },
        ]);
        // Give id 1 a big resident KVC footprint (e.g. preempted GT).
        assert!(w
            .kvc_mut()
            .extend(1, 600, crate::kvc::ReserveClass::Reserved)
            .ok());
        w.kvc_mut().record_write(1, 600);
        w.recs[0].req.deadline = w.clock + 100.0;
        w.recs[1].req.deadline = w.clock + 100.0;
        let mut ids = vec![0, 1];
        sort_pts(&w, &mut ids);
        assert_eq!(ids[0], 1, "bigger KVC holder first despite shorter prompt");
    }

    fn task(seq: u64, slack: f64, len: u32) -> QueuedTask {
        QueuedTask { seq, priority: 0, slack, occupied_kvc: 0, len }
    }

    #[test]
    fn policy_registry_by_name() {
        assert_eq!(QueuePolicy::by_name("fcfs"), Some(QueuePolicy::Fcfs));
        assert_eq!(QueuePolicy::by_name("econoserve"), Some(QueuePolicy::EconoServe));
        assert_eq!(QueuePolicy::by_name("nope"), None);
        for name in QueuePolicy::names() {
            assert_eq!(QueuePolicy::by_name(name).unwrap().name(), *name);
        }
    }

    #[test]
    fn fcfs_selects_in_submission_order() {
        let q = [task(5, 0.1, 9), task(2, 100.0, 1), task(7, 0.0, 50)];
        assert_eq!(QueuePolicy::Fcfs.select(&q), Some(1));
        assert_eq!(QueuePolicy::Fcfs.select(&[]), None);
    }

    #[test]
    fn econoserve_selects_urgent_then_longest() {
        // Same lax deadline bucket: the longer prompt wins (this is the
        // Reverse(len) factor, previously an usize::MAX subtraction hack
        // on the real path).
        let q = [task(0, 100.0, 10), task(1, 100.0, 80), task(2, 100.0, 40)];
        assert_eq!(QueuePolicy::EconoServe.select(&q), Some(1));
        // An urgent task beats a longer lax one.
        let q = [task(0, 100.0, 80), task(1, 0.1, 4)];
        assert_eq!(QueuePolicy::EconoServe.select(&q), Some(1));
    }

    #[test]
    fn explicit_priority_ranks_above_deadline() {
        let urgent_low_pri = QueuedTask { seq: 0, priority: 1, slack: 0.1, occupied_kvc: 0, len: 4 };
        let lax_high_pri = QueuedTask { seq: 1, priority: 0, slack: 100.0, occupied_kvc: 0, len: 4 };
        assert_eq!(QueuePolicy::EconoServe.select(&[urgent_low_pri, lax_high_pri]), Some(1));
    }

    #[test]
    fn policy_key_matches_sim_order_key() {
        // The simulated path's order_key and the real path's policy key
        // are the same function: identical inputs -> identical key.
        let w = world(&[TraceItem { arrival: 0.0, prompt_len: 50, true_rl: 10 }]);
        let rec = &w.recs[0];
        let via_world = order_key(&w, 0, 50);
        let via_policy = QueuePolicy::EconoServe.key(&QueuedTask {
            seq: 0,
            priority: 0,
            slack: rec.req.deadline - w.clock,
            occupied_kvc: w.occupied_kvc(0),
            len: 50,
        });
        assert_eq!(via_world, via_policy);
    }

    #[test]
    fn best_fit_binary_search() {
        // Descending lengths.
        let pairs = vec![(512u32, 0usize), (256, 1), (128, 2), (64, 3), (16, 4)];
        assert_eq!(best_fit_leq(&pairs, 1024), Some(0));
        assert_eq!(best_fit_leq(&pairs, 300), Some(1));
        assert_eq!(best_fit_leq(&pairs, 128), Some(2));
        assert_eq!(best_fit_leq(&pairs, 100), Some(3));
        assert_eq!(best_fit_leq(&pairs, 10), None);
        assert_eq!(best_fit_leq(&[], 10), None);
    }
}
