//! Prompt and Generation Task Ordering (§3.4).
//!
//! Tasks are ordered by three bucketed factors, in priority order:
//!  1. **SLO deadline slack** (ascending): ranges 0–0.5 s, 0.5–2 s, > 2 s
//!     (the paper's example ranges);
//!  2. **occupied KVC** (descending, bucketed): run big KVC holders first
//!     so their space frees earlier (Observation 5);
//!  3. **length** (descending): predicted RL for GTs / prompt length for
//!     PTs, so tasks that fill the remaining resource gap are found fast.
//!
//! [`best_fit_leq`] is the paper's "binary search to find a task with the
//! predicted RL or prompt length close to the required length".

use crate::core::world::World;
use crate::core::ReqId;

/// Composite sort key: smaller = higher priority.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct OrderKey {
    pub deadline_bucket: u8,
    /// Negated bucketed occupied-KVC (so larger occupancy sorts first).
    pub neg_kvc_bucket: i32,
    /// Negated length (longer first).
    pub neg_len: i64,
    /// Tie-break for determinism.
    pub id: ReqId,
}

/// Deadline slack buckets (seconds until the JCT deadline).
pub fn deadline_bucket(slack: f64) -> u8 {
    if slack < 0.5 {
        0
    } else if slack < 2.0 {
        1
    } else {
        2
    }
}

/// Occupied-KVC bucket width in tokens (two vLLM blocks of 32 by default;
/// buckets keep factor 2 from overriding factor 1 on noise).
pub const KVC_BUCKET: u32 = 256;

/// Key for a task; `len` is predicted RL (GT) or prompt length (PT).
pub fn order_key(world: &World, id: ReqId, len: u32) -> OrderKey {
    let rec = &world.recs[id];
    let slack = rec.req.deadline - world.clock;
    OrderKey {
        deadline_bucket: deadline_bucket(slack),
        neg_kvc_bucket: -((world.occupied_kvc(id) / KVC_BUCKET) as i32),
        neg_len: -(len as i64),
        id,
    }
}

/// Sort `ids` in scheduling-priority order (stable, deterministic).
pub fn sort_pts(world: &World, ids: &mut [ReqId]) {
    ids.sort_by_key(|&id| {
        let len = world.recs[id].req.prompt_len - world.recs[id].prompt_done;
        order_key(world, id, len)
    });
}

pub fn sort_gts(world: &World, ids: &mut [ReqId]) {
    ids.sort_by_key(|&id| order_key(world, id, world.recs[id].predicted_remaining()));
}

/// Binary search over a **descending-length-sorted** slice of (len, idx)
/// pairs: the first entry with `len <= cap` (i.e. the largest that fits).
/// Returns the position in `pairs`, or None if nothing fits.
pub fn best_fit_leq(pairs: &[(u32, usize)], cap: u32) -> Option<usize> {
    if pairs.is_empty() {
        return None;
    }
    // pairs sorted descending by len: find first index with len <= cap.
    let (mut lo, mut hi) = (0usize, pairs.len());
    while lo < hi {
        let mid = (lo + hi) / 2;
        if pairs[mid].0 > cap {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    if lo < pairs.len() {
        Some(lo)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelProfile, SystemConfig};
    use crate::predictor::OraclePredictor;
    use crate::trace::TraceItem;

    fn world(items: &[TraceItem]) -> World {
        let cfg = SystemConfig::new(ModelProfile::opt_13b());
        let p = Box::new(OraclePredictor::new(1));
        World::new(cfg, items, p)
    }

    #[test]
    fn deadline_buckets() {
        assert_eq!(deadline_bucket(0.1), 0);
        assert_eq!(deadline_bucket(1.0), 1);
        assert_eq!(deadline_bucket(10.0), 2);
        assert_eq!(deadline_bucket(-3.0), 0); // overdue = most urgent
    }

    #[test]
    fn urgent_tasks_first_then_big_kvc_then_long() {
        let mut w = world(&[
            TraceItem { arrival: 0.0, prompt_len: 100, true_rl: 10 }, // long, lax
            TraceItem { arrival: 0.0, prompt_len: 10, true_rl: 10 },  // short, lax
            TraceItem { arrival: 0.0, prompt_len: 50, true_rl: 10 },  // urgent
        ]);
        // Force deadlines: id 2 nearly due, others far out.
        w.recs[0].req.deadline = w.clock + 100.0;
        w.recs[1].req.deadline = w.clock + 100.0;
        w.recs[2].req.deadline = w.clock + 0.1;
        let mut ids = vec![0, 1, 2];
        sort_pts(&w, &mut ids);
        assert_eq!(ids[0], 2, "urgent first");
        assert_eq!(ids[1], 0, "then longest prompt");
        assert_eq!(ids[2], 1);
    }

    #[test]
    fn occupied_kvc_beats_length() {
        let mut w = world(&[
            TraceItem { arrival: 0.0, prompt_len: 500, true_rl: 10 },
            TraceItem { arrival: 0.0, prompt_len: 10, true_rl: 10 },
        ]);
        // Give id 1 a big resident KVC footprint (e.g. preempted GT).
        w.pool.alloc_tokens(1, 600, crate::kvc::Priority::Reserved).unwrap();
        w.pool.write_tokens(1, 600);
        w.recs[0].req.deadline = w.clock + 100.0;
        w.recs[1].req.deadline = w.clock + 100.0;
        let mut ids = vec![0, 1];
        sort_pts(&w, &mut ids);
        assert_eq!(ids[0], 1, "bigger KVC holder first despite shorter prompt");
    }

    #[test]
    fn best_fit_binary_search() {
        // Descending lengths.
        let pairs = vec![(512u32, 0usize), (256, 1), (128, 2), (64, 3), (16, 4)];
        assert_eq!(best_fit_leq(&pairs, 1024), Some(0));
        assert_eq!(best_fit_leq(&pairs, 300), Some(1));
        assert_eq!(best_fit_leq(&pairs, 128), Some(2));
        assert_eq!(best_fit_leq(&pairs, 100), Some(3));
        assert_eq!(best_fit_leq(&pairs, 10), None);
        assert_eq!(best_fit_leq(&[], 10), None);
    }
}
