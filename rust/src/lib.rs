//! # EconoServe
//!
//! Reproduction of *"EconoServe: Maximizing Multi-Resource Utilization with
//! SLO Guarantees in LLM Serving"* (Shen & Sen, 2024) as a three-layer
//! Rust + JAX + Pallas serving stack:
//!
//!  * **L3 (this crate)** — the paper's contribution: the EconoServe
//!    scheduler (SyncDecoupled batching + KVC pipelining + task Ordering)
//!    plus every baseline it is evaluated against (ORCA, SRTF, FastServe,
//!    vLLM, Sarathi-Serve, MultiRes, DistServe), a block-granular KVC
//!    manager, trace generators, an RL-prediction model, a calibrated
//!    discrete-event engine for the paper's figures, and a PJRT runtime
//!    that serves a real (small) transformer end-to-end.
//!  * **L2 (python/compile/model.py)** — OPT-style decoder with explicit
//!    KV cache, AOT-lowered to HLO text at build time.
//!  * **L1 (python/compile/kernels/)** — Pallas flash-attention kernels
//!    (prefill + decode), validated against a pure-jnp oracle.
//!
//! Start with [`coordinator::Coordinator`] for the serving loop, or the
//! `examples/` directory for end-to-end usage.

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod figures;
pub mod ordering;
pub mod sched;
pub mod core;
pub mod kvc;
pub mod metrics;
pub mod predictor;
pub mod runtime;
pub mod server;
pub mod trace;
pub mod util;
