//! # EconoServe
//!
//! Reproduction of *"EconoServe: Maximizing Multi-Resource Utilization with
//! SLO Guarantees in LLM Serving"* (Shen & Sen, 2024) as a three-layer
//! Rust + JAX + Pallas serving stack:
//!
//!  * **L3 (this crate)** — the paper's contribution: the EconoServe
//!    scheduler (SyncDecoupled batching + KVC pipelining + task Ordering)
//!    plus every baseline it is evaluated against (ORCA, SRTF, FastServe,
//!    vLLM, Sarathi-Serve, MultiRes, DistServe), a block-granular KVC
//!    manager, trace generators, an RL-prediction model, a calibrated
//!    discrete-event engine for the paper's figures, and a PJRT runtime
//!    that serves a real (small) transformer end-to-end.
//!  * **L2 (python/compile/model.py)** — OPT-style decoder with explicit
//!    KV cache, AOT-lowered to HLO text at build time.
//!  * **L1 (python/compile/kernels/)** — Pallas flash-attention kernels
//!    (prefill + decode), validated against a pure-jnp oracle.
//!
//! ## Two engines, one request lifecycle
//!
//! The crate serves through two back-ends that share one front door
//! (see `docs/API.md` for the full tour):
//!
//!  * the **simulation engine** — [`coordinator`] drives a
//!    [`sched::Scheduler`] over a [`core::world::World`] on the
//!    calibrated [`engine::SimEngine`]; this is what reproduces the
//!    paper's figures. Batching policy ([`sched`]) and KVC allocation
//!    policy ([`kvc::Allocator`]) are separate axes, composed by name
//!    (`sched::by_name("<sched>+<alloc>")`, e.g. `"vllm+exact"`).
//!    `coordinator::run_admitted` applies the same admission control as
//!    the real path.
//!  * the **real engine** — [`server::RealServer`] batches requests over
//!    decode slots of the PJRT model ([`runtime::PjrtModel`]), fronted
//!    by a std-only HTTP server ([`server::http`]) with per-token
//!    streaming (`POST /v1/stream`) and blocking generation
//!    (`POST /v1/generate`).
//!
//! Above the single-replica engines sits the **fleet layer** ([`fleet`]):
//! an event-driven multi-replica simulation where N per-replica worlds
//! advance on a shared clock behind a routing front door
//! ([`fleet::router`]: round-robin / least-queue / least-kvc /
//! power-of-two), an autoscaler ([`fleet::autoscale`]: static-k /
//! reactive / forecast, with boot latency and drain-before-retire), and
//! non-stationary workloads ([`trace::ArrivalProcess`]: poisson / mmpp /
//! diurnal). It reports goodput, SLO satisfaction, GPU-hours and
//! goodput-per-GPU-hour — the paper's Fig 12 capacity story, told
//! dynamically. ([`cluster`] retains only the DistServe baseline; the
//! legacy pre-sharded capacity wrappers are gone.) The fleet is also
//! chaos-testable: [`fleet::faults`] compiles named fault profiles
//! (replica crashes, correlated zone outages, stragglers, flaky boots)
//! into seed-deterministic event timelines; routers see replica health,
//! autoscalers observe crash losses and re-provision, in-flight requests
//! are re-routed or counted lost ([`fleet::FaultTally`]), and
//! `econoserve fleet --chaos <profile>` compares each router's
//! goodput/SSR retention against its fault-free baseline.
//!
//! Both speak the typed request lifecycle of [`api`]: admission-checked
//! submission ([`api::SubmitOptions`] → [`api::AdmissionController`]),
//! channel-backed token streaming ([`api::RequestHandle`] yielding
//! [`api::StreamEvent`]s), cooperative cancellation
//! ([`api::CancelToken`], freeing the decode slot mid-generation), and a
//! structured terminal state ([`api::FinishReason`] /
//! [`api::Completion`] / [`api::ServeError`]). Queue ordering on both
//! paths is the single shared EconoServe §3.4 implementation in
//! [`ordering`] ([`ordering::QueuePolicy`], selectable by name).
//!
//! Experiments themselves are parallel programs: the paper's results
//! are grids (rate × scheduler × seed × fleet axes), and [`exp`] is the
//! deterministic fan-out engine behind all of them — the figure
//! drivers, the Fig 12 capacity search, the hot-path bench grid, and
//! the `econoserve sweep` CLI all run their independent cells over it,
//! with input-order collection and coordinate-derived RNG streams so
//! output is bit-identical at any thread count (`--threads` /
//! `ECONOSERVE_THREADS`). The core simulation types are `Send` by
//! contract ([`sched::Scheduler`], [`kvc::Allocator`],
//! [`predictor::Predictor`], [`fleet::Router`], [`fleet::Autoscaler`]),
//! so whole worlds move across worker threads; the fleet layer also
//! advances its live replicas concurrently between routing events.
//!
//! Start with [`coordinator`] for the simulated serving loop, [`api`]
//! for the client-facing request lifecycle, or the `examples/` directory
//! for end-to-end usage.

pub mod api;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod exp;
pub mod figures;
pub mod fleet;
pub mod ordering;
pub mod reliability;
pub mod sched;
pub mod core;
pub mod kvc;
pub mod metrics;
pub mod predictor;
pub mod telemetry;
#[cfg(feature = "pjrt")]
pub mod runtime;
#[cfg(feature = "pjrt")]
pub mod server;
pub mod trace;
pub mod util;
