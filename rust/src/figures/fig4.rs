//! Figure 4: impact of the RL-prediction padding ratio (§2.3) on JCT
//! (split into waiting + processing), KVC utilization, and the fraction
//! of under-provisioned requests — the sweet-spot study.

use super::common::{self, DURATION, MAX_TIME};
use crate::util::bench::BenchOut;
use crate::util::stats::Table;

pub fn run(fast: bool) {
    let mut out = BenchOut::new("fig4");
    let duration = if fast { 30.0 } else { DURATION };
    let ratios = [0.0, 0.05, 0.10, 0.15, 0.20, 0.25, 0.30];

    for trace in common::traces() {
        let mut t = Table::new(&[
            "padding_%",
            "jct_s",
            "wait_s",
            "proc_s",
            "kvc_util_%",
            "underprov_%",
        ]);
        for ratio in ratios {
            let mut cfg = common::cfg("opt-13b", trace);
            cfg.padding_ratio = ratio;
            let rate = common::capacity_estimate(&cfg, trace) * 0.8;
            let items = common::workload(&cfg, trace, rate, duration, cfg.seed);
            // SyncDecoupled (= econoserve-sdo, §2.3 uses SyncDecoupled),
            // noisy predictor (padding only matters with prediction error).
            let (res, world) =
                common::run_world(&cfg, "econoserve-sdo", trace, &items, false, MAX_TIME);
            let s = &res.summary;
            // Under-provisioned = requests that hit reached_prediction at
            // least once == preempt_count>0 or rescued; approximate from
            // the recs: generated exceeded the FIRST padded prediction.
            let under = world
                .recs
                .iter()
                .filter(|r| r.preempt_count > 0 || r.predicted_base > 0)
                .count() as f64
                / world.recs.len().max(1) as f64
                * 100.0;
            t.rowf(
                &format!("{:.0}", ratio * 100.0),
                &[
                    s.mean_jct,
                    s.mean_wait,
                    (s.mean_jct - s.mean_wait).max(0.0),
                    s.kvc_util * 100.0,
                    under,
                ],
            );
        }
        out.section(&format!("{trace}: padding-ratio sweep (SyncDecoupled)"), t);
    }
    out.finish();
}
