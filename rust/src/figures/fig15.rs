//! Figure 15: sensitivity of EconoServe (OPT-13B) to the SLO scale,
//! padding ratio, reserved-KVC share, and KVCPipe buffer — normalized
//! JCT / throughput / SSR per setting. Every (trace, value) cell is an
//! independent run, fanned out over the parallel experiment engine.

use super::common::{self, MAX_TIME};
use crate::util::bench::BenchOut;
use crate::util::stats::Table;

fn sweep<F: Fn(&mut crate::config::SystemConfig, f64) + Sync>(
    out: &mut BenchOut,
    title: &str,
    values: &[f64],
    fast: bool,
    apply: F,
) {
    let duration = if fast { 30.0 } else { 60.0 };
    let cells: Vec<(&'static str, f64)> = common::traces()
        .into_iter()
        .flat_map(|trace| values.iter().map(move |&v| (trace, v)))
        .collect();
    let results = crate::exp::map_indexed(&cells, 0, |_, &(trace, v)| {
        let mut cfg = common::cfg("opt-13b", trace);
        // Concurrent cells must not charge measured scheduler wall-clock
        // into the sim clock (contention would bias the sweep; Fig 14
        // owns the overhead story).
        cfg.sched_time_scale = 0.0;
        apply(&mut cfg, v);
        let rate = common::capacity_estimate(&cfg, trace) * 0.8;
        let items = common::workload(&cfg, trace, rate, duration, cfg.seed);
        let s = common::run_world(&cfg, "econoserve", trace, &items, false, MAX_TIME).0.summary;
        (s.mean_jct, s.throughput_rps, s.ssr)
    });
    let mut it = results.into_iter();
    for trace in common::traces() {
        let mut t = Table::new(&["value", "jct_s", "tput_rps", "ssr_%"]);
        for &v in values {
            let (jct, tput, ssr) = it.next().expect("one result per cell");
            t.rowf(&format!("{v}"), &[jct, tput, ssr * 100.0]);
        }
        out.section(&format!("{title} — {trace}"), t);
    }
}

pub fn run(fast: bool) {
    let mut out = BenchOut::new("fig15");
    sweep(&mut out, "(a) SLO scale", &[0.5, 1.0, 1.5, 2.0, 2.5], fast, |c, v| {
        c.slo_scale = v;
    });
    sweep(&mut out, "(b) padding ratio", &[0.0, 0.1, 0.15, 0.2, 0.3], fast, |c, v| {
        c.padding_ratio = v;
    });
    sweep(&mut out, "(c) reserved KVC frac", &[0.01, 0.02, 0.03, 0.04, 0.08], fast, |c, v| {
        c.reserve_frac = v;
    });
    sweep(&mut out, "(d) KVCPipe buffer frac", &[0.05, 0.10, 0.15, 0.20, 0.30], fast, |c, v| {
        c.buffer_frac = v;
    });
    out.finish();
}
