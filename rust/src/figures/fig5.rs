//! Figure 5: RL misprediction effects at the sweet-spot padding.
//!  (a) over-/under-provisioned KVC share per request;
//!  (b) preemption-time share of JCT for the three under-provision
//!      recovery strategies: offload-based preemption, offload-free
//!      preemption, and reserved-KVC-first.

use super::common::{self, DURATION, MAX_TIME};
use crate::config::PreemptMode;
use crate::predictor::{Predictor, SimPredictor};
use crate::trace::{TraceGen, TraceSpec};
use crate::util::bench::BenchOut;
use crate::util::stats::Table;

/// (a): analytic sampling of the calibrated predictor.
fn provision_split(trace: &str, padding: f64) -> (f64, f64, f64) {
    let mut p = SimPredictor::for_trace(trace, 32, 7);
    let gen = TraceGen::new(TraceSpec::by_name(trace).unwrap());
    let items = gen.generate(20_000, 10.0, 4096, 11);
    let (mut over_sum, mut under_cnt, mut alloc_sum) = (0.0, 0usize, 0.0);
    for (i, it) in items.iter().enumerate() {
        let padded = (p.predict_raw(i, it.true_rl) as f64 * (1.0 + padding)).ceil();
        alloc_sum += padded;
        if padded < it.true_rl as f64 {
            under_cnt += 1;
        } else {
            over_sum += padded - it.true_rl as f64;
        }
    }
    let over_pct = over_sum / alloc_sum * 100.0; // over-provisioned share of allocated KVC
    let under_pct = under_cnt as f64 / items.len() as f64 * 100.0;
    (over_pct, under_pct, alloc_sum / items.len() as f64)
}

pub fn run(fast: bool) {
    let mut out = BenchOut::new("fig5");
    let duration = if fast { 30.0 } else { DURATION };

    // (a) over/under-provisioning at sweet-spot padding.
    let mut a = Table::new(&["trace", "over_%_of_alloc", "under_%_of_reqs", "mean_alloc_tok"]);
    for (trace, pad) in [("alpaca", 0.10), ("sharegpt", 0.15), ("bookcorpus", 0.20)] {
        let (over, under, alloc) = provision_split(trace, pad);
        a.rowf(trace, &[over, under, alloc]);
    }
    out.section("(a) provisioning split at sweet-spot padding", a);

    // (b) preemption-time share of JCT (preempted requests only) per
    // recovery strategy, on ShareGPT.
    let mut b = Table::new(&["strategy", "preempt_share_of_jct_%", "preempted_reqs", "mean_jct_s"]);
    for (label, mode) in [
        ("offload-swap", PreemptMode::OffloadSwap),
        ("offload-free", PreemptMode::OffloadFree),
        ("reserved-then-free", PreemptMode::ReservedThenFree),
    ] {
        let mut cfg = common::cfg("opt-13b", "sharegpt");
        cfg.preempt_mode = mode;
        let rate = common::capacity_estimate(&cfg, "sharegpt") * 0.8;
        let items = common::workload(&cfg, "sharegpt", rate, duration, cfg.seed);
        let (_res, world) =
            common::run_world(&cfg, "econoserve-sdo", "sharegpt", &items, false, MAX_TIME);
        let mut share_sum = 0.0;
        let mut n = 0usize;
        let mut jct_sum = 0.0;
        for r in &world.recs {
            if r.preempt_count > 0 {
                if let Some(j) = r.jct() {
                    share_sum += r.preempt_total / j.max(1e-9);
                    jct_sum += j;
                    n += 1;
                }
            }
        }
        b.rowf(
            label,
            &[
                if n > 0 { share_sum / n as f64 * 100.0 } else { 0.0 },
                n as f64,
                if n > 0 { jct_sum / n as f64 } else { 0.0 },
            ],
        );
    }
    out.section("(b) preemption-time share by recovery strategy (sharegpt)", b);
    out.finish();
}
