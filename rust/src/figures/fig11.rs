//! Figure 11: average KVC and GPU utilization vs request rate on
//! ShareGPT for each model, across the Fig 9 systems — the (rate,
//! system) cells fan out over `figures::common::run_rate_grid` like
//! fig9's.

use super::common::{self, MAX_TIME};
use crate::cluster::{DistServeConfig, DistServeSim};
use crate::util::bench::BenchOut;
use crate::util::stats::Table;

const SYSTEMS: [&str; 5] = ["orca", "vllm", "sarathi", "distserve", "econoserve"];

pub fn run(fast: bool) {
    let mut out = BenchOut::new("fig11");
    let duration = if fast { 30.0 } else { 60.0 };
    let models: &[&str] = if fast { &["opt-13b"] } else { &["opt-13b", "llama-33b", "opt-175b"] };
    let trace = "sharegpt";
    let points = if fast { 3 } else { 5 };

    for model in models {
        let cfg = common::cfg(model, trace);
        let rows = common::run_rate_grid(
            &cfg,
            trace,
            points,
            duration,
            &SYSTEMS,
            0,
            |cfg, sys, items, _rate| {
                if sys == "distserve" {
                    let dcfg = DistServeConfig::homogeneous(cfg.profile.clone(), cfg);
                    let r = DistServeSim::new(dcfg).run(items, MAX_TIME);
                    (r.summary.kvc_util, r.summary.gpu_util)
                } else {
                    let s = common::run_world(cfg, sys, trace, items, false, MAX_TIME).0.summary;
                    (s.kvc_util, s.gpu_util)
                }
            },
        );
        let mut kvc_t =
            Table::new(&["rate_rps", "ORCA", "vLLM", "Sarathi", "DistServe", "EconoServe"]);
        let mut gpu_t =
            Table::new(&["rate_rps", "ORCA", "vLLM", "Sarathi", "DistServe", "EconoServe"]);
        for (rate, cells) in rows {
            let mut kvc_row = vec![format!("{rate:.2}")];
            let mut gpu_row = vec![format!("{rate:.2}")];
            for (kvc, gpu) in cells {
                kvc_row.push(format!("{:.1}", kvc * 100.0));
                gpu_row.push(format!("{:.1}", gpu * 100.0));
            }
            kvc_t.row(&kvc_row);
            gpu_t.row(&gpu_row);
        }
        out.section(&format!("{model}/{trace}: KVC utilization (%) vs rate"), kvc_t);
        out.section(&format!("{model}/{trace}: GPU utilization (%) vs rate"), gpu_t);
    }
    out.finish();
}
