//! Figure 14: scheduling-time overhead of each system — measured from the
//! actual batch-formation code (wall-clock per `Scheduler::plan`, charged
//! to the simulation at `sched_time_scale`), reported as overhead share
//! and mean per-iteration scheduling time.

use super::common::{self, MAX_TIME};
use crate::util::bench::BenchOut;
use crate::util::stats::Table;

pub fn systems() -> Vec<(&'static str, &'static str)> {
    vec![
        ("ORCA", "orca"),
        ("FastServe", "fastserve"),
        ("vLLM", "vllm"),
        ("Sarathi", "sarathi"),
        ("MultiRes", "multires"),
        ("SyncCoupled", "sync_coupled"),
        ("EconoServe-D", "econoserve-d"),
        ("EconoServe-SD", "econoserve-sd"),
        ("EconoServe-SDO", "econoserve-sdo"),
        ("EconoServe", "econoserve"),
    ]
}

pub fn run(fast: bool) {
    let mut out = BenchOut::new("fig14");
    let duration = if fast { 30.0 } else { 60.0 };

    for trace in common::traces() {
        let cfg = common::cfg("opt-13b", trace);
        // Load high enough that queues are deep (scheduling work visible).
        let rate = common::capacity_estimate(&cfg, trace) * 1.2;
        let items = common::workload(&cfg, trace, rate, duration, cfg.seed);
        let mut t = Table::new(&[
            "scheduler",
            "sched_overhead_%",
            "mean_step_us",
            "iterations",
            "jct_s",
        ]);
        for (label, sys) in systems() {
            let s = common::run_world(&cfg, sys, trace, &items, false, MAX_TIME).0.summary;
            t.rowf(
                label,
                &[
                    s.sched_overhead_frac * 100.0,
                    s.sched_time_mean / cfg.sched_time_scale * 1e6, // native rust µs
                    s.iterations as f64,
                    s.mean_jct,
                ],
            );
        }
        out.section(&format!("{trace}: scheduling overhead"), t);
    }
    out.finish();
}
