//! Figure 2: CDF of the number of requests per same-RL group in
//! SyncCoupled batching — the observation (O2) that makes time-synced
//! batching viable.

use super::common::{self, DURATION, MAX_TIME};
use crate::coordinator::{run, RunLimits};
use crate::core::world::World;
use crate::engine::SimEngine;
use crate::predictor::SimPredictor;
use crate::sched::sync_coupled::SyncCoupled;
use crate::util::bench::BenchOut;
use crate::util::stats::{Samples, Table};

pub fn run_fig(fast: bool) {
    let mut out = BenchOut::new("fig2");
    let duration = if fast { 30.0 } else { DURATION };

    let mut table = Table::new(&["trace", "p25", "p50", "p75", "p90", "max", ">=4_frac_%", ">=12_frac_%"]);
    for trace in common::traces() {
        let cfg = common::cfg("opt-13b", trace);
        // Deep queues are what create groups; the paper's Table 2 rates are
        // heavily overloaded, so measure at 2x the estimated capacity.
        let rate = common::capacity_estimate(&cfg, trace) * 2.0;
        let items = common::workload(&cfg, trace, rate, duration, cfg.seed);
        let pred = Box::new(SimPredictor::for_trace(trace, cfg.block_size, cfg.seed));
        let mut world = World::new(cfg.clone(), &items, pred);
        let mut sched = SyncCoupled::new();
        let engine = SimEngine::new();
        let _ = run(&mut world, &mut sched, &engine, RunLimits::for_time(MAX_TIME));

        let mut sizes = Samples::new();
        sizes.extend(sched.group_sizes.iter().map(|g| *g as f64));
        // Request-weighted fractions (the paper reports "% of requests in
        // groups with >= k members").
        let total_reqs: u32 = sched.group_sizes.iter().sum();
        let reqs_ge = |k: u32| -> f64 {
            sched.group_sizes.iter().filter(|g| **g >= k).map(|g| *g).sum::<u32>() as f64
                / total_reqs.max(1) as f64
                * 100.0
        };
        table.rowf(
            trace,
            &[
                sizes.percentile(25.0),
                sizes.p50(),
                sizes.percentile(75.0),
                sizes.percentile(90.0),
                sizes.percentile(100.0),
                reqs_ge(4),
                reqs_ge(12),
            ],
        );
    }
    out.section("same-RL group sizes (SyncCoupled)", table);
    out.finish();
}
