//! Shared experiment plumbing: calibrated configs, rate grids, run
//! helpers with access to the final World (for figure-specific
//! instrumentation).

use crate::config::{ModelProfile, SystemConfig};
use crate::coordinator::{run, RunLimits, RunResult};
use crate::core::world::World;
use crate::engine::SimEngine;
use crate::predictor::{OraclePredictor, Predictor, SimPredictor};
use crate::trace::{TraceGen, TraceItem, TraceSpec};

/// The paper's three models.
pub fn models() -> [&'static str; 3] {
    ["opt-13b", "llama-33b", "opt-175b"]
}

/// The paper's three traces.
pub fn traces() -> [&'static str; 3] {
    ["alpaca", "sharegpt", "bookcorpus"]
}

/// SystemConfig with the paper's per-trace sweet spots (§2.3, Fig 15) and
/// SLO constants derived from the cost model.
pub fn cfg(model: &str, trace: &str) -> SystemConfig {
    let profile = ModelProfile::by_name(model).unwrap_or_else(|| panic!("model {model}"));
    let mut cfg = SystemConfig::new(profile);
    match trace {
        "alpaca" => {
            cfg.padding_ratio = 0.10;
            cfg.reserve_frac = 0.02;
            cfg.buffer_frac = 0.15;
        }
        "sharegpt" => {
            cfg.padding_ratio = 0.15;
            cfg.reserve_frac = 0.03;
            cfg.buffer_frac = 0.15;
        }
        "bookcorpus" => {
            cfg.padding_ratio = 0.20;
            cfg.reserve_frac = 0.04;
            cfg.buffer_frac = 0.10;
        }
        _ => {}
    }
    let spec = TraceSpec::by_name(trace).unwrap_or_else(TraceSpec::sharegpt);
    cfg.t_p = cfg.profile.flops_per_token() * spec.input.avg / cfg.profile.peak_flops
        + cfg.profile.iter_overhead;
    cfg.t_g = cfg.profile.weight_bytes / cfg.profile.mem_bw + cfg.profile.iter_overhead;
    cfg
}

/// Crude capacity estimate (req/s) for scaling rate grids across models
/// and traces: min of the compute and KVC rooflines.
pub fn capacity_estimate(cfg: &SystemConfig, trace: &str) -> f64 {
    let spec = TraceSpec::by_name(trace).unwrap();
    cfg.capacity_estimate(&spec)
}

/// A rate grid spanning under- to over-load for (model, trace).
pub fn rate_grid(cfg: &SystemConfig, trace: &str, points: usize) -> Vec<f64> {
    let cap = capacity_estimate(cfg, trace);
    (1..=points).map(|i| cap * 0.25 * i as f64).collect()
}

/// Generate the standard workload for (cfg, trace) at `rate` for
/// `duration` simulated seconds.
pub fn workload(cfg: &SystemConfig, trace: &str, rate: f64, duration: f64, seed: u64) -> Vec<TraceItem> {
    let gen = TraceGen::new(TraceSpec::by_name(trace).unwrap());
    gen.generate_for(duration, rate, cfg.profile.max_total_len, seed)
}

/// Run a system and return both the result and the final world (for
/// figure-specific post-processing).
pub fn run_world(
    cfg: &SystemConfig,
    system: &str,
    trace: &str,
    items: &[TraceItem],
    oracle: bool,
    max_time: f64,
) -> (RunResult, World) {
    let pred: Box<dyn Predictor> = if oracle {
        Box::new(OraclePredictor::new(cfg.block_size))
    } else {
        Box::new(SimPredictor::for_trace(trace, cfg.block_size, cfg.seed))
    };
    let mut world = World::new(cfg.clone(), items, pred);
    let sys =
        crate::sched::by_name(system).unwrap_or_else(|| panic!("unknown system {system}"));
    world.set_allocator(sys.alloc);
    let mut sched = sys.sched;
    let engine = SimEngine::new();
    let res = run(&mut world, sched.as_mut(), &engine, RunLimits::for_time(max_time));
    (res, world)
}

/// The rate × system grid every latency/utilization figure sweeps,
/// hoisted from the (formerly duplicated) fig9/fig11 loop shape and
/// fanned out over the parallel experiment engine: one `eval` call per
/// (rate, system) cell via [`crate::exp::map_indexed`], results
/// regrouped rate-major in grid order. Each cell regenerates its own
/// workload from `(cfg, trace, rate, cfg.seed)` — deterministic, so
/// rival systems at one rate see the identical trace and the same rows
/// come back at any thread count.
///
/// Grid cells always run with `sched_time_scale = 0`: charging MEASURED
/// scheduler wall-clock into the simulated clock (the Fig 14 overhead
/// model) would let CPU contention between concurrent cells bias the
/// results and vary them run-to-run. Fig 14 is the overhead figure and
/// keeps measured charging on its own (serial) driver; the latency/
/// utilization grids are bit-deterministic instead.
///
/// `eval(cfg, system, items, rate)` prices one cell; `threads` follows
/// `exp::resolve_threads` (0 = env/auto).
pub fn run_rate_grid<R: Send>(
    cfg: &SystemConfig,
    trace: &str,
    points: usize,
    duration: f64,
    systems: &[&'static str],
    threads: usize,
    eval: impl Fn(&SystemConfig, &'static str, &[TraceItem], f64) -> R + Sync,
) -> Vec<(f64, Vec<R>)> {
    let mut cfg = cfg.clone();
    cfg.sched_time_scale = 0.0;
    let cfg = &cfg;
    let grid = rate_grid(cfg, trace, points);
    let cells: Vec<(f64, &'static str)> = grid
        .iter()
        .flat_map(|&rate| systems.iter().map(move |&sys| (rate, sys)))
        .collect();
    let results = crate::exp::map_indexed(&cells, threads, |_, &(rate, sys)| {
        let items = workload(cfg, trace, rate, duration, cfg.seed);
        eval(cfg, sys, &items, rate)
    });
    let mut it = results.into_iter();
    grid.into_iter().map(|rate| (rate, it.by_ref().take(systems.len()).collect())).collect()
}

/// Default experiment duration (simulated seconds) — short enough that
/// all figures regenerate in minutes, long enough for steady state.
pub const DURATION: f64 = 90.0;

/// Default drain allowance after arrivals stop.
pub const MAX_TIME: f64 = 900.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_estimates_ordered_by_model_size() {
        let c13 = capacity_estimate(&cfg("opt-13b", "sharegpt"), "sharegpt");
        let c175 = capacity_estimate(&cfg("opt-175b", "sharegpt"), "sharegpt");
        assert!(c13 > 0.0 && c175 > 0.0);
    }

    #[test]
    fn rate_grid_monotone() {
        let c = cfg("opt-13b", "alpaca");
        let g = rate_grid(&c, "alpaca", 6);
        assert_eq!(g.len(), 6);
        for w in g.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn rate_grid_rows_stay_grid_ordered() {
        let mut c = cfg("opt-13b", "alpaca");
        c.sched_time_scale = 0.0;
        let eval = |cfg: &SystemConfig, sys: &'static str, items: &[TraceItem], rate: f64| {
            assert!(!items.is_empty(), "{sys}@{rate}");
            let s = run_world(cfg, sys, "alpaca", items, true, 120.0).0.summary;
            (sys, s.n_done)
        };
        let rows = run_rate_grid(&c, "alpaca", 2, 4.0, &["orca", "vllm"], 2, eval);
        assert_eq!(rows.len(), 2);
        for (rate, cells) in &rows {
            assert!(*rate > 0.0);
            // System-minor order within each rate row.
            assert_eq!(cells[0].0, "orca");
            assert_eq!(cells[1].0, "vllm");
        }
    }

    #[test]
    fn run_world_smoke() {
        let c = cfg("opt-13b", "alpaca");
        let items = workload(&c, "alpaca", 5.0, 10.0, 1);
        let (res, world) = run_world(&c, "vllm", "alpaca", &items, true, 200.0);
        assert_eq!(res.summary.n_done, items.len());
        assert!(world.all_done());
    }
}
