//! Figure 9: normalized latency vs request rate for all models and
//! traces, comparing ORCA / vLLM / Sarathi-Serve / DistServe (2x GPUs) /
//! EconoServe. The paper's headline sustainable-rate comparison.
//!
//! Every (rate, system) cell is an independent simulation, so the whole
//! grid fans out over `figures::common::run_rate_grid` (the parallel
//! experiment engine); rows come back in grid order regardless of
//! thread count.

use super::common::{self, MAX_TIME};
use crate::cluster::{DistServeConfig, DistServeSim};
use crate::util::bench::BenchOut;
use crate::util::stats::Table;

pub fn systems() -> Vec<(&'static str, &'static str)> {
    vec![
        ("ORCA", "orca"),
        ("vLLM", "vllm"),
        ("Sarathi", "sarathi"),
        ("DistServe", "distserve"),
        ("EconoServe", "econoserve"),
    ]
}

pub fn run(fast: bool) {
    let mut out = BenchOut::new("fig9");
    let duration = if fast { 30.0 } else { 60.0 };
    let models: &[&str] = if fast { &["opt-13b"] } else { &["opt-13b", "llama-33b", "opt-175b"] };
    let points = if fast { 4 } else { 6 };
    let sys_names: Vec<&'static str> = systems().iter().map(|(_, s)| *s).collect();

    for model in models {
        for trace in common::traces() {
            let cfg = common::cfg(model, trace);
            let rows = common::run_rate_grid(
                &cfg,
                trace,
                points,
                duration,
                &sys_names,
                0,
                |cfg, sys, items, _rate| {
                    if sys == "distserve" {
                        let dcfg = DistServeConfig::homogeneous(cfg.profile.clone(), cfg);
                        DistServeSim::new(dcfg).run(items, MAX_TIME).summary.norm_latency
                    } else {
                        common::run_world(cfg, sys, trace, items, false, MAX_TIME)
                            .0
                            .summary
                            .norm_latency
                    }
                },
            );
            let mut t = Table::new(&{
                let mut h = vec!["rate_rps"];
                h.extend(systems().iter().map(|(l, _)| *l));
                h
            });
            for (rate, cells) in rows {
                let mut row = vec![format!("{rate:.2}")];
                row.extend(cells.iter().map(|nl| format!("{nl:.4}")));
                t.row(&row);
            }
            out.section(
                &format!("{model} / {trace}: normalized latency (s/token) vs rate"),
                t,
            );
        }
    }
    out.finish();
}
