//! Figure 1: comparison of schedulers (§2.2 motivation study).
//!
//! Reproduces, per trace with RLs pre-known (Oracle, as in the paper's
//! first measurement): (a) throughput, (b) KVC utilization, (c) forward
//! size, (d) KVC allocation-failure %, (e) JCT decomposition, and
//! (f) the completed-requests-per-iteration distribution that motivates
//! the GT-domination observation.

use super::common::{self, DURATION, MAX_TIME};
use crate::util::bench::BenchOut;
use crate::util::stats::Table;

/// The §2 schedulers (EconoServe ladder entries renamed as in Fig 1).
pub fn systems() -> Vec<(&'static str, &'static str)> {
    vec![
        ("SRTF", "srtf"),
        ("ORCA", "orca"),
        ("FastServe", "fastserve"),
        ("vLLM", "vllm"),
        ("Sarathi-Serve", "sarathi"),
        ("MultiRes", "multires"),
        ("SyncCoupled", "sync_coupled"),
        ("SyncDecoupled", "econoserve-sdo"),
    ]
}

pub fn run(fast: bool) {
    let mut out = BenchOut::new("fig1");
    let duration = if fast { 30.0 } else { DURATION };

    for trace in common::traces() {
        let cfg = common::cfg("opt-13b", trace);
        // 80% of estimated capacity: "some requests are queued while a
        // batch is processing" (§2.1).
        let rate = common::capacity_estimate(&cfg, trace) * 0.8;
        let items = common::workload(&cfg, trace, rate, duration, cfg.seed);

        let mut main_t = Table::new(&[
            "scheduler",
            "tput_rps",
            "kvc_util_%",
            "fwd_size",
            "alloc_fail_%",
            "gpu_util_%",
        ]);
        let mut jct_t = Table::new(&[
            "scheduler",
            "jct_s",
            "wait_s",
            "exec_s",
            "preempt_s",
            "sched_s",
        ]);
        let mut citer_t = Table::new(&["scheduler", "c0_%", "c1_%", "c2_%", "c3+_%"]);

        for (label, sys) in systems() {
            let (res, world) = common::run_world(&cfg, sys, trace, &items, true, MAX_TIME);
            let s = &res.summary;
            main_t.rowf(
                label,
                &[
                    s.throughput_rps,
                    s.kvc_util * 100.0,
                    s.avg_forward_size,
                    s.alloc_failure_frac * 100.0,
                    s.gpu_util * 100.0,
                ],
            );
            jct_t.rowf(
                label,
                &[s.mean_jct, s.mean_wait, s.mean_exec, s.mean_preempt, s.mean_sched_share],
            );
            // (f) completed-per-iteration histogram.
            let hist = &world.col.completions_per_iter;
            let total: u64 = hist.iter().sum::<u64>().max(1);
            let pct = |i: usize| -> f64 {
                if i < 3 {
                    *hist.get(i).unwrap_or(&0) as f64 / total as f64 * 100.0
                } else {
                    hist.iter().skip(3).sum::<u64>() as f64 / total as f64 * 100.0
                }
            };
            citer_t.rowf(label, &[pct(0), pct(1), pct(2), pct(3)]);
        }
        out.section(&format!("{trace} (rate {rate:.2}/s): throughput/utilization"), main_t);
        out.section(&format!("{trace}: JCT decomposition (e)"), jct_t);
        out.section(&format!("{trace}: completions per iteration (f)"), citer_t);
    }
    out.finish();
}
