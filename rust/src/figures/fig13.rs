//! Figure 13: ablation study — EconoServe-D / -SD / -SDO / full / Oracle
//! on JCT, TBT, SSR and throughput.

use super::common::{self, MAX_TIME};
use crate::util::bench::BenchOut;
use crate::util::stats::Table;

pub fn variants() -> Vec<(&'static str, &'static str, bool)> {
    vec![
        ("EconoServe-D", "econoserve-d", false),
        ("EconoServe-SD", "econoserve-sd", false),
        ("EconoServe-SDO", "econoserve-sdo", false),
        ("EconoServe", "econoserve", false),
        ("Oracle", "econoserve", true),
    ]
}

pub fn run(fast: bool) {
    let mut out = BenchOut::new("fig13");
    let duration = if fast { 30.0 } else { 60.0 };
    let models: &[&str] = if fast { &["opt-13b"] } else { &["opt-13b", "llama-33b", "opt-175b"] };

    for model in models {
        for trace in common::traces() {
            let cfg = common::cfg(model, trace);
            let rate = common::capacity_estimate(&cfg, trace) * 0.8;
            let items = common::workload(&cfg, trace, rate, duration, cfg.seed);
            let mut t = Table::new(&["variant", "jct_s", "tbt_s", "ssr_%", "tput_rps"]);
            for (label, sys, oracle) in variants() {
                let s = common::run_world(&cfg, sys, trace, &items, oracle, MAX_TIME).0.summary;
                t.rowf(
                    label,
                    &[s.mean_jct, s.mean_tbt, s.ssr * 100.0, s.throughput_rps],
                );
            }
            out.section(&format!("{model} / {trace}"), t);
        }
    }
    out.finish();
}
