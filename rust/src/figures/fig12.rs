//! Figure 12: number of GPUs EconoServe needs to match DistServe's
//! goodput, across homogeneous, heterogeneous (H100 prefill) and
//! large-scale (Vidur-style analytic scaling) settings — plus the fleet
//! layer's dynamic extension: GPU-hour cost under diurnal load, where an
//! autoscaled fleet matches the static peak fleet's SLO attainment with
//! measurably fewer GPU-hours (the SageServe/Aladdin cost story neither
//! static layer can express).
//!
//! Parallelism: the min-GPU search evaluates every candidate fleet size
//! concurrently (`fleet::min_replicas_for_goodput` over the experiment
//! engine), and the diurnal autoscaler scenarios fan out as independent
//! cells.

use super::common::{self, MAX_TIME};
use crate::cluster::{DistServeConfig, DistServeSim};
use crate::config::ModelProfile;
use crate::fleet::{self, FleetConfig};
use crate::trace::ArrivalProcess;
use crate::util::bench::BenchOut;
use crate::util::stats::Table;

pub fn run(fast: bool) {
    let mut out = BenchOut::new("fig12");
    let duration = if fast { 30.0 } else { 60.0 };
    let trace = "sharegpt";
    let models: &[&str] = if fast { &["opt-13b"] } else { &["opt-13b", "llama-33b"] };

    for het in [false, true] {
        let mut t = Table::new(&[
            "model",
            "dist_goodput_rps",
            "dist_gpus",
            "econo_gpus",
            "saved_%",
        ]);
        for model in models {
            let cfg = common::cfg(model, trace);
            let rate = common::capacity_estimate(&cfg, trace) * 0.8;
            let items = common::workload(&cfg, trace, rate, duration, cfg.seed);
            let dcfg = if het {
                DistServeConfig::heterogeneous(cfg.profile.clone(), &cfg)
            } else {
                DistServeConfig::homogeneous(cfg.profile.clone(), &cfg)
            };
            let dist = DistServeSim::new(dcfg).run(&items, MAX_TIME);
            let dist_gpus = 2 * cfg.profile.gpus_per_replica as usize;
            let econo = fleet::min_replicas_for_goodput(
                &cfg,
                "econoserve",
                trace,
                &items,
                false,
                dist.goodput,
                4,
                MAX_TIME,
            );
            let econo_gpus =
                econo.map(|k| k * cfg.profile.gpus_per_replica as usize).unwrap_or(0);
            t.rowf(
                model,
                &[
                    dist.goodput,
                    dist_gpus as f64,
                    econo_gpus as f64,
                    if econo_gpus > 0 {
                        (1.0 - econo_gpus as f64 / dist_gpus as f64) * 100.0
                    } else {
                        f64::NAN
                    },
                ],
            );
        }
        out.section(
            if het { "heterogeneous (H100 prefill + A100 decode)" } else { "homogeneous (A100+A100)" },
            t,
        );
    }

    // Large-scale: one pair vs one replica, scaled analytically to 4000
    // GPUs (the paper itself uses the Vidur simulator here).
    let profile = ModelProfile::by_name("llama3-8b").unwrap();
    let mut cfg = common::cfg("opt-13b", trace);
    cfg.profile = profile;
    let rate = common::capacity_estimate(&cfg, trace) * 0.8;
    let items = common::workload(&cfg, trace, rate, duration, cfg.seed);
    let dcfg = DistServeConfig::homogeneous(cfg.profile.clone(), &cfg);
    let dist = DistServeSim::new(dcfg).run(&items, MAX_TIME);
    let per_pair = dist.goodput; // goodput per 2 GPUs
    let target_total = per_pair * 2000.0; // 2000 prefill + 2000 decode GPUs
    let econo_goodput = fleet::replicated_run(&cfg, "econoserve", trace, &items, false, 1, MAX_TIME)
        .summary
        .goodput_rps;
    let econo_gpus_needed = (target_total / econo_goodput.max(1e-9)).ceil();
    let mut t = Table::new(&["setting", "dist_gpus", "econo_gpus", "saved_%"]);
    t.rowf(
        "llama3-8b @4000 GPUs",
        &[
            4000.0,
            econo_gpus_needed,
            (1.0 - econo_gpus_needed / 4000.0) * 100.0,
        ],
    );
    out.section("large-scale analytic scaling (Vidur substitute)", t);

    // Dynamic extension: GPU-hour cost under a diurnal day-curve. A
    // static fleet must be provisioned for the peak; the autoscalers
    // ride the curve (reactive chases pressure, forecast pre-boots
    // ahead of ramps) and bank the trough as GPU-hours saved.
    let cfg = common::cfg("opt-13b", trace);
    let cap = common::capacity_estimate(&cfg, trace);
    let max_replicas = 4usize;
    let period = if fast { 200.0 } else { 400.0 };
    let diurnal_duration = 2.0 * period;
    let process = ArrivalProcess::Diurnal {
        // Peak (1.6x mean) wants ~3-4 replicas; trough (0.4x mean) fits
        // comfortably on one.
        mean_rate: 1.6 * cap,
        amplitude: 0.6,
        period,
    };
    let gen = crate::trace::TraceGen::new(crate::trace::TraceSpec::by_name(trace).unwrap());
    let items =
        gen.generate_arrivals(process, diurnal_duration, cfg.profile.max_total_len, cfg.seed);
    let mut t = Table::new(&[
        "autoscaler",
        "ssr_%",
        "goodput_rps",
        "gpu_hours",
        "goodput_per_gpu_h",
        "peak_reps",
        "mean_reps",
    ]);
    // The three autoscaler scenarios are independent fleet runs: fan
    // them out as cells (each with serial replica stepping — the
    // cell-level parallelism owns the cores).
    let scalers = ["static-k", "reactive", "forecast"];
    let summaries = crate::exp::map_indexed(&scalers, 0, |_, &scaler| {
        let mut cfg = cfg.clone();
        // Concurrent cells must not charge measured scheduler wall-clock
        // (contention bias; Fig 14 owns the overhead story).
        cfg.sched_time_scale = 0.0;
        let mut fc = FleetConfig::new(cfg, "econoserve", trace);
        fc.router = "least-kvc".to_string();
        fc.autoscaler = scaler.to_string();
        fc.max_sim_time = diurnal_duration * 4.0;
        fc.max_replicas = max_replicas;
        fc.threads = 1;
        if scaler == "static-k" {
            // The static baseline pays for peak capacity the whole day.
            fc.init_replicas = max_replicas;
            fc.min_replicas = max_replicas;
        } else {
            fc.init_replicas = 2;
            fc.min_replicas = 1;
            fc.boot_latency = 8.0;
        }
        fleet::run(&fc, &items).summary
    });
    for (scaler, s) in scalers.iter().zip(&summaries) {
        t.rowf(
            scaler,
            &[
                s.ssr * 100.0,
                s.goodput_rps,
                s.gpu_hours,
                s.goodput_per_gpu_hour,
                s.peak_replicas as f64,
                s.mean_replicas,
            ],
        );
    }
    out.section("GPU-hour cost under diurnal load (fleet layer)", t);
    out.finish();
}
