//! Figure 10: SLO satisfaction ratio (SSR) per model and trace, at a
//! moderately loaded operating point, for the Fig 9 systems + Oracle.

use super::common::{self, MAX_TIME};
use crate::cluster::{DistServeConfig, DistServeSim};
use crate::util::bench::BenchOut;
use crate::util::stats::Table;

pub fn run(fast: bool) {
    let mut out = BenchOut::new("fig10");
    let duration = if fast { 30.0 } else { 60.0 };
    let models: &[&str] = if fast { &["opt-13b"] } else { &["opt-13b", "llama-33b", "opt-175b"] };

    for trace in common::traces() {
        let mut t = Table::new(&[
            "model",
            "ORCA",
            "vLLM",
            "Sarathi",
            "DistServe",
            "EconoServe",
            "Oracle",
        ]);
        for model in models {
            let cfg = common::cfg(model, trace);
            let rate = common::capacity_estimate(&cfg, trace) * 0.7;
            let items = common::workload(&cfg, trace, rate, duration, cfg.seed);
            let ssr = |sys: &str, oracle: bool| -> f64 {
                common::run_world(&cfg, sys, trace, &items, oracle, MAX_TIME).0.summary.ssr
                    * 100.0
            };
            let dist = {
                let dcfg = DistServeConfig::homogeneous(cfg.profile.clone(), &cfg);
                DistServeSim::new(dcfg).run(&items, MAX_TIME).summary.ssr * 100.0
            };
            t.rowf(
                model,
                &[
                    ssr("orca", false),
                    ssr("vllm", false),
                    ssr("sarathi", false),
                    dist,
                    ssr("econoserve", false),
                    ssr("econoserve", true),
                ],
            );
        }
        out.section(&format!("{trace}: SSR (%)"), t);
    }
    out.finish();
}
