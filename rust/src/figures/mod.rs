//! Paper-figure reproduction drivers.
//!
//! One submodule per figure of the paper's evaluation; each prints the
//! figure's rows/series as tables (stdout) and CSVs (bench_out/) via
//! [`crate::util::bench::BenchOut`]. The `benches/` binaries are thin
//! wrappers so `cargo bench` regenerates every figure.
//!
//! Experiment scales are chosen so the full set runs in minutes on a
//! laptop while preserving the paper's qualitative shapes (who wins, by
//! roughly what factor, where crossovers fall). EXPERIMENTS.md records a
//! paper-vs-measured comparison for each.

pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig9;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;
