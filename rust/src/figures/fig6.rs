//! Figure 6: occupied KVC of queued tasks — newly transitioned GTs,
//! preempted GTs, and chunked prompts (Observation 5: occupancy varies
//! widely, so prioritize big holders to free KVC earlier).

use super::common::{self, DURATION, MAX_TIME};
use crate::util::bench::BenchOut;
use crate::util::stats::Table;

pub fn run(fast: bool) {
    let mut out = BenchOut::new("fig6");
    let duration = if fast { 30.0 } else { DURATION };

    let mut t = Table::new(&[
        "trace",
        "category",
        "n_samples",
        "p5_tok",
        "p50_tok",
        "p95_tok",
        "mean_tok",
    ]);
    for trace in common::traces() {
        let cfg = common::cfg("opt-13b", trace);
        // Slight overload so queues (and preemptions) exist.
        let rate = common::capacity_estimate(&cfg, trace) * 1.1;
        let items = common::workload(&cfg, trace, rate, duration, cfg.seed);
        let (_res, world) = common::run_world(&cfg, "econoserve", trace, &items, false, MAX_TIME);
        for (cat, samples) in [
            ("new-GT", world.col.occ_new_gt.clone()),
            ("preempted-GT", world.col.occ_preempted_gt.clone()),
            ("chunked-PT", world.col.occ_chunked_pt.clone()),
        ] {
            let mut s = samples;
            t.row(&[
                trace.to_string(),
                cat.to_string(),
                s.len().to_string(),
                format!("{:.0}", s.p5()),
                format!("{:.0}", s.p50()),
                format!("{:.0}", s.p95()),
                format!("{:.0}", s.mean()),
            ]);
        }
    }
    out.section("occupied KVC of queued tasks", t);
    out.finish();
}
