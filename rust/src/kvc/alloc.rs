//! The first-class KVC allocator API: the *allocation policy* axis of
//! Table 1, decoupled from batching policy.
//!
//! A [`Allocator`] hands out **leases** over the block pool. The three
//! base policies size a lease differently at admission ([`Allocator::admit`]):
//!
//! | allocator     | admission grant                      | systems (Table 1)   |
//! |---------------|--------------------------------------|---------------------|
//! | [`MaxAlloc`]  | the model's max total length         | ORCA, SRTF, FastServe|
//! | [`BlockAlloc`]| only the immediately-needed tokens   | vLLM, Sarathi-Serve |
//! | [`ExactAlloc`]| immediate + padded predicted RL + 1  | MultiRes, EconoServe|
//!
//! [`Pipelined<A>`] composes §3.2 KVC pipelining over any inner
//! allocator: a hosting span lends its allocated-but-unwritten tail to
//! guests, which then consume **no new blocks** ([`AllocOutcome::Hosted`]).
//! The host/guest registry, guest-write accounting, overrun detection and
//! eviction mechanics all live here — schedulers only decide *who* lends
//! to *whom*.
//!
//! Every mutating call returns a typed [`AllocOutcome`] and is tallied;
//! `World::apply_plan` drains the per-iteration tally into the metrics
//! collector, so allocation behaviour is observable per iteration for
//! every scheduler × allocator combination.

use super::pipeline::PipeRegistry;
use super::{AllocError, BlockPool, ReserveClass};
use crate::core::{ReqId, ReqRec};

/// Typed outcome of an allocation attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocOutcome {
    /// Capacity secured from the request's own blocks. `tokens` is the
    /// block-rounded capacity newly taken from the free list (0 when the
    /// existing lease already covered the request).
    Granted { tokens: u32 },
    /// Placed inside another request's span (KVC pipelining): no new
    /// blocks were consumed.
    Hosted { host: ReqId, offset: u32, len: u32 },
    /// Not enough free capacity in the requested class.
    Exhausted { needed: u32, free: u32 },
}

impl AllocOutcome {
    /// True when the request can proceed (granted or hosted).
    pub fn ok(&self) -> bool {
        !matches!(self, AllocOutcome::Exhausted { .. })
    }
}

/// A request's current lease over the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lease {
    /// Capacity in tokens (block-rounded).
    pub grant: u32,
    /// Class charged by the most recent grant.
    pub reserve_class: ReserveClass,
}

/// Sizing inputs for an admission decision — everything any policy on the
/// allocation axis needs to size a lease.
#[derive(Debug, Clone, Copy)]
pub struct Demand {
    /// Tokens that must be writable right away (prompt remainder, dropped
    /// KV awaiting recompute, ...).
    pub immediate: u32,
    /// Padded predicted remaining response tokens.
    pub predicted: u32,
    /// The model's maximum total sequence length (max-allocation bound).
    pub max_total: u32,
}

impl Demand {
    /// Standard demand of a request record: remaining prompt + dropped KV
    /// as immediate need, predicted remaining RL as the lookahead.
    pub fn of(rec: &ReqRec, max_total: u32) -> Demand {
        Demand {
            immediate: (rec.req.prompt_len - rec.prompt_done) + rec.lost_kv,
            predicted: rec.predicted_remaining(),
            max_total,
        }
    }
}

/// What a released lease held.
#[derive(Debug, Clone, Default)]
pub struct Released {
    /// Tokens written into the request's own blocks.
    pub written: u32,
    /// Tokens written into borrowed (pipelined) space.
    pub guest_written: u32,
    /// Guests that were hosted inside the released span and are now
    /// detached (their borrowed KV is gone; the caller must preempt them).
    pub orphans: Vec<ReqId>,
}

/// Cumulative allocator counters (mechanism-level).
#[derive(Debug, Clone, Copy, Default)]
pub struct AllocStats {
    /// Allocation attempts (admit / extend / grow).
    pub calls: u64,
    /// Attempts rejected for lack of free capacity.
    pub failures: u64,
    /// Writes that outran the lease and were covered by an implicit
    /// reserve-class grow (only exotic scheduler × allocator combos).
    pub implicit_grows: u64,
}

/// Per-iteration outcome tally, drained by `World::apply_plan` into the
/// metrics collector.
#[derive(Debug, Clone, Copy, Default)]
pub struct AllocTally {
    pub granted: u32,
    pub hosted: u32,
    pub exhausted: u32,
}

/// The shared pool-backed mechanism behind the base allocators. Exposed
/// only so trait default methods can reach it; sizing policy stays in the
/// concrete [`Allocator`] types.
#[derive(Debug, Clone)]
pub struct PoolCore {
    pool: BlockPool,
    tally: AllocTally,
    implicit_grows: u64,
}

impl PoolCore {
    pub fn new(capacity_tokens: u32, block_size: u32, reserve_tokens: u32) -> Self {
        PoolCore {
            pool: BlockPool::new(capacity_tokens, block_size, reserve_tokens),
            tally: AllocTally::default(),
            implicit_grows: 0,
        }
    }

    fn outcome(&mut self, res: Result<u32, AllocError>) -> AllocOutcome {
        let bs = self.pool.block_size();
        match res {
            Ok(blocks) => {
                self.tally.granted += 1;
                AllocOutcome::Granted { tokens: blocks * bs }
            }
            Err(AllocError::OutOfBlocks { needed, free }) => {
                self.tally.exhausted += 1;
                AllocOutcome::Exhausted { needed: needed * bs, free: free * bs }
            }
        }
    }

    /// Extend `id`'s lease to cover `more` tokens beyond what it has
    /// already written.
    pub fn extend(&mut self, id: ReqId, more: u32, class: ReserveClass) -> AllocOutcome {
        let res = self.pool.alloc_tokens(id, more, class);
        self.outcome(res)
    }

    /// Grow `id`'s lease to hold `total` written tokens (no-op when the
    /// lease already covers it).
    pub fn grow_to(&mut self, id: ReqId, total: u32, class: ReserveClass) -> AllocOutcome {
        let res = self.pool.ensure_capacity(id, total, class);
        self.outcome(res)
    }

    /// Record `n` tokens written into `id`'s own lease. A write that
    /// outruns the lease is covered by an implicit reserve-class grow
    /// (counted in [`AllocStats::implicit_grows`]); if even the reserve is
    /// exhausted this panics, preserving the never-write-past-allocation
    /// invariant.
    pub fn write_own(&mut self, id: ReqId, n: u32) {
        let capacity = self.pool.allocated_tokens(id);
        let written = self.pool.written_tokens(id);
        if written + n > capacity && self.pool.alloc_tokens(id, n, ReserveClass::Reserved).is_ok()
        {
            self.implicit_grows += 1;
        }
        self.pool.write_tokens(id, n);
    }

    pub fn restore(&mut self, id: ReqId, n: u32) {
        self.pool.restore_written(id, n);
    }

    pub fn release_own(&mut self, id: ReqId) -> Released {
        let (_blocks, written) = self.pool.release(id);
        Released { written, guest_written: 0, orphans: Vec::new() }
    }

    /// Trim the lease down to its written tokens; returns tokens freed.
    pub fn shrink_to_written(&mut self, id: ReqId) -> u32 {
        self.pool.trim_to_written(id) * self.pool.block_size()
    }

    pub fn take_tally(&mut self) -> AllocTally {
        std::mem::take(&mut self.tally)
    }

    pub(crate) fn tally_hosted(&mut self) {
        self.tally.hosted += 1;
    }

    pub fn stats(&self) -> AllocStats {
        AllocStats {
            calls: self.pool.alloc_calls,
            failures: self.pool.alloc_failures,
            implicit_grows: self.implicit_grows,
        }
    }

    pub fn free_tokens(&self, class: ReserveClass) -> u32 {
        self.pool.free_tokens(class)
    }

    pub fn capacity_tokens(&self) -> u32 {
        self.pool.capacity_tokens()
    }

    pub fn reserve_tokens(&self) -> u32 {
        self.pool.reserve_tokens()
    }

    pub fn block_size(&self) -> u32 {
        self.pool.block_size()
    }

    pub fn allocated(&self, id: ReqId) -> u32 {
        self.pool.allocated_tokens(id)
    }

    pub fn written(&self, id: ReqId) -> u32 {
        self.pool.written_tokens(id)
    }

    pub fn lease_of(&self, id: ReqId) -> Option<Lease> {
        self.pool.alloc_of(id).map(|a| Lease {
            grant: a.blocks * self.pool.block_size(),
            reserve_class: a.class,
        })
    }

    pub fn total_allocated(&self) -> u64 {
        self.pool.total_allocated()
    }

    pub fn total_written(&self) -> u64 {
        self.pool.total_written()
    }

    pub fn check_invariants(&self) {
        self.pool.check_invariants();
    }
}

/// The first-class KVC allocation API. Policy types decide *how much* to
/// grant ([`Allocator::admit`]); the shared mechanism in [`PoolCore`]
/// (and, for hosting, [`Pipelined`]) executes it.
///
/// The trait is object-safe: `World` owns a `Box<dyn Allocator>` and
/// hands it to schedulers through `IterCtx::alloc()`.
///
/// `Send` is part of the contract: the allocator travels inside its
/// `World` when the parallel experiment engine ([`crate::exp`]) moves a
/// simulation across worker threads — keep implementations free of
/// non-`Send` state.
pub trait Allocator: Send {
    /// Registry name of this allocator (`max`, `block`, `exact`,
    /// `pipelined-<inner>`).
    fn name(&self) -> &'static str;

    fn core(&self) -> &PoolCore;
    fn core_mut(&mut self) -> &mut PoolCore;

    /// Size and take the admission-time lease for `id` — the Table 1
    /// allocation-policy axis. The grant is *incremental*: capacity beyond
    /// what `id` has already written (except [`MaxAlloc`], which sizes the
    /// total lease to the model maximum).
    fn admit(&mut self, id: ReqId, d: Demand, class: ReserveClass) -> AllocOutcome;

    // ------------------------------------------------------------------
    // Lease lifecycle (mechanism; shared across policies)
    // ------------------------------------------------------------------

    /// Extend the lease to cover `more` tokens beyond current written.
    fn extend(&mut self, id: ReqId, more: u32, class: ReserveClass) -> AllocOutcome {
        self.core_mut().extend(id, more, class)
    }

    /// Grow the lease to hold `total` written tokens.
    fn grow_to(&mut self, id: ReqId, total: u32, class: ReserveClass) -> AllocOutcome {
        self.core_mut().grow_to(id, total, class)
    }

    /// Shrink the lease to its written tokens; returns tokens freed.
    fn shrink_to_written(&mut self, id: ReqId) -> u32 {
        self.core_mut().shrink_to_written(id)
    }

    /// Release the whole lease (and, under [`Pipelined`], this request's
    /// guest role and hosted guests — see [`Released::orphans`]).
    fn release(&mut self, id: ReqId) -> Released {
        self.core_mut().release_own(id)
    }

    /// Record `n` tokens of KV written for `id` (routed to borrowed space
    /// for pipelined guests).
    fn record_write(&mut self, id: ReqId, n: u32) {
        self.core_mut().write_own(id, n);
    }

    /// Restore swapped-out written tokens after a swap-in.
    fn restore(&mut self, id: ReqId, n: u32) {
        self.core_mut().restore(id, n);
    }

    // ------------------------------------------------------------------
    // Queries
    // ------------------------------------------------------------------

    fn free_tokens(&self, class: ReserveClass) -> u32 {
        self.core().free_tokens(class)
    }

    fn capacity_tokens(&self) -> u32 {
        self.core().capacity_tokens()
    }

    fn reserve_tokens(&self) -> u32 {
        self.core().reserve_tokens()
    }

    fn allocated(&self, id: ReqId) -> u32 {
        self.core().allocated(id)
    }

    fn written(&self, id: ReqId) -> u32 {
        self.core().written(id)
    }

    fn lease_of(&self, id: ReqId) -> Option<Lease> {
        self.core().lease_of(id)
    }

    /// Tokens this request holds in the KVC right now: own written plus
    /// guest-written (pipelined) tokens.
    fn occupied(&self, id: ReqId) -> u32 {
        self.written(id) + self.guest_written(id)
    }

    fn total_allocated(&self) -> u64 {
        self.core().total_allocated()
    }

    /// Total written tokens (own + guest) — the numerator of the paper's
    /// KVC-utilization metric.
    fn total_written(&self) -> u64 {
        self.core().total_written()
    }

    /// KVC utilization: written tokens / capacity (what gpustat-style
    /// sampling sees: memory actually holding KV data).
    fn utilization(&self) -> f64 {
        self.total_written() as f64 / (self.capacity_tokens() as f64).max(1.0)
    }

    /// Allocation ratio: allocated / capacity (1.0 == fully allocated).
    fn allocation_ratio(&self) -> f64 {
        self.total_allocated() as f64 / (self.capacity_tokens() as f64).max(1.0)
    }

    fn stats(&self) -> AllocStats {
        self.core().stats()
    }

    /// Drain the per-iteration outcome tally (called by `apply_plan`).
    fn take_tally(&mut self) -> AllocTally {
        self.core_mut().take_tally()
    }

    fn check_invariants(&self) {
        self.core().check_invariants();
    }

    // ------------------------------------------------------------------
    // KVC pipelining (inert unless wrapped in [`Pipelined`])
    // ------------------------------------------------------------------

    fn is_guest(&self, _id: ReqId) -> bool {
        false
    }

    fn guest_written(&self, _id: ReqId) -> u32 {
        0
    }

    fn guest_count(&self) -> usize {
        0
    }

    /// Largest guest RL `host` could currently absorb: half the gap
    /// between its write head and the lending frontier, minus the safety
    /// buffer (§3.2's invariant). 0 for non-hosting allocators.
    fn lend_capacity(&self, _host: ReqId, _span: u32, _head: u32, _buffer_frac: f64) -> u32 {
        0
    }

    /// Place `guest` (predicted RL `rl`) right-aligned against `host`'s
    /// lending frontier. Fails unless `rl <= lend_capacity(...)`.
    fn lend(
        &mut self,
        _host: ReqId,
        _span: u32,
        _head: u32,
        _buffer_frac: f64,
        _guest: ReqId,
        rl: u32,
    ) -> AllocOutcome {
        AllocOutcome::Exhausted { needed: rl, free: 0 }
    }

    /// Guests whose slot the host's write head (at `head` tokens into its
    /// span) has overrun — they must be evicted now.
    fn overrun_guests(&self, _host: ReqId, _head: u32) -> Vec<ReqId> {
        Vec::new()
    }

    /// Detach and return all of `host`'s direct guests (their slots are
    /// gone; guest-written counters survive until `adopt` / `drop_guest`).
    fn detach_host(&mut self, _host: ReqId) -> Vec<ReqId> {
        Vec::new()
    }

    /// Drop `id`'s guest state: remove its slot (if still registered) and
    /// return the borrowed tokens it had written (now lost).
    fn drop_guest(&mut self, _id: ReqId) -> u32 {
        0
    }

    /// Move a detached guest onto its own lease: extend by `extra`
    /// (reserve class) and migrate its guest-written tokens in.
    fn adopt(&mut self, id: ReqId, extra: u32) -> AllocOutcome {
        self.extend(id, extra, ReserveClass::Reserved)
    }

    /// Testing / failure-injection hook: register `guest` at an explicit
    /// slot of `host`'s span, bypassing the safety check.
    fn host_at(&mut self, _guest: ReqId, _host: ReqId, _offset: u32, _len: u32) {
        panic!("host_at requires a pipelined allocator");
    }
}

macro_rules! base_allocator {
    ($name:ident, $reg:literal, $doc:literal) => {
        #[doc = $doc]
        #[derive(Debug, Clone)]
        pub struct $name {
            core: PoolCore,
        }

        impl $name {
            pub fn new(capacity_tokens: u32, block_size: u32, reserve_tokens: u32) -> Self {
                $name { core: PoolCore::new(capacity_tokens, block_size, reserve_tokens) }
            }
        }
    };
}

base_allocator!(
    MaxAlloc,
    "max",
    "Max-allocation (ORCA/SRTF/FastServe): admission leases the model's \
     maximum total length, so allocation can never fail mid-flight but the \
     KVC is massively over-provisioned."
);
base_allocator!(
    BlockAlloc,
    "block",
    "Block-allocation (vLLM/Sarathi): admission leases only the immediate \
     need; the lease grows block-by-block and can FAIL mid-execution — the \
     paper's KVC allocation failure (Fig 1d)."
);
base_allocator!(
    ExactAlloc,
    "exact",
    "Exact-allocation (MultiRes/EconoServe): admission leases immediate \
     need + padded predicted RL + 1, so a correctly-predicted request \
     never fails mid-flight and never over-provisions by more than the \
     padding."
);

impl Allocator for MaxAlloc {
    fn name(&self) -> &'static str {
        "max"
    }

    fn core(&self) -> &PoolCore {
        &self.core
    }

    fn core_mut(&mut self) -> &mut PoolCore {
        &mut self.core
    }

    fn admit(&mut self, id: ReqId, d: Demand, class: ReserveClass) -> AllocOutcome {
        self.core.grow_to(id, d.max_total, class)
    }
}

impl Allocator for BlockAlloc {
    fn name(&self) -> &'static str {
        "block"
    }

    fn core(&self) -> &PoolCore {
        &self.core
    }

    fn core_mut(&mut self) -> &mut PoolCore {
        &mut self.core
    }

    fn admit(&mut self, id: ReqId, d: Demand, class: ReserveClass) -> AllocOutcome {
        self.core.extend(id, d.immediate, class)
    }
}

impl Allocator for ExactAlloc {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn core(&self) -> &PoolCore {
        &self.core
    }

    fn core_mut(&mut self) -> &mut PoolCore {
        &mut self.core
    }

    fn admit(&mut self, id: ReqId, d: Demand, class: ReserveClass) -> AllocOutcome {
        self.core.extend(id, d.immediate + d.predicted + 1, class)
    }
}

/// KVC pipelining (§3.2) as a composable wrapper: any inner allocator
/// gains the ability to host guests in the allocated-but-unwritten tail
/// of a running span. Guests write into borrowed space (no new blocks);
/// the wrapper tracks the host/guest tree, routes their KV writes,
/// detects write-head overruns and migrates or drops guest KV when a
/// host goes away.
#[derive(Debug, Clone)]
pub struct Pipelined<A> {
    inner: A,
    pipes: PipeRegistry,
    /// Borrowed-space written tokens per guest, as a dense slab keyed by
    /// `ReqId` (survives slot detach until the guest is adopted or
    /// dropped). 0 == no borrowed tokens.
    guest_written: Vec<u32>,
    /// Σ guest-written tokens, maintained incrementally so
    /// `total_written` stays O(1).
    guest_written_total: u64,
}

impl<A: Allocator> Pipelined<A> {
    pub fn new(inner: A) -> Self {
        Pipelined {
            inner,
            pipes: PipeRegistry::new(),
            guest_written: Vec::new(),
            guest_written_total: 0,
        }
    }

    /// Take (zero out) `id`'s guest-written counter, keeping the total in
    /// sync.
    fn take_guest_written(&mut self, id: ReqId) -> u32 {
        let w = self.guest_written.get(id).copied().unwrap_or(0);
        if w > 0 {
            self.guest_written[id] = 0;
            self.guest_written_total -= w as u64;
        }
        w
    }

    fn frontier(&self, host: ReqId, span: u32) -> u32 {
        self.pipes
            .guests_of(host)
            .iter()
            .filter_map(|g| self.pipes.host_of(*g).map(|s| s.offset))
            .min()
            .unwrap_or(span)
    }
}

impl<A: Allocator> Allocator for Pipelined<A> {
    fn name(&self) -> &'static str {
        match self.inner.name() {
            "max" => "pipelined-max",
            "block" => "pipelined-block",
            "exact" => "pipelined-exact",
            _ => "pipelined",
        }
    }

    fn core(&self) -> &PoolCore {
        self.inner.core()
    }

    fn core_mut(&mut self) -> &mut PoolCore {
        self.inner.core_mut()
    }

    fn admit(&mut self, id: ReqId, d: Demand, class: ReserveClass) -> AllocOutcome {
        self.inner.admit(id, d, class)
    }

    fn record_write(&mut self, id: ReqId, n: u32) {
        if let Some(slot) = self.pipes.host_of(id) {
            if id >= self.guest_written.len() {
                self.guest_written.resize(id + 1, 0);
            }
            let written = &mut self.guest_written[id];
            assert!(
                *written + n <= slot.len,
                "pipelined guest {id} overflow: {} + {n} > slot len {}",
                *written,
                slot.len
            );
            *written += n;
            self.guest_written_total += n as u64;
        } else {
            self.inner.record_write(id, n);
        }
    }

    fn release(&mut self, id: ReqId) -> Released {
        // Drop this request's own guest role, then orphan its guests.
        self.pipes.release_guest(id);
        let guest_written = self.take_guest_written(id);
        let orphans = self.pipes.remove_host(id);
        let mut rel = self.inner.release(id);
        rel.guest_written += guest_written;
        rel.orphans = orphans;
        rel
    }

    fn is_guest(&self, id: ReqId) -> bool {
        self.pipes.is_guest(id)
    }

    fn guest_written(&self, id: ReqId) -> u32 {
        self.guest_written.get(id).copied().unwrap_or(0)
    }

    fn guest_count(&self) -> usize {
        self.pipes.guest_count()
    }

    fn lend_capacity(&self, host: ReqId, span: u32, head: u32, buffer_frac: f64) -> u32 {
        let gap = self.frontier(host, span).saturating_sub(head);
        let buffer = (buffer_frac * gap as f64).ceil() as u32;
        (gap / 2).saturating_sub(buffer)
    }

    fn lend(
        &mut self,
        host: ReqId,
        span: u32,
        head: u32,
        buffer_frac: f64,
        guest: ReqId,
        rl: u32,
    ) -> AllocOutcome {
        let target = self.lend_capacity(host, span, head, buffer_frac);
        if rl == 0 || rl > target {
            self.core_mut().tally.exhausted += 1;
            return AllocOutcome::Exhausted { needed: rl, free: target };
        }
        let offset = self.frontier(host, span) - rl;
        self.pipes.add_guest(guest, host, offset, rl);
        self.core_mut().tally_hosted();
        AllocOutcome::Hosted { host, offset, len: rl }
    }

    fn overrun_guests(&self, host: ReqId, head: u32) -> Vec<ReqId> {
        self.pipes.overrun_guests(host, head)
    }

    fn detach_host(&mut self, host: ReqId) -> Vec<ReqId> {
        self.pipes.remove_host(host)
    }

    fn drop_guest(&mut self, id: ReqId) -> u32 {
        self.pipes.release_guest(id);
        self.take_guest_written(id)
    }

    fn adopt(&mut self, id: ReqId, extra: u32) -> AllocOutcome {
        match self.inner.extend(id, extra, ReserveClass::Reserved) {
            out @ AllocOutcome::Granted { .. } => {
                // Usually already detached via detach_host; drop any slot
                // still registered so writes stop routing to guest space.
                self.pipes.release_guest(id);
                let moved = self.take_guest_written(id);
                if moved > 0 {
                    // Modelled as a block copy into the new lease
                    // (cudaMemcpyAsync overlap in the real system).
                    self.inner.record_write(id, moved);
                }
                out
            }
            out => out,
        }
    }

    fn total_written(&self) -> u64 {
        self.inner.total_written() + self.guest_written_total
    }

    fn check_invariants(&self) {
        self.inner.check_invariants();
        self.pipes.check_invariants();
        let mut sum = 0u64;
        for (g, w) in self.guest_written.iter().enumerate() {
            sum += *w as u64;
            if *w > 0 {
                if let Some(slot) = self.pipes.host_of(g) {
                    assert!(*w <= slot.len, "guest {g} wrote past its slot");
                }
            }
        }
        assert_eq!(sum, self.guest_written_total, "guest-written counter drift");
    }

    fn host_at(&mut self, guest: ReqId, host: ReqId, offset: u32, len: u32) {
        self.pipes.add_guest(guest, host, offset, len);
    }
}

/// Canonical allocator names, in Table 1 order plus the pipelined grid.
pub fn all_allocators() -> &'static [&'static str] {
    &["max", "block", "exact", "pipelined-max", "pipelined-block", "pipelined-exact"]
}

/// Resolve a (possibly user-typed) allocator name to its canonical
/// `'static` registry entry.
pub fn canonical_alloc_name(name: &str) -> Option<&'static str> {
    all_allocators().iter().copied().find(|n| *n == name)
}

/// Build an allocator by registry name over a pool of `capacity_tokens`.
pub fn by_name(
    name: &str,
    capacity_tokens: u32,
    block_size: u32,
    reserve_tokens: u32,
) -> Option<Box<dyn Allocator>> {
    let a: Box<dyn Allocator> = match name {
        "max" => Box::new(MaxAlloc::new(capacity_tokens, block_size, reserve_tokens)),
        "block" => Box::new(BlockAlloc::new(capacity_tokens, block_size, reserve_tokens)),
        "exact" => Box::new(ExactAlloc::new(capacity_tokens, block_size, reserve_tokens)),
        "pipelined-max" => Box::new(Pipelined::new(MaxAlloc::new(
            capacity_tokens,
            block_size,
            reserve_tokens,
        ))),
        "pipelined-block" => Box::new(Pipelined::new(BlockAlloc::new(
            capacity_tokens,
            block_size,
            reserve_tokens,
        ))),
        "pipelined-exact" => Box::new(Pipelined::new(ExactAlloc::new(
            capacity_tokens,
            block_size,
            reserve_tokens,
        ))),
        _ => return None,
    };
    Some(a)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demand(immediate: u32, predicted: u32) -> Demand {
        Demand { immediate, predicted, max_total: 512 }
    }

    #[test]
    fn registry_builds_all() {
        for name in all_allocators() {
            let a = by_name(name, 1024, 32, 64).unwrap();
            assert_eq!(a.name(), *name);
            assert_eq!(canonical_alloc_name(name), Some(*name));
        }
        assert!(by_name("paged", 1024, 32, 0).is_none());
        assert!(canonical_alloc_name("paged").is_none());
    }

    #[test]
    fn max_admits_model_maximum() {
        let mut a = MaxAlloc::new(2048, 32, 0);
        let out = a.admit(1, demand(16, 4), ReserveClass::Reserved);
        assert!(out.ok());
        assert_eq!(a.allocated(1), 512);
        // Growth within the max lease is free.
        assert!(matches!(a.grow_to(1, 500, ReserveClass::Normal), AllocOutcome::Granted { tokens: 0 }));
        // 2048/512 = 4 leases, then exhaustion.
        for id in 2..=4 {
            assert!(a.admit(id, demand(16, 4), ReserveClass::Reserved).ok());
        }
        assert!(!a.admit(5, demand(16, 4), ReserveClass::Reserved).ok());
    }

    #[test]
    fn block_admits_immediate_only_and_fails_midflight() {
        let mut a = BlockAlloc::new(160, 32, 0);
        assert!(a.admit(1, demand(33, 400), ReserveClass::Reserved).ok());
        assert_eq!(a.allocated(1), 64); // 2 blocks, prediction ignored
        a.record_write(1, 33);
        assert!(a.grow_to(1, 96, ReserveClass::Reserved).ok());
        assert!(a.admit(2, demand(33, 0), ReserveClass::Reserved).ok());
        // Pool is now full: mid-flight growth fails (Fig 1d).
        assert!(!a.grow_to(1, 129, ReserveClass::Reserved).ok());
        assert_eq!(a.stats().failures, 1);
    }

    #[test]
    fn exact_admits_prediction_span() {
        let mut a = ExactAlloc::new(1024, 32, 0);
        assert!(a.admit(1, demand(20, 40), ReserveClass::Normal).ok());
        // 20 + 40 + 1 = 61 tokens -> 2 blocks of 32.
        assert_eq!(a.allocated(1), 64);
        assert_eq!(a.lease_of(1).unwrap().reserve_class, ReserveClass::Normal);
    }

    #[test]
    fn lease_reports_grant_and_class() {
        let mut a = ExactAlloc::new(1024, 32, 64);
        assert!(a.lease_of(9).is_none());
        a.admit(9, demand(10, 10), ReserveClass::Reserved);
        let lease = a.lease_of(9).unwrap();
        assert_eq!(lease.grant, 32);
        assert_eq!(lease.reserve_class, ReserveClass::Reserved);
        let rel = a.release(9);
        assert_eq!(rel.written, 0);
        assert!(a.lease_of(9).is_none());
    }

    #[test]
    fn pipelined_hosts_without_new_blocks() {
        let mut a = Pipelined::new(ExactAlloc::new(1024, 32, 0));
        // Host: span of 64 tokens.
        assert!(a.admit(1, demand(0, 63), ReserveClass::Normal).ok());
        let allocated_before = a.total_allocated();
        let cap = a.lend_capacity(1, 64, 0, 0.0);
        assert_eq!(cap, 32);
        let out = a.lend(1, 64, 0, 0.0, 2, 16);
        assert_eq!(out, AllocOutcome::Hosted { host: 1, offset: 48, len: 16 });
        assert_eq!(a.total_allocated(), allocated_before, "guest took no blocks");
        a.record_write(2, 16);
        assert_eq!(a.guest_written(2), 16);
        assert_eq!(a.occupied(2), 16);
        assert_eq!(a.total_written(), 16);
        a.check_invariants();
    }

    #[test]
    fn pipelined_rejects_oversized_guest() {
        let mut a = Pipelined::new(ExactAlloc::new(1024, 32, 0));
        a.admit(1, demand(0, 63), ReserveClass::Normal);
        assert!(!a.lend(1, 64, 0, 0.0, 2, 40).ok());
        // Buffer shrinks the lendable target further.
        assert!(a.lend_capacity(1, 64, 0, 0.2) < a.lend_capacity(1, 64, 0, 0.0));
    }

    #[test]
    #[should_panic(expected = "guest 2 overflow")]
    fn guest_write_past_slot_panics() {
        let mut a = Pipelined::new(ExactAlloc::new(1024, 32, 0));
        a.admit(1, demand(0, 63), ReserveClass::Normal);
        a.lend(1, 64, 0, 0.0, 2, 16);
        a.record_write(2, 17);
    }

    #[test]
    fn release_orphans_hosted_guests() {
        let mut a = Pipelined::new(ExactAlloc::new(1024, 32, 0));
        a.admit(1, demand(0, 63), ReserveClass::Normal);
        a.lend(1, 64, 0, 0.0, 2, 16);
        a.record_write(2, 8);
        let rel = a.release(1);
        assert_eq!(rel.orphans, vec![2]);
        assert!(!a.is_guest(2));
        // The orphan's borrowed tokens are still recorded until dropped.
        assert_eq!(a.drop_guest(2), 8);
        assert_eq!(a.total_written(), 0);
        a.check_invariants();
    }

    #[test]
    fn adopt_migrates_guest_tokens() {
        let mut a = Pipelined::new(ExactAlloc::new(1024, 32, 0));
        a.admit(1, demand(0, 63), ReserveClass::Normal);
        a.lend(1, 64, 0, 0.0, 2, 16);
        a.record_write(2, 8);
        let orphans = a.detach_host(1);
        assert_eq!(orphans, vec![2]);
        assert!(a.adopt(2, 8 + 4).ok());
        assert_eq!(a.guest_written(2), 0);
        assert_eq!(a.written(2), 8);
        a.check_invariants();
    }

    #[test]
    fn tally_drains_per_iteration() {
        let mut a = Pipelined::new(ExactAlloc::new(256, 32, 0));
        a.admit(1, demand(0, 200), ReserveClass::Normal);
        a.lend(1, 201, 0, 0.0, 2, 64);
        assert!(!a.admit(3, demand(300, 0), ReserveClass::Normal).ok());
        let t = a.take_tally();
        assert_eq!((t.granted, t.hosted, t.exhausted), (1, 1, 1));
        let t2 = a.take_tally();
        assert_eq!((t2.granted, t2.hosted, t2.exhausted), (0, 0, 0));
    }

    #[test]
    fn implicit_grow_covers_unplanned_writes() {
        // A scheduler × allocator combo that never calls grow_to must not
        // crash: the write is covered from the reserve and counted.
        let mut a = BlockAlloc::new(1024, 32, 128);
        a.admit(1, demand(16, 0), ReserveClass::Reserved);
        a.record_write(1, 16);
        a.record_write(1, 32); // outruns the 1-block lease
        assert_eq!(a.stats().implicit_grows, 1);
        assert_eq!(a.written(1), 48);
        a.check_invariants();
    }
}
